//! Bench: regenerate Table III and time the FireFly crossbars on a
//! spiking workload (varying firing rates — the SNN cost driver).

use dsp48_systolic::engines::snn::{SnnConfig, SnnEngine, SnnVariant};
use dsp48_systolic::engines::Engine;
use dsp48_systolic::util::bench::{bench, section};
use dsp48_systolic::util::rng::XorShift;
use dsp48_systolic::workload::snn::SpikeTrain;
use dsp48_systolic::workload::MatI8;

fn main() {
    section("Table III regeneration (FireFly 32x32 crossbar)");
    for v in [SnnVariant::FireFly, SnnVariant::Enhanced] {
        let eng = SnnEngine::new(SnnConfig::paper_32x32(v));
        let row = eng.table_row();
        println!(
            "{:<8} LUT {:>3}  FF {:>5}  DSP {:>3}  {:.0} MHz  {:.3} W",
            v.label(),
            row.lut,
            row.ff,
            row.dsp,
            row.freq_mhz,
            row.power_w
        );
    }

    section("crossbar simulation across firing rates");
    let mut rng = XorShift::new(11);
    let weights = MatI8::random_bounded(&mut rng, 32, 32, 63);
    for (num, den) in [(1u64, 10u64), (1, 4), (1, 2)] {
        let train = SpikeTrain::random(&mut rng, 32, 32, num, den);
        for v in [SnnVariant::FireFly, SnnVariant::Enhanced] {
            let mut eng = SnnEngine::new(SnnConfig::paper_32x32(v));
            let label = format!(
                "{} T=32 rate {:.0}%",
                v.label(),
                100.0 * num as f64 / den as f64
            );
            bench(&label, || {
                let (_, currents, _) = eng.run_snn(&train, &weights).unwrap();
                std::hint::black_box(currents.len());
            });
        }
    }
}
