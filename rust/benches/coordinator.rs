//! Bench: coordinator job throughput — tiling overhead, the service's
//! queue/dispatch path, and the prefetch-policy gap the paper's
//! technique 1 closes.

use dsp48_systolic::coordinator::scheduler::{schedule, PrefetchPolicy};
use dsp48_systolic::coordinator::service::EngineKind;
use dsp48_systolic::coordinator::{GemmTiler, Job, Service, ServiceConfig};
use dsp48_systolic::engines::ws::{WsConfig, WsEngine, WsVariant};
use dsp48_systolic::engines::Engine;
use dsp48_systolic::util::bench::{bench, bench_with, section};
use dsp48_systolic::util::rng::XorShift;
use dsp48_systolic::workload::MatI8;
use std::time::Duration;

fn main() {
    section("tiler");
    let mut rng = XorShift::new(2);
    let a = MatI8::random_bounded(&mut rng, 16, 112, 63);
    let w = MatI8::random(&mut rng, 112, 56);
    let tiler = GemmTiler::new(14, 14);
    bench("tile 16x112x56 into 8x4 tiles", || {
        std::hint::black_box(tiler.tiles(&a, &w).len());
    });

    section("prefetch policy aggregation (the paper's technique 1)");
    let mut eng = WsEngine::new(WsConfig::paper_14x14_for(WsVariant::DspFetch));
    let per_tile: Vec<_> = tiler
        .tiles(&a, &w)
        .iter()
        .map(|t| eng.run_gemm(&t.a, &t.w).unwrap().stats)
        .collect();
    for policy in [PrefetchPolicy::PingPong, PrefetchPolicy::Stall] {
        let rep = schedule(policy, &per_tile, 14);
        println!(
            "{:?}: {} cycles ({} weight), {:.1}% compute, {:.1} MACs/cycle",
            policy,
            rep.cycles,
            rep.weight_cycles,
            100.0 * rep.compute_fraction(),
            rep.macs_per_cycle()
        );
    }

    section("service end-to-end (queue + workers + verify)");
    for workers in [1usize, 2, 4] {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers,
            ws_rows: 14,
            ws_cols: 14,
            verify: false,
            shard_width: 1,
        });
        let mut rng = XorShift::new(7);
        let jobs = 24;
        let m = bench_with(
            &format!("{workers} worker(s), {jobs} jobs of 16x28x28"),
            Duration::from_millis(100),
            Duration::from_secs(2),
            &mut || {
                for _ in 0..jobs {
                    let a = MatI8::random_bounded(&mut rng, 16, 28, 63);
                    let w = MatI8::random(&mut rng, 28, 28);
                    svc.submit(Job::Gemm { a, w });
                }
                for _ in 0..jobs {
                    svc.wait_any(Duration::from_secs(30)).expect("done");
                }
            },
        );
        println!(
            "    -> {:.0} jobs/s",
            jobs as f64 * m.per_sec()
        );
        svc.shutdown();
    }
}
