//! Bench: regenerate Table II and time the B1024 engines on a
//! conv-shaped GEMM (official replicate vs in-DSP mux + ring acc).

use dsp48_systolic::engines::os::{OsConfig, OsEngine, OsVariant};
use dsp48_systolic::engines::Engine;
use dsp48_systolic::util::bench::{bench, section};
use dsp48_systolic::workload::gemm::GemmProblem;

fn main() {
    section("Table II regeneration (DPU B1024 breakdown)");
    for v in [OsVariant::Official, OsVariant::Enhanced] {
        let eng = OsEngine::new(OsConfig::b1024(v));
        let row = eng.table_row();
        let t = eng.timing().report();
        println!(
            "{:<10} LUT {:>5}  FF {:>5}  DSP {:>4}  WNS {:+.3}  power {:.3} W",
            v.label(),
            row.lut,
            row.ff,
            row.dsp,
            t.wns_ns,
            row.power_w
        );
    }

    section("B1024 cycle-accurate GEMM (16x64 @ 64x32)");
    let p = GemmProblem::random(16, 32, 64, 7);
    for v in [OsVariant::Official, OsVariant::Enhanced] {
        let mut eng = OsEngine::new(OsConfig::b1024(v));
        let m = bench(&format!("simulate DPU-{}", v.label()), || {
            let run = eng.run_gemm(&p.a, &p.w).unwrap();
            std::hint::black_box(run.stats.cycles);
        });
        let run = eng.run_gemm(&p.a, &p.w).unwrap();
        println!(
            "    -> util {:.1}%, {} slow cycles, {:.1} sim-cycles/host-us",
            100.0 * run.stats.utilization(eng.peak_macs_per_cycle()),
            run.stats.cycles,
            run.stats.cycles as f64 / m.mean.as_micros().max(1) as f64
        );
    }
}
