//! Bench: the simulator hot paths in isolation — the targets of the
//! §Perf optimization pass (EXPERIMENTS.md §Perf records before/after).
//!
//! * single DSP48E2 tick (the innermost loop),
//! * one full-array WS cycle (196 + 14 DSPs + staging),
//! * ring-accumulator tick,
//! * packed_dot (the functional fast path the coordinator may use).

use dsp48_systolic::dsp::{Attributes, Dsp48e2, DspInputs, OpMode};
use dsp48_systolic::engines::os::RingAccumulator;
use dsp48_systolic::engines::ws::{WsConfig, WsEngine};
use dsp48_systolic::engines::Engine;
use dsp48_systolic::packing;
use dsp48_systolic::util::bench::{bench, section};
use dsp48_systolic::util::rng::XorShift;
use dsp48_systolic::workload::MatI8;

fn main() {
    section("DSP48E2 cell");
    let mut dsp = Dsp48e2::new(Attributes::ws_prefetch_pe());
    let inp = DspInputs {
        a: 123 << 18,
        d: -45,
        b: 77,
        opmode: OpMode::MULT_CASCADE,
        pcin: 991,
        ..DspInputs::default()
    };
    let m = bench("dsp tick (prefetch PE)", || {
        dsp.tick(&inp);
        std::hint::black_box(dsp.p());
    });
    println!(
        "    -> {:.1} M ticks/s",
        m.per_sec() / 1e6
    );

    section("WS array cycle (14x14 paper config)");
    let mut eng = WsEngine::new(WsConfig::paper_14x14());
    let mut rng = XorShift::new(1);
    let a = MatI8::random_bounded(&mut rng, 8, 14, 63);
    let w = MatI8::random(&mut rng, 14, 14);
    let m = bench("run_gemm 8x14x14 (one tile)", || {
        let run = eng.run_gemm(&a, &w).unwrap();
        std::hint::black_box(run.stats.cycles);
    });
    let cycles = eng.run_gemm(&a, &w).unwrap().stats.cycles;
    println!(
        "    -> {:.2} M DSP-ticks/s host",
        cycles as f64 * 210.0 * m.per_sec() / 1e6
    );

    section("ring accumulator");
    let mut ring = RingAccumulator::new(0);
    let mut i = 0i64;
    bench("ring tick", || {
        i = (i + 1) & 0xFFFF;
        ring.tick(i, i ^ 0x5A5A);
        std::hint::black_box(ring.output());
    });

    section("packed arithmetic (functional fast path)");
    let hi: Vec<i8> = (0..1024).map(|i| (i % 251) as i8).collect();
    let lo: Vec<i8> = (0..1024).map(|i| (i % 127) as i8).collect();
    let wv: Vec<i8> = (0..1024).map(|i| (i % 83) as i8).collect();
    let m = bench("packed_dot K=1024", || {
        std::hint::black_box(packing::packed_dot(&hi, &lo, &wv));
    });
    println!(
        "    -> {:.1} M packed-MACs/s (x2 lanes)",
        1024.0 * m.per_sec() / 1e6
    );
}
