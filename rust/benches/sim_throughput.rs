//! Bench: the simulator hot paths in isolation — the targets of the
//! §Perf optimization pass (EXPERIMENTS.md §Perf records before/after) —
//! plus the sharded-coordinator throughput on one large GEMM.
//!
//! * single DSP48E2 tick (the innermost loop),
//! * the whole-array bank pass vs a per-column loop (14×14),
//! * one full-array WS cycle (196 + 14 DSPs + staging),
//! * ring-accumulator tick,
//! * packed_dot (the functional fast path the coordinator may use),
//! * a single large GEMM sharded across 1 vs 4 workers,
//! * the wire protocol end-to-end over a TCP loopback socket,
//! * a whole transformer-block model graph served as dependency-gated
//!   passes with arena-resident intermediates.
//!
//! Emits `BENCH_sim_throughput.json` so CI accumulates the perf
//! trajectory. Set `SIM_BENCH_SMOKE=1` for a fast CI-sized run.

use dsp48_systolic::coordinator::service::EngineKind;
use dsp48_systolic::coordinator::{Batch, Job, JobState, Service, ServiceConfig};
use dsp48_systolic::dsp::{Attributes, Dsp48e2, DspArray, DspColumn, DspInputs, InMode, OpMode};
use dsp48_systolic::engines::os::RingAccumulator;
use dsp48_systolic::engines::ws::{WsConfig, WsEngine};
use dsp48_systolic::engines::Engine;
use dsp48_systolic::model::ModelPreset;
use dsp48_systolic::packing;
use dsp48_systolic::proto::{
    Frontend, QosConfig, Request, Response, Session, SessionBudget,
    TcpServer, TcpSession,
};
use dsp48_systolic::util::bench::{bench, section};
use dsp48_systolic::util::json::Json;
use dsp48_systolic::util::rng::XorShift;
use dsp48_systolic::workload::conv::ConvShape;
use dsp48_systolic::workload::{CsrMatI8, MatI8, NmPattern, SparseMatI8};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One sharded run: a single `size³` GEMM fanned out over `workers`.
/// Returns host-side simulated MACs per second.
fn sharded_gemm_rate(workers: usize, size: usize) -> f64 {
    let mut svc = Service::start(ServiceConfig {
        kind: EngineKind::WsDspFetch,
        workers,
        ws_rows: 14,
        ws_cols: 14,
        verify: false,
        shard_width: 1,
    });
    let mut rng = XorShift::new(11);
    let a = MatI8::random_bounded(&mut rng, size, size, 63);
    let w = MatI8::random(&mut rng, size, size);
    let t0 = Instant::now();
    svc.submit(Job::Gemm { a, w });
    let r = svc
        .wait_any(Duration::from_secs(1800))
        .expect("sharded GEMM completes");
    let wall = t0.elapsed();
    svc.shutdown();
    let rate = r.stats.macs as f64 / wall.as_secs_f64();
    println!(
        "bench sharded {size}x{size}x{size} @ {workers} worker(s): \
         {wall:?} wall -> {:.2} M MACs/s",
        rate / 1e6
    );
    rate
}

/// Run `count` jobs of one shape that all share a weight matrix,
/// either as one batch (weight-tile reuse groups the fills) or as
/// single submissions. Returns `(sim_cycles, macs, fills_issued,
/// fills_avoided, fill_cycles_saved)` — all *simulated* quantities,
/// deterministic across machines and worker counts, which is what
/// makes them safe regression-gate inputs.
fn shared_weight_serve(
    batched: bool,
    count: usize,
    (m, k, n): (usize, usize, usize),
) -> (u64, u64, u64, u64, u64) {
    let mut svc = Service::start(ServiceConfig {
        kind: EngineKind::WsDspFetch,
        workers: 2,
        ws_rows: 14,
        ws_cols: 14,
        verify: false,
        shard_width: 1,
    });
    let mut rng = XorShift::new(19);
    let w = MatI8::random(&mut rng, k, n);
    let jobs: Vec<Job> = (0..count)
        .map(|_| Job::Gemm {
            a: MatI8::random_bounded(&mut rng, m, k, 63),
            w: w.clone(),
        })
        .collect();
    if batched {
        svc.submit_batch(Batch::from(jobs));
    } else {
        for job in jobs {
            svc.submit(job);
        }
    }
    let results = svc.drain(Duration::from_secs(600)).completed;
    assert_eq!(results.len(), count, "all shared-weight jobs complete");
    let cycles: u64 = results.iter().map(|r| r.stats.cycles).sum();
    let macs: u64 = results.iter().map(|r| r.stats.macs).sum();
    let issued = svc
        .metrics
        .fills_issued
        .load(std::sync::atomic::Ordering::Relaxed);
    let avoided = svc
        .metrics
        .fills_avoided
        .load(std::sync::atomic::Ordering::Relaxed);
    let saved = svc
        .metrics
        .fill_cycles_saved
        .load(std::sync::atomic::Ordering::Relaxed);
    svc.shutdown();
    (cycles, macs, issued, avoided, saved)
}

/// `count` conv jobs sharing one weight set, submitted as a batch on
/// the lazy conv tiling path (per-tile im2col patch extraction — the
/// full patch matrix is never materialized). Returns `(sim_cycles,
/// macs, fills_issued, fills_avoided, fill_cycles_saved)` — simulated,
/// deterministic quantities safe to gate on.
fn conv_serve(count: usize) -> (u64, u64, u64, u64, u64) {
    let mut svc = Service::start(ServiceConfig {
        kind: EngineKind::WsDspFetch,
        workers: 2,
        ws_rows: 14,
        ws_cols: 14,
        verify: false,
        shard_width: 1,
    });
    let shape = ConvShape {
        in_c: 8,
        in_h: 12,
        in_w: 12,
        out_c: 16,
        k: 3,
        stride: 1,
        pad: 1,
        dilation: 1,
        groups: 1,
    };
    let mut rng = XorShift::new(23);
    let weights: Vec<i8> = (0..shape.weight_len())
        .map(|_| rng.i8_in(-63, 63))
        .collect();
    let jobs: Vec<Job> = (0..count)
        .map(|_| Job::Conv {
            input: (0..shape.input_len()).map(|_| rng.i8_in(-63, 63)).collect(),
            weights: weights.clone(),
            shape,
        })
        .collect();
    svc.submit_batch(Batch::from(jobs));
    let results = svc.drain(Duration::from_secs(600)).completed;
    assert_eq!(results.len(), count, "all conv jobs complete");
    let cycles: u64 = results.iter().map(|r| r.stats.cycles).sum();
    let macs: u64 = results.iter().map(|r| r.stats.macs).sum();
    let issued = svc
        .metrics
        .fills_issued
        .load(std::sync::atomic::Ordering::Relaxed);
    let avoided = svc
        .metrics
        .fills_avoided
        .load(std::sync::atomic::Ordering::Relaxed);
    let saved = svc
        .metrics
        .fill_cycles_saved
        .load(std::sync::atomic::Ordering::Relaxed);
    svc.shutdown();
    (cycles, macs, issued, avoided, saved)
}

/// One sparse GEMM (CSR activations × N:M striped weights) on the
/// 14×14 tiler. `live_every` controls which weight blocks survive:
/// blocks are aligned to the tile grid, so dead blocks become whole
/// dead tiles that the tiler skips before enqueue. Returns
/// `(sim_cycles, macs, tiles_skipped)` — simulated, deterministic
/// quantities; `macs` stays dense-equivalent, so MACs/cycle rises
/// with sparsity instead of staying flat.
fn sparse_serve(
    nm: NmPattern,
    live_every: usize,
    (m, k, n): (usize, usize, usize),
) -> (u64, u64, u64) {
    let mut svc = Service::start(ServiceConfig {
        kind: EngineKind::WsDspFetch,
        workers: 2,
        ws_rows: 14,
        ws_cols: 14,
        verify: false,
        shard_width: 1,
    });
    let mut rng = XorShift::new(31);
    let w = SparseMatI8::striped(&mut rng, k, n, nm, live_every, (14, 14));
    let a = CsrMatI8::random_density(&mut rng, m, k, 0.5);
    svc.submit(Job::SparseGemm { a, w });
    let results = svc.drain(Duration::from_secs(600)).completed;
    assert_eq!(results.len(), 1, "sparse job completes");
    let cycles = results[0].stats.cycles;
    let macs = results[0].stats.macs;
    let skipped = svc.metrics.tiles_skipped.load(Ordering::Relaxed);
    svc.shutdown();
    (cycles, macs, skipped)
}

/// The wire protocol end-to-end over a loopback socket: a batch of 4
/// shared-weight GEMMs submitted in one `SubmitBatch` frame (weight-
/// tile reuse must survive the socket round trip: 4 fills issued, 12
/// avoided on the 14×14 tiler) plus one conv job, all verified, then
/// a graceful wire `Shutdown`. Returns `(wall jobs/s, jobs verified,
/// fills issued, fills avoided, fill cycles saved)` — everything but
/// the wall rate is a deterministic simulated quantity, safe to gate.
fn serve_loopback() -> (f64, u64, u64, u64, u64) {
    let svc = Service::start(ServiceConfig {
        kind: EngineKind::WsDspFetch,
        workers: 2,
        ws_rows: 14,
        ws_cols: 14,
        verify: true,
        shard_width: 1,
    });
    let metrics = Arc::clone(&svc.metrics);
    let server = TcpServer::bind("127.0.0.1:0", svc).expect("bind loopback");
    let addr = server
        .local_addr()
        .expect("loopback server has an address")
        .to_string();
    let server_thread = std::thread::spawn(move || server.run());
    let mut client = TcpSession::connect(&addr).expect("connect loopback");

    let mut rng = XorShift::new(29);
    let t0 = Instant::now();
    let (m, k, n) = (16, 28, 28);
    let w = MatI8::random(&mut rng, k, n);
    let jobs: Vec<Job> = (0..4)
        .map(|_| Job::Gemm {
            a: MatI8::random_bounded(&mut rng, m, k, 63),
            w: w.clone(),
        })
        .collect();
    let ids = client.submit_batch(jobs).expect("wire batch submit");
    let mut ok = 0u64;
    for id in ids {
        if let JobState::Done(r) = client
            .wait(id, Some(Duration::from_secs(600)))
            .expect("wire wait")
        {
            if r.verified == Some(true) {
                ok += 1;
            }
        }
    }
    let shape = ConvShape {
        in_c: 8,
        in_h: 12,
        in_w: 12,
        out_c: 16,
        k: 3,
        stride: 1,
        pad: 1,
        dilation: 1,
        groups: 1,
    };
    let input: Vec<i8> =
        (0..shape.input_len()).map(|_| rng.i8_in(-63, 63)).collect();
    let weights: Vec<i8> =
        (0..shape.weight_len()).map(|_| rng.i8_in(-63, 63)).collect();
    let id = client
        .submit(Job::Conv {
            input,
            weights,
            shape,
        })
        .expect("wire conv submit");
    if let JobState::Done(r) = client
        .wait(id, Some(Duration::from_secs(600)))
        .expect("wire conv wait")
    {
        if r.verified == Some(true) {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    client.shutdown().expect("wire shutdown");
    drop(client);
    server_thread.join().expect("server thread joins cleanly");
    let issued = metrics.fills_issued.load(Ordering::Relaxed);
    let avoided = metrics.fills_avoided.load(Ordering::Relaxed);
    let saved = metrics.fill_cycles_saved.load(Ordering::Relaxed);
    (5.0 / wall.as_secs_f64(), ok, issued, avoided, saved)
}

/// One `transformer-block` preset model served whole (verify on): 38
/// layers — 12 GEMMs plus elementwise glue — executed as dependency-
/// gated passes on the 14×14 weight-stationary tiler, intermediates
/// arena-resident. Returns `(wall layers/s, layers_completed,
/// inter_layer_fill_reuse, fills_issued, fill_cycles_saved)` —
/// everything but the wall rate is a simulated/deterministic quantity,
/// safe to gate. The fill counters depend only on the preset's layer
/// shapes, never on the weight values: per block, Q/V/O projections
/// are 28×28 (2×2 = 4 tiles each), the FFN pair is 28×56 and 56×28
/// (8 tiles each) — 28 fills per block, 56 per model; the shared-QK
/// pair merges K's 4 tiles into Q's fill groups at the same wavefront
/// level, so 4 fills per block (8 per model) are streamed instead of
/// issued, at rows+1 = 15 fill cycles each = 120 saved.
fn model_serve() -> (f64, u64, u64, u64, u64) {
    let mut svc = Service::start(ServiceConfig {
        kind: EngineKind::WsDspFetch,
        workers: 2,
        ws_rows: 14,
        ws_cols: 14,
        verify: true,
        shard_width: 1,
    });
    let (model, input) = ModelPreset::TransformerBlock.build(false, 5);
    let t0 = Instant::now();
    svc.submit(Job::Model { model, input });
    let r = svc
        .wait_any(Duration::from_secs(1800))
        .expect("model completes");
    let wall = t0.elapsed();
    assert_eq!(r.verified, Some(true), "model verifies vs golden replay");
    let layers = svc.metrics.layers_completed.load(Ordering::Relaxed);
    let reuse = svc.metrics.inter_layer_fill_reuse.load(Ordering::Relaxed);
    let issued = svc.metrics.fills_issued.load(Ordering::Relaxed);
    let saved = svc.metrics.fill_cycles_saved.load(Ordering::Relaxed);
    svc.shutdown();
    (layers as f64 / wall.as_secs_f64(), layers, reuse, issued, saved)
}

/// QoS-layer wall-clock probes (trend only, never gated):
///
/// * `admission_overhead_ns` — the per-submit cost of the admission
///   path (quota ledger, cost accounting, high-water gate), measured
///   as budgeted-session submit latency minus the privileged-exempt
///   baseline through the same `Frontend`;
/// * `shed_recovery_ms` — wall time from the submit that trips the
///   high-water gate (shedding the largest unprivileged holder) to that newcomer's
///   own result arriving: how fast the server recovers usefulness for
///   a compliant client after shedding.
fn qos_probes(smoke: bool) -> (f64, f64) {
    let count = if smoke { 40 } else { 200 };
    let small_cfg = || ServiceConfig {
        kind: EngineKind::WsDspFetch,
        workers: 2,
        ws_rows: 14,
        ws_cols: 14,
        verify: false,
        shard_width: 1,
    };
    let mut per_submit_ns = Vec::new();
    for privileged in [true, false] {
        let qos = QosConfig {
            budget: SessionBudget {
                max_inflight: count + 1,
                ..SessionBudget::default()
            },
            ..QosConfig::default()
        };
        let frontend = Frontend::with_qos(Service::start(small_cfg()), qos);
        let sess = frontend.open_session(privileged);
        let mut rng = XorShift::new(43);
        let a = MatI8::random_bounded(&mut rng, 4, 14, 63);
        let w = MatI8::random(&mut rng, 14, 14);
        let t0 = Instant::now();
        for _ in 0..count {
            let (resp, _) = frontend.handle(
                Request::SubmitGemm {
                    a: a.clone(),
                    w: w.clone(),
                },
                &sess,
            );
            assert!(matches!(resp, Response::Handle { .. }));
        }
        per_submit_ns.push(t0.elapsed().as_nanos() as f64 / count as f64);
        let (resp, _) = frontend.handle(
            Request::DrainMine {
                timeout_ms: Some(600_000),
            },
            &sess,
        );
        assert!(matches!(resp, Response::Drained { .. }));
        let op = frontend.open_session(true);
        frontend.handle(Request::Shutdown, &op);
    }
    // Noise can make the diff negative on a fast box; the trend key
    // floors at zero rather than reporting nonsense.
    let admission_ns = (per_submit_ns[1] - per_submit_ns[0]).max(0.0);

    let qos = QosConfig {
        max_outstanding: 4,
        ..QosConfig::default()
    };
    let frontend = Frontend::with_qos(Service::start(small_cfg()), qos);
    let old = frontend.open_session(false);
    let mut rng = XorShift::new(47);
    let w = MatI8::random(&mut rng, 14, 14);
    for _ in 0..4 {
        let (resp, _) = frontend.handle(
            Request::SubmitGemm {
                a: MatI8::random_bounded(&mut rng, 4, 14, 63),
                w: w.clone(),
            },
            &old,
        );
        assert!(matches!(resp, Response::Handle { .. }));
    }
    let newcomer = frontend.open_session(false);
    let t0 = Instant::now();
    let (resp, _) = frontend.handle(
        Request::SubmitGemm {
            a: MatI8::random_bounded(&mut rng, 4, 14, 63),
            w,
        },
        &newcomer,
    );
    let id = match resp {
        Response::Handle { id } => id,
        other => panic!("newcomer admitted by shedding, got {}", other.tag()),
    };
    let (resp, _) = frontend.handle(
        Request::Wait {
            id,
            timeout_ms: Some(600_000),
        },
        &newcomer,
    );
    assert!(matches!(resp, Response::Result(_)));
    let shed_recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
    let op = frontend.open_session(true);
    frontend.handle(Request::Shutdown, &op);
    (admission_ns, shed_recovery_ms)
}

fn main() {
    section("DSP48E2 cell");
    let mut dsp = Dsp48e2::new(Attributes::ws_prefetch_pe());
    let inp = DspInputs {
        a: 123 << 18,
        d: -45,
        b: 77,
        opmode: OpMode::MULT_CASCADE,
        pcin: 991,
        ..DspInputs::default()
    };
    let m = bench("dsp tick (prefetch PE)", || {
        dsp.tick(&inp);
        std::hint::black_box(dsp.p());
    });
    println!(
        "    -> {:.1} M ticks/s",
        m.per_sec() / 1e6
    );

    section("SoA column vs scalar cells (the hot-loop rewrite)");
    // The default GEMM case's cascade column: a 14-deep DSP-Fetch
    // chain streaming packed activations. The scalar side is the
    // golden-reference drive — one materialized DspInputs + tick per
    // cell per edge (what every engine inner loop did before the
    // column rewrite); the column side is one tick_ws_stream pass
    // over the register banks. Simulated semantics are bit-identical
    // (tests/column_props.rs); only wall-clock differs.
    let col_rows = 14usize;
    let col_attrs = Attributes {
        areg: 1,
        ..Attributes::ws_prefetch_pe()
    };
    let mut scalar_col: Vec<Dsp48e2> =
        (0..col_rows).map(|_| Dsp48e2::new(col_attrs)).collect();
    let mut soa_col = DspColumn::new(col_attrs, col_rows);
    let a_feed: Vec<i64> = (0..col_rows)
        .map(|r| ((r as i64 * 31 % 100) - 50) << 18)
        .collect();
    let d_feed: Vec<i64> =
        (0..col_rows).map(|r| (r as i64 * 17 % 100) - 50).collect();
    let mut pcouts = vec![0i64; col_rows];
    let m_scalar = bench("scalar cascade x14 (DspInputs per cell)", || {
        for (slot, cell) in pcouts.iter_mut().zip(scalar_col.iter()) {
            *slot = cell.pcout();
        }
        for r in 0..col_rows {
            scalar_col[r].tick(&DspInputs {
                a: a_feed[r],
                d: d_feed[r],
                inmode: InMode::A2_B2.with_d(),
                opmode: if r == 0 {
                    OpMode::MULT
                } else {
                    OpMode::MULT_CASCADE
                },
                pcin: if r == 0 { 0 } else { pcouts[r - 1] },
                ceb1: false,
                ceb2: false,
                ..DspInputs::default()
            });
        }
        std::hint::black_box(scalar_col[col_rows - 1].p());
    });
    let m_col = bench("SoA column x14 (tick_ws_stream)", || {
        soa_col.tick_ws_stream(&a_feed, &d_feed);
        std::hint::black_box(soa_col.p(col_rows - 1));
    });
    let cells_ticked_per_s = col_rows as f64 * m_col.per_sec();
    let column_speedup = m_col.per_sec() / m_scalar.per_sec();
    println!(
        "    -> {:.1} M cells/s SoA, {column_speedup:.2}x over the \
         scalar golden model",
        cells_ticked_per_s / 1e6
    );

    section("whole-array SoA vs per-column loop (the array rewrite)");
    // The paper's full 14x14 WS array on the same streaming drive: the
    // per-column side ticks 14 independent DspColumns (what every
    // engine steady-state loop did before the array rewrite); the
    // array side is one tick_ws_stream bank pass over all 196 slices.
    // Simulated semantics are bit-identical (tests/array_props.rs);
    // only wall-clock differs.
    let (arr_rows, arr_cols) = (14usize, 14usize);
    let mut col_bank: Vec<DspColumn> = (0..arr_cols)
        .map(|_| DspColumn::new(col_attrs, arr_rows))
        .collect();
    let mut array = DspArray::new(col_attrs, arr_rows, arr_cols);
    let a_flat: Vec<i64> = (0..arr_rows * arr_cols)
        .map(|i| ((i as i64 * 31 % 100) - 50) << 18)
        .collect();
    let d_flat: Vec<i64> = (0..arr_rows * arr_cols)
        .map(|i| (i as i64 * 17 % 100) - 50)
        .collect();
    let m_cols = bench("per-column loop x14 (tick_ws_stream per column)", || {
        for (c, col) in col_bank.iter_mut().enumerate() {
            col.tick_ws_stream(
                &a_flat[c * arr_rows..(c + 1) * arr_rows],
                &d_flat[c * arr_rows..(c + 1) * arr_rows],
            );
        }
        std::hint::black_box(col_bank[arr_cols - 1].p(arr_rows - 1));
    });
    let m_arr = bench("DspArray 14x14 (one array-wide bank pass)", || {
        array.tick_ws_stream(&a_flat, &d_flat);
        std::hint::black_box(array.p(arr_cols - 1, arr_rows - 1));
    });
    let array_cells_per_s = (arr_rows * arr_cols) as f64 * m_arr.per_sec();
    let array_speedup = m_arr.per_sec() / m_cols.per_sec();
    println!(
        "    -> {:.1} M cells/s array-wide, {array_speedup:.2}x over \
         the per-column loop",
        array_cells_per_s / 1e6
    );

    section("WS array cycle (14x14 paper config)");
    let mut eng = WsEngine::new(WsConfig::paper_14x14());
    let mut rng = XorShift::new(1);
    let a = MatI8::random_bounded(&mut rng, 8, 14, 63);
    let w = MatI8::random(&mut rng, 14, 14);
    let m = bench("run_gemm 8x14x14 (one tile)", || {
        let run = eng.run_gemm(&a, &w).unwrap();
        std::hint::black_box(run.stats.cycles);
    });
    let cycles = eng.run_gemm(&a, &w).unwrap().stats.cycles;
    println!(
        "    -> {:.2} M DSP-ticks/s host",
        cycles as f64 * 210.0 * m.per_sec() / 1e6
    );

    section("ring accumulator");
    let mut ring = RingAccumulator::new(0);
    let mut i = 0i64;
    bench("ring tick", || {
        i = (i + 1) & 0xFFFF;
        ring.tick(i, i ^ 0x5A5A);
        std::hint::black_box(ring.output());
    });

    section("packed arithmetic (functional fast path)");
    let hi: Vec<i8> = (0..1024).map(|i| (i % 251) as i8).collect();
    let lo: Vec<i8> = (0..1024).map(|i| (i % 127) as i8).collect();
    let wv: Vec<i8> = (0..1024).map(|i| (i % 83) as i8).collect();
    let m = bench("packed_dot K=1024", || {
        std::hint::black_box(packing::packed_dot(&hi, &lo, &wv));
    });
    println!(
        "    -> {:.1} M packed-MACs/s (x2 lanes)",
        1024.0 * m.per_sec() / 1e6
    );
    let packed_dot_rate = 1024.0 * m.per_sec();

    section("sharded coordinator (single large GEMM across workers)");
    let smoke = std::env::var("SIM_BENCH_SMOKE").is_ok();
    let size = if smoke { 128 } else { 512 };
    let rate_1w = sharded_gemm_rate(1, size);
    let rate_4w = sharded_gemm_rate(4, size);
    let speedup = rate_4w / rate_1w;
    println!("    -> 4-worker speedup over 1 worker: {speedup:.2}x");

    section("batched submission (weight-tile reuse / fill amortization)");
    // Fixed shape in smoke and full runs: these are simulated-cycle
    // metrics — deterministic, so CI gates on them (>10% macs/cycle
    // regression fails the workflow; see tools/check_bench_regression.py).
    let (count, shape) = (8, (16, 28, 28));
    let (b_cycles, b_macs, fills_issued, fills_avoided, fill_saved) =
        shared_weight_serve(true, count, shape);
    let (s_cycles, s_macs, ..) = shared_weight_serve(false, count, shape);
    let batched_mpc = b_macs as f64 / b_cycles as f64;
    let single_mpc = s_macs as f64 / s_cycles as f64;
    println!(
        "bench batched {count} shared-weight 16x28x28 jobs: \
         {b_cycles} sim-cycles batched vs {s_cycles} single \
         -> {batched_mpc:.3} vs {single_mpc:.3} MACs/cycle"
    );
    println!(
        "    -> fills: {fills_issued} issued, {fills_avoided} avoided \
         ({fill_saved} fill cycles saved)"
    );

    section("conv-native lazy tiling (per-tile im2col patch extraction)");
    // Shared-weight conv batch on the lazy tiling path; simulated
    // metrics only, so the regression gate covers conv end-to-end.
    let conv_jobs = 6;
    let (c_cycles, c_macs, c_issued, c_avoided, c_saved) =
        conv_serve(conv_jobs);
    let conv_mpc = c_macs as f64 / c_cycles as f64;
    let conv_amort = c_avoided as f64 / (c_issued + c_avoided) as f64;
    println!(
        "bench conv {conv_jobs} shared-weight 8x12x12 k3 s1 p1 jobs: \
         {c_cycles} sim-cycles -> {conv_mpc:.3} MACs/cycle"
    );
    println!(
        "    -> fills: {c_issued} issued, {c_avoided} avoided \
         ({c_saved} fill cycles saved, {:.1}% amortized)",
        100.0 * conv_amort
    );

    section("sparse dataflow (N:M weight tiles, zero-work skipping)");
    // Density sweep on one 16x140x140 sparse GEMM over the 14x14
    // tiler (10x10 = 100 weight tiles; striped blocks align to the
    // tile grid). All simulated quantities — MACs stay dense-
    // equivalent, so MACs/cycle measures delivered work per cycle and
    // rises as dead tiles are skipped. `nm24` is fully structured 2:4
    // sparsity with every tile live: it shows that within-tile
    // sparsity alone skips nothing (the skip unit is the tile).
    let sparse_shape = (16, 140, 140);
    let dense_nm = NmPattern::DENSE;
    let nm_24 = NmPattern::new(2, 4).expect("2:4 is valid");
    // (label, pattern, live_every) -> weight density 1.0 / 0.5 /
    // 0.5-structured / 0.1.
    let (d100_c, d100_m, d100_skip) = sparse_serve(dense_nm, 1, sparse_shape);
    let (d50_c, d50_m, d50_skip) = sparse_serve(dense_nm, 2, sparse_shape);
    let (nm24_c, nm24_m, nm24_skip) = sparse_serve(nm_24, 1, sparse_shape);
    let (d10_c, d10_m, d10_skip) = sparse_serve(nm_24, 5, sparse_shape);
    let sparse_mpc = |macs: u64, cycles: u64| macs as f64 / cycles as f64;
    let (mpc_d100, mpc_d50, mpc_nm24, mpc_d10) = (
        sparse_mpc(d100_m, d100_c),
        sparse_mpc(d50_m, d50_c),
        sparse_mpc(nm24_m, nm24_c),
        sparse_mpc(d10_m, d10_c),
    );
    let sparse_skipped = d100_skip + d50_skip + nm24_skip + d10_skip;
    println!(
        "bench sparse 16x140x140 density sweep (dense-equivalent \
         MACs/cycle):"
    );
    println!(
        "    -> d=1.0: {mpc_d100:.3} ({d100_skip} tiles skipped), \
         d=0.5: {mpc_d50:.3} ({d50_skip} skipped)"
    );
    println!(
        "    -> 2:4 all-live: {mpc_nm24:.3} ({nm24_skip} skipped), \
         d=0.1 2:4: {mpc_d10:.3} ({d10_skip} skipped, \
         {:.2}x over dense)",
        mpc_d10 / mpc_d100
    );

    section("model graph (whole transformer block, arena-resident)");
    let (mdl_rate, mdl_layers, mdl_reuse, mdl_issued, mdl_saved) =
        model_serve();
    println!(
        "bench model transformer-block (2 blocks, {mdl_layers} layers, \
         verify on): {mdl_rate:.1} layers/s wall"
    );
    println!(
        "    -> fills: {mdl_issued} issued, {mdl_reuse} inter-layer \
         reuses ({mdl_saved} fill cycles saved via shared-QK)"
    );

    section("serve loopback (wire protocol end-to-end over TCP)");
    let (lb_rate, lb_ok, lb_issued, lb_avoided, lb_saved) = serve_loopback();
    println!(
        "bench loopback 4 shared-weight GEMMs (one wire batch) + 1 conv: \
         {lb_ok}/5 verified, {lb_rate:.1} jobs/s wall"
    );
    println!(
        "    -> fills: {lb_issued} issued, {lb_avoided} avoided \
         ({lb_saved} fill cycles saved) — reuse survives the socket"
    );

    section("QoS admission / shed recovery (overload path)");
    let (admission_ns, shed_recovery_ms) = qos_probes(smoke);
    println!(
        "bench qos admission: {admission_ns:.0} ns/submit over the \
         exempt baseline; shed->fresh-result recovery: \
         {shed_recovery_ms:.1} ms"
    );

    // Perf-trajectory artifact for CI (stable keys, one flat object),
    // emitted through the shared util/json serializer — the same
    // emitter behind Metrics::snapshot_json and the Stats response.
    let artifact = Json::object([
        ("bench", Json::from("sim_throughput")),
        ("smoke", Json::from(smoke)),
        ("packed_dot_macs_per_s", Json::float(packed_dot_rate)),
        // Wall-clock trajectory of the SoA hot-loop rewrite (trend
        // only, never gated — host-speed dependent).
        ("cells_ticked_per_s", Json::float(cells_ticked_per_s)),
        ("column_vs_scalar_speedup", Json::float(column_speedup)),
        ("array_cells_ticked_per_s", Json::float(array_cells_per_s)),
        ("array_vs_column_speedup", Json::float(array_speedup)),
        ("sharded_gemm_size", Json::from(size)),
        ("sharded_gemm_macs_per_s_1w", Json::float(rate_1w)),
        ("sharded_gemm_macs_per_s_4w", Json::float(rate_4w)),
        ("sharded_speedup_4w_over_1w", Json::float(speedup)),
        ("batched_macs_per_cycle", Json::float(batched_mpc)),
        ("single_macs_per_cycle", Json::float(single_mpc)),
        ("fills_issued", Json::uint(fills_issued)),
        ("fills_avoided", Json::uint(fills_avoided)),
        ("fill_cycles_saved", Json::uint(fill_saved)),
        ("conv_macs_per_cycle", Json::float(conv_mpc)),
        ("conv_fill_amortization", Json::float(conv_amort)),
        ("conv_fills_issued", Json::uint(c_issued)),
        ("conv_fills_avoided", Json::uint(c_avoided)),
        ("conv_fill_cycles_saved", Json::uint(c_saved)),
        // Sparse density sweep: MACs/cycle trend keys (rising with
        // sparsity) plus the exact skip count CI gates bit-for-bit.
        ("sparse_macs_per_cycle_d100", Json::float(mpc_d100)),
        ("sparse_macs_per_cycle_d50", Json::float(mpc_d50)),
        ("sparse_macs_per_cycle_nm24", Json::float(mpc_nm24)),
        ("sparse_macs_per_cycle_d10", Json::float(mpc_d10)),
        ("sparse_tiles_skipped", Json::uint(sparse_skipped)),
        // Model graph: layers/s is wall-clock (trend only); the layer
        // and fill counters are simulated and gated exactly.
        ("model_layers_per_s", Json::float(mdl_rate)),
        ("model_layers_completed", Json::uint(mdl_layers)),
        ("model_inter_layer_fill_reuse", Json::uint(mdl_reuse)),
        ("model_fills_issued", Json::uint(mdl_issued)),
        ("model_fill_cycles_saved", Json::uint(mdl_saved)),
        ("loopback_jobs_per_s", Json::float(lb_rate)),
        ("loopback_jobs_ok", Json::uint(lb_ok)),
        ("loopback_fills_issued", Json::uint(lb_issued)),
        ("loopback_fills_avoided", Json::uint(lb_avoided)),
        ("loopback_fill_cycles_saved", Json::uint(lb_saved)),
        // QoS probes: wall-clock, trend only, never gated.
        ("admission_overhead_ns", Json::float(admission_ns)),
        ("shed_recovery_ms", Json::float(shed_recovery_ms)),
    ]);
    let json = artifact.to_pretty() + "\n";
    match std::fs::write("BENCH_sim_throughput.json", &json) {
        Ok(()) => println!("wrote BENCH_sim_throughput.json"),
        Err(e) => eprintln!("could not write BENCH_sim_throughput.json: {e}"),
    }
}
