//! Bench: PJRT execution latency for the AOT artifacts (the functional
//! fast path the coordinator serves values from).
//!
//! Skips politely when `artifacts/` is absent (run `make artifacts`).

use dsp48_systolic::runtime::{ArtifactRegistry, MixedBuf};
use dsp48_systolic::util::bench::{bench, section};
use dsp48_systolic::util::rng::XorShift;
use std::path::Path;

fn main() {
    if !Path::new("artifacts/manifest.json").exists() {
        println!("SKIP runtime_latency: artifacts/ missing (make artifacts)");
        return;
    }
    let mut reg = ArtifactRegistry::open_default().expect("registry");

    section("packed GEMM artifacts");
    let mut rng = XorShift::new(5);
    for (m, k, n) in [(32usize, 64usize, 64usize), (32, 256, 256), (64, 512, 512)] {
        let Some(name) = reg.gemm_artifact(m, k, n) else { continue };
        let a_hi = rng.i8_vec(m * k);
        let a_lo = rng.i8_vec(m * k);
        let w = rng.i8_vec(k * n);
        let module = reg.module(&name).expect("compiles");
        let meas = bench(&format!("pjrt {name}"), || {
            let out = module
                .execute_i8_to_i32(&[&a_hi, &a_lo, &w])
                .expect("executes");
            std::hint::black_box(out[0].len());
        });
        let macs = 2 * m * k * n;
        println!(
            "    -> {:.2} GMAC/s effective",
            macs as f64 * meas.per_sec() / 1e9
        );
    }

    section("MLP artifact (batch 64)");
    let name = "mlp_b64_784_256_128_10";
    if reg.entry(name).is_some() {
        let x = rng.i8_vec(64 * 784);
        let w1 = rng.i8_vec(784 * 256);
        let b1: Vec<i32> = (0..256).map(|_| rng.next_i8() as i32).collect();
        let w2 = rng.i8_vec(256 * 128);
        let b2: Vec<i32> = (0..128).map(|_| rng.next_i8() as i32).collect();
        let w3 = rng.i8_vec(128 * 10);
        let b3: Vec<i32> = (0..10).map(|_| rng.next_i8() as i32).collect();
        let module = reg.module(name).expect("compiles");
        let bufs = [
            MixedBuf::I8(&x),
            MixedBuf::I8(&w1),
            MixedBuf::I32(&b1),
            MixedBuf::I8(&w2),
            MixedBuf::I32(&b2),
            MixedBuf::I8(&w3),
            MixedBuf::I32(&b3),
        ];
        let meas = bench("pjrt mlp forward", || {
            let out = module.execute_mixed(&bufs).expect("executes");
            std::hint::black_box(out[0].len());
        });
        println!(
            "    -> {:.0} images/s",
            64.0 * meas.per_sec()
        );
    }
}
