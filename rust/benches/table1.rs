//! Bench: regenerate Table I end-to-end and time each design's
//! cycle-accurate simulation of the paper-scale workload.
//!
//! Prints (a) the table itself (the reproduction artifact) and (b) the
//! host-side simulation throughput per design, so perf regressions in
//! the DSP model show up here.

use dsp48_systolic::cost::report::render_table;
use dsp48_systolic::engines::ws::{WsConfig, WsEngine, WsVariant};
use dsp48_systolic::engines::Engine;
use dsp48_systolic::util::bench::{bench, section};
use dsp48_systolic::util::rng::XorShift;
use dsp48_systolic::workload::MatI8;

fn main() {
    section("Table I regeneration (INT8 14x14 TPUv1-like, XCZU3EG)");
    let variants = [
        WsVariant::TinyTpu,
        WsVariant::Libano,
        WsVariant::ClbFetch,
        WsVariant::DspFetch,
    ];
    let rows: Vec<_> = variants
        .iter()
        .map(|&v| WsEngine::new(WsConfig::paper_14x14_for(v)).table_row())
        .collect();
    print!("{}", render_table("Table I", &rows));

    section("cycle-accurate simulation throughput (host)");
    let mut rng = XorShift::new(3);
    let a = MatI8::random_bounded(&mut rng, 32, 14, 63);
    let w = MatI8::random(&mut rng, 14, 14);
    for v in variants {
        let mut eng = WsEngine::new(WsConfig::paper_14x14_for(v));
        let m = bench(&format!("simulate {} 32x14x14", v.label()), || {
            let run = eng.run_gemm(&a, &w).unwrap();
            std::hint::black_box(run.stats.cycles);
        });
        let run = eng.run_gemm(&a, &w).unwrap();
        println!(
            "    -> {:.1} sim-cycles/host-us ({} sim cycles per run)",
            run.stats.cycles as f64 / m.mean.as_micros().max(1) as f64,
            run.stats.cycles
        );
    }

    section("table elaboration latency (inventory+timing+power)");
    bench("elaborate all four designs", || {
        for v in variants {
            let row = WsEngine::new(WsConfig::paper_14x14_for(v)).table_row();
            std::hint::black_box(row.power_w);
        }
    });
}
