//! Length-prefixed frame codec for the wire protocol.
//!
//! A frame is a 4-byte big-endian payload length followed by exactly
//! that many payload bytes (UTF-8 JSON, see [`super::message`]).
//! The codec is transport-agnostic: anything `Read`/`Write` works —
//! `TcpStream`s in production, `Cursor`s in tests.
//!
//! Failure taxonomy (all typed, never a panic):
//!
//! * clean EOF before the first prefix byte → `Ok(None)` (the peer
//!   closed between frames — a normal disconnect);
//! * EOF mid-prefix or mid-payload → [`FrameError::Truncated`];
//! * a declared length above [`MAX_FRAME_LEN`] →
//!   [`FrameError::Oversize`]. The four prefix bytes are consumed and
//!   **no payload bytes are skipped**: a server that answers with a
//!   typed error keeps the connection usable exactly when the peer
//!   stopped after the bogus prefix (the only way an in-protocol peer
//!   can produce this — an actual 64 MiB payload would mean the peer
//!   ignored the limit entirely, and the next read fails on its bytes).

use std::io::{self, Read, Write};

/// Ceiling on one frame's payload (64 MiB). A 512×512 INT32 result —
/// far above anything the engines serve today — is under 3 MiB of
/// JSON, so the cap only ever rejects garbage prefixes, not real
/// traffic.
pub const MAX_FRAME_LEN: usize = 64 << 20;

/// Why a frame could not be read (or written).
#[derive(Debug)]
pub enum FrameError {
    /// The stream ended mid-prefix or mid-payload.
    Truncated,
    /// The prefix declared a payload larger than [`MAX_FRAME_LEN`].
    Oversize { len: usize, max: usize },
    /// Transport-level failure.
    Io(io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated => {
                write!(f, "frame truncated (stream ended mid-frame)")
            }
            FrameError::Oversize { len, max } => {
                write!(f, "frame length {len} exceeds maximum {max}")
            }
            FrameError::Io(e) => write!(f, "frame i/o error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<io::Error> for FrameError {
    fn from(e: io::Error) -> FrameError {
        FrameError::Io(e)
    }
}

/// Write one frame (prefix + payload) and flush.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!(
                "frame payload {} exceeds maximum {MAX_FRAME_LEN}",
                payload.len()
            ),
        ));
    }
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame's payload. `Ok(None)` = the peer closed cleanly
/// before sending another frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>, FrameError> {
    let mut prefix = [0u8; 4];
    let mut got = 0usize;
    while got < prefix.len() {
        match r.read(&mut prefix[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(None)
                } else {
                    Err(FrameError::Truncated)
                };
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let len = u32::from_be_bytes(prefix) as usize;
    if len > MAX_FRAME_LEN {
        return Err(FrameError::Oversize {
            len,
            max: MAX_FRAME_LEN,
        });
    }
    // Grow the buffer with the bytes actually received (`take` +
    // `read_to_end` doubles adaptively) instead of trusting the
    // declared length upfront: a 4-byte prefix alone must not be able
    // to pin 64 MiB of zeroed memory per connection.
    let mut payload = Vec::with_capacity(len.min(64 * 1024));
    match r.by_ref().take(len as u64).read_to_end(&mut payload) {
        Ok(n) if n == len => Ok(Some(payload)),
        Ok(_) => Err(FrameError::Truncated),
        Err(e) => Err(FrameError::Io(e)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_round_trip_back_to_back() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"first").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, b"third frame").unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"first");
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"third frame");
        // Clean EOF between frames is a normal disconnect, not an error.
        assert!(read_frame(&mut c).unwrap().is_none());
    }

    #[test]
    fn truncated_prefix_is_typed() {
        let mut c = Cursor::new(vec![0u8, 0, 0]); // 3 of 4 prefix bytes
        assert!(matches!(read_frame(&mut c), Err(FrameError::Truncated)));
    }

    #[test]
    fn truncated_payload_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        buf.truncate(7); // prefix + 3 of 5 payload bytes
        let mut c = Cursor::new(buf);
        assert!(matches!(read_frame(&mut c), Err(FrameError::Truncated)));
    }

    #[test]
    fn oversize_prefix_is_typed_and_consumes_only_the_prefix() {
        let mut buf = u32::MAX.to_be_bytes().to_vec();
        write_frame(&mut buf, b"next").unwrap();
        let mut c = Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut c),
            Err(FrameError::Oversize { .. })
        ));
        // The reader is positioned right after the bogus prefix: the
        // following well-formed frame still parses.
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"next");
    }

    #[test]
    fn oversize_write_is_rejected() {
        // Don't allocate 64 MiB in a unit test: a zero-length slice
        // with a faked length is impossible, so check the boundary via
        // the real API on a just-over payload only when cheap.
        let payload = vec![0u8; MAX_FRAME_LEN + 1];
        let mut sink = Vec::new();
        assert!(write_frame(&mut sink, &payload).is_err());
        assert!(sink.is_empty());
    }
}
