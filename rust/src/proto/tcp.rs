//! The TCP transport: [`TcpSession`] (client) and [`TcpServer`]
//! (blocking listener + one thread per connection), both speaking the
//! length-prefixed frames of [`super::frame`] and dispatching through
//! the same [`Frontend`] the in-process [`super::LocalSession`] uses —
//! so a socket client and a local caller observe bit-identical
//! behavior.
//!
//! Failure handling on the server side follows the protocol contract:
//! an undecodable payload (bad JSON, schema violation, unknown tag,
//! version mismatch) and an oversize frame prefix each get a typed
//! [`Response::Error`] and the connection **stays open**; only
//! transport-level loss (EOF mid-frame, socket errors) ends a
//! connection — and even then the server itself keeps serving the
//! rest.

use crate::coordinator::Service;
use crate::proto::frame::{read_frame, write_frame, FrameError, MAX_FRAME_LEN};
use crate::proto::message::{ErrorCode, Request, Response, WireError};
use crate::proto::session::{
    Frontend, QosConfig, Session, SessionError, SessionState,
};
use crate::util::json::Json;
use std::net::{
    IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream,
};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A client connection to a [`TcpServer`]. One in-flight request at a
/// time (strict request/response alternation), matching the framing.
pub struct TcpSession {
    stream: TcpStream,
}

impl TcpSession {
    /// Connect to `addr` (e.g. `"127.0.0.1:7878"`).
    pub fn connect(addr: &str) -> std::io::Result<TcpSession> {
        let stream = TcpStream::connect(addr)?;
        // Request/response round trips: don't batch tiny frames.
        let _ = stream.set_nodelay(true);
        Ok(TcpSession { stream })
    }
}

impl Session for TcpSession {
    fn request(&mut self, req: Request) -> Result<Response, SessionError> {
        write_frame(&mut self.stream, &req.encode())?;
        let payload = read_frame(&mut self.stream)?
            .ok_or(SessionError::Closed)?;
        Ok(Response::decode(&payload)?)
    }
}

/// Shared state between the accept loop and connection threads.
struct ServerShared {
    frontend: Frontend,
    /// Set by the connection that served `Shutdown`.
    stop: AtomicBool,
    /// Clones of **live** connections so shutdown can unblock their
    /// reads. Each connection removes its own entry on exit, so churn
    /// does not accumulate dead fds.
    conns: Mutex<Vec<(u64, TcpStream)>>,
    /// The bound address (connection threads wake the accept loop by
    /// dialing it once after setting `stop`).
    addr: SocketAddr,
}

/// Blocking TCP server: feeds every connection's requests into one
/// shared [`Service`] via the common [`Frontend`] dispatcher.
pub struct TcpServer {
    listener: TcpListener,
    frontend: Frontend,
}

impl TcpServer {
    /// Bind `addr` (use port 0 for an OS-assigned port — read it back
    /// with [`TcpServer::local_addr`]) and wrap the service under the
    /// default (fully permissive) QoS policy. Workers are already
    /// running; traffic flows once [`TcpServer::run`] is called.
    pub fn bind(addr: &str, svc: Service) -> std::io::Result<TcpServer> {
        TcpServer::bind_with(addr, svc, QosConfig::default())
    }

    /// Bind with an explicit QoS policy: per-session budgets, the
    /// global admission gate, operator authority, and the idle read
    /// deadline all come from `qos`.
    pub fn bind_with(
        addr: &str,
        svc: Service,
        qos: QosConfig,
    ) -> std::io::Result<TcpServer> {
        Ok(TcpServer {
            listener: TcpListener::bind(addr)?,
            frontend: Frontend::with_qos(svc, qos),
        })
    }

    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Serve until a client's `Shutdown` request: the frontend drains
    /// every pending job, acks with the final metrics snapshot, the
    /// listener exits, every connection is unblocked and joined — no
    /// signal required. Returns that final snapshot.
    pub fn run(self) -> Json {
        let addr = self
            .listener
            .local_addr()
            .expect("bound listener has a local address");
        let shared = Arc::new(ServerShared {
            frontend: self.frontend,
            stop: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            addr,
        });
        let mut threads: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut next_conn: u64 = 0;
        for conn in self.listener.incoming() {
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
            // Reap exited connection threads so churn doesn't
            // accumulate handles for the server's lifetime (their fd
            // clones already removed themselves from `conns`).
            threads.retain(|t| !t.is_finished());
            let Ok(stream) = conn else { continue };
            let conn_id = next_conn;
            next_conn += 1;
            let Ok(clone) = stream.try_clone() else {
                // Without a registered clone, graceful shutdown could
                // never unblock this connection's read and join()
                // would hang forever — refuse the connection instead
                // (try_clone fails under fd exhaustion, where shedding
                // load is the right call anyway).
                continue;
            };
            shared.conns.lock().unwrap().push((conn_id, clone));
            let shared = Arc::clone(&shared);
            threads.push(std::thread::spawn(move || {
                serve_connection(stream, conn_id, &shared);
            }));
        }
        // Unblock every connection thread still parked in a read, then
        // join them all so worker state is quiesced when we return.
        for (_, conn) in shared.conns.lock().unwrap().drain(..) {
            let _ = conn.shutdown(Shutdown::Both);
        }
        for t in threads {
            let _ = t.join();
        }
        shared.frontend.metrics().snapshot_json()
    }
}

/// One connection: open its session (loopback peers get the operator
/// privilege when the QoS policy allows), run the request loop, then
/// clean up — drop this connection's fd clone and close the session,
/// which forgets every handle it never redeemed and abandons its
/// mid-model work, so a client that disconnects mid-flight cannot
/// leak results or arena residency.
fn serve_connection(stream: TcpStream, conn_id: u64, shared: &ServerShared) {
    let qos = shared.frontend.qos();
    let privileged = qos.loopback_operator
        && stream
            .peer_addr()
            .map(|p| p.ip().is_loopback())
            .unwrap_or(false);
    // The slow-loris fix: a peer that goes quiet (or trickles a frame
    // out forever) trips the idle read deadline and is reaped instead
    // of pinning this thread for the server's lifetime.
    let _ = stream.set_read_timeout(qos.idle_timeout);
    let sess = shared.frontend.open_session(privileged);
    connection_loop(stream, shared, &sess);
    shared
        .conns
        .lock()
        .unwrap()
        .retain(|(id, _)| *id != conn_id);
    shared.frontend.close_session(&sess);
}

/// One connection's request loop.
fn connection_loop(
    mut stream: TcpStream,
    shared: &ServerShared,
    sess: &Arc<SessionState>,
) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let payload = match read_frame(&mut stream) {
            Ok(Some(payload)) => payload,
            // Clean disconnect between frames.
            Ok(None) => return,
            Err(FrameError::Oversize { len, max }) => {
                // Typed error, connection stays open: the prefix is
                // consumed and no payload bytes follow it in-protocol
                // (see the frame module's contract).
                let resp = Response::Error(WireError::new(
                    ErrorCode::BadFrame,
                    format!("declared frame length {len} exceeds maximum {max}"),
                ));
                if write_frame(&mut stream, &resp.encode()).is_err() {
                    return;
                }
                continue;
            }
            // The idle read deadline expired: reap this connection.
            Err(FrameError::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                shared
                    .frontend
                    .metrics()
                    .idle_reaped
                    .fetch_add(1, Ordering::Relaxed);
                return;
            }
            // Mid-frame loss or socket error: this stream is beyond
            // recovery (no way to resynchronize), but only this
            // connection ends — the server keeps serving.
            Err(_) => return,
        };
        let (resp, close) = match Request::decode(&payload) {
            Ok(req) => shared.frontend.handle(req, sess),
            // Bad JSON / schema / version / unknown tag: typed error,
            // connection stays open (framing is still in sync).
            Err(e) => (Response::Error(WireError::from_proto(&e)), false),
        };
        // A response too large to frame must not drop the connection
        // with the results already taken out of the table. A bulk
        // Drained is re-parked (redeemable in smaller pieces); a
        // single Result that cannot fit will never fit on a retry, so
        // its handle resolves as Failed — terminal, not a retry loop.
        let mut encoded = resp.encode();
        if encoded.len() > MAX_FRAME_LEN {
            let message = match resp {
                Response::Drained { completed, failed } => {
                    shared.frontend.repark(sess, completed, failed);
                    format!(
                        "drained response would exceed the \
                         {MAX_FRAME_LEN}-byte frame limit; results were \
                         re-parked — redeem handles individually \
                         (wait/poll) instead"
                    )
                }
                Response::Result(r) => {
                    let id = r.id.0;
                    shared.frontend.repark(sess, vec![], vec![id]);
                    format!(
                        "result for job {id} exceeds the \
                         {MAX_FRAME_LEN}-byte frame limit and cannot be \
                         delivered over this transport; the handle now \
                         resolves as failed"
                    )
                }
                _ => format!(
                    "response would exceed the {MAX_FRAME_LEN}-byte \
                     frame limit"
                ),
            };
            encoded = Response::Error(WireError::new(
                ErrorCode::BadRequest,
                message,
            ))
            .encode();
        }
        let write_ok = write_frame(&mut stream, &encoded).is_ok();
        if close {
            // This connection served Shutdown (or a post-shutdown
            // request): stop the listener and wake its accept call.
            shared.stop.store(true, Ordering::SeqCst);
            wake_listener(shared.addr);
            return;
        }
        if !write_ok {
            return;
        }
    }
}

/// Unblock the accept loop after `stop` was set: dial the listener
/// once. A wildcard bind (0.0.0.0 / ::) is not dialable on every
/// platform, so the unspecified address is swapped for the matching
/// loopback; transient connect failures (fd exhaustion) are retried
/// briefly. If every attempt fails, the listener unblocks on the next
/// real connection instead — shutdown is delayed, never lost.
fn wake_listener(addr: SocketAddr) {
    let mut wake = addr;
    if wake.ip().is_unspecified() {
        wake.set_ip(match wake {
            SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
            SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
        });
    }
    for _ in 0..50 {
        if TcpStream::connect(wake).is_ok() {
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::EngineKind;
    use crate::coordinator::{Job, JobState, ServiceConfig};
    use crate::util::rng::XorShift;
    use crate::workload::gemm::golden_gemm;
    use crate::workload::MatI8;
    use std::time::Duration;

    fn start_server(workers: usize) -> (SocketAddr, std::thread::JoinHandle<Json>) {
        let svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        });
        let server = TcpServer::bind("127.0.0.1:0", svc).expect("bind");
        let addr = server.local_addr().expect("local addr");
        (addr, std::thread::spawn(move || server.run()))
    }

    #[test]
    fn gemm_round_trips_over_the_socket() {
        let (addr, server) = start_server(2);
        let mut s = TcpSession::connect(&addr.to_string()).expect("connect");
        let mut rng = XorShift::new(7);
        let a = MatI8::random_bounded(&mut rng, 4, 13, 63);
        let w = MatI8::random(&mut rng, 13, 9);
        let id = s
            .submit(Job::Gemm {
                a: a.clone(),
                w: w.clone(),
            })
            .unwrap();
        let r = s
            .wait(id, Some(Duration::from_secs(60)))
            .unwrap()
            .into_result()
            .expect("job completes over the wire");
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.output, golden_gemm(&a, &w));
        let final_metrics = s.shutdown().unwrap();
        assert_eq!(
            final_metrics.get("jobs_completed").unwrap().as_i64(),
            Some(1)
        );
        let joined = server.join().expect("listener exits after Shutdown");
        assert_eq!(joined.get("jobs_completed").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn two_clients_share_one_service() {
        let (addr, server) = start_server(2);
        let mut s1 = TcpSession::connect(&addr.to_string()).unwrap();
        let mut s2 = TcpSession::connect(&addr.to_string()).unwrap();
        let mut rng = XorShift::new(21);
        let a1 = MatI8::random_bounded(&mut rng, 3, 8, 63);
        let w1 = MatI8::random(&mut rng, 8, 4);
        let a2 = MatI8::random_bounded(&mut rng, 5, 10, 63);
        let w2 = MatI8::random(&mut rng, 10, 6);
        let id1 = s1
            .submit(Job::Gemm {
                a: a1.clone(),
                w: w1.clone(),
            })
            .unwrap();
        let id2 = s2
            .submit(Job::Gemm {
                a: a2.clone(),
                w: w2.clone(),
            })
            .unwrap();
        // Ids come from one shared service: they must differ.
        assert_ne!(id1, id2);
        let r2 = s2
            .wait(id2, Some(Duration::from_secs(60)))
            .unwrap()
            .into_result()
            .expect("client 2's job completes");
        let r1 = s1
            .wait(id1, Some(Duration::from_secs(60)))
            .unwrap()
            .into_result()
            .expect("client 1's job completes");
        assert_eq!(r1.output, golden_gemm(&a1, &w1));
        assert_eq!(r2.output, golden_gemm(&a2, &w2));
        drop(s2);
        s1.shutdown().unwrap();
        server.join().unwrap();
    }

    /// A client that disconnects without redeeming its handles must
    /// not leak its results: a later global Drain sees nothing from
    /// it (the session's unredeemed handles were forgotten).
    #[test]
    fn disconnected_clients_results_are_forgotten() {
        let (addr, server) = start_server(1);
        let mut observer = TcpSession::connect(&addr.to_string()).unwrap();
        {
            let mut ghost = TcpSession::connect(&addr.to_string()).unwrap();
            let mut rng = XorShift::new(33);
            let a = MatI8::random_bounded(&mut rng, 2, 6, 63);
            let w = MatI8::random(&mut rng, 6, 3);
            ghost.submit(Job::Gemm { a, w }).unwrap();
            // Wait (through the observer) until the job has retired,
            // then vanish without redeeming the handle.
            for _ in 0..600 {
                let snap = observer.stats().unwrap();
                if snap.get("jobs_completed").unwrap().as_i64() == Some(1) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        } // ghost drops: disconnect without redemption
        // Give the server a moment to observe the disconnect and run
        // the session cleanup.
        std::thread::sleep(Duration::from_millis(300));
        let (completed, failed) =
            observer.drain(Some(Duration::from_secs(10))).unwrap();
        assert!(
            completed.is_empty(),
            "forgotten result resurfaced: {completed:?}"
        );
        assert!(failed.is_empty());
        observer.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn disconnect_without_shutdown_leaves_server_serving() {
        let (addr, server) = start_server(1);
        {
            let mut s = TcpSession::connect(&addr.to_string()).unwrap();
            let mut rng = XorShift::new(9);
            let a = MatI8::random_bounded(&mut rng, 2, 6, 63);
            let w = MatI8::random(&mut rng, 6, 3);
            s.submit(Job::Gemm { a, w }).unwrap();
            // Dropped without waiting or shutting down.
        }
        let mut s = TcpSession::connect(&addr.to_string()).unwrap();
        let mut rng = XorShift::new(13);
        let a = MatI8::random_bounded(&mut rng, 2, 6, 63);
        let w = MatI8::random(&mut rng, 6, 3);
        let id = s.submit(Job::Gemm { a, w }).unwrap();
        assert!(matches!(
            s.wait(id, Some(Duration::from_secs(60))).unwrap(),
            JobState::Done(_)
        ));
        s.shutdown().unwrap();
        server.join().unwrap();
    }

    fn small_svc() -> Service {
        Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 1,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        })
    }

    /// With `loopback_operator` off, a socket client is a plain
    /// session: `Shutdown` answers `forbidden` until it presents the
    /// operator token via `Auth`.
    #[test]
    fn operator_token_gates_shutdown_over_tcp() {
        let qos = QosConfig {
            loopback_operator: false,
            operator_token: Some("hunter2".to_string()),
            ..QosConfig::default()
        };
        let server = TcpServer::bind_with("127.0.0.1:0", small_svc(), qos)
            .expect("bind");
        let addr = server.local_addr().unwrap();
        let handle = std::thread::spawn(move || server.run());
        let mut s = TcpSession::connect(&addr.to_string()).unwrap();
        match s.shutdown().unwrap_err() {
            SessionError::Remote(e) => {
                assert_eq!(e.code, ErrorCode::Forbidden)
            }
            other => panic!("expected forbidden, got {other}"),
        }
        match s.auth("wrong").unwrap_err() {
            SessionError::Remote(e) => {
                assert_eq!(e.code, ErrorCode::Forbidden)
            }
            other => panic!("expected forbidden, got {other}"),
        }
        s.auth("hunter2").unwrap();
        s.shutdown().unwrap();
        handle.join().unwrap();
    }

    /// A connection that goes quiet past the idle read deadline is
    /// reaped (counted in `idle_reaped`) and the server keeps
    /// serving everyone else.
    #[test]
    fn idle_connections_are_reaped() {
        let qos = QosConfig {
            idle_timeout: Some(Duration::from_millis(100)),
            ..QosConfig::default()
        };
        let server = TcpServer::bind_with("127.0.0.1:0", small_svc(), qos)
            .expect("bind");
        let addr = server.local_addr().unwrap();
        let handle = std::thread::spawn(move || server.run());
        // Connects, then never sends a byte.
        let idler = TcpSession::connect(&addr.to_string()).unwrap();
        let mut s = TcpSession::connect(&addr.to_string()).unwrap();
        let mut reaped = 0;
        for _ in 0..600 {
            reaped = s
                .stats()
                .unwrap()
                .get("idle_reaped")
                .unwrap()
                .as_i64()
                .unwrap();
            if reaped >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(reaped, 1, "idle connection was not reaped");
        // The reaped session is gone; the active one still serves.
        let mut rng = XorShift::new(29);
        let a = MatI8::random_bounded(&mut rng, 2, 6, 63);
        let w = MatI8::random(&mut rng, 6, 3);
        let id = s.submit(Job::Gemm { a, w }).unwrap();
        assert!(matches!(
            s.wait(id, Some(Duration::from_secs(60))).unwrap(),
            JobState::Done(_)
        ));
        drop(idler);
        s.shutdown().unwrap();
        handle.join().unwrap();
    }
}
