//! The versioned wire protocol and transport-agnostic front-end.
//!
//! The coordinator's [`crate::coordinator::Service`] is an in-process
//! API; this module puts the serving seam in front of it that a
//! deployment needs (the host-facing request interface the DPU runtime
//! and scalable-GEMM serving stacks separate from their array
//! schedulers):
//!
//! * [`message`] — typed [`Request`] / [`Response`] messages with a
//!   versioned JSON encoding (built on [`crate::util::json`]; no new
//!   dependencies);
//! * [`frame`] — the length-prefixed frame codec (4-byte big-endian
//!   length + JSON payload) with a typed failure taxonomy;
//! * [`session`] — the [`Session`] trait (submit/poll/wait/drain/
//!   stats/shutdown over `request`), the shared [`Frontend`]
//!   dispatcher, and the in-process [`LocalSession`];
//! * [`tcp`] — [`TcpSession`] / [`TcpServer`]: blocking socket threads
//!   feeding the same `Frontend`, so local and remote callers observe
//!   bit-identical behavior.
//!
//! Error philosophy: malformed frames and malformed payloads resolve
//! as typed [`Response::Error`]s on a still-open connection; bad job
//! shapes resolve as `Failed` handles exactly like the in-process API.
//! Nothing a client sends can panic the server or tear down another
//! client's session.
//!
//! Authority and overload: every connection is a tracked session with
//! a [`session::SessionBudget`] (inflight and queued-byte quotas,
//! deadline caps) enforced at admission — over-quota submits answer a
//! typed `overloaded` error with a retry-after hint, and the global
//! high-water gate sheds the largest unprivileged holder's work
//! deterministically before refusing a newcomer. `Drain` and
//! `Shutdown` are **operator verbs** (loopback peers by default, or
//! any session presenting the operator token via `Auth`); plain
//! sessions retire their own handles with `Poll`/`Wait`/`DrainMine` —
//! and *only* their own: redeeming a handle another session owns (or
//! one already retired) answers a typed `forbidden` error. A disconnecting client's
//! unredeemed results are forgotten and its mid-model work abandons
//! its arena residency — dropped, not leaked.

pub mod frame;
pub mod message;
pub mod session;
pub mod tcp;

pub use frame::{read_frame, write_frame, FrameError, MAX_FRAME_LEN};
pub use message::{
    ErrorCode, PollState, ProtoError, Request, Response, WireError,
    PROTO_VERSION,
};
pub use session::{
    Frontend, LocalSession, QosConfig, Session, SessionBudget,
    SessionError, SessionState,
};
pub use tcp::{TcpServer, TcpSession};
