//! The transport-agnostic front-end: a [`Session`] trait over the
//! request/response protocol, the shared [`Frontend`] dispatcher that
//! lowers requests onto a [`Service`], and the in-process
//! [`LocalSession`] implementation.
//!
//! Every transport speaks the same typed messages through the same
//! dispatcher, so `simulate`, the `serve` generator loop, and a TCP
//! client ([`super::tcp::TcpSession`]) are all "just clients": the
//! only difference is whether [`Request`]s cross a socket first.

use crate::coordinator::completion::{CompletionTable, JobHandle};
use crate::coordinator::{
    Batch, Job, JobId, JobResult, JobState, Metrics, Service, ServiceConfig,
};
use crate::proto::frame::FrameError;
use crate::proto::message::{
    PollState, ProtoError, Request, Response, WireError,
};
use crate::util::json::Json;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Why a session interaction failed. [`LocalSession`] never produces
/// transport errors; remote sessions surface frame/IO/decoding
/// failures and server-side [`WireError`]s uniformly.
#[derive(Debug)]
pub enum SessionError {
    /// The peer closed the connection.
    Closed,
    /// Transport-level failure.
    Io(std::io::Error),
    /// The response frame could not be read.
    Frame(FrameError),
    /// The response payload could not be decoded.
    Proto(ProtoError),
    /// The server answered with a typed error.
    Remote(WireError),
    /// The server answered with a well-formed response of the wrong
    /// kind for the request (protocol bug or version skew).
    Unexpected(&'static str),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Closed => write!(f, "connection closed by peer"),
            SessionError::Io(e) => write!(f, "i/o error: {e}"),
            SessionError::Frame(e) => write!(f, "frame error: {e}"),
            SessionError::Proto(e) => write!(f, "protocol error: {e}"),
            SessionError::Remote(e) => write!(f, "server error: {e}"),
            SessionError::Unexpected(tag) => {
                write!(f, "unexpected response kind `{tag}`")
            }
        }
    }
}

impl std::error::Error for SessionError {}

impl From<std::io::Error> for SessionError {
    fn from(e: std::io::Error) -> SessionError {
        SessionError::Io(e)
    }
}

impl From<FrameError> for SessionError {
    fn from(e: FrameError) -> SessionError {
        SessionError::Frame(e)
    }
}

impl From<ProtoError> for SessionError {
    fn from(e: ProtoError) -> SessionError {
        SessionError::Proto(e)
    }
}

fn timeout_ms(timeout: Option<Duration>) -> Option<u64> {
    timeout.map(|d| u64::try_from(d.as_millis()).unwrap_or(u64::MAX))
}

/// One client's view of a matrix-engine service, local or remote.
///
/// `request` is the only required method; the typed convenience
/// methods are defined on top of it, so every implementation shares
/// identical submit/wait/drain semantics.
pub trait Session {
    /// Issue one request and return the server's response. Transport
    /// failures are `Err`; server-side failures come back as
    /// `Ok(Response::Error(..))` (callers using the convenience
    /// methods get those lifted into [`SessionError::Remote`]).
    fn request(&mut self, req: Request) -> Result<Response, SessionError>;

    /// Submit one job; returns its handle id.
    fn submit(&mut self, job: Job) -> Result<u64, SessionError> {
        let req = match job {
            Job::Gemm { a, w } => Request::SubmitGemm { a, w },
            Job::Conv {
                input,
                weights,
                shape,
            } => Request::SubmitConv {
                input,
                weights,
                shape,
            },
            Job::SparseGemm { a, w } => Request::SubmitSparse {
                a,
                w,
                density: None,
            },
            Job::Model { model, input } => {
                Request::SubmitModel { model, input }
            }
            other => Request::SubmitBatch { jobs: vec![other] },
        };
        match self.request(req)? {
            Response::Handle { id } => Ok(id),
            Response::Handles { ids } if ids.len() == 1 => Ok(ids[0]),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }

    /// Submit a batch in one request; handle ids come back in job
    /// order (weight-tile reuse groups across the whole batch).
    fn submit_batch(
        &mut self,
        jobs: Vec<Job>,
    ) -> Result<Vec<u64>, SessionError> {
        match self.request(Request::SubmitBatch { jobs })? {
            Response::Handles { ids } => Ok(ids),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }

    /// Non-blocking redemption of one handle.
    fn poll(&mut self, id: u64) -> Result<JobState, SessionError> {
        state_of(self.request(Request::Poll { id })?)
    }

    /// Blocking redemption of one handle; `None` waits forever.
    fn wait(
        &mut self,
        id: u64,
        timeout: Option<Duration>,
    ) -> Result<JobState, SessionError> {
        state_of(self.request(Request::Wait {
            id,
            timeout_ms: timeout_ms(timeout),
        })?)
    }

    /// Retire everything outstanding (until done or `timeout`):
    /// completed results in arrival order plus failed handle ids.
    fn drain(
        &mut self,
        timeout: Option<Duration>,
    ) -> Result<(Vec<JobResult>, Vec<u64>), SessionError> {
        match self.request(Request::Drain {
            timeout_ms: timeout_ms(timeout),
        })? {
            Response::Drained { completed, failed } => Ok((completed, failed)),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }

    /// The service's metrics snapshot.
    fn stats(&mut self) -> Result<Json, SessionError> {
        match self.request(Request::Stats)? {
            Response::Metrics(snapshot) => Ok(snapshot),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }

    /// Gracefully shut the service down: drains every pending job
    /// first and returns the final metrics snapshot.
    fn shutdown(&mut self) -> Result<Json, SessionError> {
        match self.request(Request::Shutdown)? {
            Response::Metrics(snapshot) => Ok(snapshot),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }
}

fn state_of(resp: Response) -> Result<JobState, SessionError> {
    match resp {
        Response::Result(r) => Ok(JobState::Done(r)),
        Response::State(PollState::Pending) => Ok(JobState::Pending),
        Response::State(PollState::Failed) => Ok(JobState::Failed),
        Response::Error(e) => Err(SessionError::Remote(e)),
        other => Err(SessionError::Unexpected(other.tag())),
    }
}

/// The one request dispatcher every transport shares: lowers typed
/// [`Request`]s onto a [`Service`]. Submissions briefly lock the
/// service; redemptions go straight to the shared
/// [`CompletionTable`], so one client blocked in `Wait` never stalls
/// another client's `Submit`.
pub struct Frontend {
    svc: Mutex<Option<Service>>,
    completion: Arc<CompletionTable>,
    metrics: Arc<Metrics>,
}

impl Frontend {
    pub fn new(svc: Service) -> Frontend {
        let completion = svc.completion_table();
        let metrics = Arc::clone(&svc.metrics);
        Frontend {
            svc: Mutex::new(Some(svc)),
            completion,
            metrics,
        }
    }

    /// The service's shared metrics (valid before and after shutdown).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Abandon handles a disconnected session never redeemed: their
    /// results are dropped (now, or at retirement) instead of parked
    /// in the completion table forever. See
    /// [`CompletionTable::forget`].
    pub fn forget<I: IntoIterator<Item = u64>>(&self, ids: I) {
        let ids: Vec<JobId> = ids.into_iter().map(JobId).collect();
        if !ids.is_empty() {
            self.completion.forget(&ids);
        }
    }

    /// Put results a transport could not deliver back into the
    /// completion table: a `Drained` payload that exceeded the frame
    /// limit re-parks whole (the owner redeems it again in smaller
    /// pieces), while a single undeliverable `Result` is passed in
    /// `failed` so its handle resolves terminally as Failed instead of
    /// looping the client through identical oversize retries.
    pub fn repark(&self, completed: Vec<JobResult>, failed: Vec<u64>) {
        for r in completed {
            self.completion.complete(r);
        }
        for id in failed {
            self.completion.complete_failed(JobId(id));
        }
    }

    fn to_timeout(timeout_ms: Option<u64>) -> Duration {
        match timeout_ms {
            // PR 3 semantics: Duration::MAX = wait forever (the
            // completion table clamps the deadline, no overflow panic).
            None => Duration::MAX,
            Some(ms) => Duration::from_millis(ms),
        }
    }

    /// Handle one request. The bool asks the transport to close this
    /// session after replying (set only by `Shutdown`).
    pub fn handle(&self, req: Request) -> (Response, bool) {
        match req {
            Request::SubmitGemm { a, w } => {
                self.submit_jobs(vec![Job::Gemm { a, w }], false)
            }
            Request::SubmitConv {
                input,
                weights,
                shape,
            } => self.submit_jobs(
                vec![Job::Conv {
                    input,
                    weights,
                    shape,
                }],
                false,
            ),
            // The declared density is advisory metadata; the service
            // derives real skip decisions from the operands themselves.
            Request::SubmitSparse { a, w, density: _ } => {
                self.submit_jobs(vec![Job::SparseGemm { a, w }], false)
            }
            Request::SubmitModel { model, input } => {
                self.submit_jobs(vec![Job::Model { model, input }], false)
            }
            Request::SubmitBatch { jobs } => self.submit_jobs(jobs, true),
            Request::Poll { id } => (
                response_of(self.completion.poll(JobHandle { id: JobId(id) })),
                false,
            ),
            Request::Wait { id, timeout_ms } => (
                response_of(self.completion.wait(
                    JobHandle { id: JobId(id) },
                    Self::to_timeout(timeout_ms),
                )),
                false,
            ),
            Request::Drain { timeout_ms } => {
                let drained =
                    self.completion.drain(Self::to_timeout(timeout_ms));
                (
                    Response::Drained {
                        completed: drained.completed,
                        failed: drained
                            .failed
                            .iter()
                            .map(|id| id.0)
                            .collect(),
                    },
                    false,
                )
            }
            Request::Stats => {
                (Response::Metrics(self.metrics.snapshot_json()), false)
            }
            Request::Shutdown => self.shutdown(),
        }
    }

    fn submit_jobs(&self, jobs: Vec<Job>, many: bool) -> (Response, bool) {
        let mut guard = self.svc.lock().unwrap();
        let Some(svc) = guard.as_mut() else {
            return (Response::Error(WireError::unavailable()), false);
        };
        let handles = svc.submit_batch(Batch::from(jobs));
        let resp = if many {
            Response::Handles {
                ids: handles.iter().map(|h| h.id.0).collect(),
            }
        } else {
            Response::Handle {
                id: handles
                    .first()
                    .expect("one handle per submitted job")
                    .id
                    .0,
            }
        };
        (resp, false)
    }

    /// Take the service (first `Shutdown` wins), drain every pending
    /// job — unbounded, the graceful-exit contract — stop the worker
    /// pool, and ack with the final metrics snapshot. Unclaimed
    /// results are discarded with the drain; late requests get a
    /// typed `unavailable` error.
    fn shutdown(&self) -> (Response, bool) {
        let svc = self.svc.lock().unwrap().take();
        match svc {
            None => (Response::Error(WireError::unavailable()), true),
            Some(svc) => {
                let _ = svc.drain(Duration::MAX);
                let snapshot = self.metrics.snapshot_json();
                svc.shutdown();
                (Response::Metrics(snapshot), true)
            }
        }
    }
}

fn response_of(state: JobState) -> Response {
    match state {
        JobState::Done(r) => Response::Result(r),
        JobState::Pending => Response::State(PollState::Pending),
        JobState::Failed => Response::State(PollState::Failed),
    }
}

/// In-process session: wraps a [`Service`] behind the same protocol a
/// socket client speaks, with zero serialization. `simulate` and the
/// `serve` generator loop run on this.
pub struct LocalSession {
    frontend: Frontend,
}

impl LocalSession {
    /// Start a service and wrap it.
    pub fn start(cfg: ServiceConfig) -> LocalSession {
        LocalSession::from_service(Service::start(cfg))
    }

    /// Wrap an already-running service.
    pub fn from_service(svc: Service) -> LocalSession {
        LocalSession {
            frontend: Frontend::new(svc),
        }
    }

    /// The service's shared metrics.
    pub fn metrics(&self) -> &Arc<Metrics> {
        self.frontend.metrics()
    }
}

impl Session for LocalSession {
    fn request(&mut self, req: Request) -> Result<Response, SessionError> {
        let (resp, _close) = self.frontend.handle(req);
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::EngineKind;
    use crate::proto::message::ErrorCode;
    use crate::util::rng::XorShift;
    use crate::workload::conv::ConvShape;
    use crate::workload::gemm::golden_gemm;
    use crate::workload::MatI8;

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        }
    }

    #[test]
    fn local_session_serves_gemm_via_the_protocol() {
        let mut s = LocalSession::start(small_cfg());
        let mut rng = XorShift::new(3);
        let a = MatI8::random_bounded(&mut rng, 4, 13, 63);
        let w = MatI8::random(&mut rng, 13, 9);
        let id = s
            .submit(Job::Gemm {
                a: a.clone(),
                w: w.clone(),
            })
            .unwrap();
        let state = s.wait(id, Some(Duration::from_secs(60))).unwrap();
        let r = state.into_result().expect("job completes");
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.output, golden_gemm(&a, &w));
        // Redeeming again: taken, reports Pending.
        assert!(matches!(s.poll(id).unwrap(), JobState::Pending));
        let final_metrics = s.shutdown().unwrap();
        assert_eq!(
            final_metrics.get("jobs_completed").unwrap().as_i64(),
            Some(1)
        );
    }

    #[test]
    fn batch_submission_returns_handles_in_job_order() {
        let mut s = LocalSession::start(small_cfg());
        let mut rng = XorShift::new(11);
        let w = MatI8::random(&mut rng, 8, 5);
        let jobs: Vec<Job> = (0..3)
            .map(|_| Job::Gemm {
                a: MatI8::random_bounded(&mut rng, 2, 8, 63),
                w: w.clone(),
            })
            .collect();
        let ids = s.submit_batch(jobs).unwrap();
        assert_eq!(ids, vec![0, 1, 2]);
        let (completed, failed) =
            s.drain(Some(Duration::from_secs(60))).unwrap();
        assert_eq!(completed.len(), 3);
        assert!(failed.is_empty());
        s.shutdown().unwrap();
    }

    /// A sparse job submitted through the protocol lowers onto the
    /// skip-aware path and still verifies bit-identically against the
    /// densified golden product.
    #[test]
    fn local_session_serves_sparse_via_the_protocol() {
        use crate::workload::{CsrMatI8, NmPattern, SparseMatI8};
        let mut s = LocalSession::start(small_cfg());
        let mut rng = XorShift::new(23);
        let nm = NmPattern::new(2, 4).unwrap();
        let w =
            SparseMatI8::random_density(&mut rng, 13, 9, nm, 0.2, (6, 4));
        let a = CsrMatI8::random_density(&mut rng, 5, 13, 0.4);
        let id = s
            .submit(Job::SparseGemm {
                a: a.clone(),
                w: w.clone(),
            })
            .unwrap();
        let r = s
            .wait(id, Some(Duration::from_secs(60)))
            .unwrap()
            .into_result()
            .expect("sparse job completes");
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.output, golden_gemm(&a.to_dense(), &w.to_dense()));
        s.shutdown().unwrap();
    }

    /// Bad shapes resolve as typed `Failed` states through the
    /// protocol — no panic, and the session keeps serving.
    #[test]
    fn bad_shapes_resolve_failed_and_session_survives() {
        let mut s = LocalSession::start(small_cfg());
        let bad = ConvShape {
            in_c: 2,
            in_h: 5,
            in_w: 5,
            out_c: 3,
            k: 3,
            stride: 0, // zero stride: rejected at submit
            pad: 1,
            dilation: 1,
            groups: 1,
        };
        let id = s
            .submit(Job::Conv {
                input: vec![0; 50],
                weights: vec![0; 54],
                shape: bad,
            })
            .unwrap();
        assert!(matches!(
            s.wait(id, Some(Duration::from_secs(30))).unwrap(),
            JobState::Failed
        ));
        // Mismatched GEMM dims likewise.
        let id = s
            .submit(Job::Gemm {
                a: MatI8::zeros(4, 8),
                w: MatI8::zeros(7, 2),
            })
            .unwrap();
        assert!(matches!(
            s.wait(id, Some(Duration::from_secs(30))).unwrap(),
            JobState::Failed
        ));
        // Still serving.
        let mut rng = XorShift::new(5);
        let a = MatI8::random_bounded(&mut rng, 3, 6, 63);
        let w = MatI8::random(&mut rng, 6, 4);
        let id = s.submit(Job::Gemm { a, w }).unwrap();
        let r = s
            .wait(id, Some(Duration::from_secs(60)))
            .unwrap()
            .into_result()
            .expect("valid job completes after rejected ones");
        assert_eq!(r.verified, Some(true));
        s.shutdown().unwrap();
    }

    /// After shutdown every further request gets a typed
    /// `unavailable` error — never a panic.
    #[test]
    fn requests_after_shutdown_get_typed_errors() {
        let mut s = LocalSession::start(small_cfg());
        s.shutdown().unwrap();
        let err = s
            .submit(Job::Gemm {
                a: MatI8::zeros(2, 2),
                w: MatI8::zeros(2, 2),
            })
            .unwrap_err();
        match err {
            SessionError::Remote(e) => {
                assert_eq!(e.code, ErrorCode::Unavailable)
            }
            other => panic!("expected remote error, got {other}"),
        }
        // Stats still answer (metrics outlive the service).
        assert!(s.stats().is_ok());
    }

    /// Shutdown drains pending jobs before acking: the final snapshot
    /// accounts every submitted job.
    #[test]
    fn shutdown_drains_pending_jobs_first() {
        let mut s = LocalSession::start(ServiceConfig {
            workers: 1,
            ..small_cfg()
        });
        let mut rng = XorShift::new(17);
        for _ in 0..4 {
            let a = MatI8::random_bounded(&mut rng, 6, 40, 63);
            let w = MatI8::random(&mut rng, 40, 18);
            s.submit(Job::Gemm { a, w }).unwrap();
        }
        // No waits: shutdown itself must finish the pipeline.
        let final_metrics = s.shutdown().unwrap();
        assert_eq!(
            final_metrics.get("jobs_completed").unwrap().as_i64(),
            Some(4)
        );
        assert_eq!(
            final_metrics.get("jobs_failed").unwrap().as_i64(),
            Some(0)
        );
    }
}
