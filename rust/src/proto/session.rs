//! The transport-agnostic front-end: a [`Session`] trait over the
//! request/response protocol, the shared [`Frontend`] dispatcher that
//! lowers requests onto a [`Service`], and the in-process
//! [`LocalSession`] implementation.
//!
//! Every transport speaks the same typed messages through the same
//! dispatcher, so `simulate`, the `serve` generator loop, and a TCP
//! client ([`super::tcp::TcpSession`]) are all "just clients": the
//! only difference is whether [`Request`]s cross a socket first.
//!
//! The dispatcher is also the QoS boundary. Every transport opens a
//! [`SessionState`] per client; [`Frontend::handle`] tracks which
//! handles each session owns (handle ids are guessable, so redemption
//! is ownership-checked — a plain session polling someone else's
//! handle answers `forbidden`), enforces its [`SessionBudget`]
//! (inflight and queued-byte quotas, deadline caps), guards the
//! privileged verbs (`Drain`/`Shutdown`), and — when the global
//! high-water gate trips — sheds load deterministically
//! (largest unprivileged holder first), answering the offending
//! submit with a typed `overloaded` error carrying a retry-after hint
//! instead of accepting work the coordinator cannot retire.

use crate::coordinator::completion::{CompletionTable, JobHandle};
use crate::coordinator::{
    Batch, Job, JobId, JobResult, JobState, Metrics, Service, ServiceConfig,
};
use crate::proto::frame::FrameError;
use crate::proto::message::{
    PollState, ProtoError, Request, Response, WireError,
};
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Per-session admission quotas. Zero / `None` means unlimited — the
/// default budget changes nothing for existing single-tenant callers.
#[derive(Debug, Clone, Copy, Default)]
pub struct SessionBudget {
    /// Max unretired handles one session may hold (0 = unlimited).
    /// The N+1th submit over the quota is refused `overloaded`, with
    /// nothing enqueued.
    pub max_inflight: usize,
    /// Max operand bytes one session may have queued across its
    /// unretired jobs (0 = unlimited), measured by
    /// [`Job::cost_bytes`].
    pub max_queued_bytes: u64,
    /// Deadline cap on any blocking `Wait`/`Drain`/`DrainMine` a
    /// session issues: longer (or forever) timeouts are clamped to
    /// this many milliseconds, and an expiry under the cap counts as
    /// a deadline miss in [`Metrics`].
    pub deadline_ms: Option<u64>,
}

/// Server-side QoS policy: the per-session budget, the global
/// admission gate, and who may speak the operator verbs. The default
/// is fully permissive (no quotas, loopback peers privileged), so
/// every pre-QoS caller and test behaves exactly as before.
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// Budget applied to every session.
    pub budget: SessionBudget,
    /// Global high-water gate: max unretired handles across all
    /// sessions (0 = unlimited) — submitted but not yet redeemed, so
    /// it bounds queued work *and* parked results. When a submit
    /// would cross it, the largest unprivileged other session is shed
    /// first (privileged sessions are never shed); if no such victim
    /// exists, the submitter is refused `overloaded`.
    pub max_outstanding: usize,
    /// Operator token: a session that presents it via `Auth` becomes
    /// privileged. `None` = token auth disabled.
    pub operator_token: Option<String>,
    /// Whether loopback peers are privileged implicitly (on by
    /// default — the operator's own machine, and the pre-QoS
    /// behavior of every local test and smoke script).
    pub loopback_operator: bool,
    /// Idle read deadline on server connections: a client that sends
    /// nothing for this long is reaped (the slow-loris fix). `None` =
    /// wait forever.
    pub idle_timeout: Option<Duration>,
    /// The retry-after hint attached to `overloaded` errors.
    pub retry_after_ms: u64,
}

impl Default for QosConfig {
    fn default() -> QosConfig {
        QosConfig {
            budget: SessionBudget::default(),
            max_outstanding: 0,
            operator_token: None,
            loopback_operator: true,
            idle_timeout: None,
            retry_after_ms: 50,
        }
    }
}

/// Per-session ledger: which handles the session owns and what they
/// cost. Handles leave the ledger when redeemed terminally (`Done` /
/// `Failed` / `Shed`), drained, shed, or forgotten at disconnect.
#[derive(Debug, Default)]
struct Ledger {
    /// Owned handle id → operand cost in bytes.
    jobs: HashMap<u64, u64>,
    /// Handles evicted by admission control whose typed `Shed` marker
    /// the owner has not observed yet: they no longer count against
    /// quota, but they are still *owned* — redemption stays
    /// permitted for exactly this session until the marker is
    /// consumed (or the session disconnects).
    shed: HashSet<u64>,
    /// Sum of `jobs` values (kept incrementally; the quota check is
    /// on the submit hot path).
    queued_bytes: u64,
}

/// One transport client's identity and accounting, shared between the
/// connection (which redeems and submits through it) and the
/// [`Frontend`] registry (which sheds and reaps through it).
#[derive(Debug)]
pub struct SessionState {
    id: u64,
    privileged: AtomicBool,
    ledger: Mutex<Ledger>,
}

impl SessionState {
    /// This session's id (the key under `sessions` in the stats
    /// snapshot).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Whether this session may speak `Drain`/`Shutdown`.
    pub fn privileged(&self) -> bool {
        self.privileged.load(Ordering::Relaxed)
    }

    fn charge(&self, ids: &[(u64, u64)]) {
        let mut g = self.ledger.lock().unwrap();
        for &(id, cost) in ids {
            if g.jobs.insert(id, cost).is_none() {
                g.queued_bytes += cost;
            }
        }
    }

    fn release(&self, id: u64) {
        let mut g = self.ledger.lock().unwrap();
        if let Some(cost) = g.jobs.remove(&id) {
            g.queued_bytes -= cost;
        }
        g.shed.remove(&id);
    }

    fn release_many(&self, ids: &[u64]) {
        let mut g = self.ledger.lock().unwrap();
        for id in ids {
            if let Some(cost) = g.jobs.remove(id) {
                g.queued_bytes -= cost;
            }
            g.shed.remove(id);
        }
    }

    /// Whether this session may redeem handle `id`: unretired in the
    /// ledger, or a shed marker it has not observed yet.
    fn owns(&self, id: u64) -> bool {
        let g = self.ledger.lock().unwrap();
        g.jobs.contains_key(&id) || g.shed.contains(&id)
    }

    /// Take every owned handle (disconnect): the ledger — including
    /// unobserved shed markers — empties and the ids come back for
    /// the completion-table side.
    fn evict_all(&self) -> Vec<u64> {
        let mut g = self.ledger.lock().unwrap();
        g.queued_bytes = 0;
        let mut ids: Vec<u64> = g.jobs.drain().map(|(id, _)| id).collect();
        ids.extend(g.shed.drain());
        ids
    }

    /// Shed every unretired handle: the quota frees immediately, but
    /// the ids stay owned (moved to the shed set) so the victim can
    /// still redeem its typed `Shed` markers. Returns the shed ids.
    fn shed_all(&self) -> Vec<u64> {
        let mut g = self.ledger.lock().unwrap();
        g.queued_bytes = 0;
        let ids: Vec<u64> = g.jobs.drain().map(|(id, _)| id).collect();
        g.shed.extend(ids.iter().copied());
        ids
    }

    /// Unretired handles this session owns.
    pub fn inflight(&self) -> usize {
        self.ledger.lock().unwrap().jobs.len()
    }

    /// Operand bytes queued across this session's unretired jobs.
    pub fn queued_bytes(&self) -> u64 {
        self.ledger.lock().unwrap().queued_bytes
    }
}

/// Why a session interaction failed. [`LocalSession`] never produces
/// transport errors; remote sessions surface frame/IO/decoding
/// failures and server-side [`WireError`]s uniformly.
#[derive(Debug)]
pub enum SessionError {
    /// The peer closed the connection.
    Closed,
    /// Transport-level failure.
    Io(std::io::Error),
    /// The response frame could not be read.
    Frame(FrameError),
    /// The response payload could not be decoded.
    Proto(ProtoError),
    /// The server answered with a typed error.
    Remote(WireError),
    /// The server answered with a well-formed response of the wrong
    /// kind for the request (protocol bug or version skew).
    Unexpected(&'static str),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Closed => write!(f, "connection closed by peer"),
            SessionError::Io(e) => write!(f, "i/o error: {e}"),
            SessionError::Frame(e) => write!(f, "frame error: {e}"),
            SessionError::Proto(e) => write!(f, "protocol error: {e}"),
            SessionError::Remote(e) => write!(f, "server error: {e}"),
            SessionError::Unexpected(tag) => {
                write!(f, "unexpected response kind `{tag}`")
            }
        }
    }
}

impl std::error::Error for SessionError {}

impl From<std::io::Error> for SessionError {
    fn from(e: std::io::Error) -> SessionError {
        SessionError::Io(e)
    }
}

impl From<FrameError> for SessionError {
    fn from(e: FrameError) -> SessionError {
        SessionError::Frame(e)
    }
}

impl From<ProtoError> for SessionError {
    fn from(e: ProtoError) -> SessionError {
        SessionError::Proto(e)
    }
}

fn timeout_ms(timeout: Option<Duration>) -> Option<u64> {
    timeout.map(|d| u64::try_from(d.as_millis()).unwrap_or(u64::MAX))
}

/// One client's view of a matrix-engine service, local or remote.
///
/// `request` is the only required method; the typed convenience
/// methods are defined on top of it, so every implementation shares
/// identical submit/wait/drain semantics.
pub trait Session {
    /// Issue one request and return the server's response. Transport
    /// failures are `Err`; server-side failures come back as
    /// `Ok(Response::Error(..))` (callers using the convenience
    /// methods get those lifted into [`SessionError::Remote`]).
    fn request(&mut self, req: Request) -> Result<Response, SessionError>;

    /// Submit one job; returns its handle id.
    fn submit(&mut self, job: Job) -> Result<u64, SessionError> {
        let req = match job {
            Job::Gemm { a, w } => Request::SubmitGemm { a, w },
            Job::Conv {
                input,
                weights,
                shape,
            } => Request::SubmitConv {
                input,
                weights,
                shape,
            },
            Job::SparseGemm { a, w } => Request::SubmitSparse {
                a,
                w,
                density: None,
            },
            Job::Model { model, input } => {
                Request::SubmitModel { model, input }
            }
            other => Request::SubmitBatch { jobs: vec![other] },
        };
        match self.request(req)? {
            Response::Handle { id } => Ok(id),
            Response::Handles { ids } if ids.len() == 1 => Ok(ids[0]),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }

    /// Submit a batch in one request; handle ids come back in job
    /// order (weight-tile reuse groups across the whole batch).
    fn submit_batch(
        &mut self,
        jobs: Vec<Job>,
    ) -> Result<Vec<u64>, SessionError> {
        match self.request(Request::SubmitBatch { jobs })? {
            Response::Handles { ids } => Ok(ids),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }

    /// Non-blocking redemption of one handle.
    fn poll(&mut self, id: u64) -> Result<JobState, SessionError> {
        state_of(self.request(Request::Poll { id })?)
    }

    /// Blocking redemption of one handle; `None` waits forever.
    fn wait(
        &mut self,
        id: u64,
        timeout: Option<Duration>,
    ) -> Result<JobState, SessionError> {
        state_of(self.request(Request::Wait {
            id,
            timeout_ms: timeout_ms(timeout),
        })?)
    }

    /// Retire everything outstanding (until done or `timeout`):
    /// completed results in arrival order plus failed handle ids.
    fn drain(
        &mut self,
        timeout: Option<Duration>,
    ) -> Result<(Vec<JobResult>, Vec<u64>), SessionError> {
        match self.request(Request::Drain {
            timeout_ms: timeout_ms(timeout),
        })? {
            Response::Drained { completed, failed } => Ok((completed, failed)),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }

    /// Retire only this session's outstanding handles (until done or
    /// `timeout`): the unprivileged counterpart of [`Session::drain`].
    fn drain_mine(
        &mut self,
        timeout: Option<Duration>,
    ) -> Result<(Vec<JobResult>, Vec<u64>), SessionError> {
        match self.request(Request::DrainMine {
            timeout_ms: timeout_ms(timeout),
        })? {
            Response::Drained { completed, failed } => Ok((completed, failed)),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }

    /// Present the operator token; on success this session becomes
    /// privileged (may speak `Drain`/`Shutdown`).
    fn auth(&mut self, token: &str) -> Result<(), SessionError> {
        match self.request(Request::Auth {
            token: token.to_string(),
        })? {
            Response::Ok => Ok(()),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }

    /// The service's metrics snapshot.
    fn stats(&mut self) -> Result<Json, SessionError> {
        match self.request(Request::Stats)? {
            Response::Metrics(snapshot) => Ok(snapshot),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }

    /// Gracefully shut the service down: drains every pending job
    /// first and returns the final metrics snapshot.
    fn shutdown(&mut self) -> Result<Json, SessionError> {
        match self.request(Request::Shutdown)? {
            Response::Metrics(snapshot) => Ok(snapshot),
            Response::Error(e) => Err(SessionError::Remote(e)),
            other => Err(SessionError::Unexpected(other.tag())),
        }
    }
}

fn state_of(resp: Response) -> Result<JobState, SessionError> {
    match resp {
        Response::Result(r) => Ok(JobState::Done(r)),
        Response::State(PollState::Pending) => Ok(JobState::Pending),
        Response::State(PollState::Failed) => Ok(JobState::Failed),
        Response::State(PollState::Shed) => Ok(JobState::Shed),
        Response::Error(e) => Err(SessionError::Remote(e)),
        other => Err(SessionError::Unexpected(other.tag())),
    }
}

/// The one request dispatcher every transport shares: lowers typed
/// [`Request`]s onto a [`Service`]. Submissions briefly lock the
/// service; redemptions go straight to the shared
/// [`CompletionTable`], so one client blocked in `Wait` never stalls
/// another client's `Submit`.
///
/// The frontend is also the admission controller: every request
/// arrives attributed to a [`SessionState`], quotas are enforced
/// before anything is enqueued, and the global high-water gate sheds
/// the largest unprivileged other session's work before refusing a
/// submitter.
pub struct Frontend {
    svc: Mutex<Option<Service>>,
    completion: Arc<CompletionTable>,
    metrics: Arc<Metrics>,
    qos: QosConfig,
    /// Registry of live sessions keyed by id. Ids are allocated in
    /// arrival order, so iteration order is session age — ties in
    /// the shed-victim choice break toward the oldest session,
    /// keeping selection deterministic.
    sessions: Mutex<BTreeMap<u64, Arc<SessionState>>>,
    next_session: AtomicU64,
}

impl Frontend {
    pub fn new(svc: Service) -> Frontend {
        Frontend::with_qos(svc, QosConfig::default())
    }

    /// Wrap a service under an explicit QoS policy.
    pub fn with_qos(svc: Service, qos: QosConfig) -> Frontend {
        let completion = svc.completion_table();
        let metrics = Arc::clone(&svc.metrics);
        Frontend {
            svc: Mutex::new(Some(svc)),
            completion,
            metrics,
            qos,
            sessions: Mutex::new(BTreeMap::new()),
            next_session: AtomicU64::new(1),
        }
    }

    /// The service's shared metrics (valid before and after shutdown).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// The QoS policy this frontend enforces.
    pub fn qos(&self) -> &QosConfig {
        &self.qos
    }

    /// Register a new session. `privileged` grants the operator verbs
    /// (`Drain`/`Shutdown`) and exempts the session from quotas;
    /// transports pass it for loopback peers (when
    /// [`QosConfig::loopback_operator`] allows) and it can be earned
    /// later via `Auth`.
    pub fn open_session(&self, privileged: bool) -> Arc<SessionState> {
        let id = self.next_session.fetch_add(1, Ordering::Relaxed);
        let sess = Arc::new(SessionState {
            id,
            privileged: AtomicBool::new(privileged),
            ledger: Mutex::new(Ledger::default()),
        });
        self.sessions.lock().unwrap().insert(id, Arc::clone(&sess));
        sess
    }

    /// Retire a disconnected session: mid-model work abandons its
    /// arena residency, every unredeemed handle is forgotten, and the
    /// session leaves the registry. Safe to call after shutdown.
    pub fn close_session(&self, sess: &Arc<SessionState>) {
        self.sessions.lock().unwrap().remove(&sess.id);
        // Reap the session's metrics aggregation too: connection
        // churn must not grow the server's memory for its lifetime.
        self.metrics.remove_session(sess.id);
        let ids: Vec<JobId> =
            sess.evict_all().into_iter().map(JobId).collect();
        if ids.is_empty() {
            return;
        }
        if let Some(svc) = self.svc.lock().unwrap().as_ref() {
            svc.abandon_jobs(&ids);
        }
        self.completion.forget(&ids);
    }

    /// Abandon handles a disconnected session never redeemed: their
    /// results are dropped (now, or at retirement) instead of parked
    /// in the completion table forever. See
    /// [`CompletionTable::forget`].
    pub fn forget<I: IntoIterator<Item = u64>>(&self, ids: I) {
        let ids: Vec<JobId> = ids.into_iter().map(JobId).collect();
        if !ids.is_empty() {
            self.completion.forget(&ids);
        }
    }

    /// Put results a transport could not deliver back into the
    /// completion table: a `Drained` payload that exceeded the frame
    /// limit re-parks whole (the owner redeems it again in smaller
    /// pieces), while a single undeliverable `Result` is passed in
    /// `failed` so its handle resolves terminally as Failed instead of
    /// looping the client through identical oversize retries.
    ///
    /// Re-parked state must stay redeemable by the session it was
    /// taken from: its ledger entries were released when the response
    /// was assembled, so ownership is restored here (at zero
    /// queued-byte cost — the operands are long gone) before the
    /// redemption ownership check would refuse the retry.
    pub fn repark(
        &self,
        sess: &SessionState,
        completed: Vec<JobResult>,
        failed: Vec<u64>,
    ) {
        if !sess.privileged() {
            let charges: Vec<(u64, u64)> = completed
                .iter()
                .map(|r| (r.id.0, 0))
                .chain(failed.iter().map(|&id| (id, 0)))
                .collect();
            sess.charge(&charges);
        }
        for r in completed {
            self.completion.complete(r);
        }
        for id in failed {
            self.completion.complete_failed(JobId(id));
        }
    }

    fn to_timeout(timeout_ms: Option<u64>) -> Duration {
        match timeout_ms {
            // PR 3 semantics: Duration::MAX = wait forever (the
            // completion table clamps the deadline, no overflow panic).
            None => Duration::MAX,
            Some(ms) => Duration::from_millis(ms),
        }
    }

    /// Clamp a requested blocking timeout to the session deadline cap
    /// (plain sessions only). Returns the effective timeout and
    /// whether the cap was the binding bound — when it was and the
    /// wait still expires, that is a deadline miss.
    fn capped_timeout(
        &self,
        sess: &SessionState,
        timeout_ms: Option<u64>,
    ) -> (Duration, bool) {
        let requested = Self::to_timeout(timeout_ms);
        match self.qos.budget.deadline_ms {
            Some(ms)
                if !sess.privileged()
                    && requested > Duration::from_millis(ms) =>
            {
                (Duration::from_millis(ms), true)
            }
            _ => (requested, false),
        }
    }

    /// Retire a redeemed handle from the session ledger and record
    /// its latency; terminal states free quota, `Pending` does not.
    fn settle(&self, sess: &SessionState, id: u64, state: &JobState) {
        match state {
            JobState::Done(r) => {
                sess.release(id);
                self.metrics.record_session_latency(sess.id, r.wall);
            }
            JobState::Failed | JobState::Shed => sess.release(id),
            JobState::Pending => {}
        }
    }

    /// Handle one request from `sess`. The bool asks the transport to
    /// close this session after replying (set only by `Shutdown`).
    pub fn handle(
        &self,
        req: Request,
        sess: &Arc<SessionState>,
    ) -> (Response, bool) {
        match req {
            Request::SubmitGemm { a, w } => {
                self.submit_jobs(vec![Job::Gemm { a, w }], false, sess)
            }
            Request::SubmitConv {
                input,
                weights,
                shape,
            } => self.submit_jobs(
                vec![Job::Conv {
                    input,
                    weights,
                    shape,
                }],
                false,
                sess,
            ),
            // The declared density is advisory metadata; the service
            // derives real skip decisions from the operands themselves.
            Request::SubmitSparse { a, w, density: _ } => {
                self.submit_jobs(vec![Job::SparseGemm { a, w }], false, sess)
            }
            Request::SubmitModel { model, input } => self.submit_jobs(
                vec![Job::Model { model, input }],
                false,
                sess,
            ),
            Request::SubmitBatch { jobs } => {
                self.submit_jobs(jobs, true, sess)
            }
            Request::Poll { id } => {
                if let Some(err) = self.ownership_error(sess, id) {
                    return (Response::Error(err), false);
                }
                let state = self.completion.poll(JobHandle { id: JobId(id) });
                self.settle(sess, id, &state);
                (response_of(state), false)
            }
            Request::Wait { id, timeout_ms } => {
                if let Some(err) = self.ownership_error(sess, id) {
                    return (Response::Error(err), false);
                }
                let (timeout, capped) = self.capped_timeout(sess, timeout_ms);
                let state = self
                    .completion
                    .wait(JobHandle { id: JobId(id) }, timeout);
                if capped && matches!(state, JobState::Pending) {
                    self.metrics.record_deadline_miss(sess.id);
                }
                self.settle(sess, id, &state);
                (response_of(state), false)
            }
            Request::Drain { timeout_ms } => {
                if !sess.privileged() {
                    return (
                        Response::Error(WireError::forbidden(
                            "drain is an operator verb; plain sessions \
                             retire their own work with drain-mine",
                        )),
                        false,
                    );
                }
                let drained =
                    self.completion.drain(Self::to_timeout(timeout_ms));
                // A global drain retires handles of every session.
                let mut ids: Vec<u64> =
                    drained.completed.iter().map(|r| r.id.0).collect();
                ids.extend(drained.failed.iter().map(|id| id.0));
                let live: Vec<Arc<SessionState>> = self
                    .sessions
                    .lock()
                    .unwrap()
                    .values()
                    .cloned()
                    .collect();
                for s in live {
                    s.release_many(&ids);
                }
                (
                    Response::Drained {
                        completed: drained.completed,
                        failed: drained
                            .failed
                            .iter()
                            .map(|id| id.0)
                            .collect(),
                    },
                    false,
                )
            }
            Request::DrainMine { timeout_ms } => {
                let (timeout, capped) = self.capped_timeout(sess, timeout_ms);
                let mine: Vec<JobId> = {
                    // Shed markers are owned terminal state too: a
                    // drain-mine consumes them along with live work.
                    let g = sess.ledger.lock().unwrap();
                    g.jobs
                        .keys()
                        .chain(g.shed.iter())
                        .map(|&id| JobId(id))
                        .collect()
                };
                let drained = self.completion.drain_ids(&mine, timeout);
                let retired =
                    drained.completed.len() + drained.failed.len();
                if capped && retired < mine.len() {
                    self.metrics.record_deadline_miss(sess.id);
                }
                let mut ids: Vec<u64> = Vec::with_capacity(retired);
                for r in &drained.completed {
                    ids.push(r.id.0);
                    self.metrics.record_session_latency(sess.id, r.wall);
                }
                ids.extend(drained.failed.iter().map(|id| id.0));
                sess.release_many(&ids);
                (
                    Response::Drained {
                        completed: drained.completed,
                        failed: drained
                            .failed
                            .iter()
                            .map(|id| id.0)
                            .collect(),
                    },
                    false,
                )
            }
            Request::Auth { token } => (self.auth(sess, &token), false),
            Request::Stats => {
                (Response::Metrics(self.stats_snapshot()), false)
            }
            Request::Shutdown => {
                if !sess.privileged() {
                    return (
                        Response::Error(WireError::forbidden(
                            "shutdown is an operator verb",
                        )),
                        false,
                    );
                }
                self.shutdown()
            }
        }
    }

    /// Redemption ownership check. Handle ids are globally sequential
    /// and therefore guessable, so `Poll`/`Wait` only redeem handles
    /// the requesting session owns (live in its ledger, or its own
    /// unobserved shed markers). Without this, a hostile session
    /// could steal another's parked result — and because settling
    /// releases from the *thief's* ledger (a no-op), the victim's
    /// quota would stay consumed forever. Privileged sessions are
    /// exempt: the operator may inspect any handle.
    fn ownership_error(
        &self,
        sess: &SessionState,
        id: u64,
    ) -> Option<WireError> {
        if sess.privileged() || sess.owns(id) {
            None
        } else {
            Some(WireError::forbidden(format!(
                "handle {id} is not owned by this session"
            )))
        }
    }

    /// Per-session quota check (privileged sessions are exempt):
    /// refuse with a typed `overloaded` error before anything is
    /// enqueued, so the N+1th over-quota submit costs the coordinator
    /// nothing.
    fn admission_error(
        &self,
        sess: &SessionState,
        incoming: usize,
        cost: u64,
    ) -> Option<WireError> {
        if sess.privileged() {
            return None;
        }
        let b = &self.qos.budget;
        if b.max_inflight > 0 && sess.inflight() + incoming > b.max_inflight
        {
            return Some(WireError::overloaded(
                format!(
                    "session inflight quota exceeded ({} held, {} max)",
                    sess.inflight(),
                    b.max_inflight
                ),
                self.qos.retry_after_ms,
            ));
        }
        if b.max_queued_bytes > 0
            && sess.queued_bytes() + cost > b.max_queued_bytes
        {
            return Some(WireError::overloaded(
                format!(
                    "session queued-byte quota exceeded \
                     ({} queued + {} new, {} max)",
                    sess.queued_bytes(),
                    cost,
                    b.max_queued_bytes
                ),
                self.qos.retry_after_ms,
            ));
        }
        None
    }

    /// Enforce the global high-water gate while holding the service
    /// lock: sheds other sessions until the incoming jobs fit.
    /// Returns false when the gate still cannot admit them.
    ///
    /// Victim policy: the **largest unprivileged** holder of inflight
    /// work (ties break toward the oldest session id, keeping
    /// selection deterministic). Privileged sessions are never shed —
    /// if only they hold work, the submitter is refused instead. And
    /// preferring the largest holder means a hostile newcomer cannot
    /// repeatedly evict a small compliant session while staying under
    /// its own quota: the flooder *is* the largest holder.
    fn clear_backlog(
        &self,
        svc: &Service,
        incoming: usize,
        sess: &SessionState,
    ) -> bool {
        let max = self.qos.max_outstanding;
        if max == 0 {
            return true;
        }
        // Unretired handles across every session's ledger: the
        // deterministic load measure (worker progress does not race
        // the admission decision, so fault campaigns replay exactly).
        let outstanding = || -> usize {
            let g = self.sessions.lock().unwrap();
            g.values().map(|s| s.inflight()).sum()
        };
        loop {
            if outstanding() + incoming <= max {
                return true;
            }
            let victim = {
                let g = self.sessions.lock().unwrap();
                let mut best: Option<&Arc<SessionState>> = None;
                let mut best_inflight = 0usize;
                for s in g.values() {
                    if s.id == sess.id || s.privileged() {
                        continue;
                    }
                    let inflight = s.inflight();
                    if inflight > best_inflight {
                        best_inflight = inflight;
                        best = Some(s);
                    }
                }
                best.cloned()
            };
            let Some(victim) = victim else { return false };
            self.shed_session(svc, &victim);
        }
    }

    /// Force-retire everything a session owns: mid-model jobs abandon
    /// their arena residency, parked results drop, and the victim's
    /// next redemption of any of these handles answers `Shed` (the
    /// ids stay in the victim's shed set, so redemption remains
    /// permitted for it alone until each marker is observed).
    fn shed_session(&self, svc: &Service, victim: &SessionState) {
        let ids: Vec<JobId> =
            victim.shed_all().into_iter().map(JobId).collect();
        if ids.is_empty() {
            return;
        }
        svc.abandon_jobs(&ids);
        let n = self.completion.shed(&ids);
        self.metrics.record_shed(victim.id, n as u64);
    }

    fn auth(&self, sess: &SessionState, token: &str) -> Response {
        match &self.qos.operator_token {
            Some(expect) if token_eq(expect, token) => {
                sess.privileged.store(true, Ordering::Relaxed);
                Response::Ok
            }
            Some(_) => Response::Error(WireError::forbidden(
                "operator token mismatch",
            )),
            None => Response::Error(WireError::forbidden(
                "token auth is not enabled on this server",
            )),
        }
    }

    /// The metrics snapshot plus live completion-table telemetry —
    /// the leak counters the chaos harness asserts on after a fault
    /// campaign.
    fn stats_snapshot(&self) -> Json {
        let mut snap = self.metrics.snapshot_json();
        if let Json::Object(map) = &mut snap {
            map.insert(
                "pending_handles".to_string(),
                Json::uint(self.completion.live_pending() as u64),
            );
            map.insert(
                "shed_unobserved".to_string(),
                Json::uint(self.completion.shed_count() as u64),
            );
            let sessions = self.sessions.lock().unwrap();
            map.insert(
                "open_sessions".to_string(),
                Json::uint(sessions.len() as u64),
            );
            map.insert(
                "queued_bytes_now".to_string(),
                Json::uint(
                    sessions.values().map(|s| s.queued_bytes()).sum(),
                ),
            );
        }
        snap
    }

    fn submit_jobs(
        &self,
        jobs: Vec<Job>,
        many: bool,
        sess: &Arc<SessionState>,
    ) -> (Response, bool) {
        let costs: Vec<u64> = jobs.iter().map(Job::cost_bytes).collect();
        let total_cost: u64 = costs.iter().sum();
        // Quota check, high-water gate, and the ledger charge all run
        // under the service lock: submits serialize here, so two
        // racing over-quota submits cannot both pass the check, and a
        // concurrent submitter's `clear_backlog` cannot slip between
        // `submit_batch` and the charge to undercount outstanding
        // work and admit past `max_outstanding`.
        let mut guard = self.svc.lock().unwrap();
        let Some(svc) = guard.as_mut() else {
            return (Response::Error(WireError::unavailable()), false);
        };
        if let Some(err) =
            self.admission_error(sess, jobs.len(), total_cost)
        {
            self.metrics.record_admission_rejected(sess.id);
            return (Response::Error(err), false);
        }
        if !self.clear_backlog(svc, jobs.len(), sess) {
            self.metrics.record_admission_rejected(sess.id);
            return (
                Response::Error(WireError::overloaded(
                    "coordinator at high water and no other session \
                     to shed; retry later",
                    self.qos.retry_after_ms,
                )),
                false,
            );
        }
        let handles = svc.submit_batch(Batch::from(jobs));
        let charges: Vec<(u64, u64)> = handles
            .iter()
            .zip(&costs)
            .map(|(h, &c)| (h.id.0, c))
            .collect();
        sess.charge(&charges);
        drop(guard);
        self.metrics
            .record_session_submitted(sess.id, handles.len() as u64);
        let resp = if many {
            Response::Handles {
                ids: handles.iter().map(|h| h.id.0).collect(),
            }
        } else {
            Response::Handle {
                id: handles
                    .first()
                    .expect("one handle per submitted job")
                    .id
                    .0,
            }
        };
        (resp, false)
    }

    /// Take the service (first `Shutdown` wins), drain every pending
    /// job — unbounded, the graceful-exit contract — stop the worker
    /// pool, and ack with the final metrics snapshot. Unclaimed
    /// results are discarded with the drain; late requests get a
    /// typed `unavailable` error.
    fn shutdown(&self) -> (Response, bool) {
        let svc = self.svc.lock().unwrap().take();
        match svc {
            None => (Response::Error(WireError::unavailable()), true),
            Some(svc) => {
                let _ = svc.drain(Duration::MAX);
                let snapshot = self.stats_snapshot();
                svc.shutdown();
                (Response::Metrics(snapshot), true)
            }
        }
    }
}

/// Constant-time token comparison. Every byte of the presented token
/// is folded into one accumulator (indexing the expected token
/// cyclically) together with the length difference, so the check
/// neither short-circuits on the first mismatching byte nor varies
/// with how long a prefix matched — response timing depends only on
/// the length of the *presented* token, leaking nothing about the
/// operator token's bytes.
fn token_eq(expect: &str, got: &str) -> bool {
    let e = expect.as_bytes();
    let g = got.as_bytes();
    if e.is_empty() {
        return g.is_empty();
    }
    let mut diff = e.len() ^ g.len();
    for (i, &b) in g.iter().enumerate() {
        diff |= usize::from(b ^ e[i % e.len()]);
    }
    diff == 0
}

fn response_of(state: JobState) -> Response {
    match state {
        JobState::Done(r) => Response::Result(r),
        JobState::Pending => Response::State(PollState::Pending),
        JobState::Failed => Response::State(PollState::Failed),
        JobState::Shed => Response::State(PollState::Shed),
    }
}

/// In-process session: wraps a [`Service`] behind the same protocol a
/// socket client speaks, with zero serialization. `simulate` and the
/// `serve` generator loop run on this. The in-process caller owns the
/// service, so its session is privileged.
pub struct LocalSession {
    frontend: Frontend,
    sess: Arc<SessionState>,
}

impl LocalSession {
    /// Start a service and wrap it.
    pub fn start(cfg: ServiceConfig) -> LocalSession {
        LocalSession::from_service(Service::start(cfg))
    }

    /// Wrap an already-running service.
    pub fn from_service(svc: Service) -> LocalSession {
        let frontend = Frontend::new(svc);
        let sess = frontend.open_session(true);
        LocalSession { frontend, sess }
    }

    /// The service's shared metrics.
    pub fn metrics(&self) -> &Arc<Metrics> {
        self.frontend.metrics()
    }
}

impl Session for LocalSession {
    fn request(&mut self, req: Request) -> Result<Response, SessionError> {
        let (resp, _close) = self.frontend.handle(req, &self.sess);
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::EngineKind;
    use crate::proto::message::ErrorCode;
    use crate::util::rng::XorShift;
    use crate::workload::conv::ConvShape;
    use crate::workload::gemm::golden_gemm;
    use crate::workload::MatI8;

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        }
    }

    #[test]
    fn local_session_serves_gemm_via_the_protocol() {
        let mut s = LocalSession::start(small_cfg());
        let mut rng = XorShift::new(3);
        let a = MatI8::random_bounded(&mut rng, 4, 13, 63);
        let w = MatI8::random(&mut rng, 13, 9);
        let id = s
            .submit(Job::Gemm {
                a: a.clone(),
                w: w.clone(),
            })
            .unwrap();
        let state = s.wait(id, Some(Duration::from_secs(60))).unwrap();
        let r = state.into_result().expect("job completes");
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.output, golden_gemm(&a, &w));
        // Redeeming again: taken, reports Pending.
        assert!(matches!(s.poll(id).unwrap(), JobState::Pending));
        let final_metrics = s.shutdown().unwrap();
        assert_eq!(
            final_metrics.get("jobs_completed").unwrap().as_i64(),
            Some(1)
        );
    }

    #[test]
    fn batch_submission_returns_handles_in_job_order() {
        let mut s = LocalSession::start(small_cfg());
        let mut rng = XorShift::new(11);
        let w = MatI8::random(&mut rng, 8, 5);
        let jobs: Vec<Job> = (0..3)
            .map(|_| Job::Gemm {
                a: MatI8::random_bounded(&mut rng, 2, 8, 63),
                w: w.clone(),
            })
            .collect();
        let ids = s.submit_batch(jobs).unwrap();
        assert_eq!(ids, vec![0, 1, 2]);
        let (completed, failed) =
            s.drain(Some(Duration::from_secs(60))).unwrap();
        assert_eq!(completed.len(), 3);
        assert!(failed.is_empty());
        s.shutdown().unwrap();
    }

    /// A sparse job submitted through the protocol lowers onto the
    /// skip-aware path and still verifies bit-identically against the
    /// densified golden product.
    #[test]
    fn local_session_serves_sparse_via_the_protocol() {
        use crate::workload::{CsrMatI8, NmPattern, SparseMatI8};
        let mut s = LocalSession::start(small_cfg());
        let mut rng = XorShift::new(23);
        let nm = NmPattern::new(2, 4).unwrap();
        let w =
            SparseMatI8::random_density(&mut rng, 13, 9, nm, 0.2, (6, 4));
        let a = CsrMatI8::random_density(&mut rng, 5, 13, 0.4);
        let id = s
            .submit(Job::SparseGemm {
                a: a.clone(),
                w: w.clone(),
            })
            .unwrap();
        let r = s
            .wait(id, Some(Duration::from_secs(60)))
            .unwrap()
            .into_result()
            .expect("sparse job completes");
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.output, golden_gemm(&a.to_dense(), &w.to_dense()));
        s.shutdown().unwrap();
    }

    /// Bad shapes resolve as typed `Failed` states through the
    /// protocol — no panic, and the session keeps serving.
    #[test]
    fn bad_shapes_resolve_failed_and_session_survives() {
        let mut s = LocalSession::start(small_cfg());
        let bad = ConvShape {
            in_c: 2,
            in_h: 5,
            in_w: 5,
            out_c: 3,
            k: 3,
            stride: 0, // zero stride: rejected at submit
            pad: 1,
            dilation: 1,
            groups: 1,
        };
        let id = s
            .submit(Job::Conv {
                input: vec![0; 50],
                weights: vec![0; 54],
                shape: bad,
            })
            .unwrap();
        assert!(matches!(
            s.wait(id, Some(Duration::from_secs(30))).unwrap(),
            JobState::Failed
        ));
        // Mismatched GEMM dims likewise.
        let id = s
            .submit(Job::Gemm {
                a: MatI8::zeros(4, 8),
                w: MatI8::zeros(7, 2),
            })
            .unwrap();
        assert!(matches!(
            s.wait(id, Some(Duration::from_secs(30))).unwrap(),
            JobState::Failed
        ));
        // Still serving.
        let mut rng = XorShift::new(5);
        let a = MatI8::random_bounded(&mut rng, 3, 6, 63);
        let w = MatI8::random(&mut rng, 6, 4);
        let id = s.submit(Job::Gemm { a, w }).unwrap();
        let r = s
            .wait(id, Some(Duration::from_secs(60)))
            .unwrap()
            .into_result()
            .expect("valid job completes after rejected ones");
        assert_eq!(r.verified, Some(true));
        s.shutdown().unwrap();
    }

    /// After shutdown every further request gets a typed
    /// `unavailable` error — never a panic.
    #[test]
    fn requests_after_shutdown_get_typed_errors() {
        let mut s = LocalSession::start(small_cfg());
        s.shutdown().unwrap();
        let err = s
            .submit(Job::Gemm {
                a: MatI8::zeros(2, 2),
                w: MatI8::zeros(2, 2),
            })
            .unwrap_err();
        match err {
            SessionError::Remote(e) => {
                assert_eq!(e.code, ErrorCode::Unavailable)
            }
            other => panic!("expected remote error, got {other}"),
        }
        // Stats still answer (metrics outlive the service).
        assert!(s.stats().is_ok());
    }

    /// Shutdown drains pending jobs before acking: the final snapshot
    /// accounts every submitted job.
    #[test]
    fn shutdown_drains_pending_jobs_first() {
        let mut s = LocalSession::start(ServiceConfig {
            workers: 1,
            ..small_cfg()
        });
        let mut rng = XorShift::new(17);
        for _ in 0..4 {
            let a = MatI8::random_bounded(&mut rng, 6, 40, 63);
            let w = MatI8::random(&mut rng, 40, 18);
            s.submit(Job::Gemm { a, w }).unwrap();
        }
        // No waits: shutdown itself must finish the pipeline.
        let final_metrics = s.shutdown().unwrap();
        assert_eq!(
            final_metrics.get("jobs_completed").unwrap().as_i64(),
            Some(4)
        );
        assert_eq!(
            final_metrics.get("jobs_failed").unwrap().as_i64(),
            Some(0)
        );
    }

    fn gemm_req(rng: &mut XorShift) -> Request {
        let a = MatI8::random_bounded(rng, 2, 6, 63);
        let w = MatI8::random(rng, 6, 4);
        Request::SubmitGemm { a, w }
    }

    /// The N+1th submit over the inflight quota is refused with a
    /// typed `overloaded` error (retry hint attached) and enqueues
    /// nothing; retiring one handle frees exactly one slot.
    #[test]
    fn inflight_quota_is_exact() {
        let qos = QosConfig {
            budget: SessionBudget {
                max_inflight: 3,
                ..SessionBudget::default()
            },
            ..QosConfig::default()
        };
        let frontend =
            Frontend::with_qos(Service::start(small_cfg()), qos);
        let sess = frontend.open_session(false);
        let mut rng = XorShift::new(7);
        let mut ids = Vec::new();
        for _ in 0..3 {
            match frontend.handle(gemm_req(&mut rng), &sess).0 {
                Response::Handle { id } => ids.push(id),
                other => panic!("expected handle, got {}", other.tag()),
            }
        }
        match frontend.handle(gemm_req(&mut rng), &sess).0 {
            Response::Error(e) => {
                assert_eq!(e.code, ErrorCode::Overloaded);
                assert!(e.retry_after_ms.is_some());
            }
            other => panic!("expected overloaded, got {}", other.tag()),
        }
        // Retire one; the freed slot admits the retry.
        assert!(matches!(
            frontend
                .handle(
                    Request::Wait {
                        id: ids[0],
                        timeout_ms: Some(60_000),
                    },
                    &sess,
                )
                .0,
            Response::Result(_)
        ));
        assert!(matches!(
            frontend.handle(gemm_req(&mut rng), &sess).0,
            Response::Handle { .. }
        ));
        let snap = frontend.metrics().snapshot_json();
        assert_eq!(
            snap.get("admission_rejected").unwrap().as_i64(),
            Some(1)
        );
        let op = frontend.open_session(true);
        frontend.handle(Request::Shutdown, &op);
    }

    /// `Drain`/`Shutdown` answer `forbidden` to plain sessions; the
    /// operator token earns the privilege mid-session via `Auth`.
    #[test]
    fn operator_verbs_are_scoped_and_earned_by_token() {
        let qos = QosConfig {
            operator_token: Some("sesame".to_string()),
            ..QosConfig::default()
        };
        let frontend =
            Frontend::with_qos(Service::start(small_cfg()), qos);
        let sess = frontend.open_session(false);
        for req in [
            Request::Drain {
                timeout_ms: Some(0),
            },
            Request::Shutdown,
        ] {
            match frontend.handle(req, &sess).0 {
                Response::Error(e) => {
                    assert_eq!(e.code, ErrorCode::Forbidden)
                }
                other => {
                    panic!("expected forbidden, got {}", other.tag())
                }
            }
        }
        // Wrong token: still plain.
        match frontend
            .handle(
                Request::Auth {
                    token: "guess".to_string(),
                },
                &sess,
            )
            .0
        {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::Forbidden),
            other => panic!("expected forbidden, got {}", other.tag()),
        }
        // Right token: the same session may now shut the service down.
        assert!(matches!(
            frontend
                .handle(
                    Request::Auth {
                        token: "sesame".to_string(),
                    },
                    &sess,
                )
                .0,
            Response::Ok
        ));
        assert!(matches!(
            frontend.handle(Request::Shutdown, &sess).0,
            Response::Metrics(_)
        ));
    }

    /// Crossing the global high-water gate sheds the largest
    /// unprivileged holder deterministically, admits the newcomer,
    /// and the victim's redemptions answer typed `Shed` instead of
    /// hanging.
    #[test]
    fn high_water_gate_sheds_the_largest_plain_session() {
        let qos = QosConfig {
            max_outstanding: 4,
            ..QosConfig::default()
        };
        let frontend =
            Frontend::with_qos(Service::start(small_cfg()), qos);
        let old = frontend.open_session(false);
        let newer = frontend.open_session(false);
        let mut rng = XorShift::new(31);
        let mut old_ids = Vec::new();
        for _ in 0..4 {
            match frontend.handle(gemm_req(&mut rng), &old).0 {
                Response::Handle { id } => old_ids.push(id),
                other => panic!("expected handle, got {}", other.tag()),
            }
        }
        // The newcomer's submit trips the gate: old is shed, the
        // newcomer lands.
        let id = match frontend.handle(gemm_req(&mut rng), &newer).0 {
            Response::Handle { id } => id,
            other => panic!("expected handle, got {}", other.tag()),
        };
        // The shed victim's waits resolve terminally — no hang.
        for oid in old_ids {
            assert!(matches!(
                frontend
                    .handle(
                        Request::Wait {
                            id: oid,
                            timeout_ms: Some(60_000),
                        },
                        &old,
                    )
                    .0,
                Response::State(PollState::Shed)
            ));
        }
        // The compliant newcomer's job still completes and verifies.
        match frontend
            .handle(
                Request::Wait {
                    id,
                    timeout_ms: Some(60_000),
                },
                &newer,
            )
            .0
        {
            Response::Result(r) => assert_eq!(r.verified, Some(true)),
            other => panic!("expected result, got {}", other.tag()),
        }
        let snap = frontend.metrics().snapshot_json();
        assert_eq!(snap.get("jobs_shed").unwrap().as_i64(), Some(4));
        let op = frontend.open_session(true);
        frontend.handle(Request::Shutdown, &op);
    }

    /// Privileged sessions are never shed: when only the operator
    /// holds inflight work, a plain submitter that would cross the
    /// high-water gate is refused `overloaded` instead — and the
    /// operator's handles all still redeem.
    #[test]
    fn privileged_sessions_are_never_shed() {
        let qos = QosConfig {
            max_outstanding: 2,
            ..QosConfig::default()
        };
        let frontend =
            Frontend::with_qos(Service::start(small_cfg()), qos);
        let op = frontend.open_session(true);
        let plain = frontend.open_session(false);
        let mut rng = XorShift::new(67);
        let mut op_ids = Vec::new();
        for _ in 0..2 {
            match frontend.handle(gemm_req(&mut rng), &op).0 {
                Response::Handle { id } => op_ids.push(id),
                other => panic!("expected handle, got {}", other.tag()),
            }
        }
        match frontend.handle(gemm_req(&mut rng), &plain).0 {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::Overloaded),
            other => panic!("expected overloaded, got {}", other.tag()),
        }
        assert_eq!(
            frontend.metrics().snapshot_json().get("jobs_shed").unwrap()
                .as_i64(),
            Some(0),
            "operator work must never be shed"
        );
        for id in op_ids {
            assert!(matches!(
                frontend
                    .handle(
                        Request::Wait {
                            id,
                            timeout_ms: Some(60_000),
                        },
                        &op,
                    )
                    .0,
                Response::Result(_)
            ));
        }
        frontend.handle(Request::Shutdown, &op);
    }

    /// Handle ids are guessable, but redemption is ownership-checked:
    /// another plain session's `Poll`/`Wait` on a handle it does not
    /// own answers `forbidden`, steals nothing, and leaves the
    /// owner's quota accounting intact.
    #[test]
    fn cross_session_redemption_is_forbidden() {
        let frontend = Frontend::with_qos(
            Service::start(small_cfg()),
            QosConfig::default(),
        );
        let victim = frontend.open_session(false);
        let thief = frontend.open_session(false);
        let mut rng = XorShift::new(71);
        let id = match frontend.handle(gemm_req(&mut rng), &victim).0 {
            Response::Handle { id } => id,
            other => panic!("expected handle, got {}", other.tag()),
        };
        for req in [
            Request::Poll { id },
            Request::Wait {
                id,
                timeout_ms: Some(60_000),
            },
        ] {
            match frontend.handle(req, &thief).0 {
                Response::Error(e) => {
                    assert_eq!(e.code, ErrorCode::Forbidden)
                }
                other => {
                    panic!("theft not refused: got {}", other.tag())
                }
            }
        }
        // Nothing was stolen or released: the owner still redeems its
        // result and its ledger empties only then.
        assert_eq!(victim.inflight(), 1);
        assert!(matches!(
            frontend
                .handle(
                    Request::Wait {
                        id,
                        timeout_ms: Some(60_000),
                    },
                    &victim,
                )
                .0,
            Response::Result(_)
        ));
        assert_eq!(victim.inflight(), 0);
        let op = frontend.open_session(true);
        frontend.handle(Request::Shutdown, &op);
    }

    /// Closing a session reaps its metrics aggregation: connection
    /// churn cannot grow the per-session map for the server's
    /// lifetime.
    #[test]
    fn close_session_reaps_its_metrics_entry() {
        let frontend = Frontend::with_qos(
            Service::start(small_cfg()),
            QosConfig::default(),
        );
        let sess = frontend.open_session(false);
        let sid = sess.id().to_string();
        let mut rng = XorShift::new(79);
        let id = match frontend.handle(gemm_req(&mut rng), &sess).0 {
            Response::Handle { id } => id,
            other => panic!("expected handle, got {}", other.tag()),
        };
        assert!(matches!(
            frontend
                .handle(
                    Request::Wait {
                        id,
                        timeout_ms: Some(60_000),
                    },
                    &sess,
                )
                .0,
            Response::Result(_)
        ));
        let snap = frontend.metrics().snapshot_json();
        assert!(snap.get("sessions").unwrap().get(&sid).is_some());
        frontend.close_session(&sess);
        let snap = frontend.metrics().snapshot_json();
        assert!(
            snap.get("sessions").unwrap().get(&sid).is_none(),
            "closed session's metrics entry was not reaped"
        );
        let op = frontend.open_session(true);
        frontend.handle(Request::Shutdown, &op);
    }

    /// The constant-time token comparison still decides equality
    /// correctly across every length relation.
    #[test]
    fn token_eq_matches_plain_equality() {
        for (a, b) in [
            ("sesame", "sesame"),
            ("sesame", "sesamf"),
            ("sesame", "sesam"),
            ("sesame", "sesamee"),
            ("sesame", ""),
            ("", ""),
            ("", "x"),
            ("a", "aaaaaaa"),
        ] {
            assert_eq!(token_eq(a, b), a == b, "token_eq({a:?}, {b:?})");
        }
    }

    /// `DrainMine` retires only the caller's handles; another
    /// session's results stay parked and redeemable.
    #[test]
    fn drain_mine_leaves_other_sessions_work_alone() {
        let frontend = Frontend::with_qos(
            Service::start(small_cfg()),
            QosConfig::default(),
        );
        let alpha = frontend.open_session(false);
        let beta = frontend.open_session(false);
        let mut rng = XorShift::new(41);
        for _ in 0..2 {
            assert!(matches!(
                frontend.handle(gemm_req(&mut rng), &alpha).0,
                Response::Handle { .. }
            ));
        }
        let beta_id = match frontend.handle(gemm_req(&mut rng), &beta).0 {
            Response::Handle { id } => id,
            other => panic!("expected handle, got {}", other.tag()),
        };
        match frontend
            .handle(
                Request::DrainMine {
                    timeout_ms: Some(60_000),
                },
                &alpha,
            )
            .0
        {
            Response::Drained { completed, failed } => {
                assert_eq!(completed.len(), 2);
                assert!(failed.is_empty());
            }
            other => panic!("expected drained, got {}", other.tag()),
        }
        assert!(matches!(
            frontend
                .handle(
                    Request::Wait {
                        id: beta_id,
                        timeout_ms: Some(60_000),
                    },
                    &beta,
                )
                .0,
            Response::Result(_)
        ));
        let op = frontend.open_session(true);
        frontend.handle(Request::Shutdown, &op);
    }

    /// Closing a session forgets its unredeemed handles: nothing
    /// stays parked for an operator drain to find.
    #[test]
    fn close_session_reclaims_unredeemed_work() {
        let frontend = Frontend::with_qos(
            Service::start(small_cfg()),
            QosConfig::default(),
        );
        let sess = frontend.open_session(false);
        let mut rng = XorShift::new(53);
        for _ in 0..3 {
            assert!(matches!(
                frontend.handle(gemm_req(&mut rng), &sess).0,
                Response::Handle { .. }
            ));
        }
        frontend.close_session(&sess);
        let op = frontend.open_session(true);
        match frontend
            .handle(Request::Drain { timeout_ms: None }, &op)
            .0
        {
            Response::Drained { completed, failed } => {
                assert!(completed.is_empty());
                assert!(failed.is_empty());
            }
            other => panic!("expected drained, got {}", other.tag()),
        }
        frontend.handle(Request::Shutdown, &op);
    }
}
