//! Typed wire messages and their JSON encoding.
//!
//! Every message is one JSON object with a version field (`"v"`), a
//! tag (`"req"` / `"resp"`), and flat payload fields. The encoding is
//! total — `to_json` can represent every value the coordinator
//! produces — and decoding is typed: schema violations, unknown tags
//! and version mismatches come back as [`ProtoError`], which servers
//! answer with [`Response::Error`] instead of dropping the connection.
//!
//! Payload schemas (version 1):
//!
//! ```text
//! matrix  {"rows": R, "cols": C, "data": [ints, row-major]}
//! sparse  {"rows": R, "cols": C, "n": N, "m": M,
//!          "idx": [slot column indices, 255 = empty], "val": [i8 slots]}
//! csr     {"rows": R, "cols": C, "row_ptr": [ints], "col_idx": [ints],
//!          "val": [i8]}
//! shape   {"in_c", "in_h", "in_w", "out_c", "k", "stride", "pad",
//!          "dilation", "groups"}   (last two optional, default 1)
//! layer   {"op": "gemm"|"sparse-gemm"|"conv"|"snn"|"requant"|"quant"
//!                |"add"|"chw", <op fields>, "in": [tensor ids]}
//! model   {"layers": [layer], "input_rows": R, "input_cols": C,
//!          "spikes": bool}
//! job     {"kind": "gemm",  "a": matrix, "w": matrix}
//!       | {"kind": "conv",  "input": [i8], "weights": [i8], "shape": shape}
//!       | {"kind": "snn",   "spikes": matrix, "weights": matrix}
//!       | {"kind": "sparse", "a": csr, "w": sparse}
//!       | {"kind": "model", "model": model, "input": matrix}
//! result  {"id", "output": matrix, "stats": {run-stat counters},
//!          "simulated_us", "wall_us", "verified": bool|null}
//! ```
//!
//! `timeout_ms` fields are `null` (or absent) for "wait forever",
//! which the service clamps safely (`Duration::MAX` semantics).

use crate::coordinator::{Job, JobResult};
use crate::engines::RunStats;
use crate::model::{Layer, LayerOp, Model};
use crate::util::json::{Json, JsonError};
use crate::workload::conv::ConvShape;
use crate::workload::{CsrMatI8, MatI32, MatI8, NmPattern, SparseMatI8};
use std::time::Duration;

/// Wire protocol version; bumped on any incompatible schema change.
/// Decoders reject other versions with a typed error, so a stale
/// client gets a diagnosable `Error` response instead of garbage.
pub const PROTO_VERSION: i64 = 1;

/// A client-to-server message.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Submit one GEMM job; answered with [`Response::Handle`].
    SubmitGemm { a: MatI8, w: MatI8 },
    /// Submit one conv job (raw NCHW input; the server lowers it
    /// lazily); answered with [`Response::Handle`].
    SubmitConv {
        input: Vec<i8>,
        weights: Vec<i8>,
        shape: ConvShape,
    },
    /// Submit one sparse GEMM (CSR activations against an N:M
    /// structured weight matrix; the server skips all-zero weight
    /// tiles); answered with [`Response::Handle`]. `density` is
    /// client-side metadata (the generator's target) carried for
    /// observability — the server recomputes real density from the
    /// operands and never trusts this value for scheduling.
    SubmitSparse {
        a: CsrMatI8,
        w: SparseMatI8,
        density: Option<f64>,
    },
    /// Submit one whole model graph (a DAG of layers over the given
    /// input tensor); answered with [`Response::Handle`]. Structural
    /// schema violations (bad matrices, unknown op tags) are decode
    /// errors; *graph* violations (cycles, dangling edges, shape
    /// mismatches) decode fine and resolve as a typed `Failed` handle
    /// at submit. Intermediate activations stay server-side — only the
    /// final output tensor ever travels back.
    SubmitModel { model: Model, input: MatI8 },
    /// Submit a batch in one call (weight-tile reuse groups across the
    /// whole batch, exactly like the in-process API); answered with
    /// [`Response::Handles`] in job order.
    SubmitBatch { jobs: Vec<Job> },
    /// Non-blocking handle redemption; answered with
    /// [`Response::Result`] or [`Response::State`].
    Poll { id: u64 },
    /// Blocking handle redemption; `timeout_ms: None` waits forever.
    Wait { id: u64, timeout_ms: Option<u64> },
    /// Retire everything outstanding (or until `timeout_ms`); answered
    /// with [`Response::Drained`]. **Global**: takes every session's
    /// unclaimed completions, not just this one's — a *privileged*
    /// operator verb (loopback peers or token-authenticated sessions);
    /// unprivileged sessions get a `forbidden` error and should use
    /// [`Request::DrainMine`] instead.
    Drain { timeout_ms: Option<u64> },
    /// Retire only this session's outstanding handles (or until
    /// `timeout_ms`); answered with [`Response::Drained`]. The
    /// unprivileged counterpart of [`Request::Drain`] — other
    /// sessions' handles are never touched.
    DrainMine { timeout_ms: Option<u64> },
    /// Present an operator token. On a match the session becomes
    /// privileged (may issue `Drain` / `Shutdown`); answered with
    /// [`Response::Ok`], or a `forbidden` error on a mismatch.
    Auth { token: String },
    /// Metrics snapshot; answered with [`Response::Metrics`].
    Stats,
    /// Graceful shutdown: the server drains every pending job
    /// (unbounded wait), answers with the final [`Response::Metrics`]
    /// snapshot, and stops its listener. Privileged like `Drain`.
    Shutdown,
}

/// Handle states that carry no result (a completed redemption answers
/// [`Response::Result`] instead). `Shed` is terminal like `Failed`,
/// but distinguishes admission-control eviction (the job was dropped
/// by the server to protect other sessions) from a job that ran and
/// failed on its own merits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PollState {
    Pending,
    Failed,
    Shed,
}

/// Machine-readable error class on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Unreadable frame (oversize declared length).
    BadFrame,
    /// The payload was not valid JSON.
    BadJson,
    /// Valid JSON that violates the message schema (missing field,
    /// unknown tag, wrong version).
    BadRequest,
    /// The service has already shut down.
    Unavailable,
    /// Admission control refused the work: a quota or the global
    /// high-water gate would be exceeded. The error carries a
    /// retry-after hint; nothing was enqueued.
    Overloaded,
    /// The verb is privileged and this session is not (plain TCP
    /// session issuing `Drain`/`Shutdown`, or a bad `Auth` token).
    Forbidden,
    /// An error code this client build does not know (newer server).
    Unknown,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadFrame => "bad-frame",
            ErrorCode::BadJson => "bad-json",
            ErrorCode::BadRequest => "bad-request",
            ErrorCode::Unavailable => "unavailable",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Forbidden => "forbidden",
            ErrorCode::Unknown => "unknown",
        }
    }

    pub fn parse(s: &str) -> ErrorCode {
        match s {
            "bad-frame" => ErrorCode::BadFrame,
            "bad-json" => ErrorCode::BadJson,
            "bad-request" => ErrorCode::BadRequest,
            "unavailable" => ErrorCode::Unavailable,
            "overloaded" => ErrorCode::Overloaded,
            "forbidden" => ErrorCode::Forbidden,
            _ => ErrorCode::Unknown,
        }
    }
}

/// A typed error response: the request (or frame) was not served, the
/// connection stays open.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
    /// Backoff hint, only meaningful on [`ErrorCode::Overloaded`]:
    /// the server suggests retrying after this many milliseconds.
    pub retry_after_ms: Option<u64>,
}

impl WireError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> WireError {
        WireError {
            code,
            message: message.into(),
            retry_after_ms: None,
        }
    }

    pub fn unavailable() -> WireError {
        WireError::new(
            ErrorCode::Unavailable,
            "service has shut down; no further requests are served",
        )
    }

    /// Admission refused; retry after the hinted backoff.
    pub fn overloaded(
        message: impl Into<String>,
        retry_after_ms: u64,
    ) -> WireError {
        WireError {
            code: ErrorCode::Overloaded,
            message: message.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    /// Privileged verb from an unprivileged session.
    pub fn forbidden(message: impl Into<String>) -> WireError {
        WireError::new(ErrorCode::Forbidden, message)
    }

    /// Classify a decode failure for the wire.
    pub fn from_proto(e: &ProtoError) -> WireError {
        let code = match e {
            ProtoError::Json(_) | ProtoError::Utf8 => ErrorCode::BadJson,
            _ => ErrorCode::BadRequest,
        };
        WireError::new(code, e.to_string())
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

/// A server-to-client message.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// One job accepted.
    Handle { id: u64 },
    /// A batch accepted, handles in job order.
    Handles { ids: Vec<u64> },
    /// Handle redeemed without a result (still pending, or failed).
    State(PollState),
    /// Handle redeemed: the completed job.
    Result(Box<JobResult>),
    /// Everything a `Drain` retired.
    Drained {
        completed: Vec<JobResult>,
        failed: Vec<u64>,
    },
    /// A metrics snapshot (`Stats`, and the `Shutdown` ack).
    Metrics(Json),
    /// Bare acknowledgement (the `Auth` success ack).
    Ok,
    /// The request could not be served; the connection stays open.
    Error(WireError),
}

impl Response {
    /// Short tag for diagnostics ("expected Result, got `state`").
    pub fn tag(&self) -> &'static str {
        match self {
            Response::Handle { .. } => "handle",
            Response::Handles { .. } => "handles",
            Response::State(_) => "state",
            Response::Result(_) => "result",
            Response::Drained { .. } => "drained",
            Response::Metrics(_) => "metrics",
            Response::Ok => "ok",
            Response::Error(_) => "error",
        }
    }
}

/// Why a payload failed to decode.
#[derive(Debug, Clone, PartialEq)]
pub enum ProtoError {
    /// Payload bytes are not UTF-8.
    Utf8,
    /// Payload is not valid JSON.
    Json(JsonError),
    /// Wrong protocol version.
    Version { got: i64 },
    /// A required field is missing or has the wrong type/range.
    Schema { what: &'static str },
    /// Unknown `req`/`resp`/`kind` tag.
    UnknownTag { kind: &'static str, tag: String },
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Utf8 => write!(f, "payload is not valid UTF-8"),
            ProtoError::Json(e) => write!(f, "payload is not JSON: {e}"),
            ProtoError::Version { got } => write!(
                f,
                "unsupported protocol version {got} (this build speaks \
                 {PROTO_VERSION})"
            ),
            ProtoError::Schema { what } => {
                write!(f, "missing or mistyped field `{what}`")
            }
            ProtoError::UnknownTag { kind, tag } => {
                write!(f, "unknown {kind} tag `{tag}`")
            }
        }
    }
}

impl std::error::Error for ProtoError {}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

fn envelope(
    tag_key: &'static str,
    tag: &'static str,
    fields: Vec<(&'static str, Json)>,
) -> Json {
    let mut pairs = vec![
        ("v", Json::Int(PROTO_VERSION)),
        (tag_key, Json::from(tag)),
    ];
    pairs.extend(fields);
    Json::object(pairs)
}

fn mat_i8_to_json(m: &MatI8) -> Json {
    Json::object([
        ("rows", Json::from(m.rows)),
        ("cols", Json::from(m.cols)),
        ("data", Json::array(m.data.iter().map(|&v| Json::Int(v as i64)))),
    ])
}

fn mat_i32_to_json(m: &MatI32) -> Json {
    Json::object([
        ("rows", Json::from(m.rows)),
        ("cols", Json::from(m.cols)),
        ("data", Json::array(m.data.iter().map(|&v| Json::Int(v as i64)))),
    ])
}

fn i8_slice_to_json(s: &[i8]) -> Json {
    Json::array(s.iter().map(|&v| Json::Int(v as i64)))
}

fn sparse_to_json(w: &SparseMatI8) -> Json {
    let (idx, val) = w.slots();
    Json::object([
        ("rows", Json::from(w.rows())),
        ("cols", Json::from(w.cols())),
        ("n", Json::from(w.nm().n)),
        ("m", Json::from(w.nm().m)),
        ("idx", Json::array(idx.iter().map(|&v| Json::Int(v as i64)))),
        ("val", i8_slice_to_json(val)),
    ])
}

fn csr_to_json(a: &CsrMatI8) -> Json {
    let (row_ptr, col_idx, val) = a.parts();
    Json::object([
        ("rows", Json::from(a.rows())),
        ("cols", Json::from(a.cols())),
        (
            "row_ptr",
            Json::array(row_ptr.iter().map(|&v| Json::Int(v as i64))),
        ),
        (
            "col_idx",
            Json::array(col_idx.iter().map(|&v| Json::Int(v as i64))),
        ),
        ("val", i8_slice_to_json(val)),
    ])
}

fn shape_to_json(s: ConvShape) -> Json {
    // Encoders always write dilation/groups; decoders default absent
    // fields to 1 so pre-dilation clients keep round-tripping.
    Json::object([
        ("in_c", Json::from(s.in_c)),
        ("in_h", Json::from(s.in_h)),
        ("in_w", Json::from(s.in_w)),
        ("out_c", Json::from(s.out_c)),
        ("k", Json::from(s.k)),
        ("stride", Json::from(s.stride)),
        ("pad", Json::from(s.pad)),
        ("dilation", Json::from(s.dilation)),
        ("groups", Json::from(s.groups)),
    ])
}

fn layer_to_json(layer: &Layer) -> Json {
    let mut fields: Vec<(&'static str, Json)> =
        vec![("op", Json::from(layer.op.label()))];
    match &layer.op {
        LayerOp::Gemm { w } | LayerOp::Snn { w } => {
            fields.push(("w", mat_i8_to_json(w)));
        }
        LayerOp::SparseGemm { w } => fields.push(("w", sparse_to_json(w))),
        LayerOp::Conv { weights, shape } => {
            fields.push(("weights", i8_slice_to_json(weights)));
            fields.push(("shape", shape_to_json(*shape)));
        }
        LayerOp::Requant {
            num,
            shift,
            zero_point,
        } => {
            fields.push(("num", Json::Int(*num as i64)));
            fields.push(("shift", Json::Int(*shift as i64)));
            fields.push(("zp", Json::Int(*zero_point as i64)));
        }
        LayerOp::Quant { num, shift } => {
            fields.push(("num", Json::Int(*num as i64)));
            fields.push(("shift", Json::Int(*shift as i64)));
        }
        LayerOp::Add => {}
        LayerOp::Chw { h, w } => {
            fields.push(("h", Json::from(*h)));
            fields.push(("w", Json::from(*w)));
        }
    }
    fields.push((
        "in",
        Json::array(layer.inputs.iter().map(|&t| Json::from(t))),
    ));
    Json::object(fields)
}

fn model_to_json(m: &Model) -> Json {
    Json::object([
        ("layers", Json::array(m.layers.iter().map(layer_to_json))),
        ("input_rows", Json::from(m.input_rows)),
        ("input_cols", Json::from(m.input_cols)),
        ("spikes", Json::Bool(m.spike_input)),
    ])
}

fn job_to_json(job: &Job) -> Json {
    match job {
        Job::Gemm { a, w } => Json::object([
            ("kind", Json::from("gemm")),
            ("a", mat_i8_to_json(a)),
            ("w", mat_i8_to_json(w)),
        ]),
        Job::Conv {
            input,
            weights,
            shape,
        } => Json::object([
            ("kind", Json::from("conv")),
            ("input", i8_slice_to_json(input)),
            ("weights", i8_slice_to_json(weights)),
            ("shape", shape_to_json(*shape)),
        ]),
        Job::Snn { spikes, weights } => Json::object([
            ("kind", Json::from("snn")),
            ("spikes", mat_i8_to_json(spikes)),
            ("weights", mat_i8_to_json(weights)),
        ]),
        Job::SparseGemm { a, w } => Json::object([
            ("kind", Json::from("sparse")),
            ("a", csr_to_json(a)),
            ("w", sparse_to_json(w)),
        ]),
        Job::Model { model, input } => Json::object([
            ("kind", Json::from("model")),
            ("model", model_to_json(model)),
            ("input", mat_i8_to_json(input)),
        ]),
    }
}

fn stats_to_json(s: &RunStats) -> Json {
    // Exhaustive destructuring: adding a RunStats field breaks this
    // build instead of silently dropping the counter off the wire.
    let RunStats {
        cycles,
        fast_cycles,
        macs,
        weight_stall_cycles,
        weight_loads,
        guard_overflows,
        fills_avoided,
        fill_cycles_saved,
    } = *s;
    Json::object([
        ("cycles", Json::uint(cycles)),
        ("fast_cycles", Json::uint(fast_cycles)),
        ("macs", Json::uint(macs)),
        ("weight_stall_cycles", Json::uint(weight_stall_cycles)),
        ("weight_loads", Json::uint(weight_loads)),
        ("guard_overflows", Json::uint(guard_overflows)),
        ("fills_avoided", Json::uint(fills_avoided)),
        ("fill_cycles_saved", Json::uint(fill_cycles_saved)),
    ])
}

fn result_to_json(r: &JobResult) -> Json {
    Json::object([
        ("id", Json::uint(r.id.0)),
        ("output", mat_i32_to_json(&r.output)),
        ("stats", stats_to_json(&r.stats)),
        ("simulated_us", Json::uint(r.simulated.as_micros() as u64)),
        ("wall_us", Json::uint(r.wall.as_micros() as u64)),
        (
            "verified",
            match r.verified {
                None => Json::Null,
                Some(b) => Json::Bool(b),
            },
        ),
    ])
}

fn opt_u64_to_json(v: Option<u64>) -> Json {
    match v {
        None => Json::Null,
        Some(ms) => Json::uint(ms),
    }
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::SubmitGemm { a, w } => envelope(
                "req",
                "submit-gemm",
                vec![("a", mat_i8_to_json(a)), ("w", mat_i8_to_json(w))],
            ),
            Request::SubmitConv {
                input,
                weights,
                shape,
            } => envelope(
                "req",
                "submit-conv",
                vec![
                    ("input", i8_slice_to_json(input)),
                    ("weights", i8_slice_to_json(weights)),
                    ("shape", shape_to_json(*shape)),
                ],
            ),
            Request::SubmitSparse { a, w, density } => envelope(
                "req",
                "submit-sparse",
                vec![
                    ("a", csr_to_json(a)),
                    ("w", sparse_to_json(w)),
                    (
                        "density",
                        match density {
                            None => Json::Null,
                            Some(d) => Json::float(*d),
                        },
                    ),
                ],
            ),
            Request::SubmitModel { model, input } => envelope(
                "req",
                "submit-model",
                vec![
                    ("model", model_to_json(model)),
                    ("input", mat_i8_to_json(input)),
                ],
            ),
            Request::SubmitBatch { jobs } => envelope(
                "req",
                "submit-batch",
                vec![("jobs", Json::array(jobs.iter().map(job_to_json)))],
            ),
            Request::Poll { id } => {
                envelope("req", "poll", vec![("id", Json::uint(*id))])
            }
            Request::Wait { id, timeout_ms } => envelope(
                "req",
                "wait",
                vec![
                    ("id", Json::uint(*id)),
                    ("timeout_ms", opt_u64_to_json(*timeout_ms)),
                ],
            ),
            Request::Drain { timeout_ms } => envelope(
                "req",
                "drain",
                vec![("timeout_ms", opt_u64_to_json(*timeout_ms))],
            ),
            Request::DrainMine { timeout_ms } => envelope(
                "req",
                "drain-mine",
                vec![("timeout_ms", opt_u64_to_json(*timeout_ms))],
            ),
            Request::Auth { token } => envelope(
                "req",
                "auth",
                vec![("token", Json::from(token.as_str()))],
            ),
            Request::Stats => envelope("req", "stats", vec![]),
            Request::Shutdown => envelope("req", "shutdown", vec![]),
        }
    }

    /// Serialize to frame-payload bytes.
    pub fn encode(&self) -> Vec<u8> {
        self.to_json().to_string().into_bytes()
    }

    /// Decode frame-payload bytes.
    pub fn decode(bytes: &[u8]) -> Result<Request, ProtoError> {
        Request::from_json(&parse_payload(bytes)?)
    }

    pub fn from_json(v: &Json) -> Result<Request, ProtoError> {
        let tag = check_envelope(v, "req")?;
        Ok(match tag {
            "submit-gemm" => Request::SubmitGemm {
                a: mat_i8_field(v, "a")?,
                w: mat_i8_field(v, "w")?,
            },
            "submit-conv" => Request::SubmitConv {
                input: i8_vec_field(v, "input")?,
                weights: i8_vec_field(v, "weights")?,
                shape: shape_field(v, "shape")?,
            },
            "submit-sparse" => Request::SubmitSparse {
                a: csr_field(v, "a")?,
                w: sparse_field(v, "w")?,
                density: opt_f64_field(v, "density")?,
            },
            "submit-model" => Request::SubmitModel {
                model: model_field(v, "model")?,
                input: mat_i8_field(v, "input")?,
            },
            "submit-batch" => {
                let jobs = v
                    .get("jobs")
                    .and_then(Json::as_array)
                    .ok_or(ProtoError::Schema { what: "jobs" })?;
                Request::SubmitBatch {
                    jobs: jobs
                        .iter()
                        .map(job_from_json)
                        .collect::<Result<_, _>>()?,
                }
            }
            "poll" => Request::Poll {
                id: u64_field(v, "id")?,
            },
            "wait" => Request::Wait {
                id: u64_field(v, "id")?,
                timeout_ms: opt_u64_field(v, "timeout_ms")?,
            },
            "drain" => Request::Drain {
                timeout_ms: opt_u64_field(v, "timeout_ms")?,
            },
            "drain-mine" => Request::DrainMine {
                timeout_ms: opt_u64_field(v, "timeout_ms")?,
            },
            "auth" => Request::Auth {
                token: v
                    .get("token")
                    .and_then(Json::as_str)
                    .ok_or(ProtoError::Schema { what: "token" })?
                    .to_string(),
            },
            "stats" => Request::Stats,
            "shutdown" => Request::Shutdown,
            other => {
                return Err(ProtoError::UnknownTag {
                    kind: "request",
                    tag: other.to_string(),
                })
            }
        })
    }
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Handle { id } => {
                envelope("resp", "handle", vec![("id", Json::uint(*id))])
            }
            Response::Handles { ids } => envelope(
                "resp",
                "handles",
                vec![(
                    "ids",
                    Json::array(ids.iter().map(|&id| Json::uint(id))),
                )],
            ),
            Response::State(state) => envelope(
                "resp",
                "state",
                vec![(
                    "state",
                    Json::from(match state {
                        PollState::Pending => "pending",
                        PollState::Failed => "failed",
                        PollState::Shed => "shed",
                    }),
                )],
            ),
            Response::Result(r) => {
                envelope("resp", "result", vec![("result", result_to_json(r))])
            }
            Response::Drained { completed, failed } => envelope(
                "resp",
                "drained",
                vec![
                    (
                        "completed",
                        Json::array(completed.iter().map(result_to_json)),
                    ),
                    (
                        "failed",
                        Json::array(failed.iter().map(|&id| Json::uint(id))),
                    ),
                ],
            ),
            Response::Metrics(snapshot) => envelope(
                "resp",
                "metrics",
                vec![("metrics", snapshot.clone())],
            ),
            Response::Ok => envelope("resp", "ok", vec![]),
            Response::Error(e) => envelope(
                "resp",
                "error",
                vec![
                    ("code", Json::from(e.code.as_str())),
                    ("message", Json::from(e.message.as_str())),
                    ("retry_after_ms", opt_u64_to_json(e.retry_after_ms)),
                ],
            ),
        }
    }

    /// Serialize to frame-payload bytes.
    pub fn encode(&self) -> Vec<u8> {
        self.to_json().to_string().into_bytes()
    }

    /// Decode frame-payload bytes.
    pub fn decode(bytes: &[u8]) -> Result<Response, ProtoError> {
        Response::from_json(&parse_payload(bytes)?)
    }

    pub fn from_json(v: &Json) -> Result<Response, ProtoError> {
        let tag = check_envelope(v, "resp")?;
        Ok(match tag {
            "handle" => Response::Handle {
                id: u64_field(v, "id")?,
            },
            "handles" => Response::Handles {
                ids: u64_vec_field(v, "ids")?,
            },
            "state" => {
                let state = v
                    .get("state")
                    .and_then(Json::as_str)
                    .ok_or(ProtoError::Schema { what: "state" })?;
                Response::State(match state {
                    "pending" => PollState::Pending,
                    "failed" => PollState::Failed,
                    "shed" => PollState::Shed,
                    other => {
                        return Err(ProtoError::UnknownTag {
                            kind: "state",
                            tag: other.to_string(),
                        })
                    }
                })
            }
            "result" => Response::Result(Box::new(result_field(v, "result")?)),
            "drained" => {
                let completed = v
                    .get("completed")
                    .and_then(Json::as_array)
                    .ok_or(ProtoError::Schema { what: "completed" })?;
                Response::Drained {
                    completed: completed
                        .iter()
                        .map(result_from_json)
                        .collect::<Result<_, _>>()?,
                    failed: u64_vec_field(v, "failed")?,
                }
            }
            "metrics" => Response::Metrics(
                v.get("metrics")
                    .ok_or(ProtoError::Schema { what: "metrics" })?
                    .clone(),
            ),
            "ok" => Response::Ok,
            "error" => {
                let code = v
                    .get("code")
                    .and_then(Json::as_str)
                    .ok_or(ProtoError::Schema { what: "code" })?;
                let message = v
                    .get("message")
                    .and_then(Json::as_str)
                    .ok_or(ProtoError::Schema { what: "message" })?;
                let mut e = WireError::new(ErrorCode::parse(code), message);
                e.retry_after_ms = opt_u64_field(v, "retry_after_ms")?;
                Response::Error(e)
            }
            other => {
                return Err(ProtoError::UnknownTag {
                    kind: "response",
                    tag: other.to_string(),
                })
            }
        })
    }
}

// ---------------------------------------------------------------------
// Decoding helpers
// ---------------------------------------------------------------------

fn parse_payload(bytes: &[u8]) -> Result<Json, ProtoError> {
    let text = std::str::from_utf8(bytes).map_err(|_| ProtoError::Utf8)?;
    Json::parse(text).map_err(ProtoError::Json)
}

/// Verify version + extract the message tag.
fn check_envelope<'a>(
    v: &'a Json,
    tag_key: &'static str,
) -> Result<&'a str, ProtoError> {
    let version = v
        .get("v")
        .and_then(Json::as_i64)
        .ok_or(ProtoError::Schema { what: "v" })?;
    if version != PROTO_VERSION {
        return Err(ProtoError::Version { got: version });
    }
    v.get(tag_key)
        .and_then(Json::as_str)
        .ok_or(ProtoError::Schema { what: tag_key })
}

fn u64_field(v: &Json, what: &'static str) -> Result<u64, ProtoError> {
    v.get(what)
        .and_then(Json::as_i64)
        .and_then(|i| u64::try_from(i).ok())
        .ok_or(ProtoError::Schema { what })
}

fn opt_u64_field(
    v: &Json,
    what: &'static str,
) -> Result<Option<u64>, ProtoError> {
    match v.get(what) {
        None | Some(Json::Null) => Ok(None),
        Some(j) => j
            .as_i64()
            .and_then(|i| u64::try_from(i).ok())
            .map(Some)
            .ok_or(ProtoError::Schema { what }),
    }
}

fn usize_field(v: &Json, what: &'static str) -> Result<usize, ProtoError> {
    v.get(what)
        .and_then(Json::as_i64)
        .and_then(|i| usize::try_from(i).ok())
        .ok_or(ProtoError::Schema { what })
}

fn u64_vec_field(
    v: &Json,
    what: &'static str,
) -> Result<Vec<u64>, ProtoError> {
    v.get(what)
        .and_then(Json::as_array)
        .ok_or(ProtoError::Schema { what })?
        .iter()
        .map(|j| {
            j.as_i64()
                .and_then(|i| u64::try_from(i).ok())
                .ok_or(ProtoError::Schema { what })
        })
        .collect()
}

fn i8_vec_from(v: &Json, what: &'static str) -> Result<Vec<i8>, ProtoError> {
    v.as_array()
        .ok_or(ProtoError::Schema { what })?
        .iter()
        .map(|j| {
            j.as_i64()
                .and_then(|i| i8::try_from(i).ok())
                .ok_or(ProtoError::Schema { what })
        })
        .collect()
}

fn i8_vec_field(v: &Json, what: &'static str) -> Result<Vec<i8>, ProtoError> {
    i8_vec_from(v.get(what).ok_or(ProtoError::Schema { what })?, what)
}

fn opt_f64_field(
    v: &Json,
    what: &'static str,
) -> Result<Option<f64>, ProtoError> {
    match v.get(what) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Float(f)) => Ok(Some(*f)),
        Some(Json::Int(i)) => Ok(Some(*i as f64)),
        Some(_) => Err(ProtoError::Schema { what }),
    }
}

fn u8_vec_field(v: &Json, what: &'static str) -> Result<Vec<u8>, ProtoError> {
    v.get(what)
        .and_then(Json::as_array)
        .ok_or(ProtoError::Schema { what })?
        .iter()
        .map(|j| {
            j.as_i64()
                .and_then(|i| u8::try_from(i).ok())
                .ok_or(ProtoError::Schema { what })
        })
        .collect()
}

fn usize_vec_field(
    v: &Json,
    what: &'static str,
) -> Result<Vec<usize>, ProtoError> {
    v.get(what)
        .and_then(Json::as_array)
        .ok_or(ProtoError::Schema { what })?
        .iter()
        .map(|j| {
            j.as_i64()
                .and_then(|i| usize::try_from(i).ok())
                .ok_or(ProtoError::Schema { what })
        })
        .collect()
}

/// Decode + revalidate a sparse weight operand. Structural invariants
/// (slot ordering, group caps, sentinel hygiene) are enforced by
/// [`SparseMatI8::from_slots`], so a malformed frame surfaces as a
/// schema error rather than corrupting the skip logic downstream.
fn sparse_from(
    v: &Json,
    what: &'static str,
) -> Result<SparseMatI8, ProtoError> {
    let rows = usize_field(v, "rows")?;
    let cols = usize_field(v, "cols")?;
    let nm = NmPattern::new(usize_field(v, "n")?, usize_field(v, "m")?)
        .map_err(|_| ProtoError::Schema { what })?;
    let idx = u8_vec_field(v, "idx")?;
    let val = i8_vec_field(v, "val")?;
    SparseMatI8::from_slots(rows, cols, nm, idx, val)
        .map_err(|_| ProtoError::Schema { what })
}

fn sparse_field(
    v: &Json,
    what: &'static str,
) -> Result<SparseMatI8, ProtoError> {
    sparse_from(v.get(what).ok_or(ProtoError::Schema { what })?, what)
}

/// Decode + revalidate a CSR activation operand (see [`sparse_from`]).
fn csr_from(v: &Json, what: &'static str) -> Result<CsrMatI8, ProtoError> {
    let rows = usize_field(v, "rows")?;
    let cols = usize_field(v, "cols")?;
    let row_ptr = usize_vec_field(v, "row_ptr")?;
    let col_idx = usize_vec_field(v, "col_idx")?;
    let val = i8_vec_field(v, "val")?;
    CsrMatI8::from_parts(rows, cols, row_ptr, col_idx, val)
        .map_err(|_| ProtoError::Schema { what })
}

fn csr_field(v: &Json, what: &'static str) -> Result<CsrMatI8, ProtoError> {
    csr_from(v.get(what).ok_or(ProtoError::Schema { what })?, what)
}

fn mat_i8_from(v: &Json, what: &'static str) -> Result<MatI8, ProtoError> {
    let rows = usize_field(v, "rows")?;
    let cols = usize_field(v, "cols")?;
    let data = i8_vec_field(v, "data")?;
    if data.len() != rows.checked_mul(cols).ok_or(ProtoError::Schema { what })? {
        return Err(ProtoError::Schema { what });
    }
    Ok(MatI8 { rows, cols, data })
}

fn mat_i8_field(v: &Json, what: &'static str) -> Result<MatI8, ProtoError> {
    mat_i8_from(v.get(what).ok_or(ProtoError::Schema { what })?, what)
}

fn mat_i32_from(v: &Json, what: &'static str) -> Result<MatI32, ProtoError> {
    let rows = usize_field(v, "rows")?;
    let cols = usize_field(v, "cols")?;
    let data: Vec<i32> = v
        .get("data")
        .and_then(Json::as_array)
        .ok_or(ProtoError::Schema { what })?
        .iter()
        .map(|j| {
            j.as_i64()
                .and_then(|i| i32::try_from(i).ok())
                .ok_or(ProtoError::Schema { what })
        })
        .collect::<Result<_, _>>()?;
    if data.len() != rows.checked_mul(cols).ok_or(ProtoError::Schema { what })? {
        return Err(ProtoError::Schema { what });
    }
    Ok(MatI32 { rows, cols, data })
}

/// A field that older encoders omit: absent means `1`, present means
/// it must be a well-typed integer.
fn usize_field_or_one(
    v: &Json,
    what: &'static str,
) -> Result<usize, ProtoError> {
    match v.get(what) {
        None => Ok(1),
        Some(j) => j
            .as_i64()
            .and_then(|i| usize::try_from(i).ok())
            .ok_or(ProtoError::Schema { what }),
    }
}

fn i32_field(v: &Json, what: &'static str) -> Result<i32, ProtoError> {
    v.get(what)
        .and_then(Json::as_i64)
        .and_then(|i| i32::try_from(i).ok())
        .ok_or(ProtoError::Schema { what })
}

fn u32_field(v: &Json, what: &'static str) -> Result<u32, ProtoError> {
    v.get(what)
        .and_then(Json::as_i64)
        .and_then(|i| u32::try_from(i).ok())
        .ok_or(ProtoError::Schema { what })
}

fn bool_field(v: &Json, what: &'static str) -> Result<bool, ProtoError> {
    match v.get(what) {
        Some(Json::Bool(b)) => Ok(*b),
        _ => Err(ProtoError::Schema { what }),
    }
}

fn shape_from_json(v: &Json) -> Result<ConvShape, ProtoError> {
    Ok(ConvShape {
        in_c: usize_field(v, "in_c")?,
        in_h: usize_field(v, "in_h")?,
        in_w: usize_field(v, "in_w")?,
        out_c: usize_field(v, "out_c")?,
        k: usize_field(v, "k")?,
        stride: usize_field(v, "stride")?,
        pad: usize_field(v, "pad")?,
        dilation: usize_field_or_one(v, "dilation")?,
        groups: usize_field_or_one(v, "groups")?,
    })
}

fn shape_field(v: &Json, what: &'static str) -> Result<ConvShape, ProtoError> {
    shape_from_json(v.get(what).ok_or(ProtoError::Schema { what })?)
}

fn layer_from_json(v: &Json) -> Result<Layer, ProtoError> {
    let tag = v
        .get("op")
        .and_then(Json::as_str)
        .ok_or(ProtoError::Schema { what: "op" })?;
    let op = match tag {
        "gemm" => LayerOp::Gemm {
            w: mat_i8_field(v, "w")?,
        },
        "sparse-gemm" => LayerOp::SparseGemm {
            w: sparse_field(v, "w")?,
        },
        "conv" => LayerOp::Conv {
            weights: i8_vec_field(v, "weights")?,
            shape: shape_field(v, "shape")?,
        },
        "snn" => LayerOp::Snn {
            w: mat_i8_field(v, "w")?,
        },
        "requant" => LayerOp::Requant {
            num: i32_field(v, "num")?,
            shift: u32_field(v, "shift")?,
            zero_point: i32_field(v, "zp")?,
        },
        "quant" => LayerOp::Quant {
            num: i32_field(v, "num")?,
            shift: u32_field(v, "shift")?,
        },
        "add" => LayerOp::Add,
        "chw" => LayerOp::Chw {
            h: usize_field(v, "h")?,
            w: usize_field(v, "w")?,
        },
        other => {
            return Err(ProtoError::UnknownTag {
                kind: "layer",
                tag: other.to_string(),
            })
        }
    };
    Ok(Layer {
        op,
        inputs: usize_vec_field(v, "in")?,
    })
}

/// Decode a model graph. Only *structural* validity is enforced here
/// (operand encodings, op tags); graph-level validity is the
/// compiler's job at submit time, where violations become a typed
/// `Failed` handle instead of a dropped frame.
fn model_from_json(v: &Json) -> Result<Model, ProtoError> {
    let layers = v
        .get("layers")
        .and_then(Json::as_array)
        .ok_or(ProtoError::Schema { what: "layers" })?
        .iter()
        .map(layer_from_json)
        .collect::<Result<_, _>>()?;
    Ok(Model {
        layers,
        input_rows: usize_field(v, "input_rows")?,
        input_cols: usize_field(v, "input_cols")?,
        spike_input: bool_field(v, "spikes")?,
    })
}

fn model_field(v: &Json, what: &'static str) -> Result<Model, ProtoError> {
    model_from_json(v.get(what).ok_or(ProtoError::Schema { what })?)
}

fn job_from_json(v: &Json) -> Result<Job, ProtoError> {
    let kind = v
        .get("kind")
        .and_then(Json::as_str)
        .ok_or(ProtoError::Schema { what: "kind" })?;
    Ok(match kind {
        "gemm" => Job::Gemm {
            a: mat_i8_field(v, "a")?,
            w: mat_i8_field(v, "w")?,
        },
        "conv" => Job::Conv {
            input: i8_vec_field(v, "input")?,
            weights: i8_vec_field(v, "weights")?,
            shape: shape_field(v, "shape")?,
        },
        "snn" => Job::Snn {
            spikes: mat_i8_field(v, "spikes")?,
            weights: mat_i8_field(v, "weights")?,
        },
        "sparse" => Job::SparseGemm {
            a: csr_field(v, "a")?,
            w: sparse_field(v, "w")?,
        },
        "model" => Job::Model {
            model: model_field(v, "model")?,
            input: mat_i8_field(v, "input")?,
        },
        other => {
            return Err(ProtoError::UnknownTag {
                kind: "job",
                tag: other.to_string(),
            })
        }
    })
}

fn stats_from_json(v: &Json) -> Result<RunStats, ProtoError> {
    Ok(RunStats {
        cycles: u64_field(v, "cycles")?,
        fast_cycles: u64_field(v, "fast_cycles")?,
        macs: u64_field(v, "macs")?,
        weight_stall_cycles: u64_field(v, "weight_stall_cycles")?,
        weight_loads: u64_field(v, "weight_loads")?,
        guard_overflows: u64_field(v, "guard_overflows")?,
        fills_avoided: u64_field(v, "fills_avoided")?,
        fill_cycles_saved: u64_field(v, "fill_cycles_saved")?,
    })
}

fn result_from_json(v: &Json) -> Result<JobResult, ProtoError> {
    use crate::coordinator::JobId;
    let verified = match v.get("verified") {
        None | Some(Json::Null) => None,
        Some(Json::Bool(b)) => Some(*b),
        Some(_) => return Err(ProtoError::Schema { what: "verified" }),
    };
    Ok(JobResult {
        id: JobId(u64_field(v, "id")?),
        output: mat_i32_from(
            v.get("output").ok_or(ProtoError::Schema { what: "output" })?,
            "output",
        )?,
        stats: stats_from_json(
            v.get("stats").ok_or(ProtoError::Schema { what: "stats" })?,
        )?,
        simulated: Duration::from_micros(u64_field(v, "simulated_us")?),
        wall: Duration::from_micros(u64_field(v, "wall_us")?),
        verified,
    })
}

fn result_field(
    v: &Json,
    what: &'static str,
) -> Result<JobResult, ProtoError> {
    result_from_json(v.get(what).ok_or(ProtoError::Schema { what })?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::JobId;

    #[test]
    fn version_mismatch_is_typed() {
        let doc = Json::parse(r#"{"v": 99, "req": "stats"}"#).unwrap();
        assert_eq!(
            Request::from_json(&doc),
            Err(ProtoError::Version { got: 99 })
        );
    }

    #[test]
    fn unknown_request_tag_is_typed() {
        let doc = Json::parse(r#"{"v": 1, "req": "transmogrify"}"#).unwrap();
        assert_eq!(
            Request::from_json(&doc),
            Err(ProtoError::UnknownTag {
                kind: "request",
                tag: "transmogrify".to_string()
            })
        );
    }

    #[test]
    fn missing_fields_are_schema_errors() {
        let doc = Json::parse(r#"{"v": 1, "req": "poll"}"#).unwrap();
        assert_eq!(
            Request::from_json(&doc),
            Err(ProtoError::Schema { what: "id" })
        );
        let doc =
            Json::parse(r#"{"v": 1, "req": "submit-gemm", "a": 3}"#).unwrap();
        assert!(Request::from_json(&doc).is_err());
    }

    #[test]
    fn mismatched_matrix_length_is_a_schema_error() {
        let doc = Json::parse(
            r#"{"v":1,"req":"submit-gemm",
                "a":{"rows":2,"cols":2,"data":[1,2,3]},
                "w":{"rows":2,"cols":2,"data":[1,2,3,4]}}"#,
        )
        .unwrap();
        assert_eq!(
            Request::from_json(&doc),
            Err(ProtoError::Schema { what: "a" })
        );
    }

    #[test]
    fn non_utf8_and_non_json_payloads_are_typed() {
        assert_eq!(Request::decode(&[0xFF, 0xFE]), Err(ProtoError::Utf8));
        assert!(matches!(
            Request::decode(b"{not json"),
            Err(ProtoError::Json(_))
        ));
    }

    #[test]
    fn timeout_null_and_absent_both_mean_forever() {
        let doc =
            Json::parse(r#"{"v":1,"req":"wait","id":3,"timeout_ms":null}"#)
                .unwrap();
        assert_eq!(
            Request::from_json(&doc).unwrap(),
            Request::Wait {
                id: 3,
                timeout_ms: None
            }
        );
        let doc = Json::parse(r#"{"v":1,"req":"drain"}"#).unwrap();
        assert_eq!(
            Request::from_json(&doc).unwrap(),
            Request::Drain { timeout_ms: None }
        );
    }

    #[test]
    fn verified_tristate_round_trips() {
        for verified in [None, Some(true), Some(false)] {
            let r = JobResult {
                id: JobId(7),
                output: MatI32 {
                    rows: 1,
                    cols: 2,
                    data: vec![i32::MIN, i32::MAX],
                },
                stats: RunStats::default(),
                simulated: Duration::from_micros(12),
                wall: Duration::from_micros(9),
                verified,
            };
            let resp = Response::Result(Box::new(r));
            assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
        }
    }

    #[test]
    fn sparse_submit_round_trips() {
        let dense = MatI8 {
            rows: 2,
            cols: 8,
            data: vec![
                0, 3, 0, -5, 0, 0, 0, 0, //
                7, 0, 0, 0, 0, 0, 2, -1,
            ],
        };
        let nm = NmPattern::new(2, 4).unwrap();
        let w = SparseMatI8::from_dense(&dense, nm).unwrap();
        let a = CsrMatI8::from_dense(&MatI8 {
            rows: 3,
            cols: 2,
            data: vec![1, 0, 0, -2, 0, 0],
        });
        for density in [None, Some(0.25)] {
            let req = Request::SubmitSparse {
                a: a.clone(),
                w: w.clone(),
                density,
            };
            assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        }
        // The same operands also travel inside a batch under the
        // "sparse" job tag.
        let req = Request::SubmitBatch {
            jobs: vec![Job::SparseGemm { a, w }],
        };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
    }

    #[test]
    fn malformed_sparse_operands_are_schema_errors() {
        // idx slot count disagrees with rows * groups * n.
        let doc = Json::parse(
            r#"{"v":1,"req":"submit-sparse",
                "a":{"rows":1,"cols":1,"row_ptr":[0,0],"col_idx":[],"val":[]},
                "w":{"rows":1,"cols":4,"n":2,"m":4,"idx":[0],"val":[1]},
                "density":null}"#,
        )
        .unwrap();
        assert_eq!(
            Request::from_json(&doc),
            Err(ProtoError::Schema { what: "w" })
        );
        // CSR row_ptr not monotone.
        let doc = Json::parse(
            r#"{"v":1,"req":"submit-sparse",
                "a":{"rows":2,"cols":2,"row_ptr":[0,2,1],
                     "col_idx":[0,1],"val":[1,2]},
                "w":{"rows":1,"cols":4,"n":2,"m":4,
                     "idx":[0,255],"val":[1,0]},
                "density":null}"#,
        )
        .unwrap();
        assert_eq!(
            Request::from_json(&doc),
            Err(ProtoError::Schema { what: "a" })
        );
    }

    #[test]
    fn model_submit_round_trips_every_layer_op() {
        use crate::workload::conv::ConvShape;
        // Codec-level coverage: one layer per op tag. Graph validity
        // is deliberately not the codec's concern, so the edges here
        // are arbitrary.
        let w = MatI8 {
            rows: 4,
            cols: 3,
            data: (0..12).map(|i| i as i8 - 6).collect(),
        };
        let nm = NmPattern::new(2, 4).unwrap();
        let sw = SparseMatI8::from_dense(
            &MatI8 {
                rows: 2,
                cols: 4,
                data: vec![0, 3, 0, -5, 7, 0, 0, 2],
            },
            nm,
        )
        .unwrap();
        let shape = ConvShape {
            in_c: 2,
            in_h: 5,
            in_w: 5,
            out_c: 2,
            k: 3,
            stride: 1,
            pad: 2,
            dilation: 2,
            groups: 2,
        };
        let mut m = Model::new(2, 4, false);
        m.layer(LayerOp::Gemm { w: w.clone() }, &[0]);
        m.layer(LayerOp::SparseGemm { w: sw }, &[1]);
        m.layer(
            LayerOp::Conv {
                weights: vec![1; 18],
                shape,
            },
            &[2],
        );
        m.layer(LayerOp::Snn { w }, &[3]);
        m.layer(
            LayerOp::Requant {
                num: 3,
                shift: 9,
                zero_point: -2,
            },
            &[4],
        );
        m.layer(LayerOp::Quant { num: 1, shift: 6 }, &[5]);
        m.layer(LayerOp::Add, &[5, 6]);
        m.layer(LayerOp::Chw { h: 2, w: 3 }, &[7]);
        let input = MatI8 {
            rows: 2,
            cols: 4,
            data: vec![1, -2, 3, -4, 5, -6, 7, -8],
        };
        let req = Request::SubmitModel {
            model: m.clone(),
            input: input.clone(),
        };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        // The same model also travels inside a batch under the
        // "model" job tag.
        let req = Request::SubmitBatch {
            jobs: vec![Job::Model { model: m, input }],
        };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
    }

    #[test]
    fn unknown_layer_op_tag_is_typed() {
        let doc = Json::parse(
            r#"{"v":1,"req":"submit-model",
                "model":{"layers":[{"op":"fft","in":[0]}],
                         "input_rows":1,"input_cols":1,"spikes":false},
                "input":{"rows":1,"cols":1,"data":[0]}}"#,
        )
        .unwrap();
        assert_eq!(
            Request::from_json(&doc),
            Err(ProtoError::UnknownTag {
                kind: "layer",
                tag: "fft".to_string()
            })
        );
    }

    #[test]
    fn shape_dilation_and_groups_default_to_one() {
        // A pre-dilation client omits both fields; the decoder fills
        // in the identity values instead of rejecting the frame.
        let doc = Json::parse(
            r#"{"v":1,"req":"submit-conv","input":[1,2,3,4],"weights":[1],
                "shape":{"in_c":1,"in_h":2,"in_w":2,"out_c":1,
                         "k":1,"stride":1,"pad":0}}"#,
        )
        .unwrap();
        match Request::from_json(&doc).unwrap() {
            Request::SubmitConv { shape, .. } => {
                assert_eq!(shape.dilation, 1);
                assert_eq!(shape.groups, 1);
            }
            other => panic!("expected submit-conv, got {other:?}"),
        }
    }

    #[test]
    fn qos_schema_round_trips() {
        // The QoS additions: session-scoped drain, token auth, the
        // shed terminal state, and the overloaded error with its
        // retry-after hint.
        for req in [
            Request::DrainMine {
                timeout_ms: Some(50),
            },
            Request::DrainMine { timeout_ms: None },
            Request::Auth {
                token: "hunter2".to_string(),
            },
        ] {
            assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        }
        for resp in [
            Response::Ok,
            Response::State(PollState::Shed),
            Response::Error(WireError::overloaded("session quota", 25)),
            Response::Error(WireError::forbidden("not an operator")),
        ] {
            assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
        }
    }

    #[test]
    fn retry_after_hint_survives_the_wire() {
        let resp = Response::Error(WireError::overloaded("busy", 40));
        match Response::decode(&resp.encode()).unwrap() {
            Response::Error(e) => {
                assert_eq!(e.code, ErrorCode::Overloaded);
                assert_eq!(e.retry_after_ms, Some(40));
            }
            other => panic!("expected error, got {other:?}"),
        }
        // Errors without the hint decode to None (and old servers
        // that omit the field entirely parse fine).
        let doc = Json::parse(
            r#"{"v":1,"resp":"error","code":"overloaded","message":"m"}"#,
        )
        .unwrap();
        match Response::from_json(&doc).unwrap() {
            Response::Error(e) => assert_eq!(e.retry_after_ms, None),
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn unknown_error_code_degrades_to_unknown() {
        let doc = Json::parse(
            r#"{"v":1,"resp":"error","code":"quantum-flux","message":"m"}"#,
        )
        .unwrap();
        match Response::from_json(&doc).unwrap() {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::Unknown),
            other => panic!("expected error, got {other:?}"),
        }
    }
}
