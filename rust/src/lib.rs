//! # dsp48-systolic
//!
//! A production-quality reproduction of *"Revealing Untapped DSP
//! Optimization Potentials for FPGA-Based Systolic Matrix Engines"*
//! (Li et al., 2024) as a hardware/software co-design framework.
//!
//! The paper contributes three DSP48E2 micro-architectural techniques:
//!
//! 1. **In-DSP operand prefetching** — absorbing the weight ping-pong
//!    registers of a weight-stationary (WS) systolic array into the
//!    DSP48E2's flexible B input pipeline + BCIN cascade ([`engines::ws`]).
//! 2. **In-DSP multiplexing** — double-data-rate operation without CLB
//!    multiplexers, by ping-ponging the B1/B2 registers and toggling the
//!    INMODE dynamic select at the fast clock ([`engines::os`]).
//! 3. **Ring accumulator** — two cascaded fast-domain DSP48E2s replacing
//!    the slow-domain accumulator pair + LUT adder tree
//!    ([`engines::os`]).
//!
//! Because the paper's testbed (Vivado + XCZU3EG + the encrypted Vitis AI
//! DPU) is unavailable, this crate implements the full evaluation
//! substrate: a bit-accurate [`dsp`] model, a cycle-accurate [`fabric`]
//! clocking/primitive layer, structural [`cost`] models (resource counts
//! emerge from elaborated inventories), all four TPUv1-like WS baselines,
//! both DPU OS engines and both FireFly SNN crossbars from the paper's
//! Tables I–III.
//!
//! The *numerics* of the matrix engine also exist as JAX/Pallas kernels
//! (see `python/compile/`), AOT-lowered to HLO and executed from the
//! [`runtime`] via PJRT — python never runs at serve time. The
//! [`coordinator`] ties the two together: it schedules tiled GEMM jobs
//! onto cycle-accurate engines (for cost) and onto the PJRT executables
//! (for values), asserting they agree bit-for-bit.
//!
//! A third correctness axis rides on top of bit-identity: the [`lint`]
//! module statically verifies every engine's *control schedule* against
//! a UG579-style legality rule set before it ever ticks on silicon —
//! and the [`chaos`] module dynamically hardens the serving layer, by
//! replaying seeded fault campaigns (malformed frames, disconnects,
//! submit storms, privilege probes) against a live server and auditing
//! that nothing leaks and compliant clients still get golden bits.

#![forbid(unsafe_code)]

pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod dsp;
pub mod engines;
pub mod exec;
pub mod fabric;
pub mod lint;
pub mod model;
pub mod packing;
pub mod proto;
pub mod runtime;
pub mod util;
pub mod workload;
