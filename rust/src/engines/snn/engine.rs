//! Cycle-accurate FireFly crossbar over bit-accurate DSP48E2 cells.

use super::{snn_inventory, snn_timing, SnnConfig, SnnVariant};
use crate::cost::{ResourceInventory, TimingModel};
use crate::dsp::{
    simd_lane, simd_pack, Attributes, CascadeTap, ColumnCtrl, DspArray,
    InputSource, OpMode, RowFeeds, SimdMode, WMux, XMux, YMux, ZMux,
};
use crate::engines::{Engine, EngineError, GemmRun, RunStats};
use crate::exec::{self, Clocking, FillPlan, Scratch, TileKernel, TilePlan};
use crate::fabric::{ClockDomain, ClockPlan, FfBank};
use crate::workload::snn::{LifLayer, SpikeTrain};
use crate::workload::{MatI32, MatI8};

/// Spiking crossbar engine (either Table-III variant).
pub struct SnnEngine {
    cfg: SnnConfig,
    name: String,
    /// Every chain as one SoA array: chain `c` is column `c`
    /// (`chain_len` slices deep). Spike bits become per-chain mux
    /// masks, so the whole crossbar advances in one
    /// [`DspArray::tick_snn_crossbar`] pass.
    array: DspArray,
    /// Per-chain spike-select masks, restaged each crossbar cycle.
    x_masks: Vec<u64>,
    y_masks: Vec<u64>,
    /// CLB ping-pong shadow for the C weight set (both variants), and
    /// for the A:B set too in the FireFly variant.
    c_bank: FfBank,
    ab_bank: FfBank,
    /// Reusable scratch arena for per-pass output staging.
    scratch: Scratch,
}

/// Pack four int8 weights into FOUR12 lanes (the 48-bit A:B / C word).
fn pack_weights(w: [i8; 4]) -> i64 {
    simd_pack(
        SimdMode::Four12,
        &[w[0] as i64, w[1] as i64, w[2] as i64, w[3] as i64],
    )
}

impl SnnEngine {
    pub fn new(cfg: SnnConfig) -> Self {
        let attrs = Attributes {
            // A:B carries a weight word; in the enhanced variant it is
            // prefetched through the cascades (in-DSP prefetch on both
            // pipelines), so inputs come from ACIN/BCIN with the hold
            // registers (A2/B2) keeping the live set.
            a_input: if cfg.variant == SnnVariant::Enhanced {
                InputSource::Cascade
            } else {
                InputSource::Direct
            },
            b_input: if cfg.variant == SnnVariant::Enhanced {
                InputSource::Cascade
            } else {
                InputSource::Direct
            },
            a_cascade_tap: CascadeTap::Reg1,
            b_cascade_tap: CascadeTap::Reg1,
            creg: true,
            ..Attributes::firefly_crossbar()
        };
        assert!(cfg.chain_len <= 64, "spike masks carry one bit per slice");
        // The whole crossbar's SoA register banks lease from the
        // engine's arena.
        let mut scratch = Scratch::new();
        let array = DspArray::new_in(attrs, cfg.chain_len, cfg.chains, &mut scratch);
        let slices = cfg.chains * cfg.chain_len;
        SnnEngine {
            name: format!(
                "{} {}x{} crossbar",
                cfg.variant.label(),
                cfg.pre(),
                cfg.pre()
            ),
            array,
            x_masks: vec![0; cfg.chains],
            y_masks: vec![0; cfg.chains],
            c_bank: FfBank::new(slices, 32, ClockDomain::Slow),
            ab_bank: FfBank::new(
                if cfg.variant == SnnVariant::FireFly { slices } else { 0 },
                32,
                ClockDomain::Slow,
            ),
            scratch,
            cfg,
        }
    }

    pub fn config(&self) -> &SnnConfig {
        &self.cfg
    }

    /// Fill cost of one pass: prefetch (chain_len shifts) overlaps
    /// compute; the commit pulse is the only exposed cycle — same story
    /// as the WS engines.
    fn fill_plan(&self) -> FillPlan {
        FillPlan {
            cycles: self.cfg.chain_len as u64 + 1,
            exposed: 1,
            loads: 1,
        }
    }

    /// Load weights for one pass: `weights[pre][post]` with
    /// `post = chain*4 + lane`. The A:B set serves lanes of even pre
    /// (slice input 0), the C set odd pre (slice input 1). Cycle
    /// accounting comes from [`SnnEngine::fill_plan`].
    fn fill_weights(&mut self, w: &MatI8, post_base: usize) {
        let cfg = self.cfg;
        for c in 0..cfg.chains {
            for j in 0..cfg.chain_len {
                let slice = c * cfg.chain_len + j;
                let mut ab = [0i8; 4];
                let mut cc = [0i8; 4];
                for lane in 0..4 {
                    let post = post_base + c * 4 + lane;
                    let (pre0, pre1) = (2 * j, 2 * j + 1);
                    ab[lane] = if post < w.cols && pre0 < w.rows {
                        w.at(pre0, post)
                    } else {
                        0
                    };
                    cc[lane] = if post < w.cols && pre1 < w.rows {
                        w.at(pre1, post)
                    } else {
                        0
                    };
                }
                let ab_word = pack_weights(ab);
                let c_word = pack_weights(cc);
                // Shadow banks (ping-pong fill — overlappable).
                self.c_bank.clock(slice, c_word, true);
                if self.cfg.variant == SnnVariant::FireFly {
                    self.ab_bank.clock(slice, ab_word, true);
                }
                // Commit into the DSP: A:B via the input pipelines
                // (enhanced: modeled as the cascade-shifted value being
                // latched by the A2/B2 hold pulse), C via the C
                // register — one slice at a time, so the array's
                // row-tick path drives bank element `(c, j)` alone.
                // The ALU muxes park at zero during the fill: with CEP
                // low the result is discarded either way, and FOUR12
                // forbids routing the multiplier (the crossbar never
                // uses it — MREG is absent from this profile).
                let park = OpMode {
                    x: XMux::Zero,
                    y: YMux::Zero,
                    z: ZMux::Zero,
                    w: WMux::Zero,
                };
                // Only the enhanced variant sources A/B from the
                // cascade; FireFly's direct inputs leave ACIN/BCIN
                // undriven.
                let cascade = self.cfg.variant == SnnVariant::Enhanced;
                self.array.tick_row(
                    c,
                    j,
                    &ColumnCtrl {
                        opmode: park,
                        cep: false,
                        ..ColumnCtrl::default()
                    },
                    &RowFeeds {
                        a: (ab_word >> 18) & ((1 << 30) - 1),
                        b: ab_word & ((1 << 18) - 1),
                        acin: if cascade {
                            (ab_word >> 18) & ((1 << 30) - 1)
                        } else {
                            0
                        },
                        bcin: if cascade { ab_word & ((1 << 18) - 1) } else { 0 },
                        c: c_word,
                        ..RowFeeds::default()
                    },
                );
                // Second edge moves A1/B1 -> A2/B2 (hold registers).
                self.array.tick_row(
                    c,
                    j,
                    &ColumnCtrl {
                        opmode: park,
                        cep: false,
                        cea1: false,
                        ceb1: false,
                        ..ColumnCtrl::default()
                    },
                    &RowFeeds {
                        c: c_word,
                        ..RowFeeds::default()
                    },
                );
            }
        }
    }

    /// One crossbar cycle: every chain ticks with its skewed spike
    /// selects, and the tail lanes for the completed timestep land in
    /// `out`. The cycle loop itself lives in [`exec::run_tile`]; this
    /// is the SNN datapath's cycle body.
    fn stream_cycle(
        &mut self,
        cycle: usize,
        train: &SpikeTrain,
        out: &mut [i32],
        stats: &mut RunStats,
    ) {
        let cfg = self.cfg;
        let len = cfg.chain_len;
        let t_steps = train.steps;
        for c in 0..cfg.chains {
            // The spike bits become per-row wide-bus mux selects
            // (bit j: X = A:B for spike 2j, Y = C for spike 2j+1).
            let (mut x_ab, mut y_c) = (0u64, 0u64);
            for j in 0..len {
                // Systolic skew: slice j sees timestep `cycle - j`.
                let t = cycle as isize - j as isize;
                let (s0, s1) = if t >= 0 && (t as usize) < t_steps {
                    (
                        train.at(t as usize, 2 * j),
                        train.at(t as usize, 2 * j + 1),
                    )
                } else {
                    (false, false)
                };
                if s0 || s1 {
                    stats.macs += 4 * (s0 as u64 + s1 as u64);
                }
                if s0 {
                    x_ab |= 1 << j;
                }
                if s1 {
                    y_c |= 1 << j;
                }
            }
            self.x_masks[c] = x_ab;
            self.y_masks[c] = y_c;
        }
        // Every chain advances in a single array-wide bank pass.
        self.array.tick_snn_crossbar(&self.x_masks, &self.y_masks);
        // Tail latency: slice j's ALU registers at cycle t+j (no M
        // reg in the crossbar path), so the tail P carries timestep
        // `cycle - (len-1)`.
        let t_out = cycle as isize - (len as isize - 1);
        if t_out >= 0 && (t_out as usize) < t_steps {
            for c in 0..cfg.chains {
                let p = self.array.p(c, len - 1);
                for lane in 0..4 {
                    let v = simd_lane(SimdMode::Four12, p, lane) as i32;
                    out[t_out as usize * cfg.post_per_pass() + c * 4 + lane] = v;
                }
            }
        }
    }

    /// Full SNN inference: crossbar currents + LIF update per timestep.
    /// `weights` is `pre() × n_post`; posts are covered in passes of
    /// [`SnnConfig::post_per_pass`]. Returns (out_spikes, currents).
    pub fn run_snn(
        &mut self,
        train: &SpikeTrain,
        weights: &MatI8,
    ) -> Result<(Vec<u8>, Vec<i32>, RunStats), EngineError> {
        if train.neurons != self.cfg.pre() {
            return Err(EngineError::Shape(format!(
                "train has {} pre-neurons, crossbar expects {}",
                train.neurons,
                self.cfg.pre()
            )));
        }
        if weights.rows != self.cfg.pre() {
            return Err(EngineError::Shape(format!(
                "weights rows {} != pre {}",
                weights.rows,
                self.cfg.pre()
            )));
        }
        let n_post = weights.cols;
        let per_pass = self.cfg.post_per_pass();
        let passes = n_post.div_ceil(per_pass);
        let mut stats = RunStats::default();
        let mut currents = vec![0i32; train.steps * n_post];
        let mut scratch = std::mem::take(&mut self.scratch);
        for pass in 0..passes {
            self.reset();
            let pass_out = {
                let mut kernel = SnnPassKernel {
                    eng: self,
                    train,
                    weights,
                    post_base: pass * per_pass,
                    out: Vec::new(),
                };
                exec::run_tile(&mut kernel, &mut scratch, &mut stats);
                kernel.out
            };
            for t in 0..train.steps {
                for p in 0..per_pass {
                    let post = pass * per_pass + p;
                    if post < n_post {
                        currents[t * n_post + post] = pass_out[t * per_pass + p];
                    }
                }
            }
            scratch.release_i32(pass_out);
        }
        self.scratch = scratch;
        // LIF neuron update (integer, bit-exact with the python ref).
        let mut lif = LifLayer::new(n_post, self.cfg.v_threshold, self.cfg.leak_shift);
        let mut out_spikes = Vec::with_capacity(train.steps * n_post);
        for t in 0..train.steps {
            let row = &currents[t * n_post..(t + 1) * n_post];
            out_spikes.extend(lif.step(row));
        }
        Ok((out_spikes, currents, stats))
    }

    pub fn reset(&mut self) {
        self.array.reset();
    }
}

/// One SNN pass (a block of post-neurons) adapted to the [`exec`] core.
struct SnnPassKernel<'a> {
    eng: &'a mut SnnEngine,
    train: &'a SpikeTrain,
    weights: &'a MatI8,
    post_base: usize,
    /// Per-pass current staging, leased from the scratch arena during
    /// fill; the caller copies it out and returns it to the pool.
    out: Vec<i32>,
}

impl TileKernel for SnnPassKernel<'_> {
    fn plan(&self) -> TilePlan {
        TilePlan {
            fill: self.eng.fill_plan(),
            stream_steps: self.train.steps,
            // Tail latency: the last timestep's word exits `chain_len`
            // cycles after it enters.
            drain_steps: self.eng.cfg.chain_len,
            clocking: Clocking::Single,
            reuse_fill: false,
        }
    }

    fn fill(&mut self, scratch: &mut Scratch, _stats: &mut RunStats) {
        self.out = scratch.lease_i32(self.train.steps * self.eng.cfg.post_per_pass());
        self.eng.fill_weights(self.weights, self.post_base);
    }

    fn step(&mut self, cycle: usize, _scratch: &mut Scratch, stats: &mut RunStats) {
        self.eng
            .stream_cycle(cycle, self.train, &mut self.out, stats);
    }
}

impl Engine for SnnEngine {
    fn name(&self) -> &str {
        &self.name
    }

    fn inventory(&self) -> ResourceInventory {
        snn_inventory(&self.cfg)
    }

    fn timing(&self) -> TimingModel {
        snn_timing(&self.cfg)
    }

    fn clock_plan(&self) -> ClockPlan {
        self.cfg.clock_plan()
    }

    fn peak_macs_per_cycle(&self) -> u64 {
        // 2 pre × 4 lanes per slice (synaptic ops).
        (self.cfg.chains * self.cfg.chain_len * 8) as u64
    }

    fn scratch_stats(&self) -> crate::exec::ScratchStats {
        self.scratch.stats()
    }

    /// GEMM view: `a` must be a {0,1} spike matrix (T × pre).
    fn run_gemm(&mut self, a: &MatI8, w: &MatI8) -> Result<GemmRun, EngineError> {
        if a.data.iter().any(|&v| v != 0 && v != 1) {
            return Err(EngineError::Shape(
                "SNN engine consumes binary spike inputs".into(),
            ));
        }
        let train = SpikeTrain {
            steps: a.rows,
            neurons: a.cols,
            spikes: a.data.iter().map(|&v| v as u8).collect(),
        };
        let (_, currents, stats) = self.run_snn(&train, w)?;
        let mut out = MatI32::zeros(a.rows, w.cols);
        out.data.copy_from_slice(&currents);
        Ok(GemmRun { output: out, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::workload::snn::golden_currents;

    fn cfg(v: SnnVariant) -> SnnConfig {
        SnnConfig::paper_32x32(v)
    }

    #[test]
    fn crossbar_currents_match_golden() {
        for v in [SnnVariant::FireFly, SnnVariant::Enhanced] {
            let mut rng = XorShift::new(3);
            let mut eng = SnnEngine::new(cfg(v));
            let train = SpikeTrain::random(&mut rng, 12, 32, 1, 3);
            // Bounded weights keep 16-deep 12-bit lanes exact.
            let w = MatI8::random_bounded(&mut rng, 32, 32, 63);
            let (_, currents, _) = eng.run_snn(&train, &w).unwrap();
            let golden = golden_currents(&train, &w.data, 32);
            assert_eq!(currents, golden, "{v:?}");
        }
    }

    #[test]
    fn multi_pass_posts() {
        let mut rng = XorShift::new(5);
        let mut eng = SnnEngine::new(cfg(SnnVariant::Enhanced));
        let train = SpikeTrain::random(&mut rng, 8, 32, 1, 2);
        let w = MatI8::random_bounded(&mut rng, 32, 40, 50); // 3 passes
        let (_, currents, stats) = eng.run_snn(&train, &w).unwrap();
        assert_eq!(currents, golden_currents(&train, &w.data, 40));
        assert_eq!(stats.weight_loads, 3);
    }

    #[test]
    fn lif_spikes_binary_and_deterministic() {
        let mut rng = XorShift::new(7);
        let mut eng = SnnEngine::new(cfg(SnnVariant::Enhanced));
        let train = SpikeTrain::random(&mut rng, 10, 32, 1, 2);
        let w = MatI8::random_bounded(&mut rng, 32, 16, 30);
        let (s1, _, _) = eng.run_snn(&train, &w).unwrap();
        let (s2, _, _) = eng.run_snn(&train, &w).unwrap();
        assert_eq!(s1, s2);
        assert!(s1.iter().all(|&s| s <= 1));
    }

    #[test]
    fn gemm_view_matches_and_rejects_nonbinary() {
        let mut rng = XorShift::new(9);
        let mut eng = SnnEngine::new(cfg(SnnVariant::FireFly));
        let train = SpikeTrain::random(&mut rng, 6, 32, 1, 2);
        let a = MatI8 {
            rows: 6,
            cols: 32,
            data: train.spikes.iter().map(|&v| v as i8).collect(),
        };
        let w = MatI8::random_bounded(&mut rng, 32, 32, 40);
        let run = eng.run_gemm(&a, &w).unwrap();
        assert_eq!(
            run.output.data,
            golden_currents(&train, &w.data, 32)
        );

        let bad = MatI8 {
            rows: 1,
            cols: 32,
            data: vec![2; 32],
        };
        assert!(eng.run_gemm(&bad, &w).is_err());
    }

    #[test]
    fn silent_input_silent_output() {
        let mut eng = SnnEngine::new(cfg(SnnVariant::Enhanced));
        let train = SpikeTrain {
            steps: 4,
            neurons: 32,
            spikes: vec![0; 4 * 32],
        };
        let w = MatI8::from_fn(32, 32, |r, c| ((r + c) % 100) as i8);
        let (_, currents, stats) = eng.run_snn(&train, &w).unwrap();
        assert!(currents.iter().all(|&c| c == 0));
        assert_eq!(stats.macs, 0);
    }
}
