//! Spiking synaptic crossbar engines (FireFly-like) — paper §VI,
//! Table III.
//!
//! FireFly's crossbar drives the DSP48E2 *wide-bus multiplexers* with
//! spike bits: two synaptic weight sets sit on the A:B concatenation
//! and the C port (four INT8 weights each, one per SIMD=FOUR12 lane);
//! the pre-synaptic spikes select, per cycle, whether each set enters
//! the 48-bit ALU (`X = A:B | 0`, `Y = C | 0`), and the PCIN cascade
//! accumulates down a 16-slice chain — a 32-input, 4-lane synaptic
//! column per chain. Four chains make the 32×32 crossbar (two passes of
//! 16 post-neurons).
//!
//! * [`SnnVariant::FireFly`] — both weight sets' ping-pong registers in
//!   CLB flip-flops (the original).
//! * [`SnnVariant::Enhanced`] — the paper's §VI improvement: the A:B
//!   set's ping-pong absorbed into the A/B input pipelines via the
//!   ACIN/BCIN cascades (the in-DSP operand-prefetching technique); only
//!   the C set remains in fabric (no C cascade exists). Halves the
//!   flip-flop count (Table III: 4344 → 2296).

mod engine;

pub use engine::SnnEngine;

use crate::cost::resource::{Primitive, ResourceInventory};
use crate::cost::timing::{PathClass, TimingModel};
use crate::fabric::{ClockDomain, ClockPlan};

/// Which Table-III design to elaborate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnnVariant {
    FireFly,
    Enhanced,
}

impl SnnVariant {
    pub fn label(self) -> &'static str {
        match self {
            SnnVariant::FireFly => "FireFly",
            SnnVariant::Enhanced => "Ours",
        }
    }
}

/// Crossbar geometry.
#[derive(Debug, Clone, Copy)]
pub struct SnnConfig {
    pub variant: SnnVariant,
    /// DSP chains (horizontal replicas).
    pub chains: usize,
    /// Slices per chain (each = 2 pre-synaptic inputs).
    pub chain_len: usize,
    pub target_mhz: f64,
    /// LIF neuron parameters for [`SnnEngine::run_snn`].
    pub v_threshold: i32,
    pub leak_shift: u32,
}

impl SnnConfig {
    /// The paper's Table-III point: 32×32 crossbar, 4 chains × 16 DSPs.
    pub fn paper_32x32(variant: SnnVariant) -> Self {
        SnnConfig {
            variant,
            chains: 4,
            chain_len: 16,
            target_mhz: 666.0,
            v_threshold: 64,
            leak_shift: 3,
        }
    }

    /// Pre-synaptic inputs covered per pass.
    pub fn pre(&self) -> usize {
        self.chain_len * 2
    }

    /// Post-synaptic neurons per pass (4 FOUR12 lanes per chain).
    pub fn post_per_pass(&self) -> usize {
        self.chains * 4
    }

    pub fn clock_plan(&self) -> ClockPlan {
        ClockPlan::single(self.target_mhz)
    }
}

/// Calibrated control constant (Table III residual): load sequencer +
/// LIF update pipeline shared by both designs.
const SNN_CTRL_FF: usize = 248;
const SNN_CTRL_LUT: usize = 60;

/// Structural inventory (Table III at the 32×32 point).
pub fn snn_inventory(cfg: &SnnConfig) -> ResourceInventory {
    let mut inv = ResourceInventory::new();
    let d = ClockDomain::Slow;
    let dsps = cfg.chains * cfg.chain_len;
    // Spike-gated datapath: at typical firing rates most ALU inputs
    // are zero, so DSP switching activity is low — the reason FireFly's
    // measured power is small despite 64 busy-clocked slices.
    inv.add("crossbar chains", Primitive::Dsp, dsps, d, 0.45);
    // Each slice holds two 4-weight sets (4 × 8b = 32b per set). The
    // ping-pong shadow copy is what differs:
    match cfg.variant {
        SnnVariant::FireFly => {
            // Both sets shadowed in CLB flip-flops.
            inv.add("wgt ping-pong A:B set", Primitive::Ff, dsps * 32, d, 0.25);
            inv.add("wgt ping-pong C set", Primitive::Ff, dsps * 32, d, 0.25);
        }
        SnnVariant::Enhanced => {
            // A:B set prefetched through the A/B input pipelines +
            // cascades (in-DSP); only the C set needs fabric FFs.
            inv.add("wgt ping-pong C set", Primitive::Ff, dsps * 32, d, 0.25);
        }
    }
    inv.add("control: sequencer+LIF", Primitive::Ff, SNN_CTRL_FF, d, 0.3);
    inv.add("control: FSM", Primitive::Lut, SNN_CTRL_LUT, d, 0.3);
    inv
}

/// Timing: both designs ride the DSP cascade at 666 MHz (Table III).
pub fn snn_timing(cfg: &SnnConfig) -> TimingModel {
    TimingModel::new(cfg.target_mhz)
        .path("crossbar cascade", PathClass::DspInternal)
        .path("spike -> OPMODE", PathClass::StagedOperand)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_firefly_counts() {
        let inv = snn_inventory(&SnnConfig::paper_32x32(SnnVariant::FireFly));
        assert_eq!(inv.total(Primitive::Dsp), 64);
        assert_eq!(inv.total(Primitive::Ff), 4344);
        assert_eq!(inv.total(Primitive::Lut), 60);
    }

    #[test]
    fn table3_enhanced_counts() {
        let inv = snn_inventory(&SnnConfig::paper_32x32(SnnVariant::Enhanced));
        assert_eq!(inv.total(Primitive::Dsp), 64);
        assert_eq!(inv.total(Primitive::Ff), 2296);
        assert_eq!(inv.total(Primitive::Lut), 60);
    }

    #[test]
    fn both_meet_666() {
        for v in [SnnVariant::FireFly, SnnVariant::Enhanced] {
            let rep = snn_timing(&SnnConfig::paper_32x32(v)).report();
            assert!(rep.wns_ns > 0.0, "{}", v.label());
        }
    }
}
