//! The OS engine: pass/round orchestration over chains + accumulators.
//!
//! ## Edge schedule (one pass)
//!
//! A *pass* fixes a pixel block (`px_groups * 4` pixels) and an output-channel block
//! (`ocs()` channels) and streams all of K through the chains in
//! *rounds* of `ics_per_round()/2 = ic_groups × chain_len` input
//! channels per 4 fast edges (2 slow cycles). Within round `r`
//! (edges `4r .. 4r+3`, φ = edge mod 4):
//!
//! * **activations**: wave 0 (pixel pair 0) rides φ0/φ1, wave 1 rides
//!   φ2/φ3; the A port takes the hi pixel (<<18), the D port the lo
//!   pixel one edge later (D has one register stage vs A's two).
//! * **weights** (enhanced): CEB1 on φ2 loads next round's oc₁ weight,
//!   CEB2 (B2-direct mux) on φ3 loads oc₀ — one weight per slow cycle
//!   per slice, *half* the official bandwidth; INMODE[4] alternates
//!   every edge. Official: the CLB mux drives B every edge (two weights
//!   per slow cycle).
//! * **products**: M-captures at edge `m` map to
//!   `(wave, oc, round) = tag(m)` (see [`tag_of_m`]); the chain tail P
//!   word for `m` appears `len` edges later.
//! * **accumulation**: enhanced routes tail words into the per-chain-
//!   pair ring of the [`RingBank`] (chain B delayed two edges per the
//!   ring contract); official behaviorally models AddTree + S2P + two
//!   slow ONE48 accumulator DSPs per chain.
//!
//! Chain depth ≤ 7 keeps every packed cascade inside the guard band, so
//! the OS engines are exact for all INT8 inputs (the 24-bit ring lanes
//! bound K per pass instead — see `max_k_per_pass`).

use super::chain::{ChainArray, ChainDrive};
use super::inventory::{os_inventory, os_timing};
use super::ring::{respace_to_two24, two24_lanes, RingBank};
use super::{OsConfig, OsVariant};
use crate::cost::{ResourceInventory, TimingModel};
use crate::engines::{Engine, EngineError, GemmRun, RunStats};
use crate::exec::{self, Clocking, FillPlan, Scratch, TileKernel, TilePlan};
use crate::fabric::ClockPlan;
use crate::packing;
use crate::workload::{MatI32, MatI8};

/// Product tag: which (wave, oc-parity, round) an M-capture belongs to.
///
/// M edges for round r are `4r+3 .. 4r+6`; parity of the edge selects
/// the weight register (odd → B1 → oc₁).
fn tag_of_m(m: usize) -> Option<(usize, usize, usize)> {
    if m < 3 {
        return None;
    }
    let q = m - 3;
    let r = q / 4;
    let (wave, oc) = match q % 4 {
        0 => (0, 1),
        1 => (0, 0),
        2 => (1, 1),
        _ => (1, 0),
    };
    Some((wave, oc, r))
}

/// An output-stationary matrix engine (official DPU replicate or the
/// paper's enhanced design).
pub struct OsEngine {
    cfg: OsConfig,
    name: String,
    /// Every chain as one SoA array; chain
    /// `ci = (g * oc_pairs + o) * ic_groups + i` is column `ci`.
    chains: ChainArray,
    /// Enhanced: one ring per (g, o) chain pair, banked (empty bank for
    /// the official variant).
    rings: RingBank,
    /// Per-chain 1-edge D-port delay, flattened `[chain][slice]`.
    d_delay: Vec<i64>,
    /// Per-ring 2-edge chain-B word buffer.
    tailb_buf: Vec<[i64; 2]>,
    /// Per-ring staged feed words for the bank-wide ring tick.
    ring_wa: Vec<i64>,
    ring_wb: Vec<i64>,
    /// Behavioral slots for the accumulators, reused across passes:
    /// `[pair][wave][lane][oc]` (lane 0 = hi pixel, 1 = lo pixel).
    slots: Vec<[[[i64; 2]; 2]; 2]>,
    /// Reusable scratch arena for the edge loop's delay lines.
    scratch: Scratch,
}

impl OsEngine {
    pub fn new(cfg: OsConfig) -> Self {
        assert!(
            cfg.chain_len <= packing::GUARD_DEPTH,
            "chain_len {} would overflow the packed guard band",
            cfg.chain_len
        );
        let n_chains = cfg.chains();
        let n_pairs = cfg.px_groups * cfg.oc_pairs;
        // The chains' and rings' SoA register banks lease from the
        // engine's arena.
        let mut scratch = Scratch::new();
        let chains = ChainArray::new_in(cfg.variant, n_chains, cfg.chain_len, &mut scratch);
        let rings = RingBank::new_in(
            0,
            match cfg.variant {
                OsVariant::Enhanced => n_pairs,
                OsVariant::Official => 0,
            },
            &mut scratch,
        );
        OsEngine {
            name: format!("DPU-{} {}", cfg.variant.label(), b_tag(&cfg)),
            chains,
            rings,
            d_delay: vec![0; n_chains * cfg.chain_len],
            tailb_buf: vec![[0; 2]; n_pairs],
            ring_wa: vec![0; n_pairs],
            ring_wb: vec![0; n_pairs],
            slots: vec![[[[0; 2]; 2]; 2]; n_pairs],
            scratch,
            cfg,
        }
    }

    pub fn config(&self) -> &OsConfig {
        &self.cfg
    }

    /// Largest K one pass can accumulate without risking the 24-bit
    /// ring lanes (enhanced) for worst-case INT8 data. The coordinator
    /// splits larger K across passes. (official: 32-bit slots, no bound
    /// below i32 for practical K).
    pub fn max_k_per_pass(&self) -> usize {
        match self.cfg.variant {
            // |psum per round| <= chain_len * ic_groups * 2^14; lane
            // headroom 2^23.
            OsVariant::Enhanced => {
                let per_round = self.cfg.chain_len * self.cfg.ic_groups;
                ((1usize << 23) / ((per_round) << 14)) * per_round * 2
            }
            OsVariant::Official => usize::MAX,
        }
    }

    fn chain_idx(&self, g: usize, o: usize, i: usize) -> usize {
        (g * self.cfg.oc_pairs + o) * self.cfg.ic_groups + i
    }

    fn pair_idx(&self, g: usize, o: usize) -> usize {
        g * self.cfg.oc_pairs + o
    }

    /// Reset sequential state for a new pass (new stationary outputs).
    fn reset_pass(&mut self) {
        self.chains.reset();
        self.rings.reset();
        self.d_delay.iter_mut().for_each(|v| *v = 0);
        self.ring_wa.iter_mut().for_each(|v| *v = 0);
        self.ring_wb.iter_mut().for_each(|v| *v = 0);
        for b in &mut self.tailb_buf {
            *b = [0; 2];
        }
        for s in &mut self.slots {
            *s = [[[0; 2]; 2]; 2];
        }
    }

    /// One fast edge of a pass: tick every chain, then route the tail
    /// words into the accumulators. The edge loop itself lives in
    /// [`exec::run_tile`]; this is the OS datapath's cycle body.
    #[allow(clippy::too_many_arguments)]
    fn pass_edge(
        &mut self,
        e: usize,
        a: &MatI8,
        w: &MatI8,
        pb: usize,
        ob: usize,
        rounds: usize,
        scratch: &mut Scratch,
    ) {
        let cfg = self.cfg;
        let len = cfg.chain_len;
        let ics_round = cfg.ic_groups * len;

        let at = |row: usize, col: usize| -> i64 {
            if row < a.rows && col < a.cols {
                a.at(row, col) as i64
            } else {
                0
            }
        };
        let wt = |row: usize, col: usize| -> i64 {
            if row < w.rows && col < w.cols {
                w.at(row, col) as i64
            } else {
                0
            }
        };

        // --- tick every chain: one array-wide bank pass --------------
        // Slice j runs the shared schedule delayed by j edges (the
        // cascade adds one register stage per position), so every
        // per-slice quantity below derives from ej = e - j. The drive
        // for all chains is staged through the ChainArray and the whole
        // grid advances in a single SoA pass.
        //
        // §Perf: swap the flattened D-delay line out through the
        // scratch arena instead of cloning (or allocating) every edge.
        let ic_groups = cfg.ic_groups;
        let oc_pairs = cfg.oc_pairs;
        let d_prev = std::mem::take(&mut self.d_delay);
        let mut d_next = scratch.lease_i64(d_prev.len());
        self.chains.tick(|ci, j| {
            let i = ci % ic_groups;
            let o = (ci / ic_groups) % oc_pairs;
            let g = ci / (ic_groups * oc_pairs);
            let Some(ej) = e.checked_sub(j) else {
                return (ChainDrive::default(), 0, 0, 0);
            };
            let phi = ej % 4;
            let r = ej / 4;
            let wave = phi / 2;
            let use_b1 = ej % 2 == 1;
            let feeding = ej < 4 * rounds;
            let px_hi = pb * cfg.px_groups * 4 + g * 4 + wave * 2;
            let ic = r * ics_round + i * len + j;
            let (a_port, d_now) = if feeding {
                (at(px_hi, ic) << 18, at(px_hi + 1, ic))
            } else {
                (0, 0)
            };
            d_next[ci * len + j] = d_now;
            let (ceb1, ceb2, b_bus) = match cfg.variant {
                OsVariant::Enhanced => {
                    // ej%4 == 2 -> load oc1 into B1;
                    // ej%4 == 3 -> load oc0 into B2.
                    if feeding && phi == 2 {
                        (true, false, wt(ic, ob * cfg.ocs() + 2 * o + 1))
                    } else if feeding && phi == 3 {
                        (false, true, wt(ic, ob * cfg.ocs() + 2 * o))
                    } else {
                        (false, false, 0)
                    }
                }
                OsVariant::Official => {
                    // Reload B2 every edge with the
                    // weight the next M-capture needs.
                    let m = ej + 1;
                    let b = match tag_of_m(m) {
                        Some((_, oc, mr)) if mr < rounds => {
                            let ic_m = mr * ics_round + i * len + j;
                            wt(ic_m, ob * cfg.ocs() + 2 * o + oc)
                        }
                        _ => 0,
                    };
                    (false, true, b)
                }
            };
            (
                ChainDrive { use_b1, ceb1, ceb2 },
                a_port,
                d_prev[ci * len + j],
                b_bus,
            )
        });
        self.d_delay = d_next;
        scratch.release_i64(d_prev);

        // --- route tail words into accumulators ----------------------
        // The tag depends only on the edge number, so it is shared by
        // every chain pair.
        let valid_tag = e.checked_sub(len).and_then(tag_of_m).filter(|t| t.2 < rounds);
        match cfg.variant {
            OsVariant::Enhanced => {
                for g in 0..cfg.px_groups {
                    for o in 0..oc_pairs {
                        let pi = self.pair_idx(g, o);
                        let tail_a = self.chains.tail_p(self.chain_idx(g, o, 0));
                        let tail_b = if ic_groups > 1 {
                            self.chains.tail_p(self.chain_idx(g, o, 1))
                        } else {
                            0
                        };
                        // Ring: chain A now, chain B two edges later.
                        self.ring_wa[pi] = if valid_tag.is_some() {
                            respace_to_two24(tail_a)
                        } else {
                            0
                        };
                        let buf = self.tailb_buf[pi];
                        self.ring_wb[pi] = buf[1];
                        self.tailb_buf[pi] = [
                            if valid_tag.is_some() {
                                respace_to_two24(tail_b)
                            } else {
                                0
                            },
                            buf[0],
                        ];
                    }
                }
                // All rings advance in one bank-wide tick.
                self.rings.tick(&self.ring_wa, &self.ring_wb);
                // Capture final-round streams as they complete: the
                // stream whose last chain-B word entered THIS edge.
                if let Some(mb) = e.checked_sub(len + 2) {
                    if let Some((wv, oc, rr)) = tag_of_m(mb) {
                        if rr == rounds - 1 {
                            for pi in 0..self.rings.rings() {
                                let (lo, hi) = two24_lanes(self.rings.output(pi));
                                self.slots[pi][wv][0][oc] = hi;
                                self.slots[pi][wv][1][oc] = lo;
                            }
                        }
                    }
                }
            }
            OsVariant::Official => {
                // AddTree combines the pair, lanes unpacked with
                // correction, slow accumulators add.
                if let Some((wv, oc, _)) = valid_tag {
                    for g in 0..cfg.px_groups {
                        for o in 0..oc_pairs {
                            let pi = self.pair_idx(g, o);
                            let tail_a = self.chains.tail_p(self.chain_idx(g, o, 0));
                            let tail_b = if ic_groups > 1 {
                                self.chains.tail_p(self.chain_idx(g, o, 1))
                            } else {
                                0
                            };
                            let word = tail_a + tail_b;
                            let (hi, lo) = packing::unpack_prod(word);
                            self.slots[pi][wv][0][oc] += hi;
                            self.slots[pi][wv][1][oc] += lo;
                        }
                    }
                }
            }
        }
    }

    /// Drain the behavioral slots into the output matrix at pass end.
    #[allow(clippy::too_many_arguments)]
    fn drain_pass(
        &self,
        a: &MatI8,
        w: &MatI8,
        pb: usize,
        ob: usize,
        out: &mut MatI32,
        stats: &mut RunStats,
    ) {
        let cfg = self.cfg;
        for g in 0..cfg.px_groups {
            for o in 0..cfg.oc_pairs {
                let pi = self.pair_idx(g, o);
                for wv in 0..2 {
                    for lane in 0..2 {
                        let px = pb * cfg.px_groups * 4 + g * 4 + wv * 2 + lane;
                        if px >= a.rows {
                            continue;
                        }
                        for oc in 0..2 {
                            let n = ob * cfg.ocs() + 2 * o + oc;
                            if n >= w.cols {
                                continue;
                            }
                            out.set(px, n, self.slots[pi][wv][lane][oc] as i32);
                            stats.macs += a.cols as u64;
                        }
                    }
                }
            }
        }
    }
}

/// One OS pass (pixel block × oc block) adapted to the [`exec`] core.
struct OsPassKernel<'a> {
    eng: &'a mut OsEngine,
    a: &'a MatI8,
    w: &'a MatI8,
    out: &'a mut MatI32,
    pb: usize,
    ob: usize,
    rounds: usize,
}

impl TileKernel for OsPassKernel<'_> {
    fn plan(&self) -> TilePlan {
        // Payload: 4 fast edges per round. Tail: final M edge offset
        // (+2), chain latency, and the ring margin (+4) — the same
        // `last_m + len + 4` budget the edge schedule derives.
        TilePlan {
            // Weights stream *during* compute (in-DSP mux / CLB DDR
            // mux): no exposed fill, one weight load per round.
            fill: FillPlan {
                cycles: 0,
                exposed: 0,
                loads: self.rounds as u64,
            },
            stream_steps: 4 * self.rounds,
            drain_steps: self.eng.cfg.chain_len + 6,
            clocking: Clocking::DoubleRate,
            // OS streams weights during compute; there is no
            // stationary fill to reuse.
            reuse_fill: false,
        }
    }

    fn fill(&mut self, _scratch: &mut Scratch, _stats: &mut RunStats) {
        self.eng.reset_pass();
    }

    fn step(&mut self, e: usize, scratch: &mut Scratch, _stats: &mut RunStats) {
        self.eng
            .pass_edge(e, self.a, self.w, self.pb, self.ob, self.rounds, scratch);
    }

    fn drain(&mut self, _scratch: &mut Scratch, stats: &mut RunStats) {
        self.eng
            .drain_pass(self.a, self.w, self.pb, self.ob, self.out, stats);
    }
}

fn b_tag(cfg: &OsConfig) -> String {
    format!("B{}", cfg.peak_macs() * 2)
}

impl Engine for OsEngine {
    fn name(&self) -> &str {
        &self.name
    }

    fn inventory(&self) -> ResourceInventory {
        os_inventory(&self.cfg)
    }

    fn timing(&self) -> TimingModel {
        os_timing(&self.cfg)
    }

    fn clock_plan(&self) -> ClockPlan {
        self.cfg.clock_plan()
    }

    fn peak_macs_per_cycle(&self) -> u64 {
        self.cfg.peak_macs()
    }

    fn scratch_stats(&self) -> crate::exec::ScratchStats {
        self.scratch.stats()
    }

    fn run_gemm(&mut self, a: &MatI8, w: &MatI8) -> Result<GemmRun, EngineError> {
        if a.cols != w.rows {
            return Err(EngineError::Shape(format!(
                "inner dims disagree: {} vs {}",
                a.cols, w.rows
            )));
        }
        let k_cap = self.max_k_per_pass();
        if a.cols > k_cap {
            return Err(EngineError::Shape(format!(
                "K={} exceeds the 24-bit ring budget ({k_cap}); tile K",
                a.cols
            )));
        }
        let cfg = self.cfg;
        let mut out = MatI32::zeros(a.rows, w.cols);
        let mut stats = RunStats::default();
        let rounds = a.cols.div_ceil(cfg.ic_groups * cfg.chain_len).max(1);
        let px_blocks = a.rows.div_ceil(cfg.px_groups * 4).max(1);
        let oc_blocks = w.cols.div_ceil(cfg.ocs()).max(1);
        let mut scratch = std::mem::take(&mut self.scratch);
        for pb in 0..px_blocks {
            for ob in 0..oc_blocks {
                let mut kernel = OsPassKernel {
                    eng: self,
                    a,
                    w,
                    out: &mut out,
                    pb,
                    ob,
                    rounds,
                };
                exec::run_tile(&mut kernel, &mut scratch, &mut stats);
            }
        }
        self.scratch = scratch;
        Ok(GemmRun { output: out, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::workload::gemm::{golden_gemm, GemmProblem};

    #[test]
    fn tag_table() {
        assert_eq!(tag_of_m(3), Some((0, 1, 0)));
        assert_eq!(tag_of_m(4), Some((0, 0, 0)));
        assert_eq!(tag_of_m(5), Some((1, 1, 0)));
        assert_eq!(tag_of_m(6), Some((1, 0, 0)));
        assert_eq!(tag_of_m(7), Some((0, 1, 1)));
        assert_eq!(tag_of_m(2), None);
    }

    fn check(cfg: OsConfig, m: usize, k: usize, n: usize, seed: u64) {
        let mut eng = OsEngine::new(cfg);
        let p = GemmProblem::random(m, n, k, seed);
        let run = eng.run_gemm(&p.a, &p.w).unwrap();
        assert_eq!(
            run.output,
            golden_gemm(&p.a, &p.w),
            "{:?} m={m} k={k} n={n}",
            cfg.variant
        );
    }

    #[test]
    fn enhanced_tiny_exact_single_pass() {
        // tiny: ic_round = 6, ocs = 4, pixels block 8.
        check(OsConfig::tiny(OsVariant::Enhanced), 8, 6, 4, 1);
    }

    #[test]
    fn official_tiny_exact_single_pass() {
        check(OsConfig::tiny(OsVariant::Official), 8, 6, 4, 2);
    }

    #[test]
    fn multi_round_k() {
        for v in [OsVariant::Enhanced, OsVariant::Official] {
            check(OsConfig::tiny(v), 8, 30, 4, 3); // 5 rounds
        }
    }

    #[test]
    fn multi_block_m_and_n() {
        for v in [OsVariant::Enhanced, OsVariant::Official] {
            check(OsConfig::tiny(v), 20, 12, 10, 4); // 3 px blocks, 3 oc blocks
        }
    }

    #[test]
    fn ragged_everything() {
        for v in [OsVariant::Enhanced, OsVariant::Official] {
            check(OsConfig::tiny(v), 7, 11, 5, 5);
            check(OsConfig::tiny(v), 1, 1, 1, 6);
        }
    }

    #[test]
    fn b1024_scale_exact() {
        for v in [OsVariant::Enhanced, OsVariant::Official] {
            check(OsConfig::b1024(v), 16, 32, 32, 7);
        }
    }

    #[test]
    fn k_cap_enforced_for_ring() {
        let mut eng = OsEngine::new(OsConfig::tiny(OsVariant::Enhanced));
        let cap = eng.max_k_per_pass();
        let p = GemmProblem::random(8, 4, cap + 12, 8);
        assert!(matches!(
            eng.run_gemm(&p.a, &p.w),
            Err(EngineError::Shape(_))
        ));
    }

    #[test]
    fn throughput_accounting() {
        let mut eng = OsEngine::new(OsConfig::b1024(OsVariant::Enhanced));
        let p = GemmProblem::random(8, 16, 64, 9);
        let run = eng.run_gemm(&p.a, &p.w).unwrap();
        assert_eq!(run.stats.macs, 8 * 16 * 64);
        // One pass: 8 rounds * 4 edges + margins; utilization sane.
        let util = run.stats.utilization(eng.peak_macs_per_cycle());
        assert!(util > 0.2, "util {util}");
        assert!(util <= 1.0);
    }

    #[test]
    fn deterministic_rerun() {
        let mut eng = OsEngine::new(OsConfig::tiny(OsVariant::Enhanced));
        let p = GemmProblem::random(8, 4, 12, 10);
        let a = eng.run_gemm(&p.a, &p.w).unwrap();
        let b = eng.run_gemm(&p.a, &p.w).unwrap();
        assert_eq!(a.output, b.output);
    }

    /// Weight-bandwidth claim (paper §V-B): per slice, the enhanced
    /// engine loads one weight per slow cycle; the official needs two.
    #[test]
    fn weight_bandwidth_halved() {
        // Structural: enhanced loads on 2 of 4 edges (φ2, φ3) per round;
        // official reloads every edge. Verified against the schedule
        // constants rather than a counter: 2 loads / 2 slow cycles vs
        // 4 loads / 2 slow cycles.
        let enhanced_loads_per_round = 2;
        let official_loads_per_round = 4;
        assert_eq!(enhanced_loads_per_round * 2, official_loads_per_round);
    }

    #[test]
    fn worst_case_values_exact_short_chain() {
        // chain_len <= 7 keeps the packed cascade exact even for the
        // adversarial all--128 case.
        let mut eng = OsEngine::new(OsConfig::tiny(OsVariant::Enhanced));
        let a = MatI8::from_fn(8, 6, |_, _| -128);
        let w = MatI8::from_fn(6, 4, |_, _| -128);
        let run = eng.run_gemm(&a, &w).unwrap();
        assert_eq!(run.output, golden_gemm(&a, &w));
    }
}
