//! Output-stationary (Vitis-AI-DPU-like) systolic engines — paper §V,
//! Table II.
//!
//! ## The DPUCZDX8G B1024 structure (as reverse-engineered in §V)
//!
//! The engine is a grid of fast-clock DSP48E2 *chains* computing vector
//! inner products, organized along three parallelism axes:
//!
//! * **pixel parallelism** — two pixels ride the pre-adder INT8 packing
//!   (one wide multiply = two MACs), and pixel *groups* replicate chains;
//! * **input-channel parallelism** — `chain_len` DSPs cascade over PCIN,
//!   and `ic_groups` chains are combined by the grouped partial-sum
//!   adder (the official LUT AddTree / our ring accumulator);
//! * **output-channel parallelism** — the DDR technique evaluates two
//!   output channels per chain (weights alternate every fast cycle),
//!   and `oc_pairs` chain columns replicate.
//!
//! B1024 = `px_groups=2 × ic_groups=2 × oc_pairs=8` = 32 chains of 4
//! DSPs: 128 multiplier DSPs × 2 (packing) × 2 (DDR) = 512 MACs per
//! slow cycle = 1024 ops.
//!
//! ## Official vs enhanced
//!
//! [`OsVariant::Official`] replicates the DPU: CLB LUT muxes feed the
//! doubled-rate weights (drawbacks 1, 2), partial sums return to the
//! slow domain via S2P flip-flops, LUT adder trees combine the
//! ic-groups (drawback 4) and two slow SIMD=ONE48 accumulator DSPs per
//! chain finish the job (drawback 3).
//!
//! [`OsVariant::Enhanced`] applies the paper's §V-B/§V-C techniques:
//! **in-DSP multiplexing** (B1/B2 ping-pong + INMODE[4] toggling at
//! Clk×2 — no CLB muxes, weight bandwidth halved) and the **ring
//! accumulator** (two cascaded fast-clock DSPs in SIMD=TWO24 with the
//! packing correction + bias folded into the W-mux RND constant,
//! halving accumulator DSPs 64 → 32).

mod chain;
mod engine;
mod inventory;
mod ring;
pub mod waveforms;

pub use chain::{ChainArray, ChainDrive, MultChain};
pub use engine::OsEngine;
pub use inventory::{os_inventory, os_timing};
pub use ring::{RingAccumulator, RingBank};

use crate::fabric::ClockPlan;

/// Which Table-II design to elaborate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OsVariant {
    /// DPUCZDX8G replicate (CLB DDR mux + AddTree + slow accumulators).
    Official,
    /// In-DSP multiplexing + ring accumulator (the paper's design).
    Enhanced,
}

impl OsVariant {
    pub fn label(self) -> &'static str {
        match self {
            OsVariant::Official => "Official",
            OsVariant::Enhanced => "Ours",
        }
    }
}

/// OS engine geometry + policy.
#[derive(Debug, Clone, Copy)]
pub struct OsConfig {
    pub variant: OsVariant,
    /// Output-channel chain columns (each covers 2 output channels).
    pub oc_pairs: usize,
    /// Pixel-group replicas (each covers 2 packed pixels).
    pub px_groups: usize,
    /// Input-channel groups combined per output (AddTree / ring).
    pub ic_groups: usize,
    /// DSPs per chain.
    pub chain_len: usize,
    /// Fast-domain clock (MHz); slow domain runs at half.
    pub fast_mhz: f64,
}

impl OsConfig {
    /// The paper's Table-II point: DPU B1024 on XCZU3EG at 333/666 MHz.
    pub fn b1024(variant: OsVariant) -> Self {
        OsConfig {
            variant,
            oc_pairs: 8,
            px_groups: 2,
            ic_groups: 2,
            chain_len: 4,
            fast_mhz: 666.0,
        }
    }

    /// A small configuration for fast exhaustive testing.
    pub fn tiny(variant: OsVariant) -> Self {
        OsConfig {
            variant,
            oc_pairs: 2,
            px_groups: 1,
            ic_groups: 2,
            chain_len: 3,
            fast_mhz: 666.0,
        }
    }

    pub fn chains(&self) -> usize {
        self.oc_pairs * self.px_groups * self.ic_groups
    }

    /// Multiplier DSP count.
    pub fn mult_dsps(&self) -> usize {
        self.chains() * self.chain_len
    }

    /// Accumulator DSP count for this variant.
    pub fn acc_dsps(&self) -> usize {
        match self.variant {
            OsVariant::Official => self.chains() * 2,
            OsVariant::Enhanced => self.chains(), // 2 per ic-group pair
        }
    }

    /// Pixels processed in parallel per slow cycle.
    pub fn pixels(&self) -> usize {
        self.px_groups * 2
    }

    /// Input channels consumed per accumulation round (2 slow cycles).
    pub fn ics_per_round(&self) -> usize {
        self.ic_groups * self.chain_len * 2
    }

    /// Output channels covered per pass.
    pub fn ocs(&self) -> usize {
        self.oc_pairs * 2
    }

    /// Peak MACs per slow cycle.
    pub fn peak_macs(&self) -> u64 {
        (self.mult_dsps() * 2 * 2) as u64
    }

    pub fn clock_plan(&self) -> ClockPlan {
        ClockPlan {
            slow_mhz: self.fast_mhz / 2.0,
            fast_mhz: self.fast_mhz,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn b1024_geometry_matches_paper() {
        let cfg = OsConfig::b1024(OsVariant::Official);
        assert_eq!(cfg.chains(), 32);
        assert_eq!(cfg.mult_dsps(), 128);
        assert_eq!(cfg.acc_dsps(), 64);
        assert_eq!(cfg.peak_macs(), 512); // = B1024 / 2 ops
        let ours = OsConfig::b1024(OsVariant::Enhanced);
        assert_eq!(ours.acc_dsps(), 32); // halved
        assert_eq!(ours.peak_macs(), 512); // same throughput
    }
}
