//! Structural inventories + timing for the two OS designs (Table II).
//!
//! At the B1024 point the formulas reproduce the paper's breakdown
//! cell-for-cell (asserted by `rust/tests/table2.rs`). Bus widths:
//! WgtWidth = 64 weight slots/slow-cycle × 8b = 512b for both designs
//! (the px-group replicas share the weight bus); ImgWidth is 512b for
//! the official (acts re-delivered every fast cycle through the DDR
//! muxes) and 256b for ours (the A1/A2 in-DSP pipeline absorbs the
//! doubling — paper §V-B).

use super::{OsConfig, OsVariant};
use crate::cost::resource::{Primitive, ResourceInventory};
use crate::cost::timing::{PathClass, TimingModel};
use crate::fabric::ClockDomain;

/// Official replicate's residual control (Vivado glue), Table II.
const OFFICIAL_CTRL_FF: usize = 112;
/// Our design's sequencing + CE-waveform control per chain pair (28 FF)
/// — larger than the official's because the CEB1/CEB2/INMODE waveform
/// generators live here instead of LUT muxes.
const ENH_CTRL_FF_PER_PAIR: usize = 28;
/// Our design's drain/control LUTs (Table II "TotalLUT: 158").
const ENH_CTRL_LUT: usize = 158;

pub fn os_inventory(cfg: &OsConfig) -> ResourceInventory {
    let mut inv = ResourceInventory::new();
    let fast = ClockDomain::Fast;
    let slow = ClockDomain::Slow;
    let chains = cfg.chains();
    let pairs = cfg.px_groups * cfg.oc_pairs;
    // Weight bus: distinct (oc_pair, ic_group, slice) slots × 8b.
    let wgt_bus_bits = cfg.oc_pairs * cfg.ic_groups * cfg.chain_len * 2 * 8 / 2;

    // Official mult DSPs see new operands every fast edge (DDR mux);
    // ours alternate B1/B2 (half the weight-side switching).
    let mult_act = match cfg.variant {
        OsVariant::Official => 1.0,
        OsVariant::Enhanced => 0.9,
    };
    inv.add("mult chains", Primitive::Dsp, cfg.mult_dsps(), fast, mult_act);

    match cfg.variant {
        OsVariant::Official => {
            inv.add("slow accumulators", Primitive::Dsp, cfg.acc_dsps(), slow, 0.9);
            // One 8-bit 2:1 DDR mux per chain pair (weights broadcast to
            // both ic-group chains): MuxLUT.
            inv.add("DDR weight mux", Primitive::Lut, pairs * 8, fast, 0.9);
            // AddTree per chain pair: two 36b lanes (72 LUT + 12 CARRY8)
            // plus 76 pipeline FFs.
            inv.add("AddTree comb", Primitive::Lut, pairs * 72, slow, 0.9);
            inv.add("AddTree regs", Primitive::Ff, pairs * 76, slow, 0.9);
            inv.add("AddTree carry", Primitive::Carry8, pairs * 12, slow, 0.9);
            // Psum: accumulator output regs (36b each) + S2P (36b/chain).
            inv.add("psum acc regs", Primitive::Ff, cfg.acc_dsps() * 36, slow, 0.9);
            inv.add("psum S2P regs", Primitive::Ff, chains * 36, fast, 0.9);
            // Staging: wgt and img buses × (ping + pong + output stage);
            // official img runs at the doubled rate -> full 512b.
            inv.add("wgt staging", Primitive::Ff, wgt_bus_bits * 3, slow, 0.5);
            // Official image staging runs at the doubled delivery rate.
            inv.add("img staging", Primitive::Ff, wgt_bus_bits * 3, slow, 0.9);
            inv.add("control: misc", Primitive::Ff, OFFICIAL_CTRL_FF, slow, 0.2);
        }
        OsVariant::Enhanced => {
            inv.add("ring accumulators", Primitive::Dsp, cfg.acc_dsps(), fast, 0.9);
            // Ring delay pair (48b × 2 per ring) — doubles as the S2P.
            inv.add(
                "psum ring delay+S2P",
                Primitive::Ff,
                pairs * 2 * 48,
                fast,
                0.9,
            );
            // Drain buffer: 4 streams × 30b per ring.
            inv.add("psum drain buffer", Primitive::Ff, pairs * 120, slow, 0.5);
            inv.add("wgt staging", Primitive::Ff, wgt_bus_bits * 3, slow, 0.5);
            // Img staging halved: the A1/A2 pipeline absorbs the DDR
            // re-delivery (in-DSP multiplexing).
            inv.add("img staging", Primitive::Ff, wgt_bus_bits * 3 / 2, slow, 0.5);
            inv.add(
                "control: CE wavegen",
                Primitive::Ff,
                pairs * ENH_CTRL_FF_PER_PAIR,
                slow,
                0.3,
            );
            inv.add("control: drain+FSM", Primitive::Lut, ENH_CTRL_LUT, slow, 0.3);
        }
    }
    inv
}

/// Timing models calibrated to Table II's WNS cells (666 MHz fast clock).
pub fn os_timing(cfg: &OsConfig) -> TimingModel {
    let t = TimingModel::new(cfg.fast_mhz);
    match cfg.variant {
        // Official: the CLB DDR mux crossing binds (paper WNS 0.095 ->
        // 1.4065 ns). The replicate places the mux column adjacent to
        // the DSP tile: -0.0235 ns vs the generic crossing model.
        OsVariant::Official => t
            .path_d(
                "CLB DDR mux -> DSP B",
                PathClass::CrossDomainMux { lut_stages: 1 },
                -0.0235,
            )
            .path("psum cascade", PathClass::DspInternal),
        // Ours: everything rides the DSP cascade (paper WNS 0.116 ->
        // 1.3855 ns = cascade + 0.0015 routing).
        OsVariant::Enhanced => t
            .path_d("psum cascade + ring", PathClass::DspInternal, 0.0015)
            .path("act staging -> A", PathClass::StagedOperand),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_official_breakdown() {
        let inv = os_inventory(&OsConfig::b1024(OsVariant::Official));
        assert_eq!(inv.total(Primitive::Dsp), 192); // 128 mult + 64 acc
        assert_eq!(inv.total_matching(Primitive::Dsp, "mult"), 128);
        assert_eq!(inv.total_matching(Primitive::Dsp, "accumulators"), 64);
        assert_eq!(inv.total_matching(Primitive::Lut, "DDR weight mux"), 128);
        assert_eq!(inv.total_matching(Primitive::Lut, "AddTree"), 1152);
        assert_eq!(inv.total_matching(Primitive::Ff, "AddTree"), 1216);
        assert_eq!(inv.total_matching(Primitive::Carry8, "AddTree"), 192);
        assert_eq!(inv.total_matching(Primitive::Ff, "psum"), 3456);
        assert_eq!(
            inv.total_matching(Primitive::Ff, "wgt staging")
                + inv.total_matching(Primitive::Ff, "img staging"),
            3072
        );
        assert_eq!(inv.total(Primitive::Lut), 1280);
        assert_eq!(inv.total(Primitive::Ff), 7856);
    }

    #[test]
    fn table2_enhanced_breakdown() {
        let inv = os_inventory(&OsConfig::b1024(OsVariant::Enhanced));
        assert_eq!(inv.total(Primitive::Dsp), 160); // 128 mult + 32 ring
        assert_eq!(inv.total_matching(Primitive::Dsp, "ring"), 32);
        assert_eq!(inv.total_matching(Primitive::Lut, "mux"), 0);
        assert_eq!(inv.total_matching(Primitive::Lut, "AddTree"), 0);
        assert_eq!(inv.total_matching(Primitive::Ff, "psum"), 3456);
        assert_eq!(inv.total(Primitive::Lut), 158);
        assert_eq!(inv.total(Primitive::Ff), 6208);
        assert_eq!(inv.total(Primitive::Carry8), 0);
    }

    #[test]
    fn timing_matches_paper_wns() {
        let off = os_timing(&OsConfig::b1024(OsVariant::Official)).report();
        assert!((off.wns_ns - 0.095).abs() < 0.01, "official {}", off.wns_ns);
        let ours = os_timing(&OsConfig::b1024(OsVariant::Enhanced)).report();
        assert!((ours.wns_ns - 0.116).abs() < 0.01, "ours {}", ours.wns_ns);
        assert!(ours.wns_ns > off.wns_ns, "more margin, paper's claim");
    }

    #[test]
    fn enhanced_saves_resources_at_any_geometry() {
        for (ocp, pxg, icg, len) in [(2, 1, 2, 3), (8, 2, 2, 4), (4, 2, 2, 6)] {
            let mk = |variant| OsConfig {
                variant,
                oc_pairs: ocp,
                px_groups: pxg,
                ic_groups: icg,
                chain_len: len,
                fast_mhz: 666.0,
            };
            let off = os_inventory(&mk(OsVariant::Official));
            let ours = os_inventory(&mk(OsVariant::Enhanced));
            assert!(ours.total(Primitive::Lut) < off.total(Primitive::Lut));
            assert!(ours.total(Primitive::Ff) < off.total(Primitive::Ff));
            assert!(ours.total(Primitive::Dsp) < off.total(Primitive::Dsp));
        }
    }
}
