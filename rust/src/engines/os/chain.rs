//! A fast-clock DSP48E2 multiplier chain (one DPU inner-product lane).
//!
//! `chain_len` slices cascade over PCIN; every slice packs two pixels
//! through the pre-adder (A = hi·2¹⁸, D = lo) and multiplies by its
//! input channel's weight. Weight delivery differs by variant:
//!
//! * **Enhanced** (in-DSP multiplexing): B1/B2 hold the two output
//!   channels' weights, reloaded via the B2-direct input mux on
//!   dedicated edges (one weight per slow cycle — half the official
//!   bandwidth), INMODE[4] alternating each fast cycle.
//! * **Official** (CLB DDR mux): a fabric [`LutMux`] drives the B port
//!   every fast cycle with the alternating weight (two weights per slow
//!   cycle — the doubled-bandwidth drawback).
//!
//! The chain state lives in a [`DspColumn`] (struct-of-arrays register
//! banks): the engine's per-slice drive is staged into SoA operand
//! banks and the three controls the schedule skews per slice —
//! INMODE[4], CEB1, CEB2 — become bitmasks, so one
//! [`DspColumn::tick_os_chain`] pass advances the whole cascade with
//! no per-cell `DspInputs`. The chain is pure datapath; the engine
//! owns the edge schedule and output tagging (see `engine.rs`).

use super::OsVariant;
use crate::dsp::{Attributes, DspColumn, DspRegs};
use crate::exec::Scratch;
use crate::fabric::{ClockDomain, LutMux};

/// One multiplier chain.
pub struct MultChain {
    /// SoA register banks for the `chain_len` cascade slices.
    col: DspColumn,
    /// Official-variant DDR weight mux (one 8-bit 2:1 LUT mux per chain
    /// pair in the inventory; modeled per chain here for activity).
    mux: Option<LutMux>,
    /// SoA operand staging, refilled from the per-slice drive each
    /// edge (§Perf: one column pass instead of `len` cell ticks).
    a_ops: Vec<i64>,
    d_ops: Vec<i64>,
    b_ops: Vec<i64>,
}

/// Per-edge drive for one chain (engine-provided).
#[derive(Debug, Clone, Copy, Default)]
pub struct ChainDrive {
    /// A-port value per slice is identical in *form*: hi pixel << 18.
    /// The engine passes per-slice values via the callback instead when
    /// input channels differ (always, in practice) — this struct carries
    /// the shared controls.
    pub use_b1: bool,
    /// Load B1 from the weight bus this edge (enhanced).
    pub ceb1: bool,
    /// Load B2 (direct input mux) from the weight bus this edge.
    pub ceb2: bool,
}

fn chain_attrs(variant: OsVariant) -> Attributes {
    match variant {
        OsVariant::Enhanced => Attributes::os_inmux_pe(),
        // Official: B arrives from the CLB mux every fast cycle;
        // single B register (B2 direct), same A/D packing pipeline.
        OsVariant::Official => Attributes {
            breg: 1,
            amultsel: crate::dsp::MultSel::Ad,
            dreg: true,
            adreg: true,
            ..Attributes::default()
        },
    }
}

impl MultChain {
    /// A chain whose register banks lease from `scratch` (the engine's
    /// arena).
    pub fn new_in(variant: OsVariant, chain_len: usize, scratch: &mut Scratch) -> Self {
        assert!(chain_len <= 64, "chain controls carry one bit per slice");
        MultChain {
            col: DspColumn::new_in(chain_attrs(variant), chain_len, scratch),
            mux: match variant {
                OsVariant::Official => Some(LutMux::new(8, ClockDomain::Fast)),
                OsVariant::Enhanced => None,
            },
            a_ops: scratch.lease_i64(chain_len),
            d_ops: scratch.lease_i64(chain_len),
            b_ops: scratch.lease_i64(chain_len),
        }
    }

    /// A free-standing chain (fresh allocations, no arena).
    pub fn new(variant: OsVariant, chain_len: usize) -> Self {
        Self::new_in(variant, chain_len, &mut Scratch::new())
    }

    pub fn len(&self) -> usize {
        self.col.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.col.rows() == 0
    }

    /// One fast edge. `per_slice(j)` returns the slice's controls and
    /// `(a_port, d_port, b_bus)` operands. Controls are per-slice
    /// because the PCIN cascade adds one register stage per position:
    /// slice `j` runs the shared schedule delayed by `j` edges (the
    /// DPU's per-position staging registers).
    ///
    /// For the official variant the `b_bus` value is what the CLB mux
    /// outputs this edge (the engine sequences the DDR alternation;
    /// activity is counted here). The official multiplier always reads
    /// B2 (single B register); only the enhanced design toggles
    /// INMODE[4].
    pub fn tick(
        &mut self,
        mut per_slice: impl FnMut(usize) -> (ChainDrive, i64, i64, i64),
    ) {
        let len = self.col.rows();
        let official = self.mux.is_some();
        let (mut use_b1, mut ceb1, mut ceb2) = (0u64, 0u64, 0u64);
        for j in 0..len {
            let (drive, a, d, b_bus) = per_slice(j);
            let b = if let Some(mux) = self.mux.as_mut() {
                mux.select(drive.use_b1, b_bus, b_bus)
            } else {
                b_bus
            };
            if !official && drive.use_b1 {
                use_b1 |= 1 << j;
            }
            if drive.ceb1 {
                ceb1 |= 1 << j;
            }
            if drive.ceb2 {
                ceb2 |= 1 << j;
            }
            self.a_ops[j] = a;
            self.d_ops[j] = d;
            self.b_ops[j] = b;
        }
        self.col.tick_os_chain(
            &self.a_ops,
            &self.d_ops,
            &self.b_ops,
            use_b1,
            ceb1,
            ceb2,
        );
    }

    /// The cascade tail's P register (post-edge).
    pub fn tail_p(&self) -> i64 {
        let len = self.col.rows();
        assert!(len > 0, "chain is non-empty");
        self.col.p(len - 1)
    }

    /// Pipeline latency from an A-port sample to the tail P:
    /// A1, A2, AD, M, P = 4 edges, plus one per extra cascade stage.
    pub fn latency(&self) -> usize {
        4 + (self.col.rows() - 1)
    }

    pub fn reset(&mut self) {
        self.col.reset();
    }

    /// Observed B-register state (debug/waveform).
    pub fn b_regs(&self, j: usize) -> (i64, i64) {
        let r = self.regs(j);
        (r.b1, r.b2)
    }

    /// Slice `j`'s full register snapshot (debug/waveform).
    pub fn regs(&self, j: usize) -> DspRegs {
        self.col.regs(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Constant operands through an enhanced chain: tail must converge
    /// to the packed dot product across slices.
    #[test]
    fn enhanced_chain_accumulates_dot() {
        let len = 3;
        let mut chain = MultChain::new(OsVariant::Enhanced, len);
        // Load both weight regs with the same value per slice first.
        let w = [5i64, -3, 7];
        // Two setup edges: CEB2 (direct) then CEB1.
        for (ceb1, ceb2) in [(false, true), (true, false)] {
            chain.tick(|j| {
                (
                    ChainDrive {
                        use_b1: false,
                        ceb1,
                        ceb2,
                    },
                    0,
                    0,
                    w[j],
                )
            });
        }
        // Stream constant packed pixels (hi=2, lo=1).
        let a = 2i64 << 18;
        let d = 1i64;
        for _ in 0..16 {
            chain.tick(|_| {
                (
                    ChainDrive {
                        use_b1: false,
                        ceb1: false,
                        ceb2: false,
                    },
                    a,
                    d,
                    0,
                )
            });
        }
        let (hi, lo) = crate::packing::unpack_prod(chain.tail_p());
        let dot: i64 = w.iter().sum();
        assert_eq!(hi, 2 * dot);
        assert_eq!(lo, dot);
    }

    #[test]
    fn b1_b2_hold_different_weights() {
        let mut chain = MultChain::new(OsVariant::Enhanced, 1);
        // CEB2 edge loads B2 directly; CEB1 edge loads B1 — different
        // values, neither disturbing the other (the in-DSP mux setup).
        chain.tick(|_| {
            (ChainDrive { use_b1: false, ceb1: false, ceb2: true }, 0, 0, 11)
        });
        chain.tick(|_| {
            (ChainDrive { use_b1: false, ceb1: true, ceb2: false }, 0, 0, 22)
        });
        assert_eq!(chain.b_regs(0), (22, 11));
    }

    #[test]
    fn latency_formula() {
        let chain = MultChain::new(OsVariant::Enhanced, 4);
        assert_eq!(chain.latency(), 7);
    }
}
