//! Fast-clock DSP48E2 multiplier chains (DPU inner-product lanes).
//!
//! `chain_len` slices cascade over PCIN; every slice packs two pixels
//! through the pre-adder (A = hi·2¹⁸, D = lo) and multiplies by its
//! input channel's weight. Weight delivery differs by variant:
//!
//! * **Enhanced** (in-DSP multiplexing): B1/B2 hold the two output
//!   channels' weights, reloaded via the B2-direct input mux on
//!   dedicated edges (one weight per slow cycle — half the official
//!   bandwidth), INMODE[4] alternating each fast cycle.
//! * **Official** (CLB DDR mux): a fabric [`LutMux`] drives the B port
//!   every fast cycle with the alternating weight (two weights per slow
//!   cycle — the doubled-bandwidth drawback).
//!
//! All of an engine's chains live in one [`ChainArray`]: a [`DspArray`]
//! whose columns are the chains (`[chain][slice]` banks), plus
//! array-wide SoA operand staging and per-chain control masks. The
//! engine's per-slice drive is staged once for the whole array, then a
//! single [`DspArray::tick_os_chain`] bank pass advances every cascade
//! — no per-chain column loop, no per-cell `DspInputs`. The three
//! controls the schedule skews per slice — INMODE[4], CEB1, CEB2 —
//! stay bitmasks, one word per chain. The chains are pure datapath;
//! the engine owns the edge schedule and output tagging (see
//! `engine.rs`).
//!
//! [`MultChain`] remains as the single-chain view (a `ChainArray` of
//! one) for unit tests and waveform probes.

use super::OsVariant;
use crate::dsp::{Attributes, DspArray, DspRegs};
use crate::exec::Scratch;
use crate::fabric::{ClockDomain, LutMux};

/// Per-edge drive for one chain slice (engine-provided).
#[derive(Debug, Clone, Copy, Default)]
pub struct ChainDrive {
    /// A-port value per slice is identical in *form*: hi pixel << 18.
    /// The engine passes per-slice values via the callback instead when
    /// input channels differ (always, in practice) — this struct carries
    /// the shared controls.
    pub use_b1: bool,
    /// Load B1 from the weight bus this edge (enhanced).
    pub ceb1: bool,
    /// Load B2 (direct input mux) from the weight bus this edge.
    pub ceb2: bool,
}

fn chain_attrs(variant: OsVariant) -> Attributes {
    match variant {
        OsVariant::Enhanced => Attributes::os_inmux_pe(),
        // Official: B arrives from the CLB mux every fast cycle;
        // single B register (B2 direct), same A/D packing pipeline.
        OsVariant::Official => Attributes {
            breg: 1,
            amultsel: crate::dsp::MultSel::Ad,
            dreg: true,
            adreg: true,
            ..Attributes::default()
        },
    }
}

/// Every multiplier chain of an OS engine as one SoA array: chain `c`
/// is column `c` of the [`DspArray`], slice `j` its row `j`.
pub struct ChainArray {
    /// Array-wide register banks: `[chain][slice]` layout.
    arr: DspArray,
    /// Official-variant DDR weight muxes (one 8-bit 2:1 LUT mux per
    /// chain pair in the inventory; modeled per chain here for
    /// activity). Empty for the enhanced variant.
    muxes: Vec<LutMux>,
    /// Array-wide SoA operand staging, refilled from the per-slice
    /// drive each edge.
    a_ops: Vec<i64>,
    d_ops: Vec<i64>,
    b_ops: Vec<i64>,
    /// Per-chain control masks (bit `j` = slice `j`).
    use_b1: Vec<u64>,
    ceb1: Vec<u64>,
    ceb2: Vec<u64>,
}

impl ChainArray {
    /// `chains` multiplier chains of `chain_len` slices whose register
    /// banks lease from `scratch` (the engine's arena).
    pub fn new_in(
        variant: OsVariant,
        chains: usize,
        chain_len: usize,
        scratch: &mut Scratch,
    ) -> Self {
        assert!(chain_len <= 64, "chain controls carry one bit per slice");
        let n = chains * chain_len;
        ChainArray {
            arr: DspArray::new_in(chain_attrs(variant), chain_len, chains, scratch),
            muxes: match variant {
                OsVariant::Official => (0..chains)
                    .map(|_| LutMux::new(8, ClockDomain::Fast))
                    .collect(),
                OsVariant::Enhanced => Vec::new(),
            },
            a_ops: scratch.lease_i64(n),
            d_ops: scratch.lease_i64(n),
            b_ops: scratch.lease_i64(n),
            use_b1: vec![0; chains],
            ceb1: vec![0; chains],
            ceb2: vec![0; chains],
        }
    }

    /// A free-standing chain array (fresh allocations, no arena).
    pub fn new(variant: OsVariant, chains: usize, chain_len: usize) -> Self {
        Self::new_in(variant, chains, chain_len, &mut Scratch::new())
    }

    /// Number of chains.
    pub fn chains(&self) -> usize {
        self.arr.cols()
    }

    /// Slices per chain.
    pub fn len(&self) -> usize {
        self.arr.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.arr.rows() == 0
    }

    /// One fast edge of every chain. `per_slice(chain, j)` returns that
    /// slice's controls and `(a_port, d_port, b_bus)` operands.
    /// Controls are per-slice because the PCIN cascade adds one
    /// register stage per position: slice `j` runs the shared schedule
    /// delayed by `j` edges (the DPU's per-position staging registers).
    ///
    /// For the official variant the `b_bus` value is what the CLB mux
    /// outputs this edge (the engine sequences the DDR alternation;
    /// activity is counted here). The official multiplier always reads
    /// B2 (single B register); only the enhanced design toggles
    /// INMODE[4].
    pub fn tick(&mut self, mut per_slice: impl FnMut(usize, usize) -> (ChainDrive, i64, i64, i64)) {
        let (chains, len) = (self.arr.cols(), self.arr.rows());
        let official = !self.muxes.is_empty();
        for ci in 0..chains {
            let base = ci * len;
            let (mut ub, mut c1, mut c2) = (0u64, 0u64, 0u64);
            for j in 0..len {
                let (drive, a, d, b_bus) = per_slice(ci, j);
                let b = if official {
                    self.muxes[ci].select(drive.use_b1, b_bus, b_bus)
                } else {
                    b_bus
                };
                if !official && drive.use_b1 {
                    ub |= 1 << j;
                }
                if drive.ceb1 {
                    c1 |= 1 << j;
                }
                if drive.ceb2 {
                    c2 |= 1 << j;
                }
                self.a_ops[base + j] = a;
                self.d_ops[base + j] = d;
                self.b_ops[base + j] = b;
            }
            self.use_b1[ci] = ub;
            self.ceb1[ci] = c1;
            self.ceb2[ci] = c2;
        }
        self.arr.tick_os_chain(
            &self.a_ops,
            &self.d_ops,
            &self.b_ops,
            &self.use_b1,
            &self.ceb1,
            &self.ceb2,
        );
    }

    /// Chain `chain`'s cascade-tail P register (post-edge).
    pub fn tail_p(&self, chain: usize) -> i64 {
        let len = self.arr.rows();
        assert!(len > 0, "chains are non-empty");
        self.arr.p(chain, len - 1)
    }

    /// Pipeline latency from an A-port sample to the tail P:
    /// A1, A2, AD, M, P = 4 edges, plus one per extra cascade stage.
    pub fn latency(&self) -> usize {
        4 + (self.arr.rows() - 1)
    }

    pub fn reset(&mut self) {
        self.arr.reset();
    }

    /// Slice `(chain, j)`'s full register snapshot (debug/waveform).
    pub fn regs(&self, chain: usize, j: usize) -> DspRegs {
        self.arr.regs(chain, j)
    }
}

/// One multiplier chain — the single-chain view of [`ChainArray`], kept
/// for unit tests and waveform probes.
pub struct MultChain {
    chains: ChainArray,
}

impl MultChain {
    /// A chain whose register banks lease from `scratch`.
    pub fn new_in(variant: OsVariant, chain_len: usize, scratch: &mut Scratch) -> Self {
        MultChain {
            chains: ChainArray::new_in(variant, 1, chain_len, scratch),
        }
    }

    /// A free-standing chain (fresh allocations, no arena).
    pub fn new(variant: OsVariant, chain_len: usize) -> Self {
        Self::new_in(variant, chain_len, &mut Scratch::new())
    }

    pub fn len(&self) -> usize {
        self.chains.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chains.is_empty()
    }

    /// One fast edge; see [`ChainArray::tick`].
    pub fn tick(&mut self, mut per_slice: impl FnMut(usize) -> (ChainDrive, i64, i64, i64)) {
        self.chains.tick(|_, j| per_slice(j));
    }

    /// The cascade tail's P register (post-edge).
    pub fn tail_p(&self) -> i64 {
        self.chains.tail_p(0)
    }

    /// Pipeline latency from an A-port sample to the tail P.
    pub fn latency(&self) -> usize {
        self.chains.latency()
    }

    pub fn reset(&mut self) {
        self.chains.reset();
    }

    /// Observed B-register state (debug/waveform).
    pub fn b_regs(&self, j: usize) -> (i64, i64) {
        let r = self.regs(j);
        (r.b1, r.b2)
    }

    /// Slice `j`'s full register snapshot (debug/waveform).
    pub fn regs(&self, j: usize) -> DspRegs {
        self.chains.regs(0, j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Constant operands through an enhanced chain: tail must converge
    /// to the packed dot product across slices.
    #[test]
    fn enhanced_chain_accumulates_dot() {
        let len = 3;
        let mut chain = MultChain::new(OsVariant::Enhanced, len);
        // Load both weight regs with the same value per slice first.
        let w = [5i64, -3, 7];
        // Two setup edges: CEB2 (direct) then CEB1.
        for (ceb1, ceb2) in [(false, true), (true, false)] {
            chain.tick(|j| {
                (
                    ChainDrive {
                        use_b1: false,
                        ceb1,
                        ceb2,
                    },
                    0,
                    0,
                    w[j],
                )
            });
        }
        // Stream constant packed pixels (hi=2, lo=1).
        let a = 2i64 << 18;
        let d = 1i64;
        for _ in 0..16 {
            chain.tick(|_| {
                (
                    ChainDrive {
                        use_b1: false,
                        ceb1: false,
                        ceb2: false,
                    },
                    a,
                    d,
                    0,
                )
            });
        }
        let (hi, lo) = crate::packing::unpack_prod(chain.tail_p());
        let dot: i64 = w.iter().sum();
        assert_eq!(hi, 2 * dot);
        assert_eq!(lo, dot);
    }

    #[test]
    fn b1_b2_hold_different_weights() {
        let mut chain = MultChain::new(OsVariant::Enhanced, 1);
        // CEB2 edge loads B2 directly; CEB1 edge loads B1 — different
        // values, neither disturbing the other (the in-DSP mux setup).
        chain.tick(|_| {
            (
                ChainDrive {
                    use_b1: false,
                    ceb1: false,
                    ceb2: true,
                },
                0,
                0,
                11,
            )
        });
        chain.tick(|_| {
            (
                ChainDrive {
                    use_b1: false,
                    ceb1: true,
                    ceb2: false,
                },
                0,
                0,
                22,
            )
        });
        assert_eq!(chain.b_regs(0), (22, 11));
    }

    #[test]
    fn latency_formula() {
        let chain = MultChain::new(OsVariant::Enhanced, 4);
        assert_eq!(chain.latency(), 7);
    }

    /// A multi-chain array must be bit-identical to independent
    /// single-chain arrays under the same per-slice drive.
    #[test]
    fn chain_array_matches_independent_chains() {
        let (chains, len) = (3usize, 4usize);
        let mut arr = ChainArray::new(OsVariant::Enhanced, chains, len);
        let mut singles: Vec<MultChain> = (0..chains)
            .map(|_| MultChain::new(OsVariant::Enhanced, len))
            .collect();
        let drive = |ci: usize, j: usize, e: usize| {
            let ej = e.wrapping_sub(j);
            if ej > e {
                return (ChainDrive::default(), 0, 0, 0);
            }
            (
                ChainDrive {
                    use_b1: ej % 2 == 1,
                    ceb1: ej % 4 == 2,
                    ceb2: ej % 4 == 3,
                },
                (((ci + 2 * j + ej) % 5) as i64) << 18,
                (ci as i64) - (j as i64) + (ej % 7) as i64,
                ((3 * ci + j + ej) % 11) as i64 - 5,
            )
        };
        for e in 0..20 {
            arr.tick(|ci, j| drive(ci, j, e));
            for (ci, single) in singles.iter_mut().enumerate() {
                single.tick(|j| drive(ci, j, e));
            }
            for (ci, single) in singles.iter().enumerate() {
                for j in 0..len {
                    assert_eq!(
                        arr.regs(ci, j),
                        single.regs(j),
                        "chain {ci} slice {j} edge {e}"
                    );
                }
            }
        }
    }
}
