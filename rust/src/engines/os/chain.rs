//! A fast-clock DSP48E2 multiplier chain (one DPU inner-product lane).
//!
//! `chain_len` slices cascade over PCIN; every slice packs two pixels
//! through the pre-adder (A = hi·2¹⁸, D = lo) and multiplies by its
//! input channel's weight. Weight delivery differs by variant:
//!
//! * **Enhanced** (in-DSP multiplexing): B1/B2 hold the two output
//!   channels' weights, reloaded via the B2-direct input mux on
//!   dedicated edges (one weight per slow cycle — half the official
//!   bandwidth), INMODE[4] alternating each fast cycle.
//! * **Official** (CLB DDR mux): a fabric [`LutMux`] drives the B port
//!   every fast cycle with the alternating weight (two weights per slow
//!   cycle — the doubled-bandwidth drawback).
//!
//! The chain is pure datapath; the engine owns the edge schedule and
//! output tagging (see `engine.rs`).

use super::OsVariant;
use crate::dsp::{Attributes, Dsp48e2, DspInputs, InMode, OpMode};
use crate::fabric::{ClockDomain, LutMux};

/// One multiplier chain.
pub struct MultChain {
    dsps: Vec<Dsp48e2>,
    /// Official-variant DDR weight mux (one 8-bit 2:1 LUT mux per chain
    /// pair in the inventory; modeled per chain here for activity).
    mux: Option<LutMux>,
    /// Pre-edge cascade snapshot, reused every tick (§Perf: no per-tick
    /// allocation in the hot loop).
    pcout_buf: Vec<i64>,
}

/// Per-edge drive for one chain (engine-provided).
#[derive(Debug, Clone, Copy, Default)]
pub struct ChainDrive {
    /// A-port value per slice is identical in *form*: hi pixel << 18.
    /// The engine passes per-slice values via the callback instead when
    /// input channels differ (always, in practice) — this struct carries
    /// the shared controls.
    pub use_b1: bool,
    /// Load B1 from the weight bus this edge (enhanced).
    pub ceb1: bool,
    /// Load B2 (direct input mux) from the weight bus this edge.
    pub ceb2: bool,
}

impl MultChain {
    pub fn new(variant: OsVariant, chain_len: usize) -> Self {
        let attrs = match variant {
            OsVariant::Enhanced => Attributes::os_inmux_pe(),
            // Official: B arrives from the CLB mux every fast cycle;
            // single B register (B2 direct), same A/D packing pipeline.
            OsVariant::Official => Attributes {
                breg: 1,
                amultsel: crate::dsp::MultSel::Ad,
                dreg: true,
                adreg: true,
                ..Attributes::default()
            },
        };
        MultChain {
            dsps: (0..chain_len).map(|_| Dsp48e2::new(attrs)).collect(),
            mux: match variant {
                OsVariant::Official => Some(LutMux::new(8, ClockDomain::Fast)),
                OsVariant::Enhanced => None,
            },
            pcout_buf: Vec::with_capacity(chain_len),
        }
    }

    pub fn len(&self) -> usize {
        self.dsps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dsps.is_empty()
    }

    /// One fast edge. `per_slice(j)` returns the slice's controls and
    /// `(a_port, d_port, b_bus)` operands. Controls are per-slice
    /// because the PCIN cascade adds one register stage per position:
    /// slice `j` runs the shared schedule delayed by `j` edges (the
    /// DPU's per-position staging registers).
    ///
    /// For the official variant the `b_bus` value is what the CLB mux
    /// outputs this edge (the engine sequences the DDR alternation;
    /// activity is counted here). The official multiplier always reads
    /// B2 (single B register); only the enhanced design toggles
    /// INMODE[4].
    pub fn tick(
        &mut self,
        mut per_slice: impl FnMut(usize) -> (ChainDrive, i64, i64, i64),
    ) {
        let MultChain {
            dsps,
            mux,
            pcout_buf,
        } = self;
        pcout_buf.clear();
        pcout_buf.extend(dsps.iter().map(|d| d.pcout()));
        let official = mux.is_some();
        for (j, dsp) in dsps.iter_mut().enumerate() {
            let (drive, a, d, b_bus) = per_slice(j);
            let b = if let Some(mux) = mux.as_mut() {
                mux.select(drive.use_b1, b_bus, b_bus)
            } else {
                b_bus
            };
            let use_b1 = if official { false } else { drive.use_b1 };
            let inmode = InMode::A2_B2.with_d().with_b1(use_b1);
            let opmode = if j == 0 {
                OpMode::MULT
            } else {
                OpMode::MULT_CASCADE
            };
            dsp.tick(&DspInputs {
                a,
                d,
                b,
                pcin: if j == 0 { 0 } else { pcout_buf[j - 1] },
                inmode,
                opmode,
                ceb1: drive.ceb1,
                ceb2: drive.ceb2,
                ..DspInputs::default()
            });
        }
    }

    /// The cascade tail's P register (post-edge).
    pub fn tail_p(&self) -> i64 {
        self.dsps.last().expect("chain is non-empty").p()
    }

    /// Pipeline latency from an A-port sample to the tail P:
    /// A1, A2, AD, M, P = 4 edges, plus one per extra cascade stage.
    pub fn latency(&self) -> usize {
        4 + (self.dsps.len() - 1)
    }

    pub fn reset(&mut self) {
        for d in &mut self.dsps {
            d.reset();
        }
    }

    /// Observed B-register state (debug/waveform).
    pub fn b_regs(&self, j: usize) -> (i64, i64) {
        let r = self.dsps[j].regs();
        (r.b1, r.b2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Constant operands through an enhanced chain: tail must converge
    /// to the packed dot product across slices.
    #[test]
    fn enhanced_chain_accumulates_dot() {
        let len = 3;
        let mut chain = MultChain::new(OsVariant::Enhanced, len);
        // Load both weight regs with the same value per slice first.
        let w = [5i64, -3, 7];
        // Two setup edges: CEB2 (direct) then CEB1.
        for (ceb1, ceb2) in [(false, true), (true, false)] {
            chain.tick(|j| {
                (
                    ChainDrive {
                        use_b1: false,
                        ceb1,
                        ceb2,
                    },
                    0,
                    0,
                    w[j],
                )
            });
        }
        // Stream constant packed pixels (hi=2, lo=1).
        let a = 2i64 << 18;
        let d = 1i64;
        for _ in 0..16 {
            chain.tick(|_| {
                (
                    ChainDrive {
                        use_b1: false,
                        ceb1: false,
                        ceb2: false,
                    },
                    a,
                    d,
                    0,
                )
            });
        }
        let (hi, lo) = crate::packing::unpack_prod(chain.tail_p());
        let dot: i64 = w.iter().sum();
        assert_eq!(hi, 2 * dot);
        assert_eq!(lo, dot);
    }

    #[test]
    fn b1_b2_hold_different_weights() {
        let mut chain = MultChain::new(OsVariant::Enhanced, 1);
        // CEB2 edge loads B2 directly; CEB1 edge loads B1 — different
        // values, neither disturbing the other (the in-DSP mux setup).
        chain.tick(|_| {
            (ChainDrive { use_b1: false, ceb1: false, ceb2: true }, 0, 0, 11)
        });
        chain.tick(|_| {
            (ChainDrive { use_b1: false, ceb1: true, ceb2: false }, 0, 0, 22)
        });
        assert_eq!(chain.b_regs(0), (22, 11));
    }

    #[test]
    fn latency_formula() {
        let chain = MultChain::new(OsVariant::Enhanced, 4);
        assert_eq!(chain.latency(), 7);
    }
}
