//! The ring accumulator — paper §V-C, Fig. 6.
//!
//! Two cascaded DSP48E2s running at Clk×2 in SIMD=TWO24, closed into a
//! ring by two fabric delay registers:
//!
//! ```text
//!  chainA word ─► A:B │DSP a│ ─PCOUT─► │DSP b│ ◄─ C  chainB word
//!  RND (bias)  ─► W   │     │          │     │
//!       ▲             └─────┘          └──┬──┘
//!       │ C (feedback, transparent)       │ P
//!       └───── delay reg ◄─── delay reg ◄─┘
//! ```
//!
//! Loop latency = DSP a (1) + DSP b (1) + two delay registers (2) = 4
//! fast cycles, exactly matching the four interleaved partial-sum
//! streams a chain pair produces per round — (wave₀·oc₀), (wave₀·oc₁),
//! (wave₁·oc₀), (wave₁·oc₁). One two-DSP ring therefore replaces the
//! official design's LUT adder tree + *four* slow accumulators per chain
//! pair (AccDSP 64 → 32 at B1024), and the two delay registers double as
//! the serial-to-parallel drain taps (paper: "repurposed for the
//! serial-to-parallel conversion").
//!
//! Each 48-bit word carries the two packed *pixel lanes* re-spaced to
//! 24-bit offsets; TWO24 confines carries so both pixels accumulate
//! independently. The bias rides the RND constant through the W mux on
//! each stream's first pass — no CLB adder, the paper's point.
//!
//! All of an engine's rings share one control word per edge, so they
//! live in a [`RingBank`]: two 1-row × `rings`-column [`DspArray`]s (one
//! per ring stage — the stages have different attributes, DSP a
//! registers its C feedback) advanced by two whole-array generic ticks.
//! [`RingAccumulator`] is the bank-of-one view for unit tests and
//! waveform probes.
//!
//! ## Exact schedule (engine contract)
//!
//! Edge numbering starts at 0 after reset. For stream `s ∈ 0..4` and
//! round `r`:
//! * feed the chain-A word as `chain_a` on edge `4r + s`;
//! * feed the chain-B word as `chain_b` on edge `4r + s + 2`;
//! * the stream's running total appears on [`RingAccumulator::output`]
//!   after edge `4r + s + 2` (and recirculates for round `r+1`).

use crate::dsp::{
    simd_lane, simd_pack, ArrayFeeds, Attributes, ColumnCtrl, DspArray, OpMode,
    SimdMode, WMux, XMux, YMux, ZMux,
};
use crate::packing;

/// Interleaved streams the ring serves (= loop latency in fast cycles).
pub const RING_STREAMS: usize = 4;

/// Convert a chain psum word (pixel lanes packed at the 18-bit product
/// offset) into the ring's TWO24 layout: lane0 = low pixel, lane1 = high
/// pixel. The split applies the packing sign-correction — the re-spacing
/// wiring plus the correction the paper folds into the DSP constants.
pub fn respace_to_two24(chain_word: i64) -> i64 {
    let (hi, lo) = packing::unpack_prod(chain_word);
    simd_pack(SimdMode::Two24, &[trunc24(lo), trunc24(hi)])
}

#[inline]
fn trunc24(v: i64) -> i64 {
    (v << 40) >> 40
}

/// Read the two pixel lanes of a TWO24 accumulator word: (lo, hi).
pub fn two24_lanes(word: i64) -> (i64, i64) {
    (
        simd_lane(SimdMode::Two24, word, 0),
        simd_lane(SimdMode::Two24, word, 1),
    )
}

/// Every two-DSP ring accumulator of an engine as two SoA arrays: ring
/// `r` is column `r` (depth 1) of both stage arrays. All rings share the
/// per-edge OPMODE (the first-pass squelch depends only on the common
/// edge counter), so one pair of whole-array ticks advances the lot.
pub struct RingBank {
    /// Stage a: A:B word in, C = ring feedback (registered — CREG is
    /// the fourth loop stage), W = RND bias on first pass.
    arr_a: DspArray,
    /// Stage b: Z = stage a's PCOUT, Y = C = chain-B word (transparent).
    arr_b: DspArray,
    /// Per-ring fabric delay pair closing the loop (S2P drain taps).
    delay: Vec<[i64; 2]>,
    /// Fast edges since reset (common to all rings).
    edge: u64,
    /// Staged per-ring feeds, refilled each edge.
    a_hi: Vec<i64>,
    b_lo: Vec<i64>,
    c_fb: Vec<i64>,
    c_b: Vec<i64>,
    pcin: Vec<i64>,
}

impl RingBank {
    /// `rings` rings whose banks lease from `scratch` (the engine's
    /// arena — so ring state shows up in the scratch telemetry like
    /// every other bank). `bias_lane` is added once per stream via the
    /// RND constant (same value on both pixel lanes; per-output biases
    /// are applied by the engine downstream when they differ).
    pub fn new_in(bias_lane: i64, rings: usize, scratch: &mut crate::exec::Scratch) -> Self {
        let rnd = simd_pack(
            SimdMode::Two24,
            &[trunc24(bias_lane), trunc24(bias_lane)],
        );
        // DSP a registers the feedback on its C input (CREG = 1): that
        // register is the fourth loop stage. DSP b's C is transparent —
        // the chain-B word combines the cycle it arrives.
        let a_attrs = Attributes {
            creg: true,
            ..Attributes::ring_accumulator(rnd)
        };
        RingBank {
            arr_a: DspArray::new_in(a_attrs, 1, rings, scratch),
            arr_b: DspArray::new_in(Attributes::ring_accumulator(rnd), 1, rings, scratch),
            delay: vec![[0; 2]; rings],
            edge: 0,
            a_hi: vec![0; rings],
            b_lo: vec![0; rings],
            c_fb: vec![0; rings],
            c_b: vec![0; rings],
            pcin: vec![0; rings],
        }
    }

    /// A free-standing bank (fresh allocations, no arena).
    pub fn new(bias_lane: i64, rings: usize) -> Self {
        Self::new_in(bias_lane, rings, &mut crate::exec::Scratch::new())
    }

    /// Number of rings in the bank.
    pub fn rings(&self) -> usize {
        self.arr_a.cols()
    }

    /// One Clk×2 edge for every ring. `chain_a[r]` / `chain_b[r]` are
    /// ring `r`'s TWO24-respaced psum words per the module-docs schedule
    /// (zero when idle/draining).
    pub fn tick(&mut self, chain_a: &[i64], chain_b: &[i64]) {
        let n = self.arr_a.cols();
        debug_assert_eq!(chain_a.len(), n);
        debug_assert_eq!(chain_b.len(), n);
        // The word captured into DSP a's A:B on the previous edge
        // combines *this* edge; it belongs to stream (edge-1) mod 4 of
        // round (edge-1)/4. On its first round the feedback path is
        // squelched and the bias enters through W=RND.
        let first_pass = self.edge >= 1 && self.edge <= RING_STREAMS as u64;
        for r in 0..n {
            let wa = chain_a[r];
            self.a_hi[r] = (wa >> 18) & ((1 << 30) - 1);
            self.b_lo[r] = wa & ((1 << 18) - 1);
            self.c_fb[r] = self.delay[r][1];
            // Pre-edge cascade value (PCOUT is the registered P).
            self.pcin[r] = self.arr_a.p(r, 0);
            self.c_b[r] = chain_b[r];
        }

        // DSP a: P = X(A:B = chainA word, registered last edge)
        //           + Y(C = feedback, registered)   [0 on first pass]
        //           + W(RND)                        [first pass only]
        self.arr_a.tick(
            &ColumnCtrl {
                opmode: OpMode {
                    x: XMux::Ab,
                    y: if first_pass { YMux::Zero } else { YMux::C },
                    z: ZMux::Zero,
                    w: if first_pass { WMux::Rnd } else { WMux::Zero },
                },
                ..ColumnCtrl::default()
            },
            &ArrayFeeds {
                a: &self.a_hi,
                b: &self.b_lo,
                c: &self.c_fb,
                ..ArrayFeeds::default()
            },
        );

        // DSP b: P = Z(PCIN = DSP a's pre-edge P) + Y(C = chainB word).
        self.arr_b.tick(
            &ColumnCtrl {
                opmode: OpMode {
                    x: XMux::Zero,
                    y: YMux::C,
                    z: ZMux::Pcin,
                    w: WMux::Zero,
                },
                ..ColumnCtrl::default()
            },
            &ArrayFeeds {
                c: &self.c_b,
                pcin0: &self.pcin,
                ..ArrayFeeds::default()
            },
        );

        // Close every ring through its delay pair.
        for r in 0..n {
            self.delay[r][1] = self.delay[r][0];
            self.delay[r][0] = self.arr_b.p(r, 0);
        }
        self.edge += 1;
    }

    /// Ring `r`'s DSP b post-edge P — the stream total that just
    /// completed.
    pub fn output(&self, ring: usize) -> i64 {
        self.arr_b.p(ring, 0)
    }

    /// Fast edges ticked since reset.
    pub fn edges(&self) -> u64 {
        self.edge
    }

    /// Synchronous reset, in place: the bias stays folded into the two
    /// stage arrays' RND attribute, so nothing reallocates —
    /// `reset_pass` calls this at the start of every OS pass.
    pub fn reset(&mut self) {
        self.arr_a.reset();
        self.arr_b.reset();
        for d in &mut self.delay {
            *d = [0; 2];
        }
        self.edge = 0;
    }
}

/// One two-DSP ring accumulator — the bank-of-one view of [`RingBank`],
/// kept for unit tests and waveform probes.
pub struct RingAccumulator {
    bank: RingBank,
}

impl RingAccumulator {
    /// A ring whose banks lease from `scratch`; see [`RingBank::new_in`].
    pub fn new_in(bias_lane: i64, scratch: &mut crate::exec::Scratch) -> Self {
        RingAccumulator {
            bank: RingBank::new_in(bias_lane, 1, scratch),
        }
    }

    /// A free-standing ring (fresh allocations, no arena).
    pub fn new(bias_lane: i64) -> Self {
        Self::new_in(bias_lane, &mut crate::exec::Scratch::new())
    }

    /// One Clk×2 edge; see [`RingBank::tick`].
    pub fn tick(&mut self, chain_a: i64, chain_b: i64) {
        self.bank.tick(&[chain_a], &[chain_b]);
    }

    /// DSP b's post-edge P — the stream total that just completed.
    pub fn output(&self) -> i64 {
        self.bank.output(0)
    }

    /// Fast edges ticked since reset.
    pub fn edges(&self) -> u64 {
        self.bank.edges()
    }

    /// Synchronous reset, in place; see [`RingBank::reset`].
    pub fn reset(&mut self) {
        self.bank.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    /// Feed R rounds of 4 interleaved streams (each a chain pair) and
    /// check every stream's two pixel lanes against scalar sums.
    fn run_rounds(
        ring: &mut RingAccumulator,
        words: &[[ (i64, i64); RING_STREAMS]], // per round, per stream: (wa, wb)
    ) -> Vec<(i64, i64)> {
        let rounds = words.len();
        let total_edges = 4 * rounds + RING_STREAMS + 2;
        let mut outputs = vec![(0i64, 0i64); RING_STREAMS];
        for e in 0..total_edges {
            let chain_a = if e < 4 * rounds {
                words[e / 4][e % 4].0
            } else {
                0
            };
            let chain_b = if e >= 2 && e - 2 < 4 * rounds {
                words[(e - 2) / 4][(e - 2) % 4].1
            } else {
                0
            };
            ring.tick(chain_a, chain_b);
            // Stream s of the FINAL round completes after edge
            // 4(R-1)+s+2.
            if e >= 4 * (rounds - 1) + 2 && e < 4 * (rounds - 1) + 2 + RING_STREAMS {
                let s = e - (4 * (rounds - 1) + 2);
                outputs[s] = two24_lanes(ring.output());
            }
        }
        outputs
    }

    #[test]
    fn four_streams_accumulate_independently() {
        let mut rng = XorShift::new(5);
        for _trial in 0..100 {
            let rounds = 1 + (rng.next_u64() % 10) as usize;
            let mut ring = RingAccumulator::new(0);
            let mut expected = [[0i64; 2]; RING_STREAMS];
            let mut words = Vec::new();
            for _ in 0..rounds {
                let mut round = [(0i64, 0i64); RING_STREAMS];
                for (s, slot) in round.iter_mut().enumerate() {
                    // INT16-class partial sums in the 18-bit packed layout.
                    let ha = rng.i8_in(-100, 100) as i64 * 37;
                    let la = rng.i8_in(-100, 100) as i64 * 41;
                    let hb = rng.i8_in(-100, 100) as i64 * 29;
                    let lb = rng.i8_in(-100, 100) as i64 * 31;
                    *slot = (
                        respace_to_two24(ha * (1 << 18) + la),
                        respace_to_two24(hb * (1 << 18) + lb),
                    );
                    expected[s][0] += la + lb;
                    expected[s][1] += ha + hb;
                }
                words.push(round);
            }
            let got = run_rounds(&mut ring, &words);
            for s in 0..RING_STREAMS {
                assert_eq!(
                    got[s],
                    (expected[s][0], expected[s][1]),
                    "stream {s}, rounds {rounds}"
                );
            }
        }
    }

    #[test]
    fn bias_applied_exactly_once_per_stream() {
        let mut ring = RingAccumulator::new(777);
        // Three rounds of zero inputs: each stream must hold exactly the
        // bias (applied on the first pass only).
        let words = vec![[(0, 0); RING_STREAMS]; 3];
        let got = run_rounds(&mut ring, &words);
        for s in 0..RING_STREAMS {
            assert_eq!(got[s], (777, 777), "stream {s}");
        }
    }

    #[test]
    fn respace_roundtrip() {
        let mut rng = XorShift::new(8);
        for _ in 0..10_000 {
            let hi = (rng.next_u64() as i64) % (1 << 17);
            let lo = (rng.next_u64() as i64) % (1 << 17);
            let word = hi * (1 << 18) + lo;
            let respaced = respace_to_two24(word);
            assert_eq!(two24_lanes(respaced), (lo, hi));
        }
    }

    #[test]
    fn lanes_do_not_interfere() {
        // Saturate lane 0 with large positive psums; lane 1 stays 0.
        let mut ring = RingAccumulator::new(0);
        let w = respace_to_two24(100_000); // lo = 100_000 > fits 24b twice?
        let words = vec![[(w, w); RING_STREAMS]; 2];
        let got = run_rounds(&mut ring, &words);
        for s in 0..RING_STREAMS {
            assert_eq!(got[s].1, 0, "hi lane clean, stream {s}");
            assert_eq!(got[s].0, 400_000, "lo lane sums, stream {s}");
        }
    }

    /// A bank of rings with per-ring inputs must match independent
    /// single accumulators bit-for-bit.
    #[test]
    fn ring_bank_matches_independent_rings() {
        let rings = 3usize;
        let mut bank = RingBank::new(13, rings);
        let mut singles: Vec<RingAccumulator> =
            (0..rings).map(|_| RingAccumulator::new(13)).collect();
        let mut rng = XorShift::new(21);
        for e in 0..40u64 {
            let mut wa = vec![0i64; rings];
            let mut wb = vec![0i64; rings];
            for r in 0..rings {
                wa[r] = respace_to_two24(
                    (rng.i8_in(-50, 50) as i64) * (1 << 18) + rng.i8_in(-50, 50) as i64,
                );
                wb[r] = respace_to_two24(
                    (rng.i8_in(-50, 50) as i64) * (1 << 18) + rng.i8_in(-50, 50) as i64,
                );
            }
            bank.tick(&wa, &wb);
            for (r, single) in singles.iter_mut().enumerate() {
                single.tick(wa[r], wb[r]);
            }
            for (r, single) in singles.iter().enumerate() {
                assert_eq!(bank.output(r), single.output(), "ring {r} edge {e}");
            }
            assert_eq!(bank.edges(), e + 1);
        }
    }
}
