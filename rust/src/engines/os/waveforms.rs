//! Fig. 5 / Fig. 6 regeneration: in-DSP multiplexing and ring-
//! accumulator schedules as cycle-accurate text traces.

use super::ring::{respace_to_two24, two24_lanes, RingAccumulator, RING_STREAMS};
use crate::dsp::{Attributes, Dsp48e2, DspInputs, InMode, OpMode};

/// Fig. 5: one DSP running DDR multiplication via INMODE[4] ping-pong.
///
/// Activations `a_t` change every slow cycle (2 fast edges), weights
/// `w_oc0/w_oc1` sit in B2/B1; the trace shows the four cross products
/// appearing on P over two slow cycles.
pub fn fig5_trace() -> String {
    use std::fmt::Write as _;

    let mut dsp = Dsp48e2::new(Attributes {
        mreg: false,
        ..Attributes::os_inmux_pe()
    });
    let mut out = String::new();
    out.push_str("Fig. 5 — in-DSP multiplexing (DDR cross products)\n");
    let _ = writeln!(
        out,
        "{:>4} {:>5} {:>8} {:>6} {:>6} {:>6} {:>6} {:>10}",
        "edge", "clk1", "a_in", "B1", "B2", "A2", "IN[4]", "P"
    );

    // Load weights: B2 <- 3 (direct), B1 <- 5.
    dsp.tick(&DspInputs {
        b: 3,
        ceb1: false,
        ceb2: true,
        cep: false,
        ..DspInputs::default()
    });
    dsp.tick(&DspInputs {
        b: 5,
        ceb1: true,
        ceb2: false,
        cep: false,
        ..DspInputs::default()
    });

    let acts = [10i64, 11, 12, 13];
    for e in 0..8 {
        let slow = e / 2;
        let a_in = acts[slow.min(acts.len() - 1)];
        let use_b1 = e % 2 == 1;
        let inmode = InMode::A2_B2.with_b1(use_b1);
        dsp.tick(&DspInputs {
            a: a_in,
            inmode,
            opmode: OpMode::MULT,
            ceb1: false,
            ceb2: false,
            ..DspInputs::default()
        });
        let r = dsp.regs();
        let _ = writeln!(
            out,
            "{:>4} {:>5} {:>8} {:>6} {:>6} {:>6} {:>6} {:>10}",
            e,
            slow,
            a_in,
            r.b1,
            r.b2,
            r.a2,
            u8::from(use_b1),
            dsp.p()
        );
    }
    out.push_str(
        "P shows a_t*w_oc0 / a_t*w_oc1 alternating: 4 products per 2 slow cycles.\n",
    );
    out
}

/// Fig. 6: the ring accumulator's 4-stream interleave over 3 rounds.
pub fn fig6_trace() -> String {
    use std::fmt::Write as _;

    let mut ring = RingAccumulator::new(0);
    let mut out = String::new();
    out.push_str("Fig. 6 — ring accumulator (two DSP48E2s, latency-4 loop)\n");
    let _ = writeln!(
        out,
        "{:>4} {:>7} {:>7} | {:>12} {:>12}",
        "edge", "inA", "inB", "out(lo px)", "out(hi px)"
    );
    let rounds = 3;
    // Stream s carries constant psums (s+1, 10*(s+1)) per round.
    let word = |s: usize| -> i64 {
        let hi = 10 * (s as i64 + 1);
        let lo = s as i64 + 1;
        respace_to_two24(hi * (1 << 18) + lo)
    };
    let total = 4 * rounds + RING_STREAMS + 2;
    for e in 0..total {
        let wa = if e < 4 * rounds { word(e % 4) } else { 0 };
        let wb = if e >= 2 && e - 2 < 4 * rounds {
            word((e - 2) % 4)
        } else {
            0
        };
        ring.tick(wa, wb);
        let (lo, hi) = two24_lanes(ring.output());
        let _ = writeln!(
            out,
            "{:>4} {:>7} {:>7} | {:>12} {:>12}",
            e, wa, wb, lo, hi
        );
    }
    let _ = writeln!(
        out,
        "each stream accumulates 2 chains x {rounds} rounds: stream s totals \
         (s+1)*{}, pixel-hi 10x that.",
        2 * rounds
    );
    out
}

pub fn print_fig5() {
    print!("{}", fig5_trace());
}

pub fn print_fig6() {
    print!("{}", fig6_trace());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_shows_cross_products() {
        let t = fig5_trace();
        // a=10 against w=3 and w=5: 30 and 50 must both appear.
        assert!(t.contains("30"), "{t}");
        assert!(t.contains("50"), "{t}");
    }

    #[test]
    fn fig6_final_totals_correct() {
        let t = fig6_trace();
        // stream 0 total: (0+1) * 2 chains * 3 rounds = 6 (lo), 60 (hi).
        assert!(t.lines().any(|l| l.contains("           6") && l.contains("          60")), "{t}");
    }
}
