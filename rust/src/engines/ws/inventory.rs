//! Structural inventories and timing models for the four WS designs.
//!
//! Counts are formulas over the array geometry; at the paper's 14×14
//! INT8 point they reproduce Table I cell-for-cell (asserted by
//! `rust/tests/table1.rs`). Groups whose size Vivado would decide
//! (control FSMs, valid trees) are named `control:*` with the calibrated
//! constant documented inline — they are <5% of every design's total
//! except tinyTPU, whose *entire* fabric usage is control.

use super::{WsConfig, WsVariant};
use crate::cost::resource::{Primitive, ResourceInventory};
use crate::cost::timing::{PathClass, TimingModel};
use crate::fabric::ClockDomain;

// Documented calibration constants (see module docs):
/// tinyTPU's controller (UART loader + sequencing FSM), from Table I.
const TINYTPU_CTRL_LUT: usize = 120;
const TINYTPU_CTRL_FF: usize = 129;
/// DSP-Fetch / CLB-Fetch sequencing FSM + CE waveform generator.
const FETCH_CTRL_LUT: usize = 55;
const FETCH_CTRL_FF: usize = 204;
/// Extra weight-load strobe staging when the ping-pong sits in CLB.
const CLB_FETCH_STROBE_FF: usize = 111;
/// Libano generator's controller + residual glue.
const LIBANO_CTRL_LUT: usize = 120;
const LIBANO_CTRL_FF: usize = 110;
const LIBANO_CTRL_CARRY: usize = 4;

/// Elaborate the structural inventory for a WS design.
///
/// Activity factors are static estimates here; [`super::WsEngine`]
/// overwrites them with measured toggle rates after simulation.
pub fn ws_inventory(cfg: &WsConfig) -> ResourceInventory {
    let (r, c) = (cfg.rows, cfg.cols);
    let d = ClockDomain::Slow; // single-clock designs
    let mut inv = ResourceInventory::new();

    match cfg.variant {
        WsVariant::TinyTpu => {
            // One MAC per DSP, broadcast activations: nearly no fabric.
            inv.add("mult array", Primitive::Dsp, r * c, d, 0.55);
            inv.add("control: sequencer", Primitive::Lut, TINYTPU_CTRL_LUT, d, 0.2);
            inv.add("control: counters", Primitive::Ff, TINYTPU_CTRL_FF, d, 0.2);
        }
        WsVariant::Libano => {
            // INT8 packing + DDR muxes per PE; CLB accumulation chain.
            inv.add("mult array", Primitive::Dsp, r * c, d, 0.9);
            // Per-PE fabric (paper footnote 2: "DDR Mux for all PEs and
            // a CLB-based accumulating chain"):
            //   72 LUT  two 36-bit psum adder lanes
            //   32 LUT  DDR operand muxes (2 × 16b)
            //    8 LUT  serial-to-parallel taps
            inv.add("psum CLB adders", Primitive::Lut, r * c * 72, d, 0.9);
            inv.add("DDR operand mux", Primitive::Lut, r * c * 32, d, 0.5);
            inv.add("psum S2P taps", Primitive::Lut, r * c * 8, d, 0.9);
            inv.add("column drain adders", Primitive::Lut, c * 72, d, 0.9);
            inv.add("control: sequencer", Primitive::Lut, LIBANO_CTRL_LUT, d, 0.2);
            // Per-PE flip-flops:
            //   72 psum accumulator lanes, 72 S2P, 64 DDR domain
            //   crossing, 32 act staging, 32 wgt ping-pong, 32 retime.
            inv.add("psum accum regs", Primitive::Ff, r * c * 72, d, 0.9);
            inv.add("psum S2P regs", Primitive::Ff, r * c * 72, d, 0.9);
            inv.add("DDR crossing regs", Primitive::Ff, r * c * 64, d, 0.9);
            inv.add("act staging mesh", Primitive::Ff, r * c * 32, d, 0.5);
            inv.add("wgt ping-pong (CLB)", Primitive::Ff, r * c * 32, d, 0.25);
            inv.add("retiming regs", Primitive::Ff, r * c * 32, d, 0.8);
            inv.add("edge skew triangle", Primitive::Ff, r * (r - 1) / 2 * 8, d, 0.5);
            inv.add("control: misc", Primitive::Ff, LIBANO_CTRL_FF, d, 0.2);
            // CARRY8: accumulating PEs (rows beyond the first) carry two
            // ~30-bit lanes -> 15 CARRY8 per PE.
            inv.add(
                "psum carry chains",
                Primitive::Carry8,
                (r - 1) * c * 15,
                d,
                0.9,
            );
            inv.add("control: carry", Primitive::Carry8, LIBANO_CTRL_CARRY, d, 0.2);
        }
        WsVariant::ClbFetch | WsVariant::DspFetch => {
            // The paper's designs: packing + PCIN cascade + per-column
            // accumulator DSP; activations staged in CLB (16b packed
            // pair per PE). DSP-Fetch's slices toggle slightly more
            // (the B1 prefetch chain shifts inside the DSP).
            let dsp_act = if cfg.variant == WsVariant::DspFetch { 0.95 } else { 0.9 };
            inv.add("mult array", Primitive::Dsp, r * c, d, dsp_act);
            inv.add("column accumulator", Primitive::Dsp, c, d, 0.9);
            inv.add("act staging mesh", Primitive::Ff, r * c * 16, d, 0.5);
            // Edge skew on the 8b pre-packing bus (pairs share a skew
            // stage; the packing happens at the array edge).
            inv.add("edge skew triangle", Primitive::Ff, r * (r - 1) / 2 * 8, d, 0.5);
            inv.add("output drain regs", Primitive::Ff, c * 32, d, 0.9);
            inv.add("control: sequencer+CE", Primitive::Ff, FETCH_CTRL_FF, d, 0.2);
            inv.add("output drain mux", Primitive::Lut, c * 8, d, 0.5);
            inv.add("control: FSM", Primitive::Lut, FETCH_CTRL_LUT, d, 0.2);
            if cfg.variant == WsVariant::ClbFetch {
                // The ablation: ping-pong weight registers in fabric
                // (8b per PE) + load strobe staging, vs absorbed into
                // the DSP B1 pipeline in DSP-Fetch.
                inv.add("wgt ping-pong (CLB)", Primitive::Ff, r * c * 8, d, 0.25);
                inv.add(
                    "wgt load strobe chain",
                    Primitive::Ff,
                    CLB_FETCH_STROBE_FF,
                    d,
                    0.25,
                );
                inv.add("control: wgt CE gen", Primitive::Lut, 1, d, 0.2);
            }
        }
    }
    inv
}

/// Timing model per design. Detours are calibrated against the paper's
/// WNS cells (see `cost::timing` docs); the *class* dominates.
pub fn ws_timing(cfg: &WsConfig) -> TimingModel {
    let t = TimingModel::new(cfg.target_mhz);
    match cfg.variant {
        WsVariant::TinyTpu => t.path(
            "act broadcast net",
            PathClass::Broadcast { fanout: cfg.cols },
        ),
        WsVariant::Libano => t
            // The DDR mux crossing into the DSP is Libano's binding path
            // (paper WNS 0.044 @666 -> 1.4575 ns): one LUT stage + the
            // domain-crossing margin + 0.0275 ns placement congestion of
            // the mux column against the DSP tile.
            .path_d(
                "DDR mux -> DSP",
                PathClass::CrossDomainMux { lut_stages: 1 },
                0.0275,
            )
            // Retimed 36b CLB accumulation lane: 5 CARRY8 blocks.
            .path("psum CLB chain", PathClass::CarryChain { carry8_blocks: 5 }),
        WsVariant::ClbFetch => t
            // Weight ping-pong FF -> B port route (paper WNS 0.083 @666
            // -> 1.4185 ns): staged operand + 0.2185 ns congestion detour
            // (the CLB weight bank competes with act staging for routes).
            .path_d("wgt CLB -> B port", PathClass::StagedOperand, 0.2185)
            .path("psum cascade", PathClass::DspInternal),
        WsVariant::DspFetch => t
            // Everything weight-side is in-DSP; the binding path is the
            // staged activation into the pre-adder (paper WNS 0.052 @666
            // -> 1.4495 ns): staged operand + 0.2495 ns (A/D double load).
            .path_d("act staging -> A/D", PathClass::StagedOperand, 0.2495)
            .path("psum cascade", PathClass::DspInternal),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::resource::Primitive;

    fn cfg(v: WsVariant) -> WsConfig {
        WsConfig::paper_14x14_for(v)
    }

    #[test]
    fn table1_tinytpu_counts() {
        let inv = ws_inventory(&cfg(WsVariant::TinyTpu));
        assert_eq!(inv.total(Primitive::Lut), 120);
        assert_eq!(inv.total(Primitive::Ff), 129);
        assert_eq!(inv.total(Primitive::Carry8), 0);
        assert_eq!(inv.total(Primitive::Dsp), 196);
    }

    #[test]
    fn table1_libano_counts() {
        let inv = ws_inventory(&cfg(WsVariant::Libano));
        assert_eq!(inv.total(Primitive::Lut), 23080);
        assert_eq!(inv.total(Primitive::Ff), 60422);
        assert_eq!(inv.total(Primitive::Carry8), 2734);
        assert_eq!(inv.total(Primitive::Dsp), 196);
    }

    #[test]
    fn table1_clb_fetch_counts() {
        let inv = ws_inventory(&cfg(WsVariant::ClbFetch));
        assert_eq!(inv.total(Primitive::Lut), 168);
        assert_eq!(inv.total(Primitive::Ff), 6195);
        assert_eq!(inv.total(Primitive::Carry8), 0);
        assert_eq!(inv.total(Primitive::Dsp), 210);
    }

    #[test]
    fn table1_dsp_fetch_counts() {
        let inv = ws_inventory(&cfg(WsVariant::DspFetch));
        assert_eq!(inv.total(Primitive::Lut), 167);
        assert_eq!(inv.total(Primitive::Ff), 4516);
        assert_eq!(inv.total(Primitive::Carry8), 0);
        assert_eq!(inv.total(Primitive::Dsp), 210);
    }

    #[test]
    fn dsp_fetch_saves_ff_vs_clb_fetch_at_any_size() {
        for (r, c) in [(6, 6), (10, 10), (14, 14), (16, 24)] {
            let mk = |variant| WsConfig {
                variant,
                rows: r,
                cols: c,
                target_mhz: 666.0,
                strict_guard: false,
            };
            let clb = ws_inventory(&mk(WsVariant::ClbFetch));
            let dsp = ws_inventory(&mk(WsVariant::DspFetch));
            let saved = clb.total(Primitive::Ff) - dsp.total(Primitive::Ff);
            assert!(
                saved >= r * c * 8,
                "in-DSP prefetch must absorb the full ping-pong bank"
            );
        }
    }

    #[test]
    fn timing_matches_paper_wns() {
        // Table I WNS column: 0.076 / 0.044 / 0.083 / 0.052 ns.
        let cases = [
            (WsVariant::TinyTpu, 0.076),
            (WsVariant::Libano, 0.044),
            (WsVariant::ClbFetch, 0.083),
            (WsVariant::DspFetch, 0.052),
        ];
        for (v, wns) in cases {
            let rep = ws_timing(&cfg(v)).report();
            assert!(
                (rep.wns_ns - wns).abs() < 0.01,
                "{}: model {:.3} vs paper {:.3}",
                v.label(),
                rep.wns_ns,
                wns
            );
        }
    }

    #[test]
    fn broadcast_design_cannot_reach_666() {
        let rep = ws_timing(&cfg(WsVariant::TinyTpu)).report();
        assert!(rep.fmax_mhz < 666.0);
        let rep = ws_timing(&cfg(WsVariant::DspFetch)).report();
        assert!(rep.fmax_mhz > 666.0);
    }
}
