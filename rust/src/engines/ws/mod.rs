//! Weight-stationary (TPUv1-like) systolic engines — paper §IV, Table I.
//!
//! Four designs share one cycle-accurate core and differ in structure:
//!
//! * [`WsVariant::TinyTpu`] — the open-source tinyTPU baseline: no INT8
//!   packing (one MAC per DSP), activations *broadcast* to all columns
//!   (high fan-out, 400 MHz class), weights loaded with a full-array
//!   stall.
//! * [`WsVariant::Libano`] — the state-of-the-art generator baseline:
//!   INT8 packing + per-PE DDR muxes, but partial sums accumulate in a
//!   *CLB* adder chain (CARRY8s) instead of the PCIN cascade, and weight
//!   ping-pong lives in CLB flip-flops.
//! * [`WsVariant::ClbFetch`] — the paper's ablation: identical to
//!   DSP-Fetch except the weight ping-pong registers stay in the CLB.
//! * [`WsVariant::DspFetch`] — the paper's contribution (§IV-B, Fig. 3):
//!   **in-DSP operand prefetching** — the B1 registers of each DSP
//!   column form the weight shift chain over the BCIN cascade while the
//!   B2 registers hold the live weights; one CEB2 pulse swaps the whole
//!   array. Plus in-DSP psum cascading (PCIN) and INT8 packing through
//!   the pre-adder.
//!
//! ## Dataflow (packed variants)
//!
//! `run_gemm(a: M×K, w: K×N)` holds `w` stationary (K = array rows,
//! N ≤ array cols). Activation rows are processed in *pairs* (two batch
//! rows per DSP multiply — the INT8 packing): the pair enters row `r`
//! skewed by `r` cycles and stages across columns one register per hop;
//! partial sums ride the PCIN cascade down each column, one extra cycle
//! per row, which exactly matches the skew. A column-end accumulator
//! DSP splits the two product lanes (sign-correction) and adds bias.

mod engine;
mod inventory;
pub mod waveforms;

pub use engine::WsEngine;
pub use inventory::ws_inventory;

use crate::fabric::ClockPlan;

/// Which Table-I design to elaborate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WsVariant {
    TinyTpu,
    Libano,
    ClbFetch,
    DspFetch,
}

impl WsVariant {
    pub fn label(self) -> &'static str {
        match self {
            WsVariant::TinyTpu => "tinyTPU",
            WsVariant::Libano => "Libano",
            WsVariant::ClbFetch => "CLB-Fetch",
            WsVariant::DspFetch => "DSP-Fetch",
        }
    }

    /// INT8 packing: two MACs per DSP (all but tinyTPU).
    pub fn packed(self) -> bool {
        !matches!(self, WsVariant::TinyTpu)
    }

    /// Activations broadcast (tinyTPU) vs staged per column.
    pub fn broadcast(self) -> bool {
        matches!(self, WsVariant::TinyTpu)
    }
}

/// WS array geometry + policy.
#[derive(Debug, Clone, Copy)]
pub struct WsConfig {
    pub variant: WsVariant,
    /// Array rows = stationary K-tile depth (cascade length).
    pub rows: usize,
    /// Array columns = stationary N-tile width.
    pub cols: usize,
    /// Constraint clock (MHz). The paper runs 666 (400 for tinyTPU).
    pub target_mhz: f64,
    /// Fail on packed guard-band overflow instead of counting it.
    pub strict_guard: bool,
}

impl WsConfig {
    /// The paper's Table-I configuration: INT8 14×14 on XCZU3EG.
    pub fn paper_14x14_for(variant: WsVariant) -> Self {
        WsConfig {
            variant,
            rows: 14,
            cols: 14,
            target_mhz: if variant == WsVariant::TinyTpu { 400.0 } else { 666.0 },
            strict_guard: false,
        }
    }

    /// DSP-Fetch at the paper scale (doc-example convenience).
    pub fn paper_14x14() -> Self {
        Self::paper_14x14_for(WsVariant::DspFetch)
    }

    pub fn clock_plan(&self) -> ClockPlan {
        ClockPlan::single(self.target_mhz)
    }
}
