//! Cycle-accurate WS array simulation over bit-accurate DSP48E2 cells.

use super::inventory::{ws_inventory, ws_timing};
use super::{WsConfig, WsVariant};
use crate::cost::{ResourceInventory, TimingModel};
use crate::dsp::{ArrayFeeds, Attributes, ColumnCtrl, DspArray, RowFeeds};
use crate::engines::{Engine, EngineError, GemmRun, RunStats};
use crate::exec::{self, Clocking, FillPlan, Scratch, TileKernel, TilePlan};
use crate::fabric::{ClockDomain, ClockPlan, FfBank, StagingChain};
use crate::packing::{self, GuardOverflow, LANE_SIGN};
use crate::workload::{MatI32, MatI8};

/// DSP pipeline latency from operand capture to P.
///
/// Packed variants route through the pre-adder (A2/D -> AD -> M -> P:
/// 3 stages); tinyTPU multiplies A2 directly (A2 -> M -> P: 2 stages).
fn pipe_latency(variant: WsVariant) -> usize {
    if variant.packed() {
        3
    } else {
        2
    }
}

/// A weight-stationary systolic engine (any Table-I variant).
pub struct WsEngine {
    cfg: WsConfig,
    name: String,
    /// All columns' register state as one set of array-wide SoA banks
    /// (`[col][row]` layout): a full-array cycle is one bank pass, not
    /// a per-column loop. The scalar `Dsp48e2` cell stays the golden
    /// reference and `DspColumn` the mid-level oracle;
    /// `tests/array_props.rs` holds all three bit-identical.
    array: DspArray,
    /// Per-row activation staging chains (packed pair or single act).
    staging: Vec<StagingChain>,
    /// CLB weight ping-pong bank (ClbFetch / Libano); empty otherwise.
    wgt_bank: FfBank,
    stats_template: RunStats,
    /// Reusable scratch arena for the streaming hot loop.
    scratch: Scratch,
    /// The stationary weight tile currently held in the B2 registers,
    /// if any — the key that makes [`Engine::run_gemm_reuse`] safe:
    /// reuse only ever happens on a bit-identical match.
    resident: Option<MatI8>,
}

impl WsEngine {
    pub fn new(cfg: WsConfig) -> Self {
        let pe_attrs = match cfg.variant {
            // In-DSP prefetch: weights ride the BCIN cascade, BCOUT taps
            // B1, multiplier reads B2; pre-adder packs the activations.
            WsVariant::DspFetch => Attributes::ws_prefetch_pe(),
            // Packed variants with fabric-side weight delivery: B from
            // the fabric, single B register (B2 loads directly).
            WsVariant::ClbFetch | WsVariant::Libano => Attributes {
                breg: 1,
                amultsel: crate::dsp::MultSel::Ad,
                dreg: true,
                adreg: true,
                areg: 1,
                ..Attributes::default()
            },
            // tinyTPU: plain A×B multiply, weight in B2, act on A.
            WsVariant::TinyTpu => Attributes {
                breg: 1,
                areg: 1,
                ..Attributes::default()
            },
        };
        let pe_attrs = match cfg.variant {
            WsVariant::DspFetch => Attributes { areg: 1, ..pe_attrs },
            _ => pe_attrs,
        };
        // The register banks lease from the engine's own arena, like
        // every other hot-loop buffer.
        let mut scratch = Scratch::new();
        let array = DspArray::new_in(pe_attrs, cfg.rows, cfg.cols, &mut scratch);
        let act_width = if cfg.variant.packed() { 16 } else { 8 };
        let staging = (0..cfg.rows)
            .map(|_| StagingChain::new(cfg.cols.max(1), act_width, ClockDomain::Slow))
            .collect();
        let wgt_bank = match cfg.variant {
            WsVariant::ClbFetch | WsVariant::Libano => {
                FfBank::new(cfg.rows * cfg.cols, 8, ClockDomain::Slow)
            }
            _ => FfBank::new(0, 8, ClockDomain::Slow),
        };
        WsEngine {
            name: format!(
                "{} {}x{}",
                cfg.variant.label(),
                cfg.rows,
                cfg.cols
            ),
            cfg,
            array,
            staging,
            wgt_bank,
            stats_template: RunStats::default(),
            scratch,
            resident: None,
        }
    }

    pub fn config(&self) -> &WsConfig {
        &self.cfg
    }

    /// Fill cost of one stationary tile under this variant's delivery
    /// path (the numbers `fill_weights` realizes in register activity).
    fn fill_plan(&self) -> FillPlan {
        let rows = self.cfg.rows as u64;
        match self.cfg.variant {
            // Prefetch paths overlap compute in steady state: only the
            // swap pulse is exposed.
            WsVariant::DspFetch | WsVariant::ClbFetch | WsVariant::Libano => FillPlan {
                cycles: rows + 1,
                exposed: 1,
                loads: 1,
            },
            // No prefetch path: the array stalls for the full reload
            // (the drawback §IV-A calls out).
            WsVariant::TinyTpu => FillPlan {
                cycles: rows,
                exposed: rows,
                loads: 1,
            },
        }
    }

    /// Load a stationary weight tile (K=rows × N<=cols), modeling the
    /// variant's delivery path through the generic array tick — fills
    /// are a handful of edges per tile, so only the payload stream gets
    /// a specialized path. Cycle accounting comes from
    /// [`WsEngine::fill_plan`].
    fn fill_weights(&mut self, w: &MatI8, scratch: &mut Scratch) {
        let (rows, cols) = (self.cfg.rows, self.cfg.cols);
        assert_eq!(w.rows, rows);
        assert!(w.cols <= cols);
        // The B2 load pulse every delivery path ends with: only CEB2
        // asserted on the weight pipeline, the datapath held.
        let swap = ColumnCtrl {
            ceb1: false,
            ceb2: true,
            cep: false,
            cem: false,
            cea1: false,
            cea2: false,
            ..ColumnCtrl::default()
        };
        match self.cfg.variant {
            WsVariant::DspFetch => {
                // Stream down every column's B1/BCIN chain at once
                // (rows edges, normally overlapped with compute), then
                // one CEB2 swap pulse. Each edge feeds every column its
                // next weight over the per-column `bcin0` slice; the
                // cascade reads are the array tick's neighboring-bank
                // taps.
                let shift = ColumnCtrl {
                    ceb2: false,
                    cep: false,
                    cem: false,
                    cea1: false,
                    cea2: false,
                    ..ColumnCtrl::default()
                };
                let mut bcin0 = scratch.lease_i64(cols);
                for t in 0..rows {
                    for (c, slot) in bcin0.iter_mut().enumerate() {
                        // Bottom row first: the chain lands the weight
                        // column bottom-up.
                        *slot = if c < w.cols {
                            w.at(rows - 1 - t, c) as i64
                        } else {
                            0
                        };
                    }
                    self.array.tick(
                        &shift,
                        &ArrayFeeds {
                            bcin0: &bcin0,
                            ..ArrayFeeds::default()
                        },
                    );
                }
                scratch.release_i64(bcin0);
                // Swap pulse: every B2 captures its B1 neighbor value.
                self.array.tick(&swap, &ArrayFeeds::default());
            }
            WsVariant::ClbFetch | WsVariant::Libano => {
                // Fill the CLB ping-pong bank (overlappable), then one
                // swap cycle drives every B port from the bank.
                for r in 0..rows {
                    for c in 0..cols {
                        let wv = if c < w.cols { w.at(r, c) } else { 0 };
                        self.wgt_bank.clock(r * cols + c, wv as i64, true);
                    }
                }
                let mut bvals = scratch.lease_i64(rows * cols);
                for c in 0..cols {
                    for r in 0..rows {
                        bvals[c * rows + r] = self.wgt_bank.get(r * cols + c);
                    }
                }
                self.array.tick(
                    &swap,
                    &ArrayFeeds {
                        b: &bvals,
                        ..ArrayFeeds::default()
                    },
                );
                scratch.release_i64(bvals);
            }
            WsVariant::TinyTpu => {
                // Row-by-row load through the B port, array idle —
                // one slice ticks per load edge, like the hardware.
                for r in 0..rows {
                    for c in 0..cols {
                        let wv = if c < w.cols { w.at(r, c) as i64 } else { 0 };
                        self.array.tick_row(
                            c,
                            r,
                            &swap,
                            &RowFeeds {
                                b: wv,
                                ..RowFeeds::default()
                            },
                        );
                    }
                }
            }
        }
    }

    /// One streaming cycle: shift staging, drive the whole array,
    /// collect finished waves. The fill → stream → drain loop itself
    /// lives in [`exec::run_tile`]; this is the WS datapath's cycle
    /// body — all columns' operands staged into two array-wide
    /// `[col][row]` feed slices (each element written exactly once),
    /// then every cascade advanced by one [`DspArray::tick_ws_stream`]
    /// bank pass: zero per-column work in steady state.
    #[allow(clippy::too_many_arguments)]
    fn stream_cycle(
        &mut self,
        t: usize,
        a: &MatI8,
        n_cols: usize,
        waves: usize,
        latency: usize,
        a_feed: &mut [i64],
        d_feed: &mut [i64],
        out: &mut MatI32,
        stats: &mut RunStats,
    ) {
        let rows = self.cfg.rows;
        let packed = self.cfg.variant.packed();
        let broadcast = self.cfg.variant.broadcast();
        let m = a.rows;

        let act = |wave: isize, r: usize, lane_hi: bool| -> i64 {
            if wave < 0 {
                return 0;
            }
            let row = if packed {
                2 * wave as usize + usize::from(!lane_hi)
            } else {
                wave as usize
            };
            if row >= m {
                0
            } else {
                a.at(row, r) as i64
            }
        };

        // Shift the staging chains (one new wave enters per cycle;
        // row r sees wave t - r at its chain input).
        for r in 0..rows {
            let wave = t as isize - r as isize;
            let v = if packed {
                ((act(wave, r, true) & 0xFF) << 8) | (act(wave, r, false) & 0xFF)
            } else {
                act(wave, r, true) & 0xFF
            };
            self.staging[r].shift(v);
        }

        // Stage the whole array's operands into the `[col][row]` feed
        // slices, then advance every cascade in one bank pass.
        let cols = self.cfg.cols;
        for c in 0..cols {
            let base = c * rows;
            for r in 0..rows {
                let staged = if broadcast {
                    // Broadcast: all columns see the chain input
                    // directly (fan-out net, no staging).
                    self.staging[r].stage(0)
                } else {
                    self.staging[r].stage(c)
                };
                if packed {
                    let hi = ((staged >> 8) & 0xFF) as i8 as i64;
                    let lo = (staged & 0xFF) as i8 as i64;
                    a_feed[base + r] = hi << packing::LANE_BITS;
                    d_feed[base + r] = lo;
                } else {
                    a_feed[base + r] = (staged & 0xFF) as i8 as i64;
                    d_feed[base + r] = 0;
                }
            }
        }
        self.array.tick_ws_stream(a_feed, d_feed);

        // Collect: column c's cascade bottom holds the result for
        // wave `t - (rows-1) - skew(c) - PIPE_LATENCY` *after* this
        // edge.
        for c in 0..n_cols {
            let skew = if broadcast { 0 } else { c };
            let wave =
                t as isize - (rows as isize - 1) - skew as isize - latency as isize;
            if wave < 0 || wave as usize >= waves {
                continue;
            }
            let p = self.array.p(c, rows - 1);
            if packed {
                let (hi, lo) = packing::unpack_prod(p);
                let row_hi = 2 * wave as usize;
                let row_lo = row_hi + 1;
                out.set(row_hi, c, hi as i32);
                if row_lo < m {
                    out.set(row_lo, c, lo as i32);
                }
                stats.macs += 2 * rows as u64;
            } else {
                out.set(wave as usize, c, p as i32);
                stats.macs += rows as u64;
            }
        }
    }

    /// Guard-band audit for packed variants: the hardware cannot see
    /// low-lane overflow; the simulator can, and reports it.
    fn guard_audit(
        &self,
        a: &MatI8,
        n_cols: usize,
        waves: usize,
        stats: &mut RunStats,
    ) -> Result<(), EngineError> {
        if !self.cfg.variant.packed() {
            return Ok(());
        }
        let rows = self.cfg.rows;
        let m = a.rows;
        for wave in 0..waves {
            let row_lo = 2 * wave + 1;
            if row_lo >= m {
                continue;
            }
            for c in 0..n_cols {
                let lo_sum: i64 = (0..rows)
                    .map(|r| a.at(row_lo, r) as i64 * self.wgt_value(r, c))
                    .sum();
                if !(-LANE_SIGN..LANE_SIGN).contains(&lo_sum) {
                    stats.guard_overflows += 1;
                    if self.cfg.strict_guard {
                        return Err(EngineError::Guard(GuardOverflow {
                            lane_sum: lo_sum,
                            depth: rows,
                        }));
                    }
                }
            }
        }
        Ok(())
    }

    /// The live weight currently held by PE (r, c) — from B2.
    fn wgt_value(&self, r: usize, c: usize) -> i64 {
        self.array.regs(c, r).b2
    }

    /// Reset all sequential state.
    pub fn reset(&mut self) {
        self.array.reset();
        for chain in &mut self.staging {
            chain.reset();
        }
        self.wgt_bank.reset();
        self.resident = None;
    }

    /// Reset the streaming datapath for a new run while keeping the
    /// stationary weights resident (B1/B2 and the CLB ping-pong bank
    /// survive). After a normal fill every non-weight register is zero
    /// and stays zero through fill, so this reproduces the exact
    /// post-fill state a fresh `reset` + `fill_weights` would leave —
    /// which is what makes skipping the fill bit-exact.
    fn reset_stream_state(&mut self) {
        self.array.reset_keep_weights();
        for chain in &mut self.staging {
            chain.reset();
        }
    }

    /// Measured staging-chain toggle activity (power-model input).
    fn staging_activity(&self) -> f64 {
        let total_ff: usize = self.staging.iter().map(|s| s.ff_count()).sum();
        let toggles: u64 = self.staging.iter().map(|s| s.toggles()).sum();
        let cycles = self.array.cycles().max(1);
        if total_ff == 0 {
            return 0.0;
        }
        (toggles as f64 / (cycles as f64 * total_ff as f64)).min(1.0)
    }
}

/// The WS array's per-tile adapter to the [`exec`] core.
struct WsTileKernel<'a> {
    eng: &'a mut WsEngine,
    a: &'a MatI8,
    w: &'a MatI8,
    out: &'a mut MatI32,
    waves: usize,
    latency: usize,
    /// Weights already resident: skip the fill, account it as saved.
    reuse: bool,
    /// Array-wide `[col][row]` operand staging for the SoA array tick,
    /// leased from the scratch arena once per tile and reused across
    /// every stream cycle (the arena's reuse-hit telemetry counts the
    /// across-tile reuse). The per-column rebuild of the old
    /// `rows`-long buffers fell away with the array rewrite: each
    /// element is written exactly once per cycle.
    a_feed: Vec<i64>,
    d_feed: Vec<i64>,
}

impl<'a> WsTileKernel<'a> {
    fn new(
        eng: &'a mut WsEngine,
        a: &'a MatI8,
        w: &'a MatI8,
        out: &'a mut MatI32,
        reuse: bool,
    ) -> Self {
        let packed = eng.cfg.variant.packed();
        // Packed: process row pairs (pad odd M with a zero row).
        let waves = if packed { a.rows.div_ceil(2) } else { a.rows };
        let latency = pipe_latency(eng.cfg.variant);
        WsTileKernel {
            eng,
            a,
            w,
            out,
            waves,
            latency,
            reuse,
            a_feed: Vec::new(),
            d_feed: Vec::new(),
        }
    }
}

impl TileKernel for WsTileKernel<'_> {
    fn plan(&self) -> TilePlan {
        let (rows, cols) = (self.eng.cfg.rows, self.eng.cfg.cols);
        let col_skew = if self.eng.cfg.variant.broadcast() {
            0
        } else {
            cols - 1
        };
        TilePlan {
            fill: self.eng.fill_plan(),
            stream_steps: self.waves,
            // Ramp-in + column skew + pipeline drain.
            drain_steps: (rows - 1) + col_skew + self.latency + 2,
            clocking: Clocking::Single,
            reuse_fill: self.reuse,
        }
    }

    fn fill(&mut self, scratch: &mut Scratch, _stats: &mut RunStats) {
        let n = self.eng.cfg.rows * self.eng.cfg.cols;
        self.a_feed = scratch.lease_i64(n);
        self.d_feed = scratch.lease_i64(n);
        if !self.reuse {
            self.eng.fill_weights(self.w, scratch);
        }
    }

    fn step(&mut self, t: usize, _scratch: &mut Scratch, stats: &mut RunStats) {
        self.eng.stream_cycle(
            t,
            self.a,
            self.w.cols,
            self.waves,
            self.latency,
            &mut self.a_feed,
            &mut self.d_feed,
            self.out,
            stats,
        );
    }

    fn drain(&mut self, scratch: &mut Scratch, _stats: &mut RunStats) {
        scratch.release_i64(std::mem::take(&mut self.a_feed));
        scratch.release_i64(std::mem::take(&mut self.d_feed));
    }
}

impl Engine for WsEngine {
    fn name(&self) -> &str {
        &self.name
    }

    fn inventory(&self) -> ResourceInventory {
        let mut inv = ws_inventory(&self.cfg);
        // Swap in measured activity where the simulation produced one.
        let measured = self.staging_activity();
        if measured > 0.0 {
            for g in &mut inv.groups {
                if g.name.contains("act staging") {
                    g.activity = measured;
                }
            }
        }
        inv
    }

    fn timing(&self) -> TimingModel {
        ws_timing(&self.cfg)
    }

    fn clock_plan(&self) -> ClockPlan {
        self.cfg.clock_plan()
    }

    fn peak_macs_per_cycle(&self) -> u64 {
        let per_dsp = if self.cfg.variant.packed() { 2 } else { 1 };
        (self.cfg.rows * self.cfg.cols * per_dsp) as u64
    }

    fn run_gemm(&mut self, a: &MatI8, w: &MatI8) -> Result<GemmRun, EngineError> {
        self.run_gemm_at(a, w, false)
    }

    fn run_gemm_reuse(
        &mut self,
        a: &MatI8,
        w: &MatI8,
    ) -> Result<GemmRun, EngineError> {
        self.run_gemm_at(a, w, true)
    }

    fn scratch_stats(&self) -> crate::exec::ScratchStats {
        self.scratch.stats()
    }
}

impl WsEngine {
    /// One GEMM run, optionally reusing the resident weight tile. The
    /// reuse request only takes effect when the resident tile is
    /// bit-identical to `w` (so a hash collision or a scheduling
    /// surprise can never corrupt results — it just pays the fill).
    fn run_gemm_at(
        &mut self,
        a: &MatI8,
        w: &MatI8,
        reuse_requested: bool,
    ) -> Result<GemmRun, EngineError> {
        if a.cols != self.cfg.rows {
            return Err(EngineError::Shape(format!(
                "K={} must equal array rows={}",
                a.cols, self.cfg.rows
            )));
        }
        if w.rows != self.cfg.rows || w.cols > self.cfg.cols {
            return Err(EngineError::Shape(format!(
                "weight tile {}x{} exceeds array {}x{}",
                w.rows, w.cols, self.cfg.rows, self.cfg.cols
            )));
        }
        let reuse =
            reuse_requested && self.resident.as_ref() == Some(w);
        if reuse {
            self.reset_stream_state();
        } else {
            self.reset();
        }
        let mut stats = self.stats_template.clone();
        let mut out = MatI32::zeros(a.rows, w.cols);
        let mut scratch = std::mem::take(&mut self.scratch);
        let waves = {
            let mut kernel = WsTileKernel::new(self, a, w, &mut out, reuse);
            exec::run_tile(&mut kernel, &mut scratch, &mut stats);
            kernel.waves
        };
        self.scratch = scratch;
        if !reuse {
            self.resident = Some(w.clone());
        }
        self.guard_audit(a, w.cols, waves, &mut stats)?;
        Ok(GemmRun { output: out, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::workload::gemm::{golden_gemm, GemmProblem};

    fn all_variants() -> [WsVariant; 4] {
        [
            WsVariant::TinyTpu,
            WsVariant::Libano,
            WsVariant::ClbFetch,
            WsVariant::DspFetch,
        ]
    }

    fn small_cfg(variant: WsVariant) -> WsConfig {
        WsConfig {
            variant,
            rows: 6,
            cols: 5,
            target_mhz: 666.0,
            strict_guard: false,
        }
    }

    #[test]
    fn every_variant_matches_golden_small() {
        for v in all_variants() {
            let mut eng = WsEngine::new(small_cfg(v));
            // Bounded activations keep even deep packed cascades exact.
            let mut rng = XorShift::new(7);
            let a = MatI8::random_bounded(&mut rng, 8, 6, 63);
            let w = MatI8::random(&mut rng, 6, 5);
            let run = eng.run_gemm(&a, &w).unwrap();
            assert_eq!(run.output, golden_gemm(&a, &w), "variant {v:?}");
            assert_eq!(run.stats.guard_overflows, 0);
        }
    }

    #[test]
    fn paper_scale_14x14_matches_golden() {
        for v in [WsVariant::DspFetch, WsVariant::TinyTpu] {
            let mut eng = WsEngine::new(WsConfig::paper_14x14_for(v));
            let mut rng = XorShift::new(3);
            let a = MatI8::random_bounded(&mut rng, 32, 14, 63);
            let w = MatI8::random(&mut rng, 14, 14);
            let run = eng.run_gemm(&a, &w).unwrap();
            assert_eq!(run.output, golden_gemm(&a, &w), "variant {v:?}");
        }
    }

    #[test]
    fn odd_row_count_pads() {
        let mut eng = WsEngine::new(small_cfg(WsVariant::DspFetch));
        let mut rng = XorShift::new(9);
        let a = MatI8::random_bounded(&mut rng, 7, 6, 63);
        let w = MatI8::random(&mut rng, 6, 5);
        let run = eng.run_gemm(&a, &w).unwrap();
        assert_eq!(run.output, golden_gemm(&a, &w));
    }

    #[test]
    fn narrow_weight_tile() {
        let mut eng = WsEngine::new(small_cfg(WsVariant::DspFetch));
        let mut rng = XorShift::new(11);
        let a = MatI8::random_bounded(&mut rng, 4, 6, 63);
        let w = MatI8::random(&mut rng, 6, 3); // only 3 of 5 columns
        let run = eng.run_gemm(&a, &w).unwrap();
        assert_eq!(run.output, golden_gemm(&a, &w));
    }

    #[test]
    fn guard_overflow_detected_and_strict_mode_errors() {
        // Worst-case inputs on a 14-deep cascade overflow the low lane.
        let mut cfg = WsConfig::paper_14x14_for(WsVariant::DspFetch);
        let a = MatI8::from_fn(2, 14, |_, _| -128);
        let w = MatI8::from_fn(14, 1, |_, _| -128);
        let mut eng = WsEngine::new(cfg);
        let run = eng.run_gemm(&a, &w).unwrap();
        assert!(run.stats.guard_overflows > 0);

        cfg.strict_guard = true;
        let mut eng = WsEngine::new(cfg);
        match eng.run_gemm(&a, &w) {
            Err(EngineError::Guard(g)) => assert_eq!(g.depth, 14),
            other => panic!("expected guard error, got {other:?}"),
        }
    }

    #[test]
    fn tinytpu_stalls_on_weight_load_others_do_not() {
        let p = GemmProblem::random(4, 5, 6, 21);
        let mut tiny = WsEngine::new(small_cfg(WsVariant::TinyTpu));
        let run_t = tiny.run_gemm(&p.a, &p.w).unwrap();
        assert_eq!(run_t.stats.weight_stall_cycles, 6);

        let mut ours = WsEngine::new(small_cfg(WsVariant::DspFetch));
        let run_o = ours.run_gemm(&p.a, &p.w).unwrap();
        assert_eq!(run_o.stats.weight_stall_cycles, 1);
    }

    #[test]
    fn shape_errors() {
        let mut eng = WsEngine::new(small_cfg(WsVariant::DspFetch));
        let a = MatI8::zeros(4, 7); // K mismatch
        let w = MatI8::zeros(6, 5);
        assert!(matches!(eng.run_gemm(&a, &w), Err(EngineError::Shape(_))));
        let a = MatI8::zeros(4, 6);
        let w = MatI8::zeros(6, 9); // too wide
        assert!(matches!(eng.run_gemm(&a, &w), Err(EngineError::Shape(_))));
    }

    #[test]
    fn stats_account_macs() {
        let p = GemmProblem::random(8, 5, 6, 5);
        let mut eng = WsEngine::new(small_cfg(WsVariant::DspFetch));
        let run = eng.run_gemm(&p.a, &p.w).unwrap();
        assert_eq!(run.stats.macs, 8 * 5 * 6);
        assert!(run.stats.cycles > 0);
        let util = run.stats.utilization(eng.peak_macs_per_cycle());
        assert!(util > 0.0 && util <= 1.0);
    }

    #[test]
    fn rerun_is_deterministic_and_clean() {
        let p = GemmProblem::random(6, 5, 6, 99);
        let mut eng = WsEngine::new(small_cfg(WsVariant::DspFetch));
        let r1 = eng.run_gemm(&p.a, &p.w).unwrap();
        let r2 = eng.run_gemm(&p.a, &p.w).unwrap();
        assert_eq!(r1.output, r2.output);
        assert_eq!(r1.stats.cycles, r2.stats.cycles);
    }

    /// Reuse skips the fill bit-exactly: same outputs, fewer cycles,
    /// the savings accounted — for every variant (even tinyTPU, whose
    /// avoided fill is a full-array stall).
    #[test]
    fn reuse_matches_full_run_and_saves_fill() {
        for v in all_variants() {
            let mut eng = WsEngine::new(small_cfg(v));
            let mut rng = XorShift::new(17);
            let w = MatI8::random(&mut rng, 6, 5);
            let a1 = MatI8::random_bounded(&mut rng, 8, 6, 63);
            let a2 = MatI8::random_bounded(&mut rng, 9, 6, 63);
            let full = eng.run_gemm(&a1, &w).unwrap();
            let reused = eng.run_gemm_reuse(&a2, &w).unwrap();
            assert_eq!(reused.output, golden_gemm(&a2, &w), "variant {v:?}");
            assert_eq!(reused.stats.fills_avoided, 1, "variant {v:?}");
            assert_eq!(reused.stats.weight_loads, 0);
            assert_eq!(reused.stats.weight_stall_cycles, 0);
            assert!(reused.stats.fill_cycles_saved > 0);
            assert!(
                reused.stats.cycles
                    < full.stats.cycles + reused.stats.fill_cycles_saved,
                "variant {v:?}: reuse did not shorten the run"
            );
            // A fresh full run on the same operands agrees exactly on
            // the payload: reuse cycles == full cycles - fill cycles.
            let full2 = eng.run_gemm(&a2, &w).unwrap();
            assert_eq!(full2.output, reused.output);
            assert_eq!(
                reused.stats.cycles + reused.stats.fill_cycles_saved,
                full2.stats.cycles,
                "variant {v:?}"
            );
        }
    }

    /// A reuse request against different weights falls back to a full
    /// run (never computes against stale weights).
    #[test]
    fn reuse_with_different_weights_falls_back_to_fill() {
        let mut eng = WsEngine::new(small_cfg(WsVariant::DspFetch));
        let mut rng = XorShift::new(23);
        let w1 = MatI8::random(&mut rng, 6, 5);
        let w2 = MatI8::random(&mut rng, 6, 5);
        let a = MatI8::random_bounded(&mut rng, 4, 6, 63);
        eng.run_gemm(&a, &w1).unwrap();
        let run = eng.run_gemm_reuse(&a, &w2).unwrap();
        assert_eq!(run.output, golden_gemm(&a, &w2));
        assert_eq!(run.stats.fills_avoided, 0);
        assert_eq!(run.stats.weight_loads, 1);
    }

    /// `run_gemm_reuse` on a cold engine is just a full run.
    #[test]
    fn reuse_on_cold_engine_is_full_run() {
        let mut eng = WsEngine::new(small_cfg(WsVariant::DspFetch));
        let p = GemmProblem::random(4, 5, 6, 31);
        let run = eng.run_gemm_reuse(&p.a, &p.w).unwrap();
        assert_eq!(run.output, golden_gemm(&p.a, &p.w));
        assert_eq!(run.stats.fills_avoided, 0);
        assert_eq!(run.stats.weight_loads, 1);
    }
}
