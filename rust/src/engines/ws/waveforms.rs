//! Fig. 3 regeneration: the in-DSP operand-prefetch waveform as a text
//! trace — CEB1/CEB2 clock enables plus the B1/B2 register contents of
//! a 4-deep DSP column while a new weight set streams down the BCIN
//! cascade and swaps in with a single CEB2 pulse.

use crate::dsp::{Attributes, Dsp48e2, DspInputs};

/// Render the Fig.-3 trace for a `depth`-deep column and two weight
/// sets; returns the text (also used by `examples/fig_waveforms.rs`).
pub fn fig3_trace(depth: usize) -> String {
    use std::fmt::Write as _;

    let mut col: Vec<Dsp48e2> = (0..depth)
        .map(|_| Dsp48e2::new(Attributes::ws_prefetch_pe()))
        .collect();
    let mut out = String::new();
    let _ = writeln!(
        out,
        "Fig. 3 — in-DSP operand prefetching ({depth}-deep column)"
    );
    let _ = write!(out, "{:>5} {:>4} {:>4} |", "cycle", "CEB1", "CEB2");
    for i in 0..depth {
        let sep = if i == 0 { " " } else { "  " };
        let _ = write!(out, "{sep}B1[{i}] B2[{i}]");
    }
    out.push('\n');

    let sets: [Vec<i64>; 2] = [
        (0..depth).map(|i| 10 + i as i64).collect(),
        (0..depth).map(|i| 50 + i as i64).collect(),
    ];

    let mut cycle = 0;
    // One snapshot buffer for the whole trace: bcouts must be sampled
    // before the edge (cascade neighbours see pre-edge values), but the
    // snapshot itself is refilled in place, never reallocated.
    let mut bcouts: Vec<i64> = Vec::with_capacity(depth);
    let line = |out: &mut String, col: &[Dsp48e2], ceb1: bool, ceb2: bool, cycle: usize| {
        let _ = write!(
            out,
            "{:>5} {:>4} {:>4} |",
            cycle,
            u8::from(ceb1),
            u8::from(ceb2)
        );
        for (i, d) in col.iter().enumerate() {
            let r = d.regs();
            let sep = if i == 0 { " " } else { "  " };
            let _ = write!(out, "{sep}{:>5} {:>5}", r.b1, r.b2);
        }
        out.push('\n');
    };

    for set in &sets {
        // Prefetch phase: CEB1 streams the set down the B1/BCIN chain
        // while B2 (the live weights) holds — compute keeps running.
        for t in 0..depth {
            bcouts.clear();
            bcouts.extend(col.iter().map(|d| d.bcout()));
            for (r, dsp) in col.iter_mut().enumerate() {
                let bcin = if r == 0 {
                    set[depth - 1 - t]
                } else {
                    bcouts[r - 1]
                };
                dsp.tick(&DspInputs {
                    bcin,
                    ceb2: false,
                    cep: false,
                    ..DspInputs::default()
                });
            }
            line(&mut out, &col, true, false, cycle);
            cycle += 1;
        }
        // Swap pulse: one CEB2 edge moves the whole column B1 -> B2.
        bcouts.clear();
        bcouts.extend(col.iter().map(|d| d.bcout()));
        for (r, dsp) in col.iter_mut().enumerate() {
            let bcin = if r == 0 { 0 } else { bcouts[r - 1] };
            dsp.tick(&DspInputs {
                bcin,
                ceb1: false,
                ceb2: true,
                cep: false,
                ..DspInputs::default()
            });
        }
        line(&mut out, &col, false, true, cycle);
        cycle += 1;
    }
    out
}

/// Print the paper-scale (4-deep illustration) trace to stdout.
pub fn print_fig3() {
    print!("{}", fig3_trace(4));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shows_swap_semantics() {
        let t = fig3_trace(3);
        // After the first prefetch+swap, B2 holds 10, 11, 12.
        assert!(t.contains("Fig. 3"));
        let lines: Vec<&str> = t.lines().collect();
        // Swap line = header + depth prefetch lines + 1.
        let swap = lines[1 + 3 + 1];
        assert!(swap.contains("   10"), "swap line: {swap}");
        assert!(swap.contains("   12"), "swap line: {swap}");
    }

    #[test]
    fn b2_stable_during_prefetch() {
        let t = fig3_trace(3);
        let lines: Vec<&str> = t.lines().collect();
        // Second set's prefetch lines (after the first swap) must keep
        // the first set's B2 values (10..12) while B1 refills (50..).
        for l in &lines[6..8] {
            assert!(l.contains("   10") || l.contains("   11") || l.contains("   12"));
        }
    }
}
