//! Systolic matrix engines: the paper's designs and all its baselines.
//!
//! | module | dataflow | designs (paper table) |
//! |--------|----------|------------------------|
//! | [`ws`] | weight-stationary, TPUv1-like | tinyTPU, Libano, CLB-Fetch, DSP-Fetch (Table I) |
//! | [`os`] | output-stationary, DPU-like | DPUCZDX8G B1024 replicate, enhanced (in-DSP mux + ring accumulator) (Table II) |
//! | [`snn`] | spiking crossbar, FireFly-like | FireFly, enhanced (in-DSP prefetch) (Table III) |
//!
//! Every engine is **cycle-accurate over bit-accurate DSP48E2 cells**:
//! the arithmetic of `run_gemm` flows through [`crate::dsp::Dsp48e2`]
//! datapaths (pre-adder packing, PCIN cascades, SIMD lanes), so a wrong
//! pipeline assumption produces wrong *values*, not just wrong cycle
//! counts. Structural cost comes from [`Engine::inventory`].

pub mod os;
pub mod snn;
pub mod ws;

use crate::cost::{PowerModel, ResourceInventory, TableRow, TimingModel};
use crate::fabric::ClockPlan;
use crate::packing::GuardOverflow;
use crate::workload::{MatI32, MatI8};

/// Cycle-level statistics of one engine run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RunStats {
    /// Slow-domain (fabric) cycles elapsed.
    pub cycles: u64,
    /// Fast-domain (DSP) cycles elapsed (== `cycles` for single-clock).
    pub fast_cycles: u64,
    /// Multiply-accumulate operations performed.
    pub macs: u64,
    /// Cycles the array stalled waiting for weights.
    pub weight_stall_cycles: u64,
    /// Weight-tile swaps performed.
    pub weight_loads: u64,
    /// Guard-band overflows detected (packed cascades).
    pub guard_overflows: u64,
    /// Stationary fills skipped because the weight tile was already
    /// resident (batched weight-tile reuse).
    pub fills_avoided: u64,
    /// Slow cycles those avoided fills would have cost.
    pub fill_cycles_saved: u64,
}

impl RunStats {
    /// Field-wise sum of two runs' counters — the aggregation for
    /// back-to-back runs with no shared scheduling (e.g. conv row
    /// blocks). Exhaustive destructuring makes adding a `RunStats`
    /// field a compile error here instead of a silently-dropped
    /// counter.
    pub fn merged_with(self, other: &RunStats) -> RunStats {
        let RunStats {
            cycles,
            fast_cycles,
            macs,
            weight_stall_cycles,
            weight_loads,
            guard_overflows,
            fills_avoided,
            fill_cycles_saved,
        } = self;
        RunStats {
            cycles: cycles + other.cycles,
            fast_cycles: fast_cycles + other.fast_cycles,
            macs: macs + other.macs,
            weight_stall_cycles: weight_stall_cycles
                + other.weight_stall_cycles,
            weight_loads: weight_loads + other.weight_loads,
            guard_overflows: guard_overflows + other.guard_overflows,
            fills_avoided: fills_avoided + other.fills_avoided,
            fill_cycles_saved: fill_cycles_saved + other.fill_cycles_saved,
        }
    }

    /// Achieved MACs per slow cycle divided by the given peak.
    pub fn utilization(&self, peak_macs_per_cycle: u64) -> f64 {
        if self.cycles == 0 || peak_macs_per_cycle == 0 {
            return 0.0;
        }
        self.macs as f64 / (self.cycles as f64 * peak_macs_per_cycle as f64)
    }

    /// Achieved MACs per slow cycle.
    pub fn macs_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.macs as f64 / self.cycles as f64
        }
    }
}

/// Result of a GEMM run: the output and its cycle accounting.
#[derive(Debug, Clone)]
pub struct GemmRun {
    pub output: MatI32,
    pub stats: RunStats,
}

impl GemmRun {
    /// MAC utilization against the engine peak.
    pub fn mac_utilization_vs(&self, peak: u64) -> f64 {
        self.stats.utilization(peak)
    }
}

/// Engine-level failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// Problem shape incompatible with the array geometry.
    Shape(String),
    /// A packed cascade left the guard band under `strict_guard`.
    Guard(GuardOverflow),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Shape(s) => write!(f, "shape error: {s}"),
            EngineError::Guard(g) => write!(f, "guard-band error: {g}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// A systolic matrix engine: functional (cycle-accurate GEMM) plus
/// structural (inventory / timing / power) views.
pub trait Engine {
    /// Display name (matches the paper's table row labels).
    fn name(&self) -> &str;

    /// Structural resource inventory (activities updated after runs).
    fn inventory(&self) -> ResourceInventory;

    /// Candidate critical paths + constraint clock.
    fn timing(&self) -> TimingModel;

    /// The clock plan (single or Clk×1/Clk×2).
    fn clock_plan(&self) -> ClockPlan;

    /// Peak MACs per slow-domain cycle.
    fn peak_macs_per_cycle(&self) -> u64;

    /// Execute `a (M×K) @ w (K×N)` cycle-accurately.
    fn run_gemm(&mut self, a: &MatI8, w: &MatI8) -> Result<GemmRun, EngineError>;

    /// Execute a GEMM whose stationary weight tile may still be
    /// resident from the previous call on this engine (batched
    /// weight-tile reuse: fill once, stream many). Engines with a
    /// stationary-reuse path skip the weight fill when — and only
    /// when — the resident tile is bit-identical to `w`, accounting
    /// the saved cycles in [`RunStats::fills_avoided`] /
    /// [`RunStats::fill_cycles_saved`]; everything else falls back to
    /// a full [`Engine::run_gemm`].
    fn run_gemm_reuse(
        &mut self,
        a: &MatI8,
        w: &MatI8,
    ) -> Result<GemmRun, EngineError> {
        self.run_gemm(a, w)
    }

    /// Scratch-arena telemetry snapshot (lease counts, reuse-hit
    /// ratio, high-water bytes) for engines that pool their hot-loop
    /// buffers. Counters are monotonic, so callers can diff snapshots
    /// for exact deltas; the default is an empty snapshot for engines
    /// without an arena.
    fn scratch_stats(&self) -> crate::exec::ScratchStats {
        crate::exec::ScratchStats::default()
    }

    /// The paper-style evaluation row for this engine.
    fn table_row(&self) -> TableRow {
        let inv = self.inventory();
        let timing = self.timing().report();
        let power = PowerModel::default().estimate(&inv, self.clock_plan());
        TableRow::from_models(self.name(), &inv, &timing, &power)
    }
}
