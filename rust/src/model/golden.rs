//! The golden model interpreter — and the *single* implementation of
//! the elementwise glue ops.
//!
//! `Reference::ModelDirect` verification replays the whole DAG through
//! [`golden_eval`] (golden GEMM / direct conv per matmul layer). The
//! coordinator's scheduler evaluates the glue layers (`Requant`,
//! `Quant`, `Add`, `Chw`) on the arena-resident tensors through the
//! **same** [`eval_elementwise`] below, so scheduler-side glue and
//! golden-side glue are bit-identical by construction — only the
//! matmul layers differ (engine vs golden), and those are covered by
//! the engine≡golden property suites.

use super::compiler::GraphCompiler;
use super::graph::{LayerOp, Model, ModelError};
use crate::workload::conv::conv2d_direct;
use crate::workload::gemm::golden_gemm;
use crate::workload::quant::requantize;
use crate::workload::{MatI32, MatI8};

/// A materialized virtual tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TensorValue {
    I8(MatI8),
    I32(MatI32),
}

impl TensorValue {
    pub fn rows(&self) -> usize {
        match self {
            TensorValue::I8(m) => m.rows,
            TensorValue::I32(m) => m.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            TensorValue::I8(m) => m.cols,
            TensorValue::I32(m) => m.cols,
        }
    }

    /// Residency cost in bytes.
    pub fn bytes(&self) -> usize {
        match self {
            TensorValue::I8(m) => m.data.len(),
            TensorValue::I32(m) => m.data.len() * 4,
        }
    }

    /// Per-element view as i32 (widening i8) — the form the requant
    /// ops consume.
    fn as_i32_iter(&self) -> Box<dyn Iterator<Item = i32> + '_> {
        match self {
            TensorValue::I8(m) => Box::new(m.data.iter().map(|&v| v as i32)),
            TensorValue::I32(m) => Box::new(m.data.iter().copied()),
        }
    }

    /// The model output as the wire's `MatI32` (i8 outputs widen).
    pub fn widen(&self) -> MatI32 {
        match self {
            TensorValue::I32(m) => m.clone(),
            TensorValue::I8(m) => MatI32 {
                rows: m.rows,
                cols: m.cols,
                data: m.data.iter().map(|&v| v as i32).collect(),
            },
        }
    }
}

/// Evaluate one elementwise glue layer. `alloc_i8` supplies the output
/// buffer (zero-filled, exactly `rows·cols` long) so the scheduler can
/// lease it from the model's arena while the golden path just
/// allocates; the arithmetic is identical either way.
pub(crate) fn eval_elementwise(
    op: &LayerOp,
    ins: &[&TensorValue],
    mut alloc_i8: impl FnMut(usize) -> Vec<i8>,
) -> TensorValue {
    match op {
        LayerOp::Requant {
            num,
            shift,
            zero_point,
        } => {
            let a = ins[0];
            let mut data = alloc_i8(a.rows() * a.cols());
            for (slot, v) in data.iter_mut().zip(a.as_i32_iter()) {
                *slot = requantize(v, *num, *shift, *zero_point);
            }
            TensorValue::I8(MatI8 {
                rows: a.rows(),
                cols: a.cols(),
                data,
            })
        }
        LayerOp::Quant { num, shift } => {
            let a = ins[0];
            let mut data = alloc_i8(a.rows() * a.cols());
            for (slot, v) in data.iter_mut().zip(a.as_i32_iter()) {
                *slot = i8::from(requantize(v, *num, *shift, 0) > 0);
            }
            TensorValue::I8(MatI8 {
                rows: a.rows(),
                cols: a.cols(),
                data,
            })
        }
        LayerOp::Add => {
            let (TensorValue::I8(a), TensorValue::I8(b)) = (ins[0], ins[1])
            else {
                unreachable!("compiler admits only i8 Add operands")
            };
            let mut data = alloc_i8(a.data.len());
            for ((slot, &x), &y) in
                data.iter_mut().zip(a.data.iter()).zip(b.data.iter())
            {
                *slot = x.saturating_add(y);
            }
            TensorValue::I8(MatI8 {
                rows: a.rows,
                cols: a.cols,
                data,
            })
        }
        LayerOp::Chw { h, w } => {
            let TensorValue::I8(a) = ins[0] else {
                unreachable!("compiler admits only i8 Chw operands")
            };
            // (h·w, c) pixel-major → NCHW-flattened (1, c·h·w).
            let (hw, c) = (h * w, a.cols);
            let mut data = alloc_i8(c * hw);
            for (slot, i) in data.iter_mut().zip(0..c * hw) {
                let (ch, pix) = (i / hw, i % hw);
                *slot = a.at(pix, ch);
            }
            TensorValue::I8(MatI8 {
                rows: 1,
                cols: c * hw,
                data,
            })
        }
        _ => unreachable!("matmul-class op routed to eval_elementwise"),
    }
}

/// Evaluate one matmul-class layer on the golden references.
pub(crate) fn eval_matmul(op: &LayerOp, a: &TensorValue) -> TensorValue {
    let TensorValue::I8(a) = a else {
        unreachable!("compiler admits only i8 matmul operands")
    };
    match op {
        LayerOp::Gemm { w } | LayerOp::Snn { w } => {
            TensorValue::I32(golden_gemm(a, w))
        }
        LayerOp::SparseGemm { w } => {
            TensorValue::I32(golden_gemm(a, &w.to_dense()))
        }
        LayerOp::Conv { weights, shape } => {
            TensorValue::I32(conv2d_direct(&a.data, weights, *shape))
        }
        _ => unreachable!("elementwise op routed to eval_matmul"),
    }
}

/// Replay the whole DAG layer by layer through the golden references.
/// This is what `Reference::ModelDirect` verifies against; it shares
/// the compiler (schedule, typed rejection) and the elementwise ops
/// with the serving path, and the matmul golden kernels with every
/// other workload's verification.
pub fn golden_eval(model: &Model, input: &MatI8) -> Result<MatI32, ModelError> {
    let plan = GraphCompiler::compile(model)?;
    if (input.rows, input.cols) != (model.input_rows, model.input_cols) {
        return Err(ModelError::BadInput {
            rows: input.rows,
            cols: input.cols,
        });
    }
    let mut tensors: Vec<Option<TensorValue>> =
        (0..model.layers.len() + 1).map(|_| None).collect();
    tensors[0] = Some(TensorValue::I8(input.clone()));
    for (s, &i) in plan.order.iter().enumerate() {
        let layer = &model.layers[i];
        let produced = if layer.op.is_matmul() {
            let a = tensors[layer.inputs[0]]
                .as_ref()
                .expect("schedule respects dependencies");
            eval_matmul(&layer.op, a)
        } else {
            let ins: Vec<&TensorValue> = layer
                .inputs
                .iter()
                .map(|&t| {
                    tensors[t]
                        .as_ref()
                        .expect("schedule respects dependencies")
                })
                .collect();
            eval_elementwise(&layer.op, &ins, |len| vec![0i8; len])
        };
        tensors[i + 1] = Some(produced);
        // Free dead tensors exactly where the scheduler would — the
        // golden path exercises the same lifetime analysis.
        for &t in &plan.free_after[s] {
            tensors[t] = None;
        }
    }
    Ok(tensors[model.output_tensor()]
        .as_ref()
        .expect("output tensor is produced")
        .widen())
}
