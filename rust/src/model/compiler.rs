//! The graph compiler: DAG → topological schedule + tensor metadata +
//! lifetime analysis.
//!
//! This is the render-graph pass-scheduler idiom applied to layers:
//! every layer is a pass over virtual tensors, the compiler recovers
//! an execution order from the dependency edges (the encoding order
//! carries no meaning), infers each tensor's `(dtype, rows, cols,
//! binary)` metadata, and computes when each tensor's **last**
//! consumer runs — the free point the scheduler uses to return the
//! buffer to the arena. Double buffering is emergent: with lifetimes
//! this tight, a layer chain ping-pongs between two pooled buffers
//! instead of accumulating one per layer.

use super::graph::{Dtype, LayerOp, Model, ModelError};
use crate::workload::conv::ConvShapeError;

/// Inferred metadata for one virtual tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorMeta {
    pub dtype: Dtype,
    pub rows: usize,
    pub cols: usize,
    /// Values constrained to {0, 1} — the precondition for feeding an
    /// [`LayerOp::Snn`] layer.
    pub binary: bool,
}

impl TensorMeta {
    /// Arena-residency cost of keeping this tensor live.
    pub fn bytes(&self) -> usize {
        self.rows * self.cols * self.dtype.bytes()
    }
}

/// The compiled schedule for one [`Model`].
#[derive(Debug, Clone)]
pub struct ModelPlan {
    /// Layer indices in execution (topological) order.
    pub order: Vec<usize>,
    /// Metadata per tensor id (`len == layers + 1`; id 0 is the model
    /// input).
    pub tensors: Vec<TensorMeta>,
    /// Remaining-consumer count per tensor id at schedule start. The
    /// output tensor carries one extra use (the client's), so it is
    /// never freed by the scheduler.
    pub uses: Vec<usize>,
    /// Wavefront level per layer: `1 + max(level of producers)`, with
    /// the model input at level 0. Two layers may share a weight-fill
    /// group only when their levels are equal — that is the rule that
    /// keeps cross-layer fill reuse deadlock-free (a group gates on
    /// tensors strictly below its level, which by induction all
    /// resolve before any level-`L` unit must run).
    pub level: Vec<usize>,
    /// For each schedule step `s`, the tensor ids whose last consumer
    /// is `order[s]` — freed back to the arena right after that layer.
    pub free_after: Vec<Vec<usize>>,
    /// Static high-water of produced-tensor residency (tensor ids
    /// ≥ 1), in bytes, over the schedule.
    pub peak_bytes: usize,
    /// Dense-equivalent MACs per layer (0 for elementwise glue).
    pub layer_macs: Vec<u64>,
    /// Sum of `layer_macs`.
    pub total_macs: u64,
}

impl ModelPlan {
    /// Count of matmul-class layers (the ones that reach an engine).
    pub fn matmul_layers(&self) -> usize {
        self.layer_macs.iter().filter(|&&m| m > 0).count()
    }
}

/// Compiles a [`Model`] into a [`ModelPlan`] or a typed [`ModelError`].
pub struct GraphCompiler;

impl GraphCompiler {
    pub fn compile(model: &Model) -> Result<ModelPlan, ModelError> {
        let n = model.layers.len();
        if n == 0 {
            return Err(ModelError::Empty);
        }
        if model.input_rows == 0 || model.input_cols == 0 {
            return Err(ModelError::BadInput {
                rows: model.input_rows,
                cols: model.input_cols,
            });
        }

        // Structural checks: arity and tensor-id range. Tensor t > 0
        // is produced by layer t-1; ids past the last layer dangle.
        for (i, layer) in model.layers.iter().enumerate() {
            let expected = layer.op.arity();
            if layer.inputs.len() != expected {
                return Err(ModelError::Arity {
                    layer: i,
                    expected,
                    got: layer.inputs.len(),
                });
            }
            for &t in &layer.inputs {
                if t > n {
                    return Err(ModelError::DanglingInput { layer: i, tensor: t });
                }
            }
        }

        // Kahn's algorithm over layer→layer edges. Forward references
        // are legal (the encoding order is not the schedule); genuine
        // cycles leave a nonempty stuck set and are reported through
        // the smallest stuck layer.
        let mut indegree = vec![0usize; n];
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, layer) in model.layers.iter().enumerate() {
            for &t in &layer.inputs {
                if t > 0 {
                    indegree[i] += 1;
                    consumers[t - 1].push(i);
                }
            }
        }
        let mut order = Vec::with_capacity(n);
        let mut done = vec![false; n];
        loop {
            // Smallest ready index first: deterministic schedules.
            let Some(next) = (0..n).find(|&i| !done[i] && indegree[i] == 0)
            else {
                break;
            };
            done[next] = true;
            order.push(next);
            for &c in &consumers[next] {
                indegree[c] -= 1;
            }
        }
        if order.len() < n {
            let stuck = (0..n).find(|&i| !done[i]).unwrap();
            return Err(ModelError::Cycle { layer: stuck });
        }

        // Tensor metadata + per-layer MACs, inferred in schedule order.
        let placeholder = TensorMeta {
            dtype: Dtype::I8,
            rows: 0,
            cols: 0,
            binary: false,
        };
        let mut tensors = vec![placeholder; n + 1];
        tensors[0] = TensorMeta {
            dtype: Dtype::I8,
            rows: model.input_rows,
            cols: model.input_cols,
            binary: model.spike_input,
        };
        let mut level = vec![0usize; n];
        let mut tensor_level = vec![0usize; n + 1];
        let mut layer_macs = vec![0u64; n];
        for &i in &order {
            let layer = &model.layers[i];
            let ins: Vec<TensorMeta> =
                layer.inputs.iter().map(|&t| tensors[t]).collect();
            let (meta, macs) = infer(i, &layer.op, &ins)?;
            tensors[i + 1] = meta;
            layer_macs[i] = macs;
            level[i] = 1 + layer
                .inputs
                .iter()
                .map(|&t| tensor_level[t])
                .max()
                .unwrap_or(0);
            tensor_level[i + 1] = level[i];
        }

        // Consumer counts. The output tensor gets the client's extra
        // use; any other unconsumed layer output is dead work.
        let mut uses = vec![0usize; n + 1];
        for layer in &model.layers {
            for &t in &layer.inputs {
                uses[t] += 1;
            }
        }
        uses[n] += 1;
        if let Some(t) = (1..n).find(|&t| uses[t] == 0) {
            return Err(ModelError::DeadLayer { layer: t - 1 });
        }

        // Lifetime analysis over the schedule: a produced tensor is
        // resident from its layer's step until its last consumer's
        // step; peak_bytes is the high-water of that resident set.
        let mut remaining = uses.clone();
        let mut free_after: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut resident = 0usize;
        let mut peak = 0usize;
        for (s, &i) in order.iter().enumerate() {
            resident += tensors[i + 1].bytes();
            peak = peak.max(resident);
            for &t in &model.layers[i].inputs {
                remaining[t] -= 1;
                if t >= 1 && remaining[t] == 0 {
                    resident -= tensors[t].bytes();
                    free_after[s].push(t);
                }
            }
        }

        let total_macs = layer_macs.iter().sum();
        Ok(ModelPlan {
            order,
            tensors,
            uses,
            level,
            free_after,
            peak_bytes: peak,
            layer_macs,
            total_macs,
        })
    }
}

/// Type/shape rules for one layer: input metas → output meta + MACs.
fn infer(
    i: usize,
    op: &LayerOp,
    ins: &[TensorMeta],
) -> Result<(TensorMeta, u64), ModelError> {
    let need_i8 = |t: TensorMeta, tensor_hint: usize| -> Result<(), ModelError> {
        if t.dtype != Dtype::I8 {
            return Err(ModelError::BadDtype {
                layer: i,
                tensor: tensor_hint,
                expected: Dtype::I8,
                got: t.dtype,
            });
        }
        Ok(())
    };
    match op {
        LayerOp::Gemm { w } | LayerOp::Snn { w } => {
            let a = ins[0];
            need_i8(a, 0)?;
            if w.rows == 0 || w.cols == 0 || a.cols != w.rows {
                return Err(ModelError::BadShape {
                    layer: i,
                    expected: (a.rows, w.rows),
                    got: (a.rows, a.cols),
                });
            }
            if matches!(op, LayerOp::Snn { .. }) && !a.binary {
                return Err(ModelError::SnnInputNotBinary {
                    layer: i,
                    tensor: 0,
                });
            }
            Ok((
                TensorMeta {
                    dtype: Dtype::I32,
                    rows: a.rows,
                    cols: w.cols,
                    binary: false,
                },
                (a.rows * w.rows * w.cols) as u64,
            ))
        }
        LayerOp::SparseGemm { w } => {
            let a = ins[0];
            need_i8(a, 0)?;
            if w.rows() == 0 || w.cols() == 0 || a.cols != w.rows() {
                return Err(ModelError::BadShape {
                    layer: i,
                    expected: (a.rows, w.rows()),
                    got: (a.rows, a.cols),
                });
            }
            Ok((
                TensorMeta {
                    dtype: Dtype::I32,
                    rows: a.rows,
                    cols: w.cols(),
                    binary: false,
                },
                // Dense-equivalent, like the sparse job path: skipped
                // work is delivered work.
                (a.rows * w.rows() * w.cols()) as u64,
            ))
        }
        LayerOp::Conv { weights, shape } => {
            let a = ins[0];
            need_i8(a, 0)?;
            shape
                .validate()
                .map_err(|err| ModelError::BadConv { layer: i, err })?;
            if weights.len() != shape.weight_len() {
                return Err(ModelError::BadConv {
                    layer: i,
                    err: ConvShapeError::WeightLen {
                        expected: shape.weight_len(),
                        got: weights.len(),
                    },
                });
            }
            if (a.rows, a.cols) != (1, shape.input_len()) {
                return Err(ModelError::BadShape {
                    layer: i,
                    expected: (1, shape.input_len()),
                    got: (a.rows, a.cols),
                });
            }
            Ok((
                TensorMeta {
                    dtype: Dtype::I32,
                    rows: shape.out_h() * shape.out_w(),
                    cols: shape.out_c,
                    binary: false,
                },
                shape.macs(),
            ))
        }
        LayerOp::Requant { shift, .. } | LayerOp::Quant { shift, .. } => {
            // i32 accumulators or i8 tensors both requantize; the
            // output is i8, binary only for Quant (the binarizer).
            if !(1..=31).contains(shift) {
                return Err(ModelError::BadQuant {
                    layer: i,
                    shift: *shift,
                });
            }
            let a = ins[0];
            Ok((
                TensorMeta {
                    dtype: Dtype::I8,
                    rows: a.rows,
                    cols: a.cols,
                    binary: matches!(op, LayerOp::Quant { .. }),
                },
                0,
            ))
        }
        LayerOp::Add => {
            let (a, b) = (ins[0], ins[1]);
            need_i8(a, 0)?;
            need_i8(b, 1)?;
            if (a.rows, a.cols) != (b.rows, b.cols) {
                return Err(ModelError::BadShape {
                    layer: i,
                    expected: (a.rows, a.cols),
                    got: (b.rows, b.cols),
                });
            }
            Ok((
                TensorMeta {
                    dtype: Dtype::I8,
                    rows: a.rows,
                    cols: a.cols,
                    binary: false,
                },
                0,
            ))
        }
        LayerOp::Chw { h, w } => {
            let a = ins[0];
            need_i8(a, 0)?;
            if *h == 0 || *w == 0 || a.rows != h * w {
                return Err(ModelError::BadShape {
                    layer: i,
                    expected: (h.saturating_mul(*w), a.cols),
                    got: (a.rows, a.cols),
                });
            }
            Ok((
                TensorMeta {
                    dtype: Dtype::I8,
                    rows: 1,
                    cols: a.cols * h * w,
                    binary: a.binary,
                },
                0,
            ))
        }
    }
}
