//! Built-in model presets: seeded, self-contained `(Model, input)`
//! pairs for the CLI, the benches, and the serve-loopback smoke.
//!
//! Every preset comes in two variants selected by the `snn` flag:
//!
//! * **dense** — GEMM/conv layers with `Requant` glue, activations
//!   bounded to ±63 and weights to ±50 so every WS packed-lane pass
//!   stays exact (the same bounds the single-job generators use);
//! * **spiking** — `Snn`/1×1-conv layers over **binary** tensors with
//!   `Quant` (binarize) glue, every matmul operand 32 columns wide to
//!   match the FireFly crossbar's fixed fan-in.
//!
//! The transformer block ties `Wk = Wq` (Reformer-style shared-QK):
//! the Q and K projections sit at the same wavefront level with
//! bit-identical weights, so the coordinator merges their tiles into
//! one fill group — the deterministic inter-layer weight-fill reuse
//! the bench gates count.

use super::graph::{LayerOp, Model};
use crate::util::rng::XorShift;
use crate::workload::conv::ConvShape;
use crate::workload::MatI8;

/// A named, seeded model the CLI can build without shipping weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelPreset {
    /// Two transformer blocks (QKV + output projection + 2-layer FFN
    /// with residuals), per the DiP-style multi-layer GEMM traffic.
    TransformerBlock,
    /// Three chained convolutions — the middle one dilated *and*
    /// grouped — with `Chw` repacks between them.
    ConvStack,
}

impl ModelPreset {
    pub fn all() -> [ModelPreset; 2] {
        [ModelPreset::TransformerBlock, ModelPreset::ConvStack]
    }

    pub fn label(self) -> &'static str {
        match self {
            ModelPreset::TransformerBlock => "transformer-block",
            ModelPreset::ConvStack => "conv-stack",
        }
    }

    /// Parse a `--preset` value ([`ModelPreset::label`] round-trips).
    pub fn parse(s: &str) -> Option<ModelPreset> {
        ModelPreset::all().into_iter().find(|p| p.label() == s)
    }

    /// Build the preset graph and its seeded input. `snn` selects the
    /// spiking variant (binary tensors, crossbar-shaped layers) for
    /// SNN servers — the same role `--spikes` plays for conv jobs.
    pub fn build(self, snn: bool, seed: u64) -> (Model, MatI8) {
        let mut rng = XorShift::new(seed);
        match (self, snn) {
            (ModelPreset::TransformerBlock, false) => {
                transformer_dense(&mut rng)
            }
            (ModelPreset::TransformerBlock, true) => {
                transformer_snn(&mut rng)
            }
            (ModelPreset::ConvStack, false) => conv_stack_dense(&mut rng),
            (ModelPreset::ConvStack, true) => conv_stack_snn(&mut rng),
        }
    }
}

impl std::fmt::Display for ModelPreset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Dense two-block transformer: m=16 tokens, d=28, d_ff=56. The
/// requant shifts are chosen so every tensor that feeds a GEMM stays
/// within ±63 (weights ±50, input ±63): 12 bits after a d=28
/// projection, 13 after the d_ff=56 contraction, and one halving bit
/// after each residual add.
fn transformer_dense(rng: &mut XorShift) -> (Model, MatI8) {
    let (m, d, d_ff) = (16, 28, 56);
    let input = MatI8::random_bounded(rng, m, d, 63);
    let mut model = Model::new(m, d, false);
    let rq = |shift: u32| LayerOp::Requant {
        num: 1,
        shift,
        zero_point: 0,
    };
    let mut x = 0;
    for _ in 0..2 {
        let wq = MatI8::random_bounded(rng, d, d, 50);
        let wv = MatI8::random_bounded(rng, d, d, 50);
        let wo = MatI8::random_bounded(rng, d, d, 50);
        let w1 = MatI8::random_bounded(rng, d, d_ff, 50);
        let w2 = MatI8::random_bounded(rng, d_ff, d, 50);
        // Shared-QK: K reuses Q's weights bit-identically, at the same
        // wavefront level — the cross-layer fill-reuse pair.
        let tq = model.layer(LayerOp::Gemm { w: wq.clone() }, &[x]);
        let q = model.layer(rq(12), &[tq]);
        let tk = model.layer(LayerOp::Gemm { w: wq }, &[x]);
        let k = model.layer(rq(12), &[tk]);
        let tv = model.layer(LayerOp::Gemm { w: wv }, &[x]);
        let v = model.layer(rq(12), &[tv]);
        let s = model.layer(LayerOp::Add, &[q, k]);
        let s2 = model.layer(LayerOp::Add, &[s, v]);
        let sq = model.layer(rq(2), &[s2]);
        let to = model.layer(LayerOp::Gemm { w: wo }, &[sq]);
        let p = model.layer(rq(12), &[to]);
        let r = model.layer(LayerOp::Add, &[p, x]);
        let rq1 = model.layer(rq(1), &[r]);
        let t1 = model.layer(LayerOp::Gemm { w: w1 }, &[rq1]);
        let f1 = model.layer(rq(12), &[t1]);
        let t2 = model.layer(LayerOp::Gemm { w: w2 }, &[f1]);
        let f2 = model.layer(rq(13), &[t2]);
        let y = model.layer(LayerOp::Add, &[f2, rq1]);
        x = model.layer(rq(1), &[y]);
    }
    (model, input)
}

/// Spiking two-block transformer: every matmul is a 32-wide crossbar
/// `Snn` layer, every tensor that feeds one is re-binarized by `Quant`.
fn transformer_snn(rng: &mut XorShift) -> (Model, MatI8) {
    let (m, d) = (16, 32);
    let input = MatI8::from_fn(m, d, |_, _| i8::from(rng.chance(1, 3)));
    let mut model = Model::new(m, d, true);
    let q6 = LayerOp::Quant { num: 1, shift: 6 };
    let q1 = LayerOp::Quant { num: 1, shift: 1 };
    let mut x = 0;
    for _ in 0..2 {
        let wq = MatI8::random_bounded(rng, d, d, 50);
        let wv = MatI8::random_bounded(rng, d, d, 50);
        let wo = MatI8::random_bounded(rng, d, d, 50);
        let w1 = MatI8::random_bounded(rng, d, d, 50);
        let w2 = MatI8::random_bounded(rng, d, d, 50);
        let tq = model.layer(LayerOp::Snn { w: wq.clone() }, &[x]);
        let q = model.layer(q6.clone(), &[tq]);
        let tk = model.layer(LayerOp::Snn { w: wq }, &[x]);
        let k = model.layer(q6.clone(), &[tk]);
        let tv = model.layer(LayerOp::Snn { w: wv }, &[x]);
        let v = model.layer(q6.clone(), &[tv]);
        let s = model.layer(LayerOp::Add, &[q, k]);
        let sb = model.layer(q1.clone(), &[s]);
        let s2 = model.layer(LayerOp::Add, &[sb, v]);
        let s2b = model.layer(q1.clone(), &[s2]);
        let to = model.layer(LayerOp::Snn { w: wo }, &[s2b]);
        let p = model.layer(q6.clone(), &[to]);
        let r = model.layer(LayerOp::Add, &[p, x]);
        let rb = model.layer(q1.clone(), &[r]);
        let t1 = model.layer(LayerOp::Snn { w: w1 }, &[rb]);
        let f1 = model.layer(q6.clone(), &[t1]);
        let t2 = model.layer(LayerOp::Snn { w: w2 }, &[f1]);
        let f2 = model.layer(q6.clone(), &[t2]);
        let y = model.layer(LayerOp::Add, &[f2, rb]);
        x = model.layer(q1.clone(), &[y]);
    }
    (model, input)
}

fn conv_weights(rng: &mut XorShift, shape: ConvShape) -> Vec<i8> {
    (0..shape.weight_len()).map(|_| rng.i8_in(-50, 50)).collect()
}

/// Dense conv stack over a 4×10×10 input: plain 3×3, then a dilated
/// (d=2) **grouped** (g=2) 3×3, then a 1×1 projection — the satellite
/// `ConvShape` fields exercised end to end, with `Chw` repacks
/// carrying each layer's pixel-major output back to NCHW.
fn conv_stack_dense(rng: &mut XorShift) -> (Model, MatI8) {
    let c1 = ConvShape {
        in_c: 4,
        in_h: 10,
        in_w: 10,
        out_c: 8,
        k: 3,
        stride: 1,
        pad: 1,
        dilation: 1,
        groups: 1,
    };
    let c2 = ConvShape {
        in_c: 8,
        in_h: 10,
        in_w: 10,
        out_c: 8,
        k: 3,
        stride: 1,
        pad: 2,
        dilation: 2,
        groups: 2,
    };
    let c3 = ConvShape {
        in_c: 8,
        in_h: 10,
        in_w: 10,
        out_c: 12,
        k: 1,
        stride: 1,
        pad: 0,
        dilation: 1,
        groups: 1,
    };
    let input = MatI8::random_bounded(rng, 1, c1.input_len(), 63);
    let mut model = Model::new(1, c1.input_len(), false);
    let rq = |shift: u32| LayerOp::Requant {
        num: 1,
        shift,
        zero_point: 0,
    };
    let t1 = model.layer(
        LayerOp::Conv {
            weights: conv_weights(rng, c1),
            shape: c1,
        },
        &[0],
    );
    let a1 = model.layer(rq(12), &[t1]);
    let n1 = model.layer(LayerOp::Chw { h: 10, w: 10 }, &[a1]);
    let t2 = model.layer(
        LayerOp::Conv {
            weights: conv_weights(rng, c2),
            shape: c2,
        },
        &[n1],
    );
    let a2 = model.layer(rq(11), &[t2]);
    let n2 = model.layer(LayerOp::Chw { h: 10, w: 10 }, &[a2]);
    let t3 = model.layer(
        LayerOp::Conv {
            weights: conv_weights(rng, c3),
            shape: c3,
        },
        &[n2],
    );
    model.layer(rq(9), &[t3]);
    (model, input)
}

/// Spiking conv stack: 1×1 convolutions over 32 channels (so the
/// im2col K dimension equals the 32-wide crossbar fan-in), binary
/// tensors throughout.
fn conv_stack_snn(rng: &mut XorShift) -> (Model, MatI8) {
    let shape = |out_c: usize| ConvShape {
        in_c: 32,
        in_h: 6,
        in_w: 6,
        out_c,
        k: 1,
        stride: 1,
        pad: 0,
        dilation: 1,
        groups: 1,
    };
    let (c1, c2, c3) = (shape(32), shape(32), shape(12));
    let input =
        MatI8::from_fn(1, c1.input_len(), |_, _| i8::from(rng.chance(1, 3)));
    let mut model = Model::new(1, c1.input_len(), true);
    let q4 = LayerOp::Quant { num: 1, shift: 4 };
    let t1 = model.layer(
        LayerOp::Conv {
            weights: conv_weights(rng, c1),
            shape: c1,
        },
        &[0],
    );
    let a1 = model.layer(q4.clone(), &[t1]);
    let n1 = model.layer(LayerOp::Chw { h: 6, w: 6 }, &[a1]);
    let t2 = model.layer(
        LayerOp::Conv {
            weights: conv_weights(rng, c2),
            shape: c2,
        },
        &[n1],
    );
    let a2 = model.layer(q4.clone(), &[t2]);
    let n2 = model.layer(LayerOp::Chw { h: 6, w: 6 }, &[a2]);
    let t3 = model.layer(
        LayerOp::Conv {
            weights: conv_weights(rng, c3),
            shape: c3,
        },
        &[n2],
    );
    model.layer(q4, &[t3]);
    (model, input)
}
