//! Model graphs: whole networks served as DAGs of layers.
//!
//! Single jobs (`Job::Gemm`/`Conv`/`Snn`/`SparseGemm`) round-trip one
//! matmul through the service; real traffic is networks. This module
//! is the graph-scheduling subsystem underneath `Job::Model`:
//!
//! * [`graph`] — [`Model`]: a validated DAG of [`Layer`] nodes over
//!   virtual tensors (tensor 0 = input, layer `i` → tensor `i+1`),
//!   with typed [`ModelError`] rejection for cycles, dangling edges,
//!   dtype/shape mismatches and dead layers;
//! * [`compiler`] — [`GraphCompiler`] lowers the DAG to a
//!   [`ModelPlan`]: topological order, per-tensor metadata, wavefront
//!   levels (the cross-layer fill-grouping rule), and lifetime
//!   analysis (when each intermediate returns to the arena);
//! * [`golden`] — [`golden_eval`] replays the DAG through the golden
//!   kernels for `Reference::ModelDirect` verification, and owns the
//!   **single** implementation of the elementwise glue ops the
//!   scheduler also executes (glue bit-identity by construction);
//! * [`presets`] — seeded [`ModelPreset`] networks
//!   (`transformer-block`, `conv-stack`) in dense and spiking
//!   variants for the CLI, benches and CI smoke.
//!
//! Execution lives in `coordinator/models.rs`: matmul layers ride the
//! existing `FillGroup`/`WorkUnit` machinery as dependency-gated
//! passes, glue layers run scheduler-side on arena-resident tensors,
//! and intermediate activations never round-trip through the client.

pub mod compiler;
pub mod golden;
pub mod graph;
pub mod presets;

pub use compiler::{GraphCompiler, ModelPlan, TensorMeta};
pub use golden::{golden_eval, TensorValue};
pub use graph::{Dtype, Layer, LayerOp, Model, ModelError};
pub use presets::ModelPreset;
