//! The model graph: a validated DAG of layers over virtual tensors.
//!
//! A [`Model`] names its tensors by index: tensor `0` is the model
//! input, and layer `i` produces tensor `i + 1`. Layer inputs may
//! reference **any** tensor id — including tensors produced by layers
//! that appear later in the encoding — so the encoding order carries
//! no scheduling meaning; [`GraphCompiler`](super::GraphCompiler)
//! recovers a topological schedule (and rejects genuine cycles and
//! dangling references with typed errors, never panics).
//!
//! Layers split into two classes:
//!
//! * **matmul-class** ([`LayerOp::Gemm`], [`LayerOp::SparseGemm`],
//!   [`LayerOp::Conv`], [`LayerOp::Snn`]) — executed on the systolic
//!   engines through the coordinator's tiling machinery;
//! * **elementwise glue** ([`LayerOp::Requant`], [`LayerOp::Quant`],
//!   [`LayerOp::Add`], [`LayerOp::Chw`]) — the `workload/quant.rs`
//!   arithmetic between array passes, evaluated scheduler-side on the
//!   arena-resident tensors (zero array cycles, zero client round
//!   trips).

use crate::workload::conv::{ConvShape, ConvShapeError};
use crate::workload::sparse::SparseMatI8;
use crate::workload::MatI8;

/// Element type of a virtual tensor: matmul-class layers accumulate
/// into `I32`; everything the engines *stream* is `I8`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    I8,
    I32,
}

impl Dtype {
    /// Bytes per element — the unit of arena-residency accounting.
    pub fn bytes(self) -> usize {
        match self {
            Dtype::I8 => 1,
            Dtype::I32 => 4,
        }
    }
}

/// One layer's operator. Weights travel *inside* the op (they are
/// model parameters, not virtual tensors): that is what lets the
/// coordinator fingerprint them for cross-layer weight-fill reuse.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerOp {
    /// Dense GEMM: `(m × k) i8 @ w (k × n) → (m × n) i32`.
    Gemm { w: MatI8 },
    /// N:M structured-sparse GEMM (densified only inside the golden
    /// checker, exactly like `Reference::SparseDense`).
    SparseGemm { w: SparseMatI8 },
    /// Conv2d over an NCHW-flattened `(1 × in_c·in_h·in_w)` tensor,
    /// producing the `(out_h·out_w × out_c)` patch-GEMM output.
    Conv { weights: Vec<i8>, shape: ConvShape },
    /// Spiking crossbar matmul: requires a **binary** input tensor.
    Snn { w: MatI8 },
    /// Requantize to i8: `clamp(((v·num + round) >> shift) + zp)` —
    /// [`crate::workload::quant::requantize`] per element. Accepts an
    /// i32 accumulator tensor or an i8 tensor (widened).
    Requant { num: i32, shift: u32, zero_point: i32 },
    /// Binarize to a spike tensor: `requantize(v, num, shift, 0) > 0`.
    /// The output is marked binary, so it may feed [`LayerOp::Snn`].
    Quant { num: i32, shift: u32 },
    /// Two-input saturating i8 add (residual/branch merge).
    Add,
    /// Repack a conv output `(h·w × c) i8` into the NCHW-flattened
    /// `(1 × c·h·w)` row the next [`LayerOp::Conv`] consumes.
    Chw { h: usize, w: usize },
}

impl LayerOp {
    /// Wire/debug tag (shared with the proto schema).
    pub fn label(&self) -> &'static str {
        match self {
            LayerOp::Gemm { .. } => "gemm",
            LayerOp::SparseGemm { .. } => "sparse-gemm",
            LayerOp::Conv { .. } => "conv",
            LayerOp::Snn { .. } => "snn",
            LayerOp::Requant { .. } => "requant",
            LayerOp::Quant { .. } => "quant",
            LayerOp::Add => "add",
            LayerOp::Chw { .. } => "chw",
        }
    }

    /// Matmul-class layers run on an engine; the rest are glue the
    /// scheduler evaluates on the resident tensors.
    pub fn is_matmul(&self) -> bool {
        matches!(
            self,
            LayerOp::Gemm { .. }
                | LayerOp::SparseGemm { .. }
                | LayerOp::Conv { .. }
                | LayerOp::Snn { .. }
        )
    }

    /// How many input tensors the op consumes.
    pub fn arity(&self) -> usize {
        match self {
            LayerOp::Add => 2,
            _ => 1,
        }
    }
}

/// One node of the DAG: an operator plus the tensor ids it reads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    pub op: LayerOp,
    /// Tensor ids (`0` = model input, `i + 1` = layer `i`'s output).
    pub inputs: Vec<usize>,
}

/// A whole network: the layer DAG plus the model-input tensor's
/// declared geometry. The model's **output** is the last layer's
/// tensor (`layers.len()`); every other layer must be consumed by
/// some later layer or the graph is rejected ([`ModelError::DeadLayer`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Model {
    pub layers: Vec<Layer>,
    /// Rows of tensor 0 (the batch/pixel dimension).
    pub input_rows: usize,
    /// Columns of tensor 0 (the feature dimension).
    pub input_cols: usize,
    /// Whether tensor 0 is a binary spike tensor (values in {0, 1}) —
    /// required before it may feed an [`LayerOp::Snn`] layer.
    pub spike_input: bool,
}

impl Model {
    pub fn new(input_rows: usize, input_cols: usize, spike_input: bool) -> Self {
        Model {
            layers: Vec::new(),
            input_rows,
            input_cols,
            spike_input,
        }
    }

    /// Append a layer and return the tensor id it produces.
    pub fn layer(&mut self, op: LayerOp, inputs: &[usize]) -> usize {
        self.layers.push(Layer {
            op,
            inputs: inputs.to_vec(),
        });
        self.layers.len()
    }

    /// Tensor id of the model output (the last layer's output).
    pub fn output_tensor(&self) -> usize {
        self.layers.len()
    }

    /// Validate the DAG without keeping the schedule around.
    pub fn validate(&self) -> Result<(), ModelError> {
        super::GraphCompiler::compile(self).map(|_| ())
    }

    /// Dense-equivalent MAC work across all matmul-class layers
    /// (`0` if the graph does not compile — the job will resolve as a
    /// typed `Failed` handle before any accounting matters).
    pub fn macs(&self) -> u64 {
        super::GraphCompiler::compile(self)
            .map(|plan| plan.total_macs)
            .unwrap_or(0)
    }
}

/// Why a [`Model`] cannot be compiled into a schedule. Returned by
/// [`Model::validate`] / `GraphCompiler::compile` so a bad submission
/// resolves as a typed `Failed` handle — never a panic, never a
/// disconnect.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelError {
    /// A model with no layers has no output tensor.
    Empty,
    /// The dependency graph contains a cycle through this layer.
    Cycle { layer: usize },
    /// A layer references a tensor id no layer (and not the model
    /// input) produces.
    DanglingInput { layer: usize, tensor: usize },
    /// Wrong number of inputs for the operator.
    Arity {
        layer: usize,
        expected: usize,
        got: usize,
    },
    /// An input tensor has the wrong element type.
    BadDtype {
        layer: usize,
        tensor: usize,
        expected: Dtype,
        got: Dtype,
    },
    /// An input tensor's geometry does not match what the operator
    /// needs (GEMM inner dim, conv input length, Add operand shapes,
    /// Chw spatial extent).
    BadShape {
        layer: usize,
        expected: (usize, usize),
        got: (usize, usize),
    },
    /// An [`LayerOp::Snn`] layer consumes a tensor that is not a
    /// binary spike tensor.
    SnnInputNotBinary { layer: usize, tensor: usize },
    /// A conv layer's shape (or weight buffer) failed
    /// [`ConvShape::validate`].
    BadConv {
        layer: usize,
        err: ConvShapeError,
    },
    /// A requant/quant shift outside `1..=31` — `requantize` needs at
    /// least one rounding bit, and an i32 value has nothing past 31.
    BadQuant { layer: usize, shift: u32 },
    /// A non-final layer's output is consumed by nobody: the work
    /// would run and be thrown away, which is always a graph bug.
    DeadLayer { layer: usize },
    /// The declared model-input geometry is degenerate.
    BadInput { rows: usize, cols: usize },
}

impl std::fmt::Display for ModelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelError::Empty => write!(f, "model has no layers"),
            ModelError::Cycle { layer } => {
                write!(f, "dependency cycle through layer {layer}")
            }
            ModelError::DanglingInput { layer, tensor } => write!(
                f,
                "layer {layer} reads tensor {tensor}, which nothing produces"
            ),
            ModelError::Arity {
                layer,
                expected,
                got,
            } => write!(
                f,
                "layer {layer} takes {expected} input(s), got {got}"
            ),
            ModelError::BadDtype {
                layer,
                tensor,
                expected,
                got,
            } => write!(
                f,
                "layer {layer}: tensor {tensor} is {got:?}, needs {expected:?}"
            ),
            ModelError::BadShape {
                layer,
                expected,
                got,
            } => write!(
                f,
                "layer {layer}: input is {}x{}, needs {}x{}",
                got.0, got.1, expected.0, expected.1
            ),
            ModelError::SnnInputNotBinary { layer, tensor } => write!(
                f,
                "layer {layer}: snn input tensor {tensor} is not binary"
            ),
            ModelError::BadConv { layer, err } => {
                write!(f, "layer {layer}: {err}")
            }
            ModelError::BadQuant { layer, shift } => write!(
                f,
                "layer {layer}: shift {shift} outside 1..=31"
            ),
            ModelError::DeadLayer { layer } => write!(
                f,
                "layer {layer}'s output is never consumed"
            ),
            ModelError::BadInput { rows, cols } => {
                write!(f, "model input {rows}x{cols} is degenerate")
            }
        }
    }
}

impl std::error::Error for ModelError {}
