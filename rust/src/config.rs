//! Configuration: a small TOML-subset parser + the typed config structs.
//!
//! Offline build — no serde/toml crates — so this module implements the
//! subset the project needs: `[section]` headers and
//! `key = value` lines with string / integer / float / boolean values
//! and `#` comments. See `examples/service.toml` for the shipped schema.

use crate::coordinator::service::EngineKind;
use crate::coordinator::ServiceConfig;
use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section.key -> value` map.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
}

/// Parse error with line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            let line = match line.find('#') {
                // Allow trailing comments outside strings (strings in
                // our schema never contain '#').
                Some(pos) if !line[..pos].contains('"') || line[..pos].matches('"').count() % 2 == 0 => {
                    line[..pos].trim()
                }
                _ => line,
            };
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    return Err(ConfigError {
                        line: i + 1,
                        msg: "unterminated section header".into(),
                    });
                };
                section = name.trim().to_string();
                continue;
            }
            let Some((key, val)) = line.split_once('=') else {
                return Err(ConfigError {
                    line: i + 1,
                    msg: format!("expected key = value, got `{line}`"),
                });
            };
            let key = key.trim();
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let val = parse_value(val.trim()).ok_or_else(|| ConfigError {
                line: i + 1,
                msg: format!("bad value `{}`", val.trim()),
            })?;
            cfg.values.insert(full_key, val);
        }
        Ok(cfg)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    /// Build a [`ServiceConfig`] from the `[service]` + `[engine]`
    /// sections (missing keys use defaults).
    pub fn service_config(&self) -> Result<ServiceConfig, ConfigError> {
        let kind_str = self.str_or("engine.kind", "ws-dsp-fetch");
        let kind = EngineKind::parse(kind_str).ok_or_else(|| ConfigError {
            line: 0,
            msg: format!("unknown engine.kind `{kind_str}`"),
        })?;
        Ok(ServiceConfig {
            kind,
            workers: self.int_or("service.workers", 2).max(1) as usize,
            ws_rows: self.int_or("engine.rows", 14).max(1) as usize,
            ws_cols: self.int_or("engine.cols", 14).max(1) as usize,
            verify: self.bool_or("service.verify", true),
            shard_width: self.int_or("service.shard_width", 1).max(1) as usize,
        })
    }
}

fn parse_value(s: &str) -> Option<Value> {
    if let Some(stripped) = s.strip_prefix('"') {
        return stripped.strip_suffix('"').map(|v| Value::Str(v.to_string()));
    }
    match s {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    if let Ok(v) = s.parse::<i64>() {
        return Some(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Some(Value::Float(v));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# matrix engine service
[service]
workers = 4
verify = true

[engine]
kind = "ws-dsp-fetch"  # the paper's design
rows = 14
cols = 14
clock_mhz = 666.0
"#;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(SAMPLE).unwrap();
        assert_eq!(cfg.int_or("service.workers", 0), 4);
        assert_eq!(cfg.bool_or("service.verify", false), true);
        assert_eq!(cfg.str_or("engine.kind", ""), "ws-dsp-fetch");
        assert_eq!(
            cfg.get("engine.clock_mhz").and_then(Value::as_float),
            Some(666.0)
        );
    }

    #[test]
    fn builds_service_config() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let svc = cfg.service_config().unwrap();
        assert_eq!(svc.workers, 4);
        assert_eq!(svc.ws_rows, 14);
        assert!(svc.verify);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("nonsense without equals").is_err());
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("key = @@@").is_err());
    }

    #[test]
    fn unknown_engine_kind_rejected() {
        let cfg = Config::parse("[engine]\nkind = \"warp-drive\"").unwrap();
        assert!(cfg.service_config().is_err());
    }

    #[test]
    fn defaults_without_file() {
        let cfg = Config::parse("").unwrap();
        let svc = cfg.service_config().unwrap();
        assert_eq!(svc.workers, 2);
        assert_eq!(svc.ws_rows, 14);
        assert_eq!(svc.shard_width, 1);
    }
}
