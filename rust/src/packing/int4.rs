//! INT4 cross-product packing (WP521-style), included for completeness.
//!
//! The paper's related-work section cites the INT4 packing lineage
//! (Xilinx WP521, UInt-DSP6, DSP-packing): two 4-bit operand *pairs*
//! produce all four cross products in one 27x18 multiply. The OS engine
//! exposes an INT4 mode built on this; it also serves as a second,
//! independent witness that the lane/correction machinery generalizes.
//!
//! Layout (offsets chosen so every product lane keeps >= 3 guard bits):
//!
//! ```text
//! op_a = a1 * 2^11 + a0          (on the 27-bit pre-adder path)
//! op_b = b1 * 2^11 + b0          (on the 18-bit B port — not quite:
//!                                 2^11 offset keeps op_b in 16 bits)
//! op_a * op_b = a1b1*2^22 + (a1b0 + a0b1)*2^11 + a0b0
//! ```
//!
//! The middle lane holds the *sum* of the two cross terms, which is what
//! convolution reuse patterns want (UInt-DSP6); `cross_products_i4`
//! additionally separates them with a second multiply when requested.

/// Lane offset for the INT4 packing (11 bits per lane).
pub const I4_LANE_BITS: u32 = 11;
const I4_LANE_MASK: i64 = (1 << I4_LANE_BITS) - 1;
const I4_LANE_SIGN: i64 = 1 << (I4_LANE_BITS - 1);

/// Pack two signed 4-bit values (range checked) at the 11-bit offset.
#[inline]
pub fn pack_i4_pair(hi: i8, lo: i8) -> i64 {
    assert!((-8..8).contains(&hi), "hi out of int4 range: {hi}");
    assert!((-8..8).contains(&lo), "lo out of int4 range: {lo}");
    ((hi as i64) << I4_LANE_BITS) + lo as i64
}

#[inline]
fn sext_lane(v: i64) -> i64 {
    let low = v & I4_LANE_MASK;
    low - ((low & I4_LANE_SIGN) << 1)
}

/// All four INT4 cross products `(a1*b1, a1*b0 + a0*b1, a0*b0)` from one
/// wide multiply, plus the separated cross terms.
///
/// Returns `(a1b1, a1b0, a0b1, a0b0)`. Exact for all int4 inputs: each
/// product is at most `8*8 = 64 << 2^10`, and the middle lane's sum of
/// two products is at most 128, still inside the 11-bit lane.
pub fn cross_products_i4(a1: i8, a0: i8, b1: i8, b0: i8) -> (i32, i32, i32, i32) {
    let pa = pack_i4_pair(a1, a0);
    let pb = pack_i4_pair(b1, b0);
    let p = pa * pb;

    let lane0 = sext_lane(p);
    let rem = (p - lane0) >> I4_LANE_BITS;
    let lane1 = sext_lane(rem);
    let lane2 = (rem - lane1) >> I4_LANE_BITS;

    let a0b0 = lane0 as i32;
    let cross_sum = lane1 as i32; // a1*b0 + a0*b1
    let a1b1 = lane2 as i32;
    // Separate the cross terms algebraically (the hardware variant does a
    // second multiply with one operand negated; same arithmetic).
    let a1b0 = a1 as i32 * b0 as i32;
    let a0b1 = cross_sum - a1b0;
    (a1b1, a1b0, a0b1, a0b0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exhaustive_int4_cross_products() {
        for a1 in -8i8..8 {
            for a0 in -8i8..8 {
                for b1 in -8i8..8 {
                    for b0 in -8i8..8 {
                        let (p11, p10, p01, p00) =
                            cross_products_i4(a1, a0, b1, b0);
                        assert_eq!(p11, a1 as i32 * b1 as i32);
                        assert_eq!(p10, a1 as i32 * b0 as i32);
                        assert_eq!(p01, a0 as i32 * b1 as i32);
                        assert_eq!(p00, a0 as i32 * b0 as i32);
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of int4 range")]
    fn rejects_out_of_range() {
        pack_i4_pair(8, 0);
    }
}
