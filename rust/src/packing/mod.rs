//! INT8 (and INT4 / SIMD12) operand-packing algebra for the DSP48E2.
//!
//! This module is the *rust twin* of `python/compile/kernels/ref.py`:
//! the same lane geometry, the same sign-correction, the same guard-band
//! constant. The python property tests (hypothesis) and the rust ones
//! (`util::quickcheck`) pin the identical contract so the functional
//! (Pallas) and structural (cycle-accurate) models cannot drift apart.
//!
//! ## The WP487 trick
//!
//! Two INT8 values `hi` and `lo` are packed into one wide operand at an
//! 18-bit offset — in hardware the pre-adder computes `(hi << 18) + lo`
//! from the A and D ports. One 27x18 multiply by a shared INT8 operand
//! `w` then yields both products in one product word:
//!
//! ```text
//! (hi*2^18 + lo) * w  =  hi*w * 2^18  +  lo*w
//! ```
//!
//! Splitting the 45-bit result at bit 18 recovers `lo*w` as a signed
//! 18-bit field; when that field is negative the `hi*w` lane must absorb
//! a +1 borrow — the famous correction step, which the paper's ring
//! accumulator folds into the DSP's W-multiplexer RND constant.

mod int4;
mod simd12;

pub use int4::{cross_products_i4, pack_i4_pair};
pub use simd12::{simd12_accumulate, Simd12Lanes};

/// Bit position of the high product lane (the packing offset).
pub const LANE_BITS: u32 = 18;
/// Mask of the low lane.
pub const LANE_MASK: i64 = (1 << LANE_BITS) - 1;
/// Sign bit value of an 18-bit lane.
pub const LANE_SIGN: i64 = 1 << (LANE_BITS - 1);

/// Deepest packed cascade that is exact for worst-case INT8 inputs.
///
/// `|i8 * i8| <= 2^14`, so a cascade of depth `d` keeps the low lane in
/// `[-2^17, 2^17)` as long as `d * 2^14 < 2^17`, i.e. `d <= 7`. Engines
/// and kernels drain at most every `GUARD_DEPTH` stages; the paper's
/// 14-deep columns rely on typical data instead (see DESIGN.md).
pub const GUARD_DEPTH: usize = 7;

/// Pack two INT8 operands into the wide pre-adder word `(hi << 18) + lo`.
///
/// This is what the DSP48E2 pre-adder produces with `hi` (pre-shifted)
/// on the A port and `lo` on the D port. The result fits the 27-bit
/// pre-adder output: `|packed| <= 127*2^18 + 128 < 2^26`.
#[inline]
pub fn pack_i8_pair(hi: i8, lo: i8) -> i64 {
    ((hi as i64) << LANE_BITS) + lo as i64
}

/// Split a packed product (or packed-product *sum*) into `(hi, lo)` lanes
/// with the sign-correction step.
///
/// Exact whenever the accumulated low lane lies in `[-2^17, 2^17)` — see
/// [`GUARD_DEPTH`]. The returned lanes always satisfy
/// `hi * 2^18 + lo == p` and `-2^17 <= lo < 2^17`.
#[inline]
pub fn unpack_prod(p: i64) -> (i64, i64) {
    let low_u = p & LANE_MASK;
    // Sign-extend the 18-bit field.
    let lo = low_u - ((low_u & LANE_SIGN) << 1);
    let hi = (p - lo) >> LANE_BITS;
    (hi, lo)
}

/// One packed MAC through the full algebra: returns `(hi*w, lo*w)`.
///
/// Exact for every INT8 input (single product, guard band trivially ok).
#[inline]
pub fn packed_mac(hi: i8, lo: i8, w: i8) -> (i32, i32) {
    let prod = pack_i8_pair(hi, lo) * w as i64;
    let (h, l) = unpack_prod(prod);
    (h as i32, l as i32)
}

/// Packed dot product of a cascade segment, as the hardware computes it:
/// a single 48-bit accumulation of packed products, split once at drain.
///
/// Returns `Err(GuardOverflow)` when the low-lane sum leaves the guard
/// band — the condition the cycle-accurate engines check per segment.
pub fn packed_dot_segment(
    hi: &[i8],
    lo: &[i8],
    w: &[i8],
) -> Result<(i32, i32), GuardOverflow> {
    assert_eq!(hi.len(), lo.len());
    assert_eq!(hi.len(), w.len());
    let mut acc: i64 = 0;
    for i in 0..hi.len() {
        acc += pack_i8_pair(hi[i], lo[i]) * w[i] as i64;
    }
    let (h, l) = unpack_prod(acc);
    // Cross-check against the exact per-lane sums: detection, not trust.
    let exact_lo: i64 = lo
        .iter()
        .zip(w)
        .map(|(&a, &b)| a as i64 * b as i64)
        .sum();
    if !(-LANE_SIGN..LANE_SIGN).contains(&exact_lo) {
        return Err(GuardOverflow {
            lane_sum: exact_lo,
            depth: hi.len(),
        });
    }
    debug_assert_eq!(l, exact_lo);
    Ok((h as i32, l as i32))
}

/// Full-length packed dot product with automatic guard-band segmentation
/// (drain every [`GUARD_DEPTH`] stages): exact for all INT8 inputs.
pub fn packed_dot(hi: &[i8], lo: &[i8], w: &[i8]) -> (i32, i32) {
    let mut out = (0i32, 0i32);
    let mut i = 0;
    while i < hi.len() {
        let j = (i + GUARD_DEPTH).min(hi.len());
        let (h, l) = packed_dot_segment(&hi[i..j], &lo[i..j], &w[i..j])
            .expect("segment within GUARD_DEPTH cannot overflow");
        out.0 += h;
        out.1 += l;
        i = j;
    }
    out
}

/// The guard band was exceeded during a packed accumulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GuardOverflow {
    /// The exact low-lane sum that left `[-2^17, 2^17)`.
    pub lane_sum: i64,
    /// Cascade depth at which it happened.
    pub depth: usize,
}

impl std::fmt::Display for GuardOverflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "packed low-lane sum {} out of guard band at depth {}",
            self.lane_sum, self.depth
        )
    }
}

impl std::error::Error for GuardOverflow {}

/// The INT8-packing *correction constant* for the W-mux RND port.
///
/// When a drained low lane is negative the high lane needs +1. Over an
/// accumulation round of `n` drains the expected correction can be
/// pre-biased through the DSP's RND constant instead of LUT logic —
/// the paper's ring-accumulator observation (§V-C). This helper returns
/// the RND value that folds a constant `bias` plus the worst-case
/// rounding offset for `n`-drain rounds.
#[inline]
pub fn rnd_correction_constant(bias: i64, n_drains: u32) -> i64 {
    // Each drain contributes its borrow via the lane split itself; the
    // RND constant carries the *bias* term so no CLB adder is needed.
    // (The per-drain borrow is data-dependent and already folded by
    // `unpack_prod`; n_drains is kept in the signature because the OS
    // engine pre-scales the bias when it is applied once per n drains.)
    let _ = n_drains;
    bias
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    #[test]
    fn pack_is_affine() {
        for hi in [-128i8, -1, 0, 1, 127] {
            for lo in [-128i8, -1, 0, 1, 127] {
                assert_eq!(
                    pack_i8_pair(hi, lo),
                    (hi as i64) * (1 << 18) + (lo as i64)
                );
            }
        }
    }

    #[test]
    fn unpack_roundtrip_and_lane_range() {
        let mut rng = XorShift::new(7);
        for _ in 0..10_000 {
            let p = (rng.next_u64() as i64) >> 18; // 46-bit values
            let (hi, lo) = unpack_prod(p);
            assert_eq!(hi * (1 << 18) + lo, p);
            assert!((-LANE_SIGN..LANE_SIGN).contains(&lo));
        }
    }

    #[test]
    fn single_mac_exact_exhaustive_corners() {
        let corners = [-128i8, -127, -65, -1, 0, 1, 64, 126, 127];
        for &hi in &corners {
            for &lo in &corners {
                for &w in &corners {
                    let (h, l) = packed_mac(hi, lo, w);
                    assert_eq!(h, hi as i32 * w as i32, "hi {hi} {lo} {w}");
                    assert_eq!(l, lo as i32 * w as i32, "lo {hi} {lo} {w}");
                }
            }
        }
    }

    #[test]
    fn single_mac_exact_random() {
        let mut rng = XorShift::new(1);
        for _ in 0..100_000 {
            let (hi, lo, w) = (rng.next_i8(), rng.next_i8(), rng.next_i8());
            let (h, l) = packed_mac(hi, lo, w);
            assert_eq!(h, hi as i32 * w as i32);
            assert_eq!(l, lo as i32 * w as i32);
        }
    }

    #[test]
    fn guard_depth_is_tight() {
        let worst = 128 * 128i64;
        assert!((GUARD_DEPTH as i64) * worst < LANE_SIGN);
        assert!((GUARD_DEPTH as i64 + 1) * worst >= LANE_SIGN);
    }

    #[test]
    fn segment_within_guard_is_exact() {
        let mut rng = XorShift::new(2);
        for _ in 0..5_000 {
            let n = 1 + (rng.next_u64() as usize) % GUARD_DEPTH;
            let hi: Vec<i8> = (0..n).map(|_| rng.next_i8()).collect();
            let lo: Vec<i8> = (0..n).map(|_| rng.next_i8()).collect();
            let w: Vec<i8> = (0..n).map(|_| rng.next_i8()).collect();
            let (h, l) = packed_dot_segment(&hi, &lo, &w).unwrap();
            let eh: i32 = hi.iter().zip(&w).map(|(&a, &b)| a as i32 * b as i32).sum();
            let el: i32 = lo.iter().zip(&w).map(|(&a, &b)| a as i32 * b as i32).sum();
            assert_eq!((h, l), (eh, el));
        }
    }

    #[test]
    fn adversarial_deep_segment_overflows() {
        let n = 16;
        let hi = vec![0i8; n];
        let lo = vec![-128i8; n];
        let w = vec![-128i8; n];
        let err = packed_dot_segment(&hi, &lo, &w).unwrap_err();
        assert_eq!(err.lane_sum, 16 * 16384);
        assert_eq!(err.depth, n);
    }

    #[test]
    fn packed_dot_exact_for_all_inputs() {
        let mut rng = XorShift::new(3);
        for _ in 0..2_000 {
            let n = 1 + (rng.next_u64() as usize) % 64;
            let hi: Vec<i8> = (0..n).map(|_| rng.next_i8()).collect();
            let lo: Vec<i8> = (0..n).map(|_| rng.next_i8()).collect();
            let w: Vec<i8> = (0..n).map(|_| rng.next_i8()).collect();
            let (h, l) = packed_dot(&hi, &lo, &w);
            let eh: i32 = hi.iter().zip(&w).map(|(&a, &b)| a as i32 * b as i32).sum();
            let el: i32 = lo.iter().zip(&w).map(|(&a, &b)| a as i32 * b as i32).sum();
            assert_eq!((h, l), (eh, el));
        }
    }

    #[test]
    fn packed_dot_worst_case() {
        let n = 56; // 8 full guard segments
        let v = vec![-128i8; n];
        let (h, l) = packed_dot(&v, &v, &v);
        assert_eq!(h, n as i32 * 16384);
        assert_eq!(l, n as i32 * 16384);
    }
}
