//! SIMD=FOUR12 lane arithmetic — the FireFly synaptic-crossbar mode.
//!
//! In FOUR12 mode the DSP48E2's 48-bit ALU splits into four independent
//! 12-bit adders (carries do not propagate across lane boundaries).
//! FireFly stores four 8-bit synaptic weights in the four lanes and
//! accumulates them when the pre-synaptic spike selects the operand via
//! the wide-bus multiplexers. A chain of 16 such DSPs forms a column of
//! the 32x32 crossbar; lane headroom is 12-8 = 4 bits, so up to 16
//! unsigned-spike accumulations are safe — exactly the chain length
//! FireFly uses.

/// Four signed 12-bit lanes packed into one 48-bit word.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Simd12Lanes(pub u64);

const LANE_W: u32 = 12;
const LANE_MASK: u64 = (1 << LANE_W) - 1;

impl Simd12Lanes {
    /// Pack four int8 weights (sign-extended to 12 bits) into the lanes.
    pub fn pack(w: [i8; 4]) -> Self {
        let mut v = 0u64;
        for (i, &x) in w.iter().enumerate() {
            v |= ((x as i16 as u16 as u64) & LANE_MASK) << (LANE_W * i as u32);
        }
        Simd12Lanes(v)
    }

    /// Extract lane `i` as a signed value.
    pub fn lane(&self, i: usize) -> i16 {
        assert!(i < 4);
        let raw = ((self.0 >> (LANE_W * i as u32)) & LANE_MASK) as u16;
        // sign-extend 12 -> 16
        ((raw << 4) as i16) >> 4
    }

    /// All four lanes.
    pub fn lanes(&self) -> [i16; 4] {
        [self.lane(0), self.lane(1), self.lane(2), self.lane(3)]
    }
}

/// One SIMD=FOUR12 ALU step: `acc + rhs` per lane, carries confined.
///
/// This is the exact hardware semantic: each 12-bit lane wraps
/// independently (two's complement); no cross-lane carry.
pub fn simd12_accumulate(acc: Simd12Lanes, rhs: Simd12Lanes) -> Simd12Lanes {
    let mut out = 0u64;
    for i in 0..4 {
        let a = acc.lane(i) as i32;
        let b = rhs.lane(i) as i32;
        let s = (a + b) as u32 as u64 & LANE_MASK;
        out |= s << (LANE_W * i as u32);
    }
    Simd12Lanes(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    #[test]
    fn pack_lane_roundtrip() {
        let w = [-128i8, -1, 0, 127];
        let lanes = Simd12Lanes::pack(w);
        for i in 0..4 {
            assert_eq!(lanes.lane(i), w[i] as i16);
        }
    }

    #[test]
    fn accumulate_matches_scalar_within_headroom() {
        // 16 chained adds of int8 values stay inside 12-bit lanes.
        let mut rng = XorShift::new(9);
        for _ in 0..2_000 {
            let ws: Vec<[i8; 4]> = (0..16)
                .map(|_| {
                    [rng.next_i8(), rng.next_i8(), rng.next_i8(), rng.next_i8()]
                })
                .collect();
            let mut acc = Simd12Lanes::default();
            let mut scalar = [0i32; 4];
            for w in &ws {
                // gate half the additions, like spikes would
                if rng.next_u64() & 1 == 0 {
                    continue;
                }
                acc = simd12_accumulate(acc, Simd12Lanes::pack(*w));
                for i in 0..4 {
                    scalar[i] += w[i] as i32;
                }
            }
            for i in 0..4 {
                // 16 * 128 = 2048 == 2^11: max magnitude exactly at the
                // signed 12-bit boundary; wrap only at +2048, which the
                // gating makes essentially unreachable — guard anyway.
                if (-2048..2048).contains(&scalar[i]) {
                    assert_eq!(acc.lane(i) as i32, scalar[i]);
                }
            }
        }
    }

    #[test]
    fn lanes_are_isolated() {
        // Overflow in lane 0 must not leak into lane 1.
        let a = Simd12Lanes::pack([127, 1, 0, 0]);
        let mut acc = Simd12Lanes::default();
        for _ in 0..32 {
            acc = simd12_accumulate(acc, a);
        }
        // lane0 wrapped (32*127 = 4064 > 2047), lane1 exact.
        assert_eq!(acc.lane(1), 32);
        assert_eq!(acc.lane(2), 0);
        assert_eq!(acc.lane(3), 0);
        let wrapped = ((32 * 127) as u64 & LANE_MASK) as u16;
        let expect = ((wrapped << 4) as i16) >> 4;
        assert_eq!(acc.lane(0), expect);
    }
}
