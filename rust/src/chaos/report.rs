//! Chaos campaign reports: one record per injected fault plus the
//! post-campaign invariant audit, rendered as human text or the
//! canonical JSON the CI gate consumes.

use crate::util::json::Json;

/// One violated expectation — a fault the server mishandled or a
/// post-campaign invariant that did not hold.
#[derive(Debug, Clone)]
pub struct ChaosDiagnostic {
    /// Fault label, or `"invariant"` for the post-campaign audit.
    pub fault: &'static str,
    /// What was expected and what happened instead.
    pub message: String,
}

/// One injected fault's record.
#[derive(Debug, Clone)]
pub struct FaultRun {
    /// Fault label.
    pub fault: &'static str,
    /// What the injection actually did (sizes, counts, ids).
    pub detail: String,
    /// Violated expectations during this injection.
    pub findings: usize,
}

/// One campaign: a seeded fault plan driven against one engine kind's
/// live server, plus the invariant audit that follows.
#[derive(Debug, Clone, Default)]
pub struct ChaosReport {
    /// Engine label the server was built with.
    pub engine: String,
    /// The plan seed.
    pub seed: u64,
    /// One entry per injected fault (plan order), then the audit.
    pub runs: Vec<FaultRun>,
    /// All violated expectations, in run order.
    pub diagnostics: Vec<ChaosDiagnostic>,
}

impl ChaosReport {
    /// Total violated expectations — any nonzero count gates CI.
    pub fn violations(&self) -> usize {
        self.diagnostics.len()
    }

    /// Canonical JSON for the CI artifact.
    pub fn to_json(&self) -> Json {
        Json::object(vec![
            ("version", Json::from(1i64)),
            ("engine", Json::from(self.engine.as_str())),
            ("seed", Json::uint(self.seed)),
            ("violations", Json::from(self.violations())),
            (
                "runs",
                Json::array(
                    self.runs
                        .iter()
                        .map(|r| {
                            Json::object(vec![
                                ("fault", Json::from(r.fault)),
                                ("detail", Json::from(r.detail.as_str())),
                                ("findings", Json::from(r.findings)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "diagnostics",
                Json::array(
                    self.diagnostics
                        .iter()
                        .map(|d| {
                            Json::object(vec![
                                ("fault", Json::from(d.fault)),
                                ("message", Json::from(d.message.as_str())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable report.
    pub fn render_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "chaos campaign: engine {} seed {} — {} injection(s)",
            self.engine,
            self.seed,
            self.runs.len()
        );
        for r in &self.runs {
            let _ = writeln!(
                out,
                "  {:<22} {}  {}",
                r.fault,
                if r.findings == 0 {
                    "ok".to_string()
                } else {
                    format!("{} finding(s)", r.findings)
                },
                r.detail
            );
        }
        for d in &self.diagnostics {
            let _ = writeln!(out, "VIOLATION [{}]: {}", d.fault, d.message);
        }
        let _ = writeln!(out, "violations: {}", self.violations());
        out
    }
}

/// Aggregate JSON for a multi-campaign run (`--seed-sweep`, multiple
/// engines): total violations up front, every campaign inline.
pub fn sweep_json(reports: &[ChaosReport]) -> Json {
    let total: usize = reports.iter().map(ChaosReport::violations).sum();
    Json::object(vec![
        ("version", Json::from(1i64)),
        ("violations", Json::from(total)),
        ("campaigns", Json::from(reports.len())),
        (
            "reports",
            Json::array(reports.iter().map(ChaosReport::to_json).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_report_serializes_clean() {
        let rep = ChaosReport {
            engine: "ws-dspfetch".to_string(),
            seed: 3,
            ..ChaosReport::default()
        };
        assert_eq!(rep.violations(), 0);
        let j = rep.to_json().to_string();
        assert!(j.contains("\"violations\": 0"), "{j}");
        assert!(j.contains("\"seed\": 3"), "{j}");
        assert!(rep.render_text().contains("violations: 0"));
        let sweep = sweep_json(&[rep]).to_string();
        assert!(sweep.contains("\"campaigns\": 1"), "{sweep}");
    }
}
