//! Deterministic fault-injection campaigns against the serving stack.
//!
//! The robustness claims of the [`crate::proto`] layer — typed errors
//! on malformed frames, quota-exact admission control, operator-scoped
//! authority, leak-free disconnect reclamation — are only claims until
//! something hostile exercises them. This module is that something:
//!
//! * [`plan`] — seeded [`plan::FaultPlan`]s: every fault archetype at
//!   least once per campaign, order and repeats derived from one seed
//!   (no wall-clock randomness — a failing campaign replays exactly);
//! * [`harness`] — [`harness::run_campaign`] boots a real
//!   [`crate::proto::TcpServer`] under a strict QoS policy, injects
//!   the plan through real sockets, audits the leak invariants, and
//!   proves a fresh compliant client is still answered bit-identically
//!   against the golden reference;
//! * [`report`] — [`report::ChaosReport`]: one record per injection
//!   plus every violated expectation, rendered as text or as the JSON
//!   artifact the CI gate consumes (any violation fails the build).
//!
//! The same campaigns run as `dsp48-systolic chaos` from the CLI
//! (`--engine all --seed-sweep N` in CI) and as property tests in
//! `tests/chaos_props.rs`.

pub mod harness;
pub mod plan;
pub mod report;

pub use harness::{campaign_qos, run_campaign, run_campaigns, OPERATOR_TOKEN};
pub use plan::{FaultKind, FaultPlan};
pub use report::{sweep_json, ChaosDiagnostic, ChaosReport, FaultRun};
