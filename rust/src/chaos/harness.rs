//! The chaos harness: boot a real [`TcpServer`] under a strict QoS
//! policy, drive a seeded [`FaultPlan`] against it through real
//! sockets, then audit the survivor.
//!
//! Faults go through the genuine transport — raw byte streams for the
//! frame-level corruption, [`TcpSession`] for the protocol-level
//! abuse — so the campaign exercises exactly the code paths a
//! misbehaving client would. After the plan runs, the harness checks
//! the post-campaign invariants:
//!
//! * no leaked handles (`pending_handles` drains to zero),
//! * no leaked arena leases (`intermediate_bytes_now` returns to
//!   zero even for models abandoned mid-DAG),
//! * no leaked sessions or queued-byte accounting,
//! * a fresh compliant client is answered **bit-identically** against
//!   the golden reference, as if the campaign never happened.
//!
//! Sleeps below only *bound* waits on outcomes that are themselves
//! deterministic; everything injected derives from the plan seed.

use crate::chaos::plan::{FaultKind, FaultPlan};
use crate::chaos::report::{ChaosDiagnostic, ChaosReport, FaultRun};
use crate::coordinator::service::EngineKind;
use crate::coordinator::{Job, JobState, Service, ServiceConfig};
use crate::model::{LayerOp, Model};
use crate::proto::frame::{read_frame, write_frame, MAX_FRAME_LEN};
use crate::proto::message::{ErrorCode, Request, Response};
use crate::proto::{
    QosConfig, Session, SessionBudget, SessionError, TcpServer, TcpSession,
};
use crate::util::json::Json;
use crate::util::rng::XorShift;
use crate::workload::gemm::golden_gemm;
use crate::workload::MatI8;
use std::io::Write as _;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// The operator token campaigns authenticate teardown with.
pub const OPERATOR_TOKEN: &str = "chaos-operator";

/// Per-session inflight quota under campaign QoS (the submit-storm
/// fault asserts the N+1th submit is refused at exactly this point).
pub const MAX_INFLIGHT: usize = 4;

const IDLE_MS: u64 = 200;

/// The strict QoS policy every campaign serves under: tight budgets,
/// token-only operator authority (loopback privilege off, so the
/// privilege probes actually probe), and a short idle read deadline.
pub fn campaign_qos() -> QosConfig {
    QosConfig {
        budget: SessionBudget {
            max_inflight: MAX_INFLIGHT,
            max_queued_bytes: 1 << 20,
            deadline_ms: Some(5_000),
        },
        max_outstanding: 32,
        operator_token: Some(OPERATOR_TOKEN.to_string()),
        loopback_operator: false,
        idle_timeout: Some(Duration::from_millis(IDLE_MS)),
        retry_after_ms: 25,
    }
}

fn is_snn(kind: EngineKind) -> bool {
    matches!(kind, EngineKind::SnnFireFly | EngineKind::SnnEnhanced)
}

/// A small valid job for `kind` (spiking crossbars need binary
/// activations; both families verify against the dense golden GEMM).
fn small_job(kind: EngineKind, rng: &mut XorShift) -> Job {
    let (job, _, _) = golden_job(kind, rng);
    job
}

/// A small valid job plus the operands its output must bit-match
/// `golden_gemm` over.
fn golden_job(kind: EngineKind, rng: &mut XorShift) -> (Job, MatI8, MatI8) {
    if is_snn(kind) {
        let spikes =
            MatI8::from_fn(4, 32, |_, _| i8::from(rng.chance(1, 3)));
        let weights = MatI8::random_bounded(rng, 32, 16, 50);
        (
            Job::Snn {
                spikes: spikes.clone(),
                weights: weights.clone(),
            },
            spikes,
            weights,
        )
    } else {
        let a = MatI8::random_bounded(rng, 4, 13, 63);
        let w = MatI8::random(rng, 13, 9);
        (
            Job::Gemm {
                a: a.clone(),
                w: w.clone(),
            },
            a,
            w,
        )
    }
}

/// A small multi-layer model DAG for `kind` (matmul → glue → matmul),
/// so a mid-model disconnect leaves arena-resident intermediates to
/// reclaim.
fn small_model(kind: EngineKind, rng: &mut XorShift) -> (Model, MatI8) {
    if is_snn(kind) {
        let input =
            MatI8::from_fn(4, 32, |_, _| i8::from(rng.chance(1, 3)));
        let w1 = MatI8::random_bounded(rng, 32, 32, 50);
        let w2 = MatI8::random_bounded(rng, 32, 32, 50);
        let mut model = Model::new(4, 32, true);
        let t1 = model.layer(LayerOp::Snn { w: w1 }, &[0]);
        let t2 = model.layer(LayerOp::Quant { num: 1, shift: 6 }, &[t1]);
        model.layer(LayerOp::Snn { w: w2 }, &[t2]);
        (model, input)
    } else {
        let input = MatI8::random_bounded(rng, 4, 8, 63);
        let w1 = MatI8::random_bounded(rng, 8, 8, 50);
        let w2 = MatI8::random_bounded(rng, 8, 6, 50);
        let mut model = Model::new(4, 8, false);
        let t1 = model.layer(LayerOp::Gemm { w: w1 }, &[0]);
        let t2 = model.layer(
            LayerOp::Requant {
                num: 1,
                shift: 10,
                zero_point: 0,
            },
            &[t1],
        );
        let t3 = model.layer(LayerOp::Add, &[t2, 0]);
        let t4 = model.layer(
            LayerOp::Requant {
                num: 1,
                shift: 1,
                zero_point: 0,
            },
            &[t3],
        );
        model.layer(LayerOp::Gemm { w: w2 }, &[t4]);
        (model, input)
    }
}

fn get_u64(snap: &Json, key: &str) -> u64 {
    snap.get(key)
        .and_then(Json::as_i64)
        .unwrap_or_default()
        .max(0) as u64
}

/// One stats round trip on a throwaway session.
fn stat_u64(addr: SocketAddr, key: &str) -> Result<u64, String> {
    let mut s = TcpSession::connect(&addr.to_string())
        .map_err(|e| format!("stats connect: {e}"))?;
    let snap = s.stats().map_err(|e| format!("stats: {e}"))?;
    Ok(get_u64(&snap, key))
}

/// Run one seeded campaign against a freshly built `kind` server.
/// `Err` is a harness failure (bind, join); everything the *server*
/// does wrong lands in the report as a violation.
pub fn run_campaign(
    kind: EngineKind,
    seed: u64,
) -> Result<ChaosReport, String> {
    let svc = Service::start(ServiceConfig {
        kind,
        ..ServiceConfig::default()
    });
    let server = TcpServer::bind_with("127.0.0.1:0", svc, campaign_qos())
        .map_err(|e| format!("bind: {e}"))?;
    let addr = server.local_addr().map_err(|e| format!("addr: {e}"))?;
    let server = std::thread::spawn(move || server.run());

    let mut report = ChaosReport {
        engine: kind.label().to_string(),
        seed,
        ..ChaosReport::default()
    };
    let plan = FaultPlan::generate(seed);
    let mut rng = XorShift::new(seed ^ 0x0DD_FA11);
    for fault in plan.steps.iter().copied() {
        let mut findings: Vec<String> = Vec::new();
        let detail = inject(fault, kind, addr, &mut rng, &mut findings);
        report.runs.push(FaultRun {
            fault: fault.label(),
            detail,
            findings: findings.len(),
        });
        report
            .diagnostics
            .extend(findings.into_iter().map(|message| ChaosDiagnostic {
                fault: fault.label(),
                message,
            }));
    }

    let mut audit: Vec<String> = Vec::new();
    settle_and_audit(kind, addr, &mut rng, &mut audit);
    report.runs.push(FaultRun {
        fault: "invariant",
        detail: "post-campaign audit".to_string(),
        findings: audit.len(),
    });
    report
        .diagnostics
        .extend(audit.into_iter().map(|message| ChaosDiagnostic {
            fault: "invariant",
            message,
        }));

    // Authenticated teardown.
    let mut op = TcpSession::connect(&addr.to_string())
        .map_err(|e| format!("operator connect: {e}"))?;
    op.auth(OPERATOR_TOKEN)
        .map_err(|e| format!("operator auth: {e}"))?;
    op.shutdown().map_err(|e| format!("shutdown: {e}"))?;
    server.join().map_err(|_| "server thread panicked".to_string())?;
    Ok(report)
}

/// Run campaigns for every `(kind, seed)` pair.
pub fn run_campaigns(
    kinds: &[EngineKind],
    seeds: &[u64],
) -> Result<Vec<ChaosReport>, String> {
    let mut reports = Vec::new();
    for &kind in kinds {
        for &seed in seeds {
            reports.push(run_campaign(kind, seed)?);
        }
    }
    Ok(reports)
}

fn inject(
    fault: FaultKind,
    kind: EngineKind,
    addr: SocketAddr,
    rng: &mut XorShift,
    findings: &mut Vec<String>,
) -> String {
    match fault {
        FaultKind::TruncatedFrame => truncated_frame(addr, rng, findings),
        FaultKind::OversizeFrame => oversize_frame(addr, rng, findings),
        FaultKind::GarbageFrame => garbage_frame(addr, rng, findings),
        FaultKind::DisconnectMidBatch => {
            disconnect_mid_batch(addr, kind, rng, findings)
        }
        FaultKind::DisconnectMidModel => {
            disconnect_mid_model(addr, kind, rng, findings)
        }
        FaultKind::SlowReader => slow_reader(addr, findings),
        FaultKind::SubmitStorm => submit_storm(addr, kind, rng, findings),
        FaultKind::PrivilegeProbe => {
            privilege_probe(addr, kind, rng, findings)
        }
    }
}

fn truncated_frame(
    addr: SocketAddr,
    rng: &mut XorShift,
    findings: &mut Vec<String>,
) -> String {
    let promised = 64 + rng.below(512) as u32;
    let sent = (promised / 2) as usize;
    match TcpStream::connect(addr) {
        Ok(mut s) => {
            let mut bytes = promised.to_be_bytes().to_vec();
            bytes.resize(4 + sent, b'{');
            let _ = s.write_all(&bytes);
            format!("promised {promised} bytes, sent {sent}, hung up")
        }
        Err(e) => {
            findings.push(format!("connect refused mid-campaign: {e}"));
            "connect failed".to_string()
        }
    }
}

fn oversize_frame(
    addr: SocketAddr,
    rng: &mut XorShift,
    findings: &mut Vec<String>,
) -> String {
    let declared = MAX_FRAME_LEN as u32 + 1 + rng.below(1024) as u32;
    let mut s = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(e) => {
            findings.push(format!("connect refused mid-campaign: {e}"));
            return "connect failed".to_string();
        }
    };
    if let Err(e) = s.write_all(&declared.to_be_bytes()) {
        findings.push(format!("oversize prefix write failed: {e}"));
        return "write failed".to_string();
    }
    match read_frame(&mut s) {
        Ok(Some(payload)) => match Response::decode(&payload) {
            Ok(Response::Error(e)) if e.code == ErrorCode::BadFrame => {}
            Ok(other) => findings.push(format!(
                "expected bad-frame error, got {}",
                other.tag()
            )),
            Err(e) => findings.push(format!("undecodable response: {e}")),
        },
        other => findings.push(format!(
            "expected typed error on open connection, got {other:?}"
        )),
    }
    // The contract: the connection survives an oversize prefix.
    expect_stats_alive(&mut s, findings);
    format!("declared {declared}-byte frame, got typed refusal")
}

fn garbage_frame(
    addr: SocketAddr,
    rng: &mut XorShift,
    findings: &mut Vec<String>,
) -> String {
    let len = 8 + rng.below(64) as usize;
    let garbage: Vec<u8> =
        (0..len).map(|_| (rng.below(26) as u8) + b'a').collect();
    let mut s = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(e) => {
            findings.push(format!("connect refused mid-campaign: {e}"));
            return "connect failed".to_string();
        }
    };
    if let Err(e) = write_frame(&mut s, &garbage) {
        findings.push(format!("garbage frame write failed: {e}"));
        return "write failed".to_string();
    }
    match read_frame(&mut s) {
        Ok(Some(payload)) => match Response::decode(&payload) {
            Ok(Response::Error(_)) => {}
            Ok(other) => findings.push(format!(
                "expected typed decode error, got {}",
                other.tag()
            )),
            Err(e) => findings.push(format!("undecodable response: {e}")),
        },
        other => findings.push(format!(
            "expected typed error on open connection, got {other:?}"
        )),
    }
    expect_stats_alive(&mut s, findings);
    format!("{len} bytes of garbage, got typed refusal")
}

/// The still-open faulted connection must keep serving: one Stats
/// round trip over the raw stream.
fn expect_stats_alive(s: &mut TcpStream, findings: &mut Vec<String>) {
    if let Err(e) = write_frame(s, &Request::Stats.encode()) {
        findings.push(format!("connection died after typed error: {e}"));
        return;
    }
    match read_frame(s) {
        Ok(Some(payload)) => match Response::decode(&payload) {
            Ok(Response::Metrics(_)) => {}
            Ok(other) => findings.push(format!(
                "stats after fault answered {}",
                other.tag()
            )),
            Err(e) => findings.push(format!("undecodable stats: {e}")),
        },
        other => findings.push(format!(
            "stats after fault got no frame: {other:?}"
        )),
    }
}

fn disconnect_mid_batch(
    addr: SocketAddr,
    kind: EngineKind,
    rng: &mut XorShift,
    findings: &mut Vec<String>,
) -> String {
    let n = 2 + rng.below(MAX_INFLIGHT as u64 - 1) as usize;
    let mut s = match TcpSession::connect(&addr.to_string()) {
        Ok(s) => s,
        Err(e) => {
            findings.push(format!("connect refused mid-campaign: {e}"));
            return "connect failed".to_string();
        }
    };
    let jobs: Vec<Job> = (0..n).map(|_| small_job(kind, rng)).collect();
    match s.submit_batch(jobs) {
        Ok(ids) => format!("submitted {} jobs, vanished", ids.len()),
        Err(e) => {
            findings.push(format!("in-quota batch refused: {e}"));
            "batch refused".to_string()
        }
    }
    // `s` drops here: disconnect with every handle unredeemed.
}

fn disconnect_mid_model(
    addr: SocketAddr,
    kind: EngineKind,
    rng: &mut XorShift,
    findings: &mut Vec<String>,
) -> String {
    let (model, input) = small_model(kind, rng);
    let layers = model.layers.len();
    let mut s = match TcpSession::connect(&addr.to_string()) {
        Ok(s) => s,
        Err(e) => {
            findings.push(format!("connect refused mid-campaign: {e}"));
            return "connect failed".to_string();
        }
    };
    match s.submit(Job::Model { model, input }) {
        Ok(id) => {
            format!("submitted {layers}-layer model (handle {id}), vanished")
        }
        Err(e) => {
            findings.push(format!("valid model refused: {e}"));
            "model refused".to_string()
        }
    }
}

fn slow_reader(addr: SocketAddr, findings: &mut Vec<String>) -> String {
    let reaped_before = match stat_u64(addr, "idle_reaped") {
        Ok(v) => v,
        Err(e) => {
            findings.push(e);
            return "baseline stats failed".to_string();
        }
    };
    let stalled = match TcpStream::connect(addr) {
        Ok(mut s) => {
            // Half a frame prefix, then silence.
            let _ = s.write_all(&[0, 0]);
            s
        }
        Err(e) => {
            findings.push(format!("connect refused mid-campaign: {e}"));
            return "connect failed".to_string();
        }
    };
    let mut reaped = reaped_before;
    for _ in 0..300 {
        match stat_u64(addr, "idle_reaped") {
            Ok(v) => reaped = v,
            Err(e) => {
                findings.push(e);
                break;
            }
        }
        if reaped > reaped_before {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    if reaped <= reaped_before {
        findings.push(format!(
            "stalled connection was not reaped within 3s \
             (idle_reaped stayed {reaped_before})"
        ));
    }
    drop(stalled);
    format!("stalled after 2 prefix bytes; idle_reaped {reaped_before} -> {reaped}")
}

fn submit_storm(
    addr: SocketAddr,
    kind: EngineKind,
    rng: &mut XorShift,
    findings: &mut Vec<String>,
) -> String {
    let mut s = match TcpSession::connect(&addr.to_string()) {
        Ok(s) => s,
        Err(e) => {
            findings.push(format!("connect refused mid-campaign: {e}"));
            return "connect failed".to_string();
        }
    };
    let mut accepted = 0usize;
    let mut refusal = None;
    for i in 0..MAX_INFLIGHT + 2 {
        match s.submit(small_job(kind, rng)) {
            Ok(_) => accepted += 1,
            Err(SessionError::Remote(e)) => {
                refusal = Some((i, e));
                break;
            }
            Err(e) => {
                findings.push(format!("storm submit transport error: {e}"));
                break;
            }
        }
    }
    match refusal {
        Some((at, e)) => {
            if at != MAX_INFLIGHT {
                findings.push(format!(
                    "quota refusal at submit {at}, expected exactly \
                     {MAX_INFLIGHT} (quota must be exact)"
                ));
            }
            if e.code != ErrorCode::Overloaded {
                findings.push(format!(
                    "storm refused with {:?}, expected overloaded",
                    e.code
                ));
            }
            if e.retry_after_ms.is_none() {
                findings
                    .push("overloaded error carried no retry hint".to_string());
            }
        }
        None => findings.push(format!(
            "no overload answer within {} submits (quota {})",
            MAX_INFLIGHT + 2,
            MAX_INFLIGHT
        )),
    }
    // Retire own work (the well-behaved exit), then vanish anyway.
    let _ = s.drain_mine(Some(Duration::from_secs(30)));
    format!("{accepted} accepted before typed overload refusal")
}

fn privilege_probe(
    addr: SocketAddr,
    kind: EngineKind,
    rng: &mut XorShift,
    findings: &mut Vec<String>,
) -> String {
    let mut s = match TcpSession::connect(&addr.to_string()) {
        Ok(s) => s,
        Err(e) => {
            findings.push(format!("connect refused mid-campaign: {e}"));
            return "connect failed".to_string();
        }
    };
    let expect_forbidden =
        |what: &str, r: Result<(), SessionError>, findings: &mut Vec<String>| {
            match r {
                Err(SessionError::Remote(e))
                    if e.code == ErrorCode::Forbidden => {}
                Err(e) => findings.push(format!(
                    "{what} by a plain session: expected forbidden, got {e}"
                )),
                Ok(()) => findings.push(format!(
                    "{what} by a plain session was ALLOWED"
                )),
            }
        };
    expect_forbidden(
        "drain",
        s.drain(Some(Duration::from_millis(10))).map(|_| ()),
        findings,
    );
    expect_forbidden("shutdown", s.shutdown().map(|_| ()), findings);
    expect_forbidden("bad-token auth", s.auth("letmein"), findings);
    // Handle theft: ids are guessable, so a victim session submits a
    // job and the probe session tries to redeem the handle. The
    // redemption must be refused — stealing it would consume the
    // victim's result and pin its quota forever.
    match TcpSession::connect(&addr.to_string()) {
        Ok(mut victim) => match victim.submit(small_job(kind, rng)) {
            Ok(id) => {
                expect_forbidden(
                    "redeeming another session's handle",
                    s.poll(id).map(|_| ()),
                    findings,
                );
                let _ = victim.drain_mine(Some(Duration::from_secs(30)));
            }
            Err(e) => {
                findings.push(format!("theft victim's submit refused: {e}"))
            }
        },
        Err(e) => {
            findings.push(format!("connect refused mid-campaign: {e}"))
        }
    }
    // And the server is still standing.
    if let Err(e) = s.stats() {
        findings.push(format!("server unreachable after probes: {e}"));
    }
    "drain/shutdown/bad-auth/handle-theft all answered forbidden"
        .to_string()
}

/// Wait (bounded) for the table to settle, then check every leak
/// invariant and the fresh-client bit-identity contract.
fn settle_and_audit(
    kind: EngineKind,
    addr: SocketAddr,
    rng: &mut XorShift,
    findings: &mut Vec<String>,
) {
    let mut obs = match TcpSession::connect(&addr.to_string()) {
        Ok(s) => s,
        Err(e) => {
            findings.push(format!("audit connect failed: {e}"));
            return;
        }
    };
    let mut snap = Json::Null;
    for _ in 0..1500 {
        snap = match obs.stats() {
            Ok(s) => s,
            Err(e) => {
                findings.push(format!("audit stats failed: {e}"));
                return;
            }
        };
        if get_u64(&snap, "pending_handles") == 0
            && get_u64(&snap, "intermediate_bytes_now") == 0
            && get_u64(&snap, "queued_bytes_now") == 0
            && get_u64(&snap, "open_sessions") == 1
        {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    for (key, want, what) in [
        ("pending_handles", 0, "leaked handles"),
        ("intermediate_bytes_now", 0, "leaked arena intermediates"),
        ("queued_bytes_now", 0, "leaked queued-byte accounting"),
        ("open_sessions", 1, "leaked sessions"),
        ("shed_unobserved", 0, "unclaimed shed markers"),
    ] {
        let got = get_u64(&snap, key);
        if got != want {
            findings.push(format!(
                "{what}: {key} = {got} after settling (expected {want})"
            ));
        }
    }
    // A fresh compliant client gets golden bits, campaign or not.
    let (job, a, w) = golden_job(kind, rng);
    let mut fresh = match TcpSession::connect(&addr.to_string()) {
        Ok(s) => s,
        Err(e) => {
            findings.push(format!("fresh client connect failed: {e}"));
            return;
        }
    };
    let id = match fresh.submit(job) {
        Ok(id) => id,
        Err(e) => {
            findings.push(format!("fresh client submit refused: {e}"));
            return;
        }
    };
    match fresh.wait(id, Some(Duration::from_secs(30))) {
        Ok(JobState::Done(r)) => {
            if r.output != golden_gemm(&a, &w) {
                findings.push(
                    "fresh client output is NOT bit-identical to the \
                     golden reference"
                        .to_string(),
                );
            }
            if r.verified != Some(true) {
                findings.push(format!(
                    "fresh client result not verified: {:?}",
                    r.verified
                ));
            }
        }
        Ok(other) => findings.push(format!(
            "fresh client job did not complete: {other:?}"
        )),
        Err(e) => findings.push(format!("fresh client wait failed: {e}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One full campaign on the default engine must come back with
    /// zero violations — the same contract the CI smoke enforces
    /// across all kinds.
    #[test]
    fn campaign_runs_clean_on_the_default_engine() {
        let report =
            run_campaign(EngineKind::WsDspFetch, 1).expect("campaign runs");
        assert_eq!(
            report.violations(),
            0,
            "violations:\n{}",
            report.render_text()
        );
        // Every archetype was exercised at least once.
        for kind in FaultKind::all() {
            assert!(
                report.runs.iter().any(|r| r.fault == kind.label()),
                "{} never injected",
                kind.label()
            );
        }
    }
}
