//! Deterministic fault plans: which faults hit the server, in what
//! order, with what parameters — all derived from one seed.
//!
//! Reproducibility is the whole point of the harness: a failing
//! campaign is re-run with the same `--seed` and replays the same
//! byte streams, the same disconnect points, the same storm sizes.
//! There is no wall-clock randomness anywhere in a plan; sleeps in
//! the harness only *bound* waits on outcomes that are themselves
//! deterministic.

use crate::util::rng::XorShift;

/// One fault archetype the harness knows how to inject through a real
/// TCP connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A frame prefix that promises more payload bytes than ever
    /// arrive, then a hangup mid-frame.
    TruncatedFrame,
    /// A prefix declaring a payload over `MAX_FRAME_LEN`: the server
    /// must answer a typed `bad-frame` error and keep the connection.
    OversizeFrame,
    /// A well-framed payload that is not valid JSON: typed decode
    /// error, connection stays open and keeps serving.
    GarbageFrame,
    /// Submit a batch of jobs, then vanish without redeeming any —
    /// the session's handles must be forgotten, not leaked.
    DisconnectMidBatch,
    /// Submit a whole model DAG, then vanish while its layers are in
    /// flight — arena-resident intermediates must be reclaimed.
    DisconnectMidModel,
    /// Connect, send half a frame prefix, and stall: the idle read
    /// deadline must reap the connection (the slow-loris probe).
    SlowReader,
    /// Flood submits without redeeming until admission control
    /// answers `overloaded` — and it must do so at exactly the
    /// budgeted point, with a retry hint.
    SubmitStorm,
    /// A plain session tries `Drain`, `Shutdown`, and a bad `Auth`
    /// token: every probe must answer `forbidden` and the server must
    /// stay up.
    PrivilegeProbe,
}

impl FaultKind {
    /// Stable label (report JSON and CLI output).
    pub fn label(self) -> &'static str {
        match self {
            FaultKind::TruncatedFrame => "truncated-frame",
            FaultKind::OversizeFrame => "oversize-frame",
            FaultKind::GarbageFrame => "garbage-frame",
            FaultKind::DisconnectMidBatch => "disconnect-mid-batch",
            FaultKind::DisconnectMidModel => "disconnect-mid-model",
            FaultKind::SlowReader => "slow-reader",
            FaultKind::SubmitStorm => "submit-storm",
            FaultKind::PrivilegeProbe => "privilege-probe",
        }
    }

    /// Every archetype, in declaration order.
    pub fn all() -> [FaultKind; 8] {
        [
            FaultKind::TruncatedFrame,
            FaultKind::OversizeFrame,
            FaultKind::GarbageFrame,
            FaultKind::DisconnectMidBatch,
            FaultKind::DisconnectMidModel,
            FaultKind::SlowReader,
            FaultKind::SubmitStorm,
            FaultKind::PrivilegeProbe,
        ]
    }
}

/// A seeded fault schedule: every archetype at least once, in a
/// seed-shuffled order, plus a few seed-chosen repeats.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    pub steps: Vec<FaultKind>,
}

impl FaultPlan {
    /// Derive the plan for `seed`. Same seed, same plan — always.
    pub fn generate(seed: u64) -> FaultPlan {
        let mut rng = XorShift::new(seed ^ 0xC4A0_5_F00D);
        let mut steps: Vec<FaultKind> = FaultKind::all().to_vec();
        // Fisher–Yates under the seeded generator.
        for i in (1..steps.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            steps.swap(i, j);
        }
        // A few repeats so campaigns also exercise fault *sequences*
        // (e.g. a storm landing on a server that just reaped a
        // slow reader).
        let extra = 2 + rng.below(3) as usize;
        for _ in 0..extra {
            let all = FaultKind::all();
            steps.push(all[rng.below(all.len() as u64) as usize]);
        }
        FaultPlan { seed, steps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_per_seed() {
        let a = FaultPlan::generate(7);
        let b = FaultPlan::generate(7);
        assert_eq!(a.steps, b.steps);
        // Every archetype appears at least once.
        for kind in FaultKind::all() {
            assert!(a.steps.contains(&kind), "{} missing", kind.label());
        }
        // Different seeds genuinely differ (shuffle or repeats).
        let c = FaultPlan::generate(8);
        assert_ne!(a.steps, c.steps);
    }
}
