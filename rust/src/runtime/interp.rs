//! The pure-Rust golden interpreter: the default, offline backend for
//! AOT artifacts.
//!
//! The artifact set is a closed vocabulary (`packed_gemm_*`, `mlp_*`,
//! `snn_*` — see `python/compile/aot.py`), and every member's numerics
//! already has a bit-exact rust twin (`golden_gemm`, `requantize`,
//! `LifLayer`). The interpreter recognizes an artifact by name, checks
//! the declared signature, and evaluates those twins — so the default
//! build executes every artifact without XLA, with outputs identical
//! to the PJRT path (the `xla` feature) by the same contract the
//! integration tests enforce.

use super::error::{rt_bail, rt_ensure, Result, RuntimeError};
use super::registry::{ArtifactEntry, MixedBuf};
use crate::workload::gemm::golden_gemm;
use crate::workload::quant::requantize;
use crate::workload::snn::{golden_currents, LifLayer, SpikeTrain};
use crate::workload::MatI8;

/// A recognized artifact program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Interp {
    /// `packed_gemm_m{M}_k{K}_n{N}`: (a_hi, a_lo, w) → (hi, lo).
    PackedGemm { m: usize, k: usize, n: usize },
    /// `snn_t{T}_p{P}_n{N}`: (spikes, weights) → (out_spikes, currents).
    Snn {
        t: usize,
        p: usize,
        n: usize,
        v_threshold: i32,
        leak_shift: u32,
    },
    /// `mlp_b{B}_{d0}_{d1}_..._{dL}`: (x, w0, b0, ..) → (logits,).
    Mlp {
        batch: usize,
        dims: Vec<usize>,
        quants: Vec<(i32, u32)>,
    },
}

fn parse_tagged(part: &str, tag: char) -> Option<usize> {
    part.strip_prefix(tag).and_then(|v| v.parse().ok())
}

impl Interp {
    /// Recognize `entry` by name (+ constants recorded in the
    /// manifest).
    pub fn from_entry(entry: &ArtifactEntry) -> Result<Interp> {
        let name = entry.name.as_str();
        if let Some(rest) = name.strip_prefix("packed_gemm_") {
            let parts: Vec<&str> = rest.split('_').collect();
            if let [m, k, n] = parts[..] {
                if let (Some(m), Some(k), Some(n)) = (
                    parse_tagged(m, 'm'),
                    parse_tagged(k, 'k'),
                    parse_tagged(n, 'n'),
                ) {
                    return Ok(Interp::PackedGemm { m, k, n });
                }
            }
            rt_bail!("malformed packed_gemm artifact name `{name}`");
        }
        if let Some(rest) = name.strip_prefix("snn_") {
            let parts: Vec<&str> = rest.split('_').collect();
            if let [t, p, n] = parts[..] {
                if let (Some(t), Some(p), Some(n)) = (
                    parse_tagged(t, 't'),
                    parse_tagged(p, 'p'),
                    parse_tagged(n, 'n'),
                ) {
                    let consts = entry.constants.as_ref();
                    let v_threshold = consts
                        .and_then(|c| c.get("v_threshold"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(64) as i32;
                    let leak_shift = consts
                        .and_then(|c| c.get("leak_shift"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(3) as u32;
                    return Ok(Interp::Snn {
                        t,
                        p,
                        n,
                        v_threshold,
                        leak_shift,
                    });
                }
            }
            rt_bail!("malformed snn artifact name `{name}`");
        }
        if let Some(rest) = name.strip_prefix("mlp_b") {
            let parts: Vec<&str> = rest.split('_').collect();
            let nums: Option<Vec<usize>> =
                parts.iter().map(|p| p.parse().ok()).collect();
            let Some(nums) = nums else {
                rt_bail!("malformed mlp artifact name `{name}`");
            };
            rt_ensure!(nums.len() >= 3, "mlp artifact `{name}` needs >= 2 layers");
            let batch = nums[0];
            let dims = nums[1..].to_vec();
            let quants = entry
                .constants
                .as_ref()
                .and_then(|c| c.get("quants"))
                .and_then(|q| q.as_array())
                .map(|arr| {
                    arr.iter()
                        .filter_map(|pair| {
                            let p = pair.as_array()?;
                            Some((
                                p.first()?.as_i64()? as i32,
                                p.get(1)?.as_i64()? as u32,
                            ))
                        })
                        .collect::<Vec<_>>()
                })
                .unwrap_or_default();
            rt_ensure!(
                quants.len() == dims.len().saturating_sub(2),
                "mlp artifact `{name}`: need {} quant pairs in manifest \
                 constants, found {}",
                dims.len().saturating_sub(2),
                quants.len()
            );
            return Ok(Interp::Mlp {
                batch,
                dims,
                quants,
            });
        }
        rt_bail!(
            "artifact `{name}` is not interpretable offline; \
             build with `--features xla` for the PJRT backend"
        )
    }

    /// Evaluate against pre-validated input buffers.
    pub fn execute(&self, bufs: &[MixedBuf<'_>]) -> Result<Vec<Vec<i32>>> {
        match self {
            Interp::PackedGemm { m, k, n } => {
                rt_ensure!(bufs.len() == 3, "packed_gemm takes 3 inputs");
                let a_hi = mat_i8(&bufs[0], *m, *k)?;
                let a_lo = mat_i8(&bufs[1], *m, *k)?;
                let w = mat_i8(&bufs[2], *k, *n)?;
                let hi = golden_gemm(&a_hi, &w);
                let lo = golden_gemm(&a_lo, &w);
                Ok(vec![hi.data, lo.data])
            }
            Interp::Snn {
                t,
                p,
                n,
                v_threshold,
                leak_shift,
            } => {
                rt_ensure!(bufs.len() == 2, "snn takes 2 inputs");
                let spikes = i8_buf(&bufs[0])?;
                rt_ensure!(
                    spikes.iter().all(|&s| s == 0 || s == 1),
                    "snn artifact consumes binary spike inputs"
                );
                let weights = i8_buf(&bufs[1])?;
                let train = SpikeTrain {
                    steps: *t,
                    neurons: *p,
                    spikes: spikes.iter().map(|&v| v as u8).collect(),
                };
                let currents = golden_currents(&train, weights, *n);
                let mut lif = LifLayer::new(*n, *v_threshold, *leak_shift);
                let mut out_spikes = Vec::with_capacity(t * n);
                for step in 0..*t {
                    let row = &currents[step * n..(step + 1) * n];
                    out_spikes
                        .extend(lif.step(row).into_iter().map(|s| s as i32));
                }
                Ok(vec![out_spikes, currents])
            }
            Interp::Mlp {
                batch,
                dims,
                quants,
            } => {
                let layers = dims.len() - 1;
                rt_ensure!(
                    bufs.len() == 1 + 2 * layers,
                    "mlp takes {} inputs (x + per-layer w, bias)",
                    1 + 2 * layers
                );
                let mut h = mat_i8(&bufs[0], *batch, dims[0])?;
                for layer in 0..layers {
                    let (din, dout) = (dims[layer], dims[layer + 1]);
                    let w = mat_i8(&bufs[1 + 2 * layer], din, dout)?;
                    let bias = i32_buf(&bufs[2 + 2 * layer])?;
                    let acc = golden_gemm(&h, &w);
                    if layer == layers - 1 {
                        // Raw logits + bias.
                        let logits: Vec<i32> = (0..*batch)
                            .flat_map(|r| {
                                (0..dout).map(move |c| (r, c))
                            })
                            .map(|(r, c)| acc.at(r, c) + bias[c])
                            .collect();
                        return Ok(vec![logits]);
                    }
                    // Bias + ReLU + requantize (bit-exact twin of
                    // ref.requantize / the e2e example).
                    let (num, shift) = quants[layer];
                    h = MatI8::from_fn(*batch, dout, |r, c| {
                        let v = (acc.at(r, c) + bias[c]).max(0);
                        requantize(v, num, shift, 0)
                    });
                }
                unreachable!("layers >= 1 by construction")
            }
        }
    }
}

fn i8_buf<'a>(buf: &'a MixedBuf<'_>) -> Result<&'a [i8]> {
    match buf {
        MixedBuf::I8(v) => Ok(v),
        MixedBuf::I32(_) => Err(RuntimeError::msg("expected an i8 buffer")),
    }
}

fn i32_buf<'a>(buf: &'a MixedBuf<'_>) -> Result<&'a [i32]> {
    match buf {
        MixedBuf::I32(v) => Ok(v),
        MixedBuf::I8(_) => Err(RuntimeError::msg("expected an i32 buffer")),
    }
}

fn mat_i8(buf: &MixedBuf<'_>, rows: usize, cols: usize) -> Result<MatI8> {
    let data = i8_buf(buf)?;
    rt_ensure!(
        data.len() == rows * cols,
        "buffer holds {} values, artifact expects {rows}x{cols}",
        data.len()
    );
    Ok(MatI8 {
        rows,
        cols,
        data: data.to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use crate::util::rng::XorShift;

    fn entry(name: &str, constants: Option<&str>) -> ArtifactEntry {
        ArtifactEntry {
            name: name.to_string(),
            file: std::path::PathBuf::from(format!("{name}.hlo.txt")),
            inputs: Vec::new(),
            outputs: Vec::new(),
            constants: constants.map(|c| Json::parse(c).unwrap()),
        }
    }

    #[test]
    fn recognizes_the_artifact_vocabulary() {
        assert_eq!(
            Interp::from_entry(&entry("packed_gemm_m32_k64_n64", None)).unwrap(),
            Interp::PackedGemm { m: 32, k: 64, n: 64 }
        );
        assert_eq!(
            Interp::from_entry(&entry(
                "snn_t16_p32_n32",
                Some(r#"{"v_threshold": 64, "leak_shift": 3}"#)
            ))
            .unwrap(),
            Interp::Snn {
                t: 16,
                p: 32,
                n: 32,
                v_threshold: 64,
                leak_shift: 3
            }
        );
        let mlp = Interp::from_entry(&entry(
            "mlp_b64_784_256_128_10",
            Some(r#"{"quants": [[77, 15], [77, 14]]}"#),
        ))
        .unwrap();
        assert_eq!(
            mlp,
            Interp::Mlp {
                batch: 64,
                dims: vec![784, 256, 128, 10],
                quants: vec![(77, 15), (77, 14)],
            }
        );
        assert!(Interp::from_entry(&entry("mystery_kernel", None)).is_err());
    }

    #[test]
    fn packed_gemm_matches_golden() {
        let interp = Interp::PackedGemm { m: 4, k: 6, n: 5 };
        let mut rng = XorShift::new(3);
        let a_hi = MatI8::random(&mut rng, 4, 6);
        let a_lo = MatI8::random(&mut rng, 4, 6);
        let w = MatI8::random(&mut rng, 6, 5);
        let outs = interp
            .execute(&[
                MixedBuf::I8(&a_hi.data),
                MixedBuf::I8(&a_lo.data),
                MixedBuf::I8(&w.data),
            ])
            .unwrap();
        assert_eq!(outs[0], golden_gemm(&a_hi, &w).data);
        assert_eq!(outs[1], golden_gemm(&a_lo, &w).data);
    }

    #[test]
    fn snn_matches_engine_pipeline() {
        use crate::engines::snn::{SnnConfig, SnnEngine, SnnVariant};
        let interp = Interp::Snn {
            t: 8,
            p: 32,
            n: 32,
            v_threshold: 64,
            leak_shift: 3,
        };
        let mut rng = XorShift::new(7);
        let train = SpikeTrain::random(&mut rng, 8, 32, 1, 3);
        let weights = MatI8::random_bounded(&mut rng, 32, 32, 63);
        let spikes_i8: Vec<i8> = train.spikes.iter().map(|&s| s as i8).collect();
        let outs = interp
            .execute(&[MixedBuf::I8(&spikes_i8), MixedBuf::I8(&weights.data)])
            .unwrap();
        let mut eng = SnnEngine::new(SnnConfig::paper_32x32(SnnVariant::Enhanced));
        let (eng_spikes, eng_currents, _) = eng.run_snn(&train, &weights).unwrap();
        assert_eq!(outs[1], eng_currents);
        let eng_spikes_i32: Vec<i32> =
            eng_spikes.iter().map(|&s| s as i32).collect();
        assert_eq!(outs[0], eng_spikes_i32);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let interp = Interp::PackedGemm { m: 2, k: 2, n: 2 };
        let short = [0i8; 3];
        let ok = [0i8; 4];
        assert!(interp
            .execute(&[
                MixedBuf::I8(&short),
                MixedBuf::I8(&ok),
                MixedBuf::I8(&ok)
            ])
            .is_err());
    }
}
