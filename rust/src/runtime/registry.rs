//! Artifact registry: manifest parsing + lazy compilation cache.

use super::client::{LoadedModule, TensorSpec, XlaRuntime};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One manifest entry (an AOT-lowered module or a data blob).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The artifact set exported by `python/compile/aot.py`.
pub struct ArtifactRegistry {
    dir: PathBuf,
    entries: HashMap<String, ArtifactEntry>,
    runtime: XlaRuntime,
    compiled: HashMap<String, LoadedModule>,
}

fn parse_specs(v: Option<&Json>) -> Result<Vec<TensorSpec>> {
    let Some(arr) = v.and_then(|v| v.as_array()) else {
        return Ok(Vec::new()); // data blobs carry no signature
    };
    arr.iter()
        .map(|spec| {
            let dtype = spec
                .get("dtype")
                .and_then(|d| d.as_str())
                .ok_or_else(|| anyhow!("missing dtype"))?
                .to_string();
            let shape = spec
                .get("shape")
                .and_then(|s| s.as_array())
                .ok_or_else(|| anyhow!("missing shape"))?
                .iter()
                .map(|d| {
                    d.as_i64()
                        .filter(|&d| d >= 0)
                        .map(|d| d as usize)
                        .ok_or_else(|| anyhow!("bad dim"))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { dtype, shape })
        })
        .collect()
}

impl ArtifactRegistry {
    /// Open `dir/manifest.json` and validate every listed file exists.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let doc = Json::parse(&text).context("parsing manifest.json")?;
        anyhow::ensure!(
            doc.get("version").and_then(|v| v.as_i64()) == Some(1),
            "unsupported manifest version"
        );
        let mut entries = HashMap::new();
        for e in doc
            .get("artifacts")
            .and_then(|a| a.as_array())
            .ok_or_else(|| anyhow!("manifest has no artifacts"))?
        {
            let name = e
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow!("artifact without name"))?
                .to_string();
            let file = dir.join(
                e.get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow!("artifact without file"))?,
            );
            anyhow::ensure!(file.exists(), "artifact file missing: {file:?}");
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name,
                    file,
                    inputs: parse_specs(e.get("inputs"))?,
                    outputs: parse_specs(e.get("outputs"))?,
                },
            );
        }
        Ok(ArtifactRegistry {
            dir: dir.to_path_buf(),
            entries,
            runtime: XlaRuntime::cpu()?,
            compiled: HashMap::new(),
        })
    }

    /// Default location relative to the repo root.
    pub fn open_default() -> Result<Self> {
        Self::open(Path::new("artifacts"))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.get(name)
    }

    /// Compile (once) and return the executable module for `name`.
    pub fn module(&mut self, name: &str) -> Result<&LoadedModule> {
        if !self.compiled.contains_key(name) {
            let entry = self
                .entries
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?
                .clone();
            anyhow::ensure!(
                entry.file.extension().is_some_and(|e| e == "txt"),
                "artifact `{name}` is a data blob, not an HLO module"
            );
            let module = self.runtime.load_hlo_text(
                &entry.file,
                entry.inputs,
                entry.outputs,
            )?;
            self.compiled.insert(name.to_string(), module);
        }
        Ok(&self.compiled[name])
    }

    /// Find the packed-GEMM artifact matching `(m, k, n)` exactly.
    pub fn gemm_artifact(&self, m: usize, k: usize, n: usize) -> Option<String> {
        let name = format!("packed_gemm_m{m}_k{k}_n{n}");
        self.entries.contains_key(&name).then_some(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Registry tests that need real artifacts live in
    /// rust/tests/runtime_roundtrip.rs (they require `make artifacts`);
    /// here we exercise manifest parsing against a synthetic dir.
    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "dsp48-registry-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule m\n").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "artifacts": [
                {"name": "m", "file": "m.hlo.txt",
                 "inputs": [{"dtype": "int8", "shape": [2, 3]}],
                 "outputs": [{"dtype": "int32", "shape": [2, 3]}]}
            ]}"#,
        )
        .unwrap();
        let reg = ArtifactRegistry::open(&dir).unwrap();
        assert_eq!(reg.names(), vec!["m"]);
        let e = reg.entry("m").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.outputs[0].dtype, "int32");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join(format!(
            "dsp48-registry-test2-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "artifacts": [
                {"name": "gone", "file": "gone.hlo.txt"}
            ]}"#,
        )
        .unwrap();
        assert!(ArtifactRegistry::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
