//! Artifact registry: manifest parsing + lazy backend compilation.
//!
//! The registry is backend-neutral: it parses the manifest written by
//! `python/compile/aot.py`, validates signatures, and hands out
//! [`LoadedModule`]s that execute on whichever backend the build
//! provides — the pure-Rust golden interpreter by default, or the PJRT
//! CPU client under `--features xla`.

use super::error::{rt_bail, rt_ensure, Result, RuntimeError};
use super::interp::Interp;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor in an artifact signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// jax dtype string: "int8", "int32", "int64", "float32".
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A borrowed input buffer of either dtype the artifacts use.
pub enum MixedBuf<'a> {
    I8(&'a [i8]),
    I32(&'a [i32]),
}

/// One manifest entry (an AOT-lowered module or a data blob).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Baked constants the manifest records (e.g. MLP quant pairs, LIF
    /// parameters) — the offline interpreter reads these.
    pub constants: Option<Json>,
}

enum Backend {
    Interp(Interp),
    #[cfg(feature = "xla")]
    Xla(super::client::XlaModule),
}

/// A compiled artifact ready to execute, plus its signature.
pub struct LoadedModule {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    backend: Backend,
}

impl LoadedModule {
    /// Execute with i8 input buffers; returns i32 output buffers.
    ///
    /// This covers most artifacts (INT8 in, INT32 logits/currents out);
    /// mixed-dtype signatures (the MLP's int32 biases) route through
    /// [`LoadedModule::execute_mixed`].
    pub fn execute_i8_to_i32(&self, inputs: &[&[i8]]) -> Result<Vec<Vec<i32>>> {
        let bufs: Vec<MixedBuf> = inputs.iter().map(|b| MixedBuf::I8(b)).collect();
        self.execute_mixed(&bufs)
    }

    /// Execute with mixed i8/i32 inputs.
    pub fn execute_mixed(&self, bufs: &[MixedBuf<'_>]) -> Result<Vec<Vec<i32>>> {
        rt_ensure!(
            bufs.len() == self.inputs.len(),
            "expected {} inputs, got {}",
            self.inputs.len(),
            bufs.len()
        );
        for (buf, spec) in bufs.iter().zip(&self.inputs) {
            match buf {
                MixedBuf::I8(v) => rt_ensure!(
                    v.len() == spec.elements() && spec.dtype == "int8",
                    "input mismatch: {} i8 values vs {:?}",
                    v.len(),
                    spec
                ),
                MixedBuf::I32(v) => rt_ensure!(
                    v.len() == spec.elements() && spec.dtype == "int32",
                    "input mismatch: {} i32 values vs {:?}",
                    v.len(),
                    spec
                ),
            }
        }
        let outs = match &self.backend {
            Backend::Interp(interp) => interp.execute(bufs)?,
            #[cfg(feature = "xla")]
            Backend::Xla(module) => module.execute(bufs, &self.inputs)?,
        };
        rt_ensure!(
            outs.len() == self.outputs.len(),
            "expected {} outputs, got {}",
            self.outputs.len(),
            outs.len()
        );
        Ok(outs)
    }
}

/// The artifact set exported by `python/compile/aot.py`.
pub struct ArtifactRegistry {
    dir: PathBuf,
    entries: HashMap<String, ArtifactEntry>,
    compiled: HashMap<String, LoadedModule>,
    #[cfg(feature = "xla")]
    runtime: super::client::XlaRuntime,
}

fn parse_specs(v: Option<&Json>) -> Result<Vec<TensorSpec>> {
    let Some(arr) = v.and_then(|v| v.as_array()) else {
        return Ok(Vec::new()); // data blobs carry no signature
    };
    arr.iter()
        .map(|spec| {
            let dtype = spec
                .get("dtype")
                .and_then(|d| d.as_str())
                .ok_or_else(|| RuntimeError::msg("missing dtype"))?
                .to_string();
            let shape = spec
                .get("shape")
                .and_then(|s| s.as_array())
                .ok_or_else(|| RuntimeError::msg("missing shape"))?
                .iter()
                .map(|d| {
                    d.as_i64()
                        .filter(|&d| d >= 0)
                        .map(|d| d as usize)
                        .ok_or_else(|| RuntimeError::msg("bad dim"))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { dtype, shape })
        })
        .collect()
}

impl ArtifactRegistry {
    /// Open `dir/manifest.json` and validate every listed file exists.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            RuntimeError(format!(
                "reading {manifest_path:?} — run `make artifacts`: {e}"
            ))
        })?;
        let doc = Json::parse(&text)
            .map_err(|e| RuntimeError(format!("parsing manifest.json: {e}")))?;
        rt_ensure!(
            doc.get("version").and_then(|v| v.as_i64()) == Some(1),
            "unsupported manifest version"
        );
        let mut entries = HashMap::new();
        for e in doc
            .get("artifacts")
            .and_then(|a| a.as_array())
            .ok_or_else(|| RuntimeError::msg("manifest has no artifacts"))?
        {
            let name = e
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| RuntimeError::msg("artifact without name"))?
                .to_string();
            let file = dir.join(
                e.get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| RuntimeError::msg("artifact without file"))?,
            );
            rt_ensure!(file.exists(), "artifact file missing: {file:?}");
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name,
                    file,
                    inputs: parse_specs(e.get("inputs"))?,
                    outputs: parse_specs(e.get("outputs"))?,
                    constants: e.get("constants").cloned(),
                },
            );
        }
        Ok(ArtifactRegistry {
            dir: dir.to_path_buf(),
            entries,
            compiled: HashMap::new(),
            #[cfg(feature = "xla")]
            runtime: super::client::XlaRuntime::cpu()?,
        })
    }

    /// Default location relative to the repo root.
    pub fn open_default() -> Result<Self> {
        Self::open(Path::new("artifacts"))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Which backend `module` compiles onto.
    pub fn backend_name(&self) -> &'static str {
        if cfg!(feature = "xla") {
            "pjrt-cpu"
        } else {
            "golden-interp"
        }
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.get(name)
    }

    /// Compile (once) and return the executable module for `name`.
    pub fn module(&mut self, name: &str) -> Result<&LoadedModule> {
        if !self.compiled.contains_key(name) {
            let entry = self
                .entries
                .get(name)
                .ok_or_else(|| RuntimeError(format!("unknown artifact `{name}`")))?
                .clone();
            if !entry.file.extension().is_some_and(|e| e == "txt") {
                rt_bail!("artifact `{name}` is a data blob, not an HLO module");
            }
            let backend = self.compile(&entry)?;
            self.compiled.insert(
                name.to_string(),
                LoadedModule {
                    inputs: entry.inputs,
                    outputs: entry.outputs,
                    backend,
                },
            );
        }
        Ok(&self.compiled[name])
    }

    #[cfg(feature = "xla")]
    fn compile(&self, entry: &ArtifactEntry) -> Result<Backend> {
        Ok(Backend::Xla(self.runtime.load_hlo_text(&entry.file)?))
    }

    #[cfg(not(feature = "xla"))]
    fn compile(&self, entry: &ArtifactEntry) -> Result<Backend> {
        Ok(Backend::Interp(Interp::from_entry(entry)?))
    }

    /// Find the packed-GEMM artifact matching `(m, k, n)` exactly.
    pub fn gemm_artifact(&self, m: usize, k: usize, n: usize) -> Option<String> {
        let name = format!("packed_gemm_m{m}_k{k}_n{n}");
        self.entries.contains_key(&name).then_some(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Registry tests that need real artifacts live in
    /// rust/tests/runtime_roundtrip.rs (they require `make artifacts`);
    /// here we exercise manifest parsing against a synthetic dir.
    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "dsp48-registry-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule m\n").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "artifacts": [
                {"name": "m", "file": "m.hlo.txt",
                 "inputs": [{"dtype": "int8", "shape": [2, 3]}],
                 "outputs": [{"dtype": "int32", "shape": [2, 3]}]}
            ]}"#,
        )
        .unwrap();
        let reg = ArtifactRegistry::open(&dir).unwrap();
        assert_eq!(reg.names(), vec!["m"]);
        let e = reg.entry("m").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.outputs[0].dtype, "int32");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join(format!(
            "dsp48-registry-test2-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "artifacts": [
                {"name": "gone", "file": "gone.hlo.txt"}
            ]}"#,
        )
        .unwrap();
        assert!(ArtifactRegistry::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Offline default: a recognized artifact compiles onto the golden
    /// interpreter and executes with validated signatures.
    #[cfg(not(feature = "xla"))]
    #[test]
    fn offline_backend_executes_packed_gemm() {
        use crate::util::rng::XorShift;
        use crate::workload::gemm::golden_gemm;
        use crate::workload::MatI8;

        let dir = std::env::temp_dir().join(format!(
            "dsp48-registry-test3-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("g.hlo.txt"), "HloModule g\n").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "artifacts": [
                {"name": "packed_gemm_m2_k3_n4", "file": "g.hlo.txt",
                 "inputs": [{"dtype": "int8", "shape": [2, 3]},
                            {"dtype": "int8", "shape": [2, 3]},
                            {"dtype": "int8", "shape": [3, 4]}],
                 "outputs": [{"dtype": "int32", "shape": [2, 4]},
                             {"dtype": "int32", "shape": [2, 4]}]}
            ]}"#,
        )
        .unwrap();
        let mut reg = ArtifactRegistry::open(&dir).unwrap();
        assert_eq!(reg.backend_name(), "golden-interp");
        let mut rng = XorShift::new(5);
        let a_hi = MatI8::random(&mut rng, 2, 3);
        let a_lo = MatI8::random(&mut rng, 2, 3);
        let w = MatI8::random(&mut rng, 3, 4);
        let module = reg.module("packed_gemm_m2_k3_n4").unwrap();
        let outs = module
            .execute_i8_to_i32(&[&a_hi.data, &a_lo.data, &w.data])
            .unwrap();
        assert_eq!(outs[0], golden_gemm(&a_hi, &w).data);
        assert_eq!(outs[1], golden_gemm(&a_lo, &w).data);
        // Signature validation still guards the interpreter path.
        let module = reg.module("packed_gemm_m2_k3_n4").unwrap();
        let short = vec![0i8; 2];
        assert!(module
            .execute_i8_to_i32(&[&short, &a_lo.data, &w.data])
            .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
