//! Runtime error type shared by both backends (the offline build has
//! no `anyhow`; the gated PJRT client maps its errors into this).

use std::fmt;

/// A runtime failure: artifact loading, signature validation, or
/// backend execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RuntimeError(pub String);

impl RuntimeError {
    pub fn msg(msg: impl Into<String>) -> Self {
        RuntimeError(msg.into())
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "runtime error: {}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// `ensure!`-style guard producing a [`RuntimeError`].
macro_rules! rt_ensure {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err($crate::runtime::RuntimeError(format!($($fmt)*)));
        }
    };
}

/// `bail!`-style early return producing a [`RuntimeError`].
macro_rules! rt_bail {
    ($($fmt:tt)*) => {
        return Err($crate::runtime::RuntimeError(format!($($fmt)*)))
    };
}

pub(crate) use rt_bail;
pub(crate) use rt_ensure;
