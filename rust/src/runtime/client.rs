//! Thin wrapper over the `xla` crate's PJRT CPU client.

use anyhow::{Context, Result};
use std::path::Path;

/// Shape + dtype of one tensor in an artifact signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// jax dtype string: "int8", "int32", "int64", "float32".
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A compiled HLO module ready to execute, plus its signature.
pub struct LoadedModule {
    exe: xla::PjRtLoadedExecutable,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The PJRT CPU client + module loader.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(XlaRuntime {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text module with a declared signature.
    pub fn load_hlo_text(
        &self,
        path: &Path,
        inputs: Vec<TensorSpec>,
        outputs: Vec<TensorSpec>,
    ) -> Result<LoadedModule> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(LoadedModule {
            exe,
            inputs,
            outputs,
        })
    }
}

/// Build an S8 literal from raw bytes (the crate's `vec1` only covers
/// the wider native types; S8 goes through the raw-copy path).
fn literal_i8(data: &[i8], shape: &[usize]) -> Result<xla::Literal> {
    let mut lit =
        xla::Literal::create_from_shape(xla::PrimitiveType::S8, shape);
    lit.copy_raw_from(data).context("copying i8 buffer")?;
    Ok(lit)
}

fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let mut lit =
        xla::Literal::create_from_shape(xla::PrimitiveType::S32, shape);
    lit.copy_raw_from(data).context("copying i32 buffer")?;
    Ok(lit)
}

impl LoadedModule {
    /// Execute with i8 input buffers; returns i32 output buffers.
    ///
    /// This covers most artifacts (INT8 in, INT32 logits/currents out);
    /// mixed-dtype signatures (the MLP's int32 biases) route through
    /// [`LoadedModule::execute_mixed`].
    pub fn execute_i8_to_i32(&self, inputs: &[&[i8]]) -> Result<Vec<Vec<i32>>> {
        let bufs: Vec<MixedBuf> = inputs.iter().map(|b| MixedBuf::I8(b)).collect();
        self.execute_mixed(&bufs)
    }

    /// Execute with mixed i8/i32 inputs.
    pub fn execute_mixed(
        &self,
        bufs: &[MixedBuf<'_>],
    ) -> Result<Vec<Vec<i32>>> {
        anyhow::ensure!(
            bufs.len() == self.inputs.len(),
            "expected {} inputs, got {}",
            self.inputs.len(),
            bufs.len()
        );
        let mut args = Vec::with_capacity(bufs.len());
        for (buf, spec) in bufs.iter().zip(&self.inputs) {
            let lit = match buf {
                MixedBuf::I8(v) => {
                    anyhow::ensure!(
                        v.len() == spec.elements() && spec.dtype == "int8",
                        "input mismatch: {} i8 values vs {:?}",
                        v.len(),
                        spec
                    );
                    literal_i8(v, &spec.shape)?
                }
                MixedBuf::I32(v) => {
                    anyhow::ensure!(
                        v.len() == spec.elements() && spec.dtype == "int32",
                        "input mismatch: {} i32 values vs {:?}",
                        v.len(),
                        spec
                    );
                    literal_i32(v, &spec.shape)?
                }
            };
            args.push(lit);
        }
        self.run(args)
    }

    fn run(&self, args: Vec<xla::Literal>) -> Result<Vec<Vec<i32>>> {
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: outputs arrive as one
        // tuple literal.
        let elems = result.to_tuple()?;
        anyhow::ensure!(
            elems.len() == self.outputs.len(),
            "expected {} outputs, got {}",
            self.outputs.len(),
            elems.len()
        );
        elems
            .into_iter()
            .zip(&self.outputs)
            .map(|(lit, spec)| {
                let v = lit.to_vec::<i32>().with_context(|| {
                    format!("reading output as i32 (spec {spec:?})")
                })?;
                Ok(v)
            })
            .collect()
    }
}

/// A borrowed input buffer of either dtype the artifacts use.
pub enum MixedBuf<'a> {
    I8(&'a [i8]),
    I32(&'a [i32]),
}
