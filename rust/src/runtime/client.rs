//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Compiled only under `--features xla` (the crate must be vendored —
//! see rust/README.md); the default offline build executes artifacts on
//! the golden interpreter instead ([`super::interp`]).

use super::error::{Result, RuntimeError};
use super::registry::{MixedBuf, TensorSpec};
use std::path::Path;

fn xe(context: &str, e: impl std::fmt::Display) -> RuntimeError {
    RuntimeError(format!("{context}: {e}"))
}

/// A compiled HLO module ready to execute on PJRT.
pub struct XlaModule {
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT CPU client + module loader.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(XlaRuntime {
            client: xla::PjRtClient::cpu()
                .map_err(|e| xe("creating PJRT CPU client", e))?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text module.
    pub fn load_hlo_text(&self, path: &Path) -> Result<XlaModule> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| RuntimeError::msg("non-utf8 path"))?,
        )
        .map_err(|e| xe(&format!("parsing HLO text {path:?}"), e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| xe(&format!("compiling {path:?}"), e))?;
        Ok(XlaModule { exe })
    }
}

/// Build an S8 literal from raw bytes (the crate's `vec1` only covers
/// the wider native types; S8 goes through the raw-copy path).
fn literal_i8(data: &[i8], shape: &[usize]) -> Result<xla::Literal> {
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S8, shape);
    lit.copy_raw_from(data)
        .map_err(|e| xe("copying i8 buffer", e))?;
    Ok(lit)
}

fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S32, shape);
    lit.copy_raw_from(data)
        .map_err(|e| xe("copying i32 buffer", e))?;
    Ok(lit)
}

impl XlaModule {
    /// Execute pre-validated mixed i8/i32 inputs (shape/dtype checks
    /// happen in [`super::registry::LoadedModule`]); `specs` supplies
    /// the declared parameter shapes for literal construction.
    pub fn execute(
        &self,
        bufs: &[MixedBuf<'_>],
        specs: &[TensorSpec],
    ) -> Result<Vec<Vec<i32>>> {
        let mut args = Vec::with_capacity(bufs.len());
        for (buf, spec) in bufs.iter().zip(specs) {
            let lit = match buf {
                MixedBuf::I8(v) => literal_i8(v, &spec.shape)?,
                MixedBuf::I32(v) => literal_i32(v, &spec.shape)?,
            };
            args.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| xe("executing module", e))?[0][0]
            .to_literal_sync()
            .map_err(|e| xe("fetching result literal", e))?;
        // aot.py lowers with return_tuple=True: outputs arrive as one
        // tuple literal.
        let elems = result
            .to_tuple()
            .map_err(|e| xe("untupling result", e))?;
        elems
            .into_iter()
            .map(|lit| {
                lit.to_vec::<i32>()
                    .map_err(|e| xe("reading output as i32", e))
            })
            .collect()
    }
}
