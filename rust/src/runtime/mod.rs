//! Artifact runtime: load AOT-compiled HLO artifacts (written by
//! `python/compile/aot.py`) and execute them from the rust hot path.
//! Python never runs at serve time.
//!
//! Two backends sit behind one [`ArtifactRegistry`]/[`LoadedModule`]
//! surface:
//!
//! * **default (offline)** — the pure-Rust golden interpreter
//!   ([`Interp`]): the artifact vocabulary is closed and every member's
//!   numerics has a bit-exact rust twin, so the default build executes
//!   artifacts with no XLA toolchain and no network.
//! * **`--features xla`** — the PJRT CPU client
//!   (`HloModuleProto::from_text_file` → `compile` → `execute`).
//!   Off by default; requires vendoring the `xla` crate (see
//!   rust/README.md). Interchange is HLO *text*: jax ≥ 0.5 emits protos
//!   with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//!   text parser reassigns ids.
//!
//! [`ArtifactRegistry`] reads `artifacts/manifest.json`, validates each
//! entry's signature and lazily compiles executables on whichever
//! backend is built in.

#[cfg(feature = "xla")]
mod client;
mod error;
mod golden;
mod interp;
mod registry;

#[cfg(feature = "xla")]
pub use client::{XlaModule, XlaRuntime};
pub use error::{Result, RuntimeError};
pub use golden::GoldenGemm;
pub use interp::Interp;
pub use registry::{
    ArtifactEntry, ArtifactRegistry, LoadedModule, MixedBuf, TensorSpec,
};
