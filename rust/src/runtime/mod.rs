//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! the rust hot path. Python never runs at serve time.
//!
//! * [`ArtifactRegistry`] reads `artifacts/manifest.json` (written by
//!   `python/compile/aot.py`), validates each entry's signature and
//!   lazily compiles executables on the PJRT CPU client.
//! * [`XlaRuntime`] wraps `xla::PjRtClient`:
//!   `HloModuleProto::from_text_file` → `compile` → `execute`.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

mod client;
mod golden;
mod registry;

pub use client::{LoadedModule, MixedBuf, TensorSpec, XlaRuntime};
pub use golden::GoldenGemm;
pub use registry::{ArtifactEntry, ArtifactRegistry};
