//! Golden test vectors exported by aot.py (`golden_gemm.bin`): the
//! cross-language bit-exactness contract between the JAX/Pallas kernels
//! and the rust engines/runtime.

use super::error::{rt_ensure, Result, RuntimeError};
use crate::workload::{MatI32, MatI8};
use std::path::Path;

/// The concrete packed-GEMM instance with python-computed outputs.
pub struct GoldenGemm {
    pub a_hi: MatI8,
    pub a_lo: MatI8,
    pub w: MatI8,
    pub hi: MatI32,
    pub lo: MatI32,
}

/// Layout constants (see aot.py): all arrays row-major little-endian i32.
const M: usize = 32;
const K: usize = 64;
const N: usize = 64;

impl GoldenGemm {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("golden_gemm.bin");
        let bytes = std::fs::read(&path).map_err(|e| {
            RuntimeError(format!("reading {path:?} — run `make artifacts`: {e}"))
        })?;
        let words: Vec<i32> = bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let expect = M * K + M * K + K * N + M * N + M * N;
        rt_ensure!(
            words.len() == expect,
            "golden blob has {} words, expected {expect}",
            words.len()
        );
        let mut off = 0;
        let mut take_i8 = |rows: usize, cols: usize| -> MatI8 {
            let data: Vec<i8> = words[off..off + rows * cols]
                .iter()
                .map(|&v| v as i8)
                .collect();
            off += rows * cols;
            MatI8 { rows, cols, data }
        };
        let a_hi = take_i8(M, K);
        let a_lo = take_i8(M, K);
        let w = take_i8(K, N);
        let hi = MatI32 {
            rows: M,
            cols: N,
            data: words[off..off + M * N].to_vec(),
        };
        let lo = MatI32 {
            rows: M,
            cols: N,
            data: words[off + M * N..off + 2 * M * N].to_vec(),
        };
        Ok(GoldenGemm { a_hi, a_lo, w, hi, lo })
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        (M, K, N)
    }
}
