//! Table rendering: the paper's Tables I / II / III as text reports.
//!
//! Each engine produces a [`TableRow`]; the bench/example harnesses
//! collect rows and render them in the same layout the paper prints, so
//! `cargo run --example table1_tpuv1` is diffable against Table I.

use super::power::PowerReport;
use super::resource::{Primitive, ResourceInventory};
use super::timing::TimingReport;

/// One design's evaluation row (the paper's table columns).
#[derive(Debug, Clone)]
pub struct TableRow {
    pub design: String,
    pub lut: usize,
    pub ff: usize,
    pub carry8: usize,
    pub dsp: usize,
    pub freq_mhz: f64,
    pub wns_ns: f64,
    pub power_w: f64,
}

impl TableRow {
    pub fn from_models(
        design: &str,
        inv: &ResourceInventory,
        timing: &TimingReport,
        power: &PowerReport,
    ) -> Self {
        TableRow {
            design: design.to_string(),
            lut: inv.total(Primitive::Lut),
            ff: inv.total(Primitive::Ff),
            carry8: inv.total(Primitive::Carry8),
            dsp: inv.total(Primitive::Dsp),
            freq_mhz: timing.target_mhz,
            wns_ns: timing.wns_ns,
            power_w: power.total_w,
        }
    }
}

/// Render rows in the paper's Table I layout.
pub fn render_table(title: &str, rows: &[TableRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!("{title}\n"));
    s.push_str(&format!(
        "{:<12} {:>7} {:>7} {:>7} {:>5} {:>6} {:>7} {:>7}\n",
        "design", "LUT", "FF", "CARRY8", "DSP", "Freq", "WNS", "Power"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<12} {:>7} {:>7} {:>7} {:>5} {:>6.0} {:>7.3} {:>7.3}\n",
            r.design, r.lut, r.ff, r.carry8, r.dsp, r.freq_mhz, r.wns_ns, r.power_w
        ));
    }
    s
}

/// Render a two-column breakdown (the paper's Table II layout):
/// `(metric, official, ours)` triples.
pub fn render_breakdown(
    title: &str,
    rows: &[(String, String, String)],
) -> String {
    let mut s = String::new();
    s.push_str(&format!("{title}\n"));
    s.push_str(&format!(
        "{:<16} {:>12} {:>12}\n",
        "metric", "Official", "Ours"
    ));
    for (m, a, b) in rows {
        s.push_str(&format!("{m:<16} {a:>12} {b:>12}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_rows() {
        let rows = vec![TableRow {
            design: "DSP-Fetch".into(),
            lut: 167,
            ff: 4516,
            carry8: 0,
            dsp: 210,
            freq_mhz: 666.0,
            wns_ns: 0.052,
            power_w: 0.93,
        }];
        let s = render_table("Table I", &rows);
        assert!(s.contains("DSP-Fetch"));
        assert!(s.contains("4516"));
        assert!(s.contains("0.052"));
    }

    #[test]
    fn renders_breakdown() {
        let s = render_breakdown(
            "Table II",
            &[("MuxLUT".into(), "128".into(), "0".into())],
        );
        assert!(s.contains("MuxLUT"));
        assert!(s.contains("Official"));
    }
}
