//! Structural resource inventory.
//!
//! An engine's netlist is summarized as named groups of primitives with
//! clock domains and activity estimates. Counts are *derived* in the
//! engine constructors (e.g. `rows * cols * act_bits` flip-flops for an
//! activation staging mesh) so that changing the array geometry changes
//! the inventory the way re-synthesis would.

use crate::fabric::ClockDomain;
use std::collections::BTreeMap;

/// FPGA primitive classes we account.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Primitive {
    /// DSP48E2 slice.
    Dsp,
    /// CLB flip-flop (FDRE).
    Ff,
    /// CLB LUT (any size).
    Lut,
    /// CARRY8 block.
    Carry8,
}

impl Primitive {
    pub fn label(self) -> &'static str {
        match self {
            Primitive::Dsp => "DSP",
            Primitive::Ff => "FF",
            Primitive::Lut => "LUT",
            Primitive::Carry8 => "CARRY8",
        }
    }
}

/// A named group of identical primitives (one inventory line).
#[derive(Debug, Clone)]
pub struct Group {
    /// Human-readable purpose, e.g. `"act staging mesh"`, `"DDR mux"`.
    pub name: String,
    pub kind: Primitive,
    pub count: usize,
    pub domain: ClockDomain,
    /// Estimated per-bit activity factor in [0, 1] (power model input);
    /// engines overwrite it with measured toggle rates after simulation.
    pub activity: f64,
}

/// The full structural inventory of one engine.
#[derive(Debug, Clone, Default)]
pub struct ResourceInventory {
    pub groups: Vec<Group>,
}

impl ResourceInventory {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a group (builder style).
    pub fn add(
        &mut self,
        name: &str,
        kind: Primitive,
        count: usize,
        domain: ClockDomain,
        activity: f64,
    ) -> &mut Self {
        debug_assert!((0.0..=1.0).contains(&activity), "activity in [0,1]");
        self.groups.push(Group {
            name: name.to_string(),
            kind,
            count,
            domain,
            activity,
        });
        self
    }

    /// Total count of a primitive class.
    pub fn total(&self, kind: Primitive) -> usize {
        self.groups
            .iter()
            .filter(|g| g.kind == kind)
            .map(|g| g.count)
            .sum()
    }

    /// Count of a primitive class restricted to groups whose name
    /// contains `pat` (used for table breakdown rows like "AddTree").
    pub fn total_matching(&self, kind: Primitive, pat: &str) -> usize {
        self.groups
            .iter()
            .filter(|g| g.kind == kind && g.name.contains(pat))
            .map(|g| g.count)
            .sum()
    }

    /// Per-group breakdown as (name, kind, count) sorted by kind, name.
    pub fn breakdown(&self) -> Vec<(String, Primitive, usize)> {
        let mut rows: Vec<_> = self
            .groups
            .iter()
            .map(|g| (g.name.clone(), g.kind, g.count))
            .collect();
        rows.sort_by(|a, b| (a.1, &a.0).cmp(&(b.1, &b.0)));
        rows
    }

    /// Merge another inventory (e.g. PE inventory × array replication is
    /// usually done arithmetically instead, but composition is handy for
    /// the coordinator's multi-engine reports).
    pub fn extend(&mut self, other: &ResourceInventory) {
        self.groups.extend(other.groups.iter().cloned());
    }

    /// Summary map primitive -> count.
    pub fn totals(&self) -> BTreeMap<Primitive, usize> {
        let mut m = BTreeMap::new();
        for g in &self.groups {
            *m.entry(g.kind).or_insert(0) += g.count;
        }
        m
    }
}

impl std::fmt::Display for ResourceInventory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "{:<34} {:>8} {:>8}  domain", "group", "kind", "count")?;
        for g in &self.groups {
            writeln!(
                f,
                "{:<34} {:>8} {:>8}  {:?}",
                g.name,
                g.kind.label(),
                g.count,
                g.domain
            )?;
        }
        let t = self.totals();
        write!(
            f,
            "TOTAL: {} LUT, {} FF, {} CARRY8, {} DSP",
            t.get(&Primitive::Lut).unwrap_or(&0),
            t.get(&Primitive::Ff).unwrap_or(&0),
            t.get(&Primitive::Carry8).unwrap_or(&0),
            t.get(&Primitive::Dsp).unwrap_or(&0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ResourceInventory {
        let mut inv = ResourceInventory::new();
        inv.add("mult array", Primitive::Dsp, 196, ClockDomain::Fast, 0.9)
            .add("act staging", Primitive::Ff, 3136, ClockDomain::Slow, 0.5)
            .add("AddTree lut", Primitive::Lut, 1152, ClockDomain::Slow, 0.5)
            .add("AddTree ff", Primitive::Ff, 1216, ClockDomain::Slow, 0.5);
        inv
    }

    #[test]
    fn totals_sum_by_kind() {
        let inv = sample();
        assert_eq!(inv.total(Primitive::Dsp), 196);
        assert_eq!(inv.total(Primitive::Ff), 4352);
        assert_eq!(inv.total(Primitive::Lut), 1152);
        assert_eq!(inv.total(Primitive::Carry8), 0);
    }

    #[test]
    fn matching_filters_by_name() {
        let inv = sample();
        assert_eq!(inv.total_matching(Primitive::Ff, "AddTree"), 1216);
        assert_eq!(inv.total_matching(Primitive::Lut, "AddTree"), 1152);
        assert_eq!(inv.total_matching(Primitive::Ff, "staging"), 3136);
    }

    #[test]
    fn display_renders() {
        let s = sample().to_string();
        assert!(s.contains("TOTAL: 1152 LUT, 4352 FF, 0 CARRY8, 196 DSP"));
    }
}
