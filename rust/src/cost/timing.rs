//! Analytic timing model: critical-path delay per net class.
//!
//! Vivado's timing engine is proprietary; what *is* public is the
//! structure of each design's critical path, and the paper's achieved
//! frequency + WNS per design pins the end-to-end delay of that path on
//! the XCZU3EG. The model here assigns each path class a delay built
//! from documented constants (register clk->Q, routing per fan-out
//! doubling, LUT/CARRY8 stage delays, cross-domain penalty), calibrated
//! once against the paper's six timing cells and then *frozen* — every
//! engine, including ones the paper never built (sweeps, ablations),
//! gets its Fmax/WNS from the same constants.
//!
//! Delay budget constants (ns), XCZU3EG speed grade -2 class fabric:
//!
//! | constant        | value | meaning                                    |
//! |-----------------|-------|--------------------------------------------|
//! | `DSP_CASCADE`   | 1.384 | DSP-internal + dedicated cascade hop       |
//! | `CLK_Q_PLUS_SU` | 0.35  | FF clk->Q + setup                          |
//! | `ROUTE_HOP`     | 0.25  | one local routing hop CLB->DSP/CLB         |
//! | `LUT_STAGE`     | 0.15  | one LUT logic stage                        |
//! | `CARRY8_STAGE`  | 0.065 | one CARRY8 block in a chain                |
//! | `FANOUT_LOG`    | 0.26  | extra routing per doubling of fan-out      |
//! | `XDOMAIN`       | 0.08  | slow->fast domain-crossing margin loss     |

/// Classes of timing-critical paths a design can contain.
#[derive(Debug, Clone, PartialEq)]
pub enum PathClass {
    /// Fully inside the DSP column (cascade-coupled MACC): the best case
    /// the paper's techniques aim for.
    DspInternal,
    /// FF -> short route -> DSP input (staged operands).
    StagedOperand,
    /// FF -> broadcast net with `fanout` loads -> DSP input (tinyTPU's
    /// activation broadcast).
    Broadcast { fanout: usize },
    /// CLB adder chain of `carry8_blocks` CARRY8s between FFs (Libano's
    /// accumulating chain).
    CarryChain { carry8_blocks: usize },
    /// LUT mux crossing from the slow to the fast domain (DPU DDR mux),
    /// with `lut_stages` logic levels.
    CrossDomainMux { lut_stages: usize },
    /// Plain LUT logic path with `lut_stages` levels between FFs.
    LutLogic { lut_stages: usize },
}

/// Delay constants (ns). See module docs for the calibration table.
pub const DSP_CASCADE: f64 = 1.384;
pub const CLK_Q_PLUS_SU: f64 = 0.35;
pub const ROUTE_HOP: f64 = 0.25;
pub const LUT_STAGE: f64 = 0.15;
pub const CARRY8_STAGE: f64 = 0.065;
pub const FANOUT_LOG: f64 = 0.3215;
pub const XDOMAIN: f64 = 0.08;
pub const DSP_IN_SETUP: f64 = 0.60;

impl PathClass {
    /// Path delay in nanoseconds.
    pub fn delay_ns(&self) -> f64 {
        match self {
            PathClass::DspInternal => DSP_CASCADE,
            PathClass::StagedOperand => CLK_Q_PLUS_SU + ROUTE_HOP + DSP_IN_SETUP,
            // Broadcast: source FF + routing tree that deepens with
            // fan-out + DSP input setup (the port register absorbs the
            // DSP-internal delay, per UG579 fully-pipelined numbers).
            PathClass::Broadcast { fanout } => {
                let tree = FANOUT_LOG * (*fanout as f64).max(2.0).log2();
                CLK_Q_PLUS_SU + ROUTE_HOP + tree + DSP_IN_SETUP
            }
            PathClass::CarryChain { carry8_blocks } => {
                CLK_Q_PLUS_SU
                    + ROUTE_HOP
                    + LUT_STAGE
                    + CARRY8_STAGE * *carry8_blocks as f64
            }
            PathClass::CrossDomainMux { lut_stages } => {
                CLK_Q_PLUS_SU
                    + ROUTE_HOP
                    + LUT_STAGE * *lut_stages as f64
                    + XDOMAIN
                    + DSP_IN_SETUP
            }
            PathClass::LutLogic { lut_stages } => {
                CLK_Q_PLUS_SU + ROUTE_HOP + LUT_STAGE * *lut_stages as f64
            }
        }
    }
}

/// A design's timing signature: its candidate critical paths plus the
/// clock it is constrained at.
#[derive(Debug, Clone)]
pub struct TimingModel {
    pub paths: Vec<(String, PathClass, f64)>,
    /// Constraint clock in MHz (of the *fast* domain when two-domain).
    pub target_mhz: f64,
}

/// Computed timing result.
#[derive(Debug, Clone)]
pub struct TimingReport {
    /// The binding path.
    pub critical: String,
    pub critical_delay_ns: f64,
    /// Highest achievable frequency (MHz).
    pub fmax_mhz: f64,
    /// Worst negative slack at the target clock (positive = met), ns.
    pub wns_ns: f64,
    pub target_mhz: f64,
}

impl TimingModel {
    pub fn new(target_mhz: f64) -> Self {
        TimingModel {
            paths: Vec::new(),
            target_mhz,
        }
    }

    pub fn path(mut self, name: &str, class: PathClass) -> Self {
        self.paths.push((name.to_string(), class, 0.0));
        self
    }

    /// A path with a calibrated routing *detour* (ns): an additive
    /// congestion term pinned against the paper's reported WNS for that
    /// design. Documented at each call site; non-paper designs (sweeps,
    /// ablations) use plain [`TimingModel::path`] with detour 0.
    pub fn path_d(mut self, name: &str, class: PathClass, detour_ns: f64) -> Self {
        self.paths.push((name.to_string(), class, detour_ns));
        self
    }

    /// Evaluate: find the slowest path, derive Fmax and WNS.
    pub fn report(&self) -> TimingReport {
        assert!(!self.paths.is_empty(), "timing model needs >= 1 path");
        let (name, delay) = self
            .paths
            .iter()
            .map(|(n, c, d)| (n.clone(), c.delay_ns() + d))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let period = 1_000.0 / self.target_mhz;
        TimingReport {
            critical: name,
            critical_delay_ns: delay,
            fmax_mhz: 1_000.0 / delay,
            wns_ns: period - delay,
            target_mhz: self.target_mhz,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsp_internal_meets_666() {
        let rep = TimingModel::new(666.0)
            .path("cascade", PathClass::DspInternal)
            .report();
        assert!(rep.wns_ns > 0.0, "WNS {} should be positive", rep.wns_ns);
        assert!(rep.fmax_mhz > 666.0);
    }

    #[test]
    fn broadcast_14_limits_to_about_400() {
        // tinyTPU: activation broadcast across 14 columns. The paper ran
        // it at 400 MHz with 0.076 ns slack.
        let rep = TimingModel::new(400.0)
            .path("act broadcast", PathClass::Broadcast { fanout: 14 })
            .report();
        assert!(rep.wns_ns > 0.0, "meets 400 MHz (wns={})", rep.wns_ns);
        assert!(
            rep.fmax_mhz < 500.0,
            "broadcast cannot reach the 666 class (fmax={})",
            rep.fmax_mhz
        );
    }

    #[test]
    fn fanout_monotonically_hurts() {
        let d1 = PathClass::Broadcast { fanout: 2 }.delay_ns();
        let d2 = PathClass::Broadcast { fanout: 8 }.delay_ns();
        let d3 = PathClass::Broadcast { fanout: 64 }.delay_ns();
        assert!(d1 < d2 && d2 < d3);
    }

    #[test]
    fn worst_path_binds() {
        let rep = TimingModel::new(666.0)
            .path("fast", PathClass::DspInternal)
            .path("slow", PathClass::Broadcast { fanout: 32 })
            .report();
        assert_eq!(rep.critical, "slow");
    }

    #[test]
    fn carry_chain_scales_with_length() {
        let short = PathClass::CarryChain { carry8_blocks: 4 }.delay_ns();
        let long = PathClass::CarryChain { carry8_blocks: 12 }.delay_ns();
        assert!(long > short);
        assert!((long - short - 8.0 * CARRY8_STAGE).abs() < 1e-12);
    }
}
