//! Activity-based dynamic power model.
//!
//! `P = Σ_groups count · activity · f_domain · E_kind  +  P_clock`
//!
//! where `E_kind` is an energy coefficient per primitive class
//! (mW/GHz ≡ pJ per toggle-cycle) and `P_clock` models the clock tree
//! (proportional to clocked-element count and frequency). Activities
//! come from the cycle-accurate simulation (toggle counters in
//! [`crate::fabric`] and [`crate::dsp`]) — not guessed — so different
//! dataflows genuinely produce different power, which is the paper's
//! point in Tables I–III.
//!
//! Coefficients below were calibrated once against the eight designs the
//! paper reports on XCZU3EG (Tables I, II, III) and are frozen; see
//! EXPERIMENTS.md for paper-vs-model deltas.

use super::resource::{Primitive, ResourceInventory};
use crate::fabric::{ClockDomain, ClockPlan};

/// Energy coefficients in mW per GHz of toggle rate (≈ pJ/toggle).
#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    pub dsp_mw_per_ghz: f64,
    pub ff_mw_per_ghz: f64,
    pub lut_mw_per_ghz: f64,
    pub carry8_mw_per_ghz: f64,
    /// Clock-tree power per thousand clocked FFs per GHz (mW).
    pub clock_mw_per_kff_ghz: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        // Calibrated on the paper's XCZU3EG rows; see module docs.
        PowerModel {
            dsp_mw_per_ghz: 5.2,
            ff_mw_per_ghz: 0.100,
            lut_mw_per_ghz: 0.030,
            carry8_mw_per_ghz: 0.130,
            clock_mw_per_kff_ghz: 5.0,
        }
    }
}

/// One line of the power breakdown.
#[derive(Debug, Clone)]
pub struct PowerLine {
    pub group: String,
    pub watts: f64,
}

/// Power estimate with per-group breakdown.
#[derive(Debug, Clone)]
pub struct PowerReport {
    pub total_w: f64,
    pub clock_w: f64,
    pub lines: Vec<PowerLine>,
}

impl PowerModel {
    fn coeff(&self, kind: Primitive) -> f64 {
        match kind {
            Primitive::Dsp => self.dsp_mw_per_ghz,
            Primitive::Ff => self.ff_mw_per_ghz,
            Primitive::Lut => self.lut_mw_per_ghz,
            Primitive::Carry8 => self.carry8_mw_per_ghz,
        }
    }

    /// Dynamic power for an elaborated inventory under a clock plan.
    pub fn estimate(&self, inv: &ResourceInventory, clocks: ClockPlan) -> PowerReport {
        let mut lines = Vec::new();
        let mut total_mw = 0.0;
        let mut clocked_ff = 0.0;
        for g in &inv.groups {
            let f_ghz = match g.domain {
                ClockDomain::Slow => clocks.slow_mhz,
                ClockDomain::Fast => clocks.fast_mhz,
            } / 1_000.0;
            let mw = g.count as f64 * g.activity * f_ghz * self.coeff(g.kind);
            if g.kind == Primitive::Ff {
                clocked_ff += g.count as f64 * f_ghz;
            }
            if g.kind == Primitive::Dsp {
                // A DSP slice clocks ~200 internal FFs; fold into the
                // clock-tree term at a slice-equivalent weight.
                clocked_ff += g.count as f64 * f_ghz * 25.0;
            }
            total_mw += mw;
            lines.push(PowerLine {
                group: g.name.clone(),
                watts: mw / 1_000.0,
            });
        }
        let clock_mw = self.clock_mw_per_kff_ghz * clocked_ff / 1_000.0;
        PowerReport {
            total_w: (total_mw + clock_mw) / 1_000.0,
            clock_w: clock_mw / 1_000.0,
            lines,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inv(dsp: usize, ff: usize, lut: usize, act: f64) -> ResourceInventory {
        let mut i = ResourceInventory::new();
        i.add("dsp", Primitive::Dsp, dsp, ClockDomain::Fast, act)
            .add("ff", Primitive::Ff, ff, ClockDomain::Slow, act)
            .add("lut", Primitive::Lut, lut, ClockDomain::Slow, act);
        i
    }

    #[test]
    fn power_scales_with_activity() {
        let m = PowerModel::default();
        let plan = ClockPlan::single(666.0);
        let low = m.estimate(&inv(100, 1000, 100, 0.1), plan);
        let high = m.estimate(&inv(100, 1000, 100, 0.9), plan);
        assert!(high.total_w > low.total_w);
    }

    #[test]
    fn power_scales_with_frequency() {
        let m = PowerModel::default();
        let i = inv(100, 1000, 100, 0.5);
        let slow = m.estimate(&i, ClockPlan::single(333.0));
        let fast = m.estimate(&i, ClockPlan::single(666.0));
        assert!((fast.total_w / slow.total_w - 2.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = PowerModel::default();
        let rep = m.estimate(&inv(10, 100, 10, 0.5), ClockPlan::single(500.0));
        let sum: f64 = rep.lines.iter().map(|l| l.watts).sum();
        assert!((sum + rep.clock_w - rep.total_w).abs() < 1e-12);
    }
}
