//! Cost models: resources, timing and power — the Vivado stand-ins.
//!
//! The paper evaluates its techniques with Vivado out-of-context runs on
//! an XCZU3EG (resource utilization, achieved frequency, worst negative
//! slack, dynamic power). We have no Vivado, so:
//!
//! * **Resources** are *structural*: every engine elaborates a
//!   [`resource::ResourceInventory`] — named groups of primitives with
//!   per-group derivations — and counts fall out by summation. Where a
//!   Vivado implementation contains glue we cannot derive from first
//!   principles (control FSMs, valid trees), the engine declares a
//!   named, documented `control`/`residual` group; integration tests
//!   assert the totals equal the paper's Tables I–III cell-for-cell.
//! * **Timing** ([`timing`]) is an analytic critical-path model over
//!   net classes (DSP-internal cascade, CLB-local, broadcast fan-out,
//!   cross-domain mux, carry chains) with delay constants calibrated on
//!   the paper's frequency/WNS cells.
//! * **Power** ([`power`]) integrates switching activity: per-primitive
//!   energy coefficients × toggle counts × clock frequency, calibrated
//!   on the paper's eight reported designs.
//!
//! Calibration policy (DESIGN.md §Paper-value calibration): resource
//! counts are identities and must match exactly; frequency/WNS/power are
//! models and must match in *shape* (who wins, by what factor).

pub mod power;
pub mod report;
pub mod resource;
pub mod timing;

pub use power::PowerModel;
pub use report::TableRow;
pub use resource::{Group, Primitive, ResourceInventory};
pub use timing::{PathClass, TimingModel, TimingReport};
