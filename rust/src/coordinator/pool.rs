//! A sharded, work-stealing task pool (std-only, no external deps).
//!
//! Each worker owns a deque shard; submissions land round-robin across
//! the shards. A worker pops from the *front* of its own shard (FIFO —
//! oldest tile first, keeping job latency predictable) and, when its
//! shard is dry, steals from the *back* of a victim's shard (the
//! classic split that minimizes contention with the owner). The pool
//! blocks idle workers on a condvar, so a drained pool costs no CPU.
//!
//! This replaces the one-job-per-worker `mpsc` drain: because the units
//! are *tiles*, a single large GEMM fans out across every worker, and a
//! mix of job sizes no longer convoys behind the largest one.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// A popped item plus whether it was stolen from another shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provenance {
    Own,
    Stolen,
}

struct Gate {
    /// Items queued across all shards (incremented before the shard
    /// push, decremented after a successful pop, so it never reads
    /// negative).
    queued: usize,
    stopped: bool,
}

/// The sharded pool. Steal accounting is the caller's: [`WorkPool::pop`]
/// reports each item's [`Provenance`] (the service folds it into its
/// metrics), so the pool itself carries no counter to drift.
pub struct WorkPool<T> {
    shards: Vec<Mutex<VecDeque<T>>>,
    gate: Mutex<Gate>,
    cv: Condvar,
    rr: AtomicUsize,
}

impl<T> WorkPool<T> {
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        WorkPool {
            shards: (0..shards).map(|_| Mutex::new(VecDeque::new())).collect(),
            gate: Mutex::new(Gate {
                queued: 0,
                stopped: false,
            }),
            cv: Condvar::new(),
            rr: AtomicUsize::new(0),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Enqueue one item (round-robin shard placement).
    pub fn push(&self, item: T) {
        {
            let mut g = self.gate.lock().unwrap();
            g.queued += 1;
        }
        let s = self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        self.shards[s].lock().unwrap().push_back(item);
        self.cv.notify_one();
    }

    /// Enqueue one item directly onto `shard` (affinity placement).
    pub fn push_to(&self, shard: usize, item: T) {
        {
            let mut g = self.gate.lock().unwrap();
            g.queued += 1;
        }
        self.shards[shard % self.shards.len()]
            .lock()
            .unwrap()
            .push_back(item);
        self.cv.notify_one();
    }

    /// Dequeue for `worker`: own shard first, then steal. Blocks until
    /// an item arrives; returns `None` only once the pool is stopped
    /// *and* fully drained.
    pub fn pop(&self, worker: usize) -> Option<(T, Provenance)> {
        loop {
            if let Some(hit) = self.try_pop(worker) {
                return Some(hit);
            }
            let mut g = self.gate.lock().unwrap();
            loop {
                if g.queued > 0 {
                    break; // retry the shard scan
                }
                if g.stopped {
                    return None;
                }
                g = self.cv.wait(g).unwrap();
            }
        }
    }

    fn try_pop(&self, worker: usize) -> Option<(T, Provenance)> {
        let n = self.shards.len();
        for i in 0..n {
            let idx = (worker + i) % n;
            let item = {
                let mut q = self.shards[idx].lock().unwrap();
                if i == 0 {
                    q.pop_front()
                } else {
                    q.pop_back()
                }
            };
            if let Some(item) = item {
                let mut g = self.gate.lock().unwrap();
                g.queued -= 1;
                drop(g);
                return Some(if i == 0 {
                    (item, Provenance::Own)
                } else {
                    (item, Provenance::Stolen)
                });
            }
        }
        None
    }

    /// Stop the pool: blocked workers drain what is queued, then see
    /// `None`.
    pub fn stop(&self) {
        self.gate.lock().unwrap().stopped = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_on_own_shard() {
        let pool: WorkPool<u32> = WorkPool::new(1);
        pool.push(1);
        pool.push(2);
        pool.push(3);
        assert_eq!(pool.pop(0), Some((1, Provenance::Own)));
        assert_eq!(pool.pop(0), Some((2, Provenance::Own)));
        pool.stop();
        assert_eq!(pool.pop(0), Some((3, Provenance::Own)));
        assert_eq!(pool.pop(0), None);
    }

    #[test]
    fn idle_worker_steals_from_victim_back() {
        let pool: WorkPool<u32> = WorkPool::new(2);
        // All four land on shard 0.
        for v in [10, 11, 12, 13] {
            pool.push_to(0, v);
        }
        // Worker 1's shard is empty: it steals from shard 0's back.
        assert_eq!(pool.pop(1), Some((13, Provenance::Stolen)));
        // Worker 0 keeps FIFO order on its own shard.
        assert_eq!(pool.pop(0), Some((10, Provenance::Own)));
    }

    #[test]
    fn stop_wakes_blocked_workers() {
        let pool: Arc<WorkPool<u32>> = Arc::new(WorkPool::new(2));
        let p = Arc::clone(&pool);
        let h = std::thread::spawn(move || p.pop(0));
        std::thread::sleep(std::time::Duration::from_millis(50));
        pool.stop();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn concurrent_workers_drain_everything_once() {
        let pool: Arc<WorkPool<u64>> = Arc::new(WorkPool::new(4));
        let n = 10_000u64;
        for v in 0..n {
            pool.push(v);
        }
        pool.stop();
        let mut handles = Vec::new();
        for wid in 0..4 {
            let p = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let mut sum = 0u64;
                let mut count = 0u64;
                while let Some((v, _)) = p.pop(wid) {
                    sum += v;
                    count += 1;
                }
                (sum, count)
            }));
        }
        let (mut sum, mut count) = (0u64, 0u64);
        for h in handles {
            let (s, c) = h.join().unwrap();
            sum += s;
            count += c;
        }
        assert_eq!(count, n);
        assert_eq!(sum, n * (n - 1) / 2);
    }
}
