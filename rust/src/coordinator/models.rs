//! Model-graph execution: dependency-gated layer scheduling with
//! arena-resident intermediate activations.
//!
//! A [`Job::Model`](super::job::Job) submission compiles its DAG once
//! ([`GraphCompiler`]) and then rides the service's existing tile
//! machinery: every matmul-class layer becomes a deferred
//! [`JobTracker`] whose work units are *gated* on the tensors they
//! read, and every elementwise glue layer (requant / quant / add /
//! chw) is evaluated right here, on the resident tensors, the moment
//! its inputs land — through the **same** [`eval_elementwise`] the
//! golden interpreter uses, so the glue cannot diverge from the
//! reference by construction.
//!
//! Intermediate tensors live in a per-model [`Scratch`] arena between
//! layers and are freed the moment their last consumer has taken them
//! — they never serialize back through the client, which sees one
//! handle and one result (the final tensor). Tiles of *different*
//! layers at the same wavefront level that share a stationary weight
//! tile are merged into one [`FillGroup`], so weight-stationary
//! engines pay one fill and stream the rest across layers
//! ([`Metrics::inter_layer_fill_reuse`] counts exactly those streamed
//! passes). Grouping strictly within one level is what keeps the
//! gating deadlock-free: a level-`L` unit waits only on tensors
//! produced strictly below `L`, which by induction all resolve
//! without it.

use super::job::{Completion, JobId, JobResult, JobTracker, Reference};
use super::metrics::Metrics;
use super::service::{
    conv_row_blocks, fingerprint_operand, FillGroup, Pass, WorkUnit,
};
use super::tiler::{ActOperand, GemmTiler, TileCoord, WeightOperand};
use crate::engines::RunStats;
use crate::exec::{Scratch, ScratchStats};
use crate::model::golden::eval_elementwise;
use crate::model::{
    GraphCompiler, LayerOp, Model, ModelError, TensorValue,
};
use crate::workload::conv::{weights_to_gemm, PatchSource};
use crate::workload::MatI8;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// A work unit parked until every tensor it reads is resident.
struct GatedUnit {
    unit: WorkUnit,
    /// Tensor ids not yet resident. Units merging passes of several
    /// layers wait on the union of their input tensors.
    waiting: HashSet<usize>,
}

/// One in-flight model: the client-facing tracker plus everything the
/// cascade needs to route layer completions.
struct ModelRun {
    /// The client's tracker (1 virtual tile, completed by the table).
    tracker: Arc<JobTracker>,
    model: Arc<Model>,
    /// Consuming layer indices per tensor id (one entry per read).
    consumers: Vec<Vec<usize>>,
    /// Per-layer engine trackers (`None` for elementwise glue).
    trackers: Vec<Option<Arc<JobTracker>>>,
    /// Resident tensor values (`len == layers + 1`; id 0 = input).
    tensors: Vec<Option<TensorValue>>,
    /// Remaining reads per tensor (the output carries the client's).
    uses: Vec<usize>,
    gated: Vec<GatedUnit>,
    /// Per-model arena: elementwise outputs lease i8 buffers here and
    /// release them when the tensor's last consumer has taken it.
    arena: Scratch,
    /// Engine stats folded across layer completions (commutative
    /// sums, so worker completion order cannot perturb the result).
    stats: RunStats,
    /// Matmul-layer completion reports still outstanding; the run is
    /// retired only when this hits zero, so a poisoned model never
    /// strands an in-flight unit's report.
    reports_left: usize,
    /// Bytes of intermediate tensors currently resident (ids >= 1,
    /// final output excluded — mirrors the metric's definition).
    resident_bytes: usize,
    total_macs: u64,
    failed: bool,
}

impl ModelRun {
    /// Record a produced tensor and update the residency high-water
    /// and the live gauge.
    fn store_tensor(&mut self, t: usize, v: TensorValue, metrics: &Metrics) {
        debug_assert!(self.tensors[t].is_none(), "tensor produced twice");
        if t != self.model.output_tensor() {
            self.resident_bytes += v.bytes();
            metrics
                .intermediate_bytes_resident
                .fetch_max(self.resident_bytes as u64, Ordering::Relaxed);
            metrics
                .intermediate_bytes_now
                .fetch_add(v.bytes() as u64, Ordering::Relaxed);
        }
        self.tensors[t] = Some(v);
    }

    /// One read of tensor `t` happened; free it after the last one.
    /// Tensor 0 is the caller's input and is never freed (the model
    /// tracker verifies against it), and the output tensor keeps the
    /// client's extra use until [`ModelTable`] takes it at finish.
    fn consume(&mut self, t: usize, metrics: &Metrics) {
        self.uses[t] -= 1;
        if self.uses[t] == 0 && t >= 1 {
            if let Some(v) = self.tensors[t].take() {
                self.resident_bytes -= v.bytes();
                metrics
                    .intermediate_bytes_now
                    .fetch_sub(v.bytes() as u64, Ordering::Relaxed);
                if let TensorValue::I8(m) = v {
                    self.arena.release_i8(m.data);
                }
            }
        }
    }

    /// Free every still-resident intermediate (ids >= 1, output
    /// excluded) ahead of the run's retirement: arena leases return
    /// immediately instead of at the last layer report.
    fn free_intermediates(&mut self, metrics: &Metrics) {
        let out_t = self.model.output_tensor();
        for ti in 1..self.tensors.len() {
            if ti == out_t {
                continue;
            }
            if let Some(v) = self.tensors[ti].take() {
                self.resident_bytes -= v.bytes();
                metrics
                    .intermediate_bytes_now
                    .fetch_sub(v.bytes() as u64, Ordering::Relaxed);
                if let TensorValue::I8(m) = v {
                    self.arena.release_i8(m.data);
                }
            }
        }
    }

    /// Evaluate one elementwise glue layer on the resident tensors,
    /// leasing the output buffer from the model's arena.
    fn eval_glue(&mut self, li: usize) -> TensorValue {
        let ModelRun {
            tensors,
            arena,
            model,
            ..
        } = self;
        let layer = &model.layers[li];
        let ins: Vec<&TensorValue> = layer
            .inputs
            .iter()
            .map(|&t| {
                tensors[t].as_ref().expect("glue inputs resident before eval")
            })
            .collect();
        eval_elementwise(&layer.op, &ins, |len| arena.lease_i8(len))
    }

    /// Tensor `t` just became resident: bind it into the matmul
    /// consumers' trackers, evaluate every glue consumer whose inputs
    /// are now complete (cascading through the graph), and release
    /// gated units that were waiting only on it. Binds always precede
    /// releases — a unit releases only once *every* tensor it waits on
    /// has run this routine, so its activations are all bound.
    fn tensor_ready(
        &mut self,
        t0: usize,
        metrics: &Metrics,
        release: &mut Vec<WorkUnit>,
    ) {
        let mut ready = vec![t0];
        while let Some(t) = ready.pop() {
            for li in self.consumers[t].clone() {
                if self.model.layers[li].op.is_matmul() {
                    let tracker = Arc::clone(
                        self.trackers[li]
                            .as_ref()
                            .expect("matmul layers carry trackers"),
                    );
                    let TensorValue::I8(m) =
                        self.tensors[t].as_ref().expect("tensor just landed")
                    else {
                        unreachable!("compiler admits only i8 matmul inputs")
                    };
                    let act = match &self.model.layers[li].op {
                        LayerOp::Conv { shape, .. } => ActOperand::Patches(
                            PatchSource::new(m.data.clone(), *shape)
                                .expect("compiler-validated conv shape"),
                        ),
                        _ => ActOperand::Dense(m.clone()),
                    };
                    tracker.bind_activation(act);
                    self.consume(t, metrics);
                } else {
                    let out_t = li + 1;
                    if self.tensors[out_t].is_some() {
                        continue; // duplicate edge already evaluated it
                    }
                    let inputs = self.model.layers[li].inputs.clone();
                    if inputs.iter().any(|&ti| self.tensors[ti].is_none()) {
                        continue; // another input still in flight
                    }
                    let out = self.eval_glue(li);
                    for &ti in &inputs {
                        self.consume(ti, metrics);
                    }
                    self.store_tensor(out_t, out, metrics);
                    metrics.layers_completed.fetch_add(1, Ordering::Relaxed);
                    ready.push(out_t);
                }
            }
            let mut gi = 0;
            while gi < self.gated.len() {
                self.gated[gi].waiting.remove(&t);
                if self.gated[gi].waiting.is_empty() {
                    release.push(self.gated.swap_remove(gi).unit);
                } else {
                    gi += 1;
                }
            }
        }
    }
}

/// What became of a layer completion routed through the table.
pub(crate) enum LayerDone {
    /// Not a model layer — retire it through the completion table.
    NotModel(Box<JobResult>),
    /// Absorbed; push these newly unblocked units (possibly none).
    Progress(Vec<WorkUnit>),
    /// The last layer landed: the assembled model result.
    Finished { result: Box<JobResult>, macs: u64 },
    /// The model's failure report is complete: fail the client handle.
    ModelFailed { model: JobId },
}

/// What became of a layer failure routed through the table.
pub(crate) enum LayerFailed {
    /// Not a model layer — fail it through the completion table.
    NotModel,
    /// Absorbed; drain these poisoned units (they skip their work).
    Swallowed(Vec<WorkUnit>),
    /// First failure of this model: fail the client handle now and
    /// drain the released units.
    ModelFailed {
        model: JobId,
        release: Vec<WorkUnit>,
    },
}

/// Outcome of a model submission.
pub(crate) enum ModelSubmit {
    /// Units ready to enqueue (layer reads satisfied by the input).
    Scheduled(Vec<WorkUnit>),
    /// The model had no matmul layers at all and finished during the
    /// submit-time cascade.
    Finished { result: Box<JobResult>, macs: u64 },
}

/// Shared registry of in-flight models, keyed by the client-facing
/// job id, plus the layer-id → model routing map workers consult on
/// every completion.
pub(crate) struct ModelTable {
    inner: Mutex<Tables>,
}

struct Tables {
    models: HashMap<u64, ModelRun>,
    /// Layer job id → (model job id, layer index). Entries retire as
    /// each layer reports, so a layer of an already-failed model still
    /// routes here (and is swallowed) instead of leaking a result the
    /// client never had a handle for.
    layer_of: HashMap<u64, (u64, usize)>,
}

impl ModelTable {
    pub(crate) fn new() -> Self {
        ModelTable {
            inner: Mutex::new(Tables {
                models: HashMap::new(),
                layer_of: HashMap::new(),
            }),
        }
    }

    /// Compile and schedule one model. On success the run is installed
    /// (nothing is visible to workers until the caller pushes the
    /// returned units); on error nothing is — the caller resolves the
    /// handle as `Failed`.
    pub(crate) fn submit(
        &self,
        id: JobId,
        model: Model,
        input: MatI8,
        verify: bool,
        tiler: Option<&GemmTiler>,
        next_id: &mut u64,
        metrics: &Metrics,
    ) -> Result<ModelSubmit, ModelError> {
        let plan = GraphCompiler::compile(&model)?;
        if (input.rows, input.cols) != (model.input_rows, model.input_cols) {
            return Err(ModelError::BadInput {
                rows: input.rows,
                cols: input.cols,
            });
        }
        let model = Arc::new(model);
        let n_layers = model.layers.len();
        let tracker = Arc::new(JobTracker::new(
            id,
            ActOperand::Dense(input.clone()),
            WeightOperand::Dense(MatI8::zeros(0, 0)),
            verify.then(|| Reference::ModelDirect {
                model: Arc::clone(&model),
            }),
            plan.total_macs,
            1,
            None,
        ));

        let mut trackers: Vec<Option<Arc<JobTracker>>> = vec![None; n_layers];
        let mut layer_ids: Vec<(u64, usize)> = Vec::new();
        let mut gated: Vec<GatedUnit> = Vec::new();
        // Cross-layer fill groups under construction, with the union
        // of input tensors their member layers read. Keyed by
        // (wavefront level, weight fingerprint, coord); membership is
        // confirmed by bit-exact weight-tile equality, exactly like
        // batch grouping.
        let mut groups: Vec<(FillGroup, HashSet<usize>)> = Vec::new();
        let mut index: HashMap<(usize, u64, TileCoord), Vec<usize>> =
            HashMap::new();
        for (li, layer) in model.layers.iter().enumerate() {
            if !layer.op.is_matmul() {
                continue;
            }
            let input_t = layer.inputs[0];
            let w_op = match &layer.op {
                LayerOp::Gemm { w } | LayerOp::Snn { w } => {
                    WeightOperand::Dense(w.clone())
                }
                LayerOp::SparseGemm { w } => WeightOperand::Sparse(w.clone()),
                LayerOp::Conv { weights, shape } => {
                    WeightOperand::Dense(weights_to_gemm(weights, *shape))
                }
                _ => unreachable!("elementwise ops never reach an engine"),
            };
            let rows = plan.tensors[li + 1].rows;
            let lid = JobId(*next_id);
            *next_id += 1;
            layer_ids.push((lid.0, li));
            match tiler {
                Some(t) => {
                    let k_dim = w_op.rows();
                    // Dead sparse weight tiles are skipped before
                    // anything is gated, same as batch submission.
                    let mut live: Vec<TileCoord> = Vec::new();
                    let (mut skipped, mut macs_skipped) = (0u64, 0u64);
                    for c in t.coords(k_dim, w_op.cols()) {
                        if w_op.tile_live(c) {
                            live.push(c);
                        } else {
                            skipped += 1;
                            macs_skipped += rows as u64
                                * (c.k1 - c.k0) as u64
                                * (c.n1 - c.n0) as u64;
                        }
                    }
                    metrics
                        .tiles_skipped
                        .fetch_add(skipped, Ordering::Relaxed);
                    metrics
                        .macs_skipped
                        .fetch_add(macs_skipped, Ordering::Relaxed);
                    let lt = Arc::new(JobTracker::new_deferred(
                        lid,
                        rows,
                        w_op,
                        None,
                        plan.layer_macs[li],
                        live.len().max(1),
                        Some(t.rows),
                    ));
                    if live.is_empty() {
                        gated.push(GatedUnit {
                            unit: WorkUnit::Empty(Arc::clone(&lt)),
                            waiting: HashSet::from([input_t]),
                        });
                    }
                    let wfp = fingerprint_operand(lt.w_operand());
                    let level = plan.level[li];
                    for coord in live {
                        let w_tile = t.w_tile_of(lt.w_operand(), coord);
                        let candidates =
                            index.entry((level, wfp, coord)).or_default();
                        match candidates
                            .iter()
                            .copied()
                            .find(|&g| groups[g].0.w == w_tile)
                        {
                            Some(g) => {
                                groups[g].0.passes.push(Pass {
                                    job: Arc::clone(&lt),
                                    coord,
                                    cross_layer: true,
                                });
                                groups[g].1.insert(input_t);
                            }
                            None => {
                                groups.push((
                                    FillGroup {
                                        w: w_tile,
                                        passes: vec![Pass {
                                            job: Arc::clone(&lt),
                                            coord,
                                            cross_layer: false,
                                        }],
                                    },
                                    HashSet::from([input_t]),
                                ));
                                candidates.push(groups.len() - 1);
                            }
                        }
                    }
                    trackers[li] = Some(lt);
                }
                None => {
                    // Internally-tiling engines: conv layers stream as
                    // lazy patch row blocks, everything else runs
                    // whole — mirroring batch submission.
                    let blocks = match &layer.op {
                        LayerOp::Conv { .. } => Some(conv_row_blocks(rows)),
                        _ => None,
                    };
                    let tiles = blocks.as_ref().map_or(1, Vec::len);
                    let lt = Arc::new(JobTracker::new_deferred(
                        lid,
                        rows,
                        w_op,
                        None,
                        plan.layer_macs[li],
                        tiles,
                        None,
                    ));
                    match blocks {
                        Some(blocks) => {
                            for (m0, m1) in blocks {
                                gated.push(GatedUnit {
                                    unit: WorkUnit::RowBlock {
                                        job: Arc::clone(&lt),
                                        m0,
                                        m1,
                                    },
                                    waiting: HashSet::from([input_t]),
                                });
                            }
                        }
                        None => gated.push(GatedUnit {
                            unit: WorkUnit::Whole(Arc::clone(&lt)),
                            waiting: HashSet::from([input_t]),
                        }),
                    }
                    trackers[li] = Some(lt);
                }
            }
        }
        for (group, waiting) in groups {
            gated.push(GatedUnit {
                unit: WorkUnit::Groups(vec![group]),
                waiting,
            });
        }

        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n_layers + 1];
        for (li, layer) in model.layers.iter().enumerate() {
            for &t in &layer.inputs {
                consumers[t].push(li);
            }
        }
        let mut tensors: Vec<Option<TensorValue>> =
            (0..=n_layers).map(|_| None).collect();
        tensors[0] = Some(TensorValue::I8(input));

        let mut run = ModelRun {
            tracker,
            model: Arc::clone(&model),
            consumers,
            trackers,
            tensors,
            uses: plan.uses.clone(),
            gated,
            arena: Scratch::new(),
            stats: RunStats::default(),
            reports_left: plan.matmul_layers(),
            resident_bytes: 0,
            total_macs: plan.total_macs,
            failed: false,
        };

        // Seed the cascade with the input tensor: binds level-1
        // activations, evaluates input-only glue, and unblocks every
        // unit that waited only on the input.
        let mut release = Vec::new();
        run.tensor_ready(0, metrics, &mut release);
        if run.tensors[model.output_tensor()].is_some() {
            // No matmul layers anywhere: the glue cascade already
            // produced the output. `slow_mhz` only scales cycles, and
            // an all-glue model charged none.
            debug_assert!(release.is_empty() && run.reports_left == 0);
            let (result, macs) = Self::finish(run, 1.0, metrics);
            return Ok(ModelSubmit::Finished { result, macs });
        }
        let mut t = self.inner.lock().unwrap();
        for (lid, li) in layer_ids {
            t.layer_of.insert(lid, (id.0, li));
        }
        t.models.insert(id.0, run);
        Ok(ModelSubmit::Scheduled(release))
    }

    /// Route one successful tracker completion. Model layers are
    /// absorbed here — their tensors go resident, the cascade advances
    /// — and only the *model's* result ever reaches the caller.
    pub(crate) fn on_layer_done(
        &self,
        id: JobId,
        result: Box<JobResult>,
        metrics: &Metrics,
        slow_mhz: f64,
    ) -> LayerDone {
        let mut t = self.inner.lock().unwrap();
        let Some((mid, li)) = t.layer_of.remove(&id.0) else {
            return LayerDone::NotModel(result);
        };
        let Some(run) = t.models.get_mut(&mid) else {
            return LayerDone::Progress(Vec::new());
        };
        run.reports_left -= 1;
        if run.failed {
            // A sibling layer already failed the model; this report
            // only settles the books.
            if run.reports_left == 0 {
                if let Some(mut run) = t.models.remove(&mid) {
                    run.free_intermediates(metrics);
                }
            }
            return LayerDone::Progress(Vec::new());
        }
        let JobResult { output, stats, .. } = *result;
        run.stats = std::mem::take(&mut run.stats).merged_with(&stats);
        metrics.layers_completed.fetch_add(1, Ordering::Relaxed);
        let mut release = Vec::new();
        run.store_tensor(li + 1, TensorValue::I32(output), metrics);
        run.tensor_ready(li + 1, metrics, &mut release);
        if run.tensors[run.model.output_tensor()].is_some() {
            // Every layer is an ancestor of the output (dead layers
            // are rejected at compile), so reaching it means nothing
            // is left in flight.
            debug_assert!(release.is_empty() && run.reports_left == 0);
            let run = t.models.remove(&mid).expect("run present");
            // Assemble (and golden-verify) outside the table lock so a
            // long replay never serializes other models' completions.
            drop(t);
            let (result, macs) = Self::finish(run, slow_mhz, metrics);
            return LayerDone::Finished { result, macs };
        }
        LayerDone::Progress(release)
    }

    /// Route one failed tracker completion. The first failing layer
    /// fails the whole model: its handle resolves `Failed` now, every
    /// sibling tracker is poisoned (released units skip their work),
    /// and still-gated units are flushed so their reports can settle.
    pub(crate) fn on_layer_failed(
        &self,
        id: JobId,
        metrics: &Metrics,
    ) -> LayerFailed {
        let mut t = self.inner.lock().unwrap();
        let Some((mid, _li)) = t.layer_of.remove(&id.0) else {
            return LayerFailed::NotModel;
        };
        let Some(run) = t.models.get_mut(&mid) else {
            return LayerFailed::Swallowed(Vec::new());
        };
        run.reports_left -= 1;
        let first = !run.failed;
        let mut release = Vec::new();
        if first {
            run.failed = true;
            for lt in run.trackers.iter().flatten() {
                lt.mark_failed();
            }
            release.extend(run.gated.drain(..).map(|g| g.unit));
            run.free_intermediates(metrics);
        }
        if run.reports_left == 0 {
            if let Some(mut run) = t.models.remove(&mid) {
                run.free_intermediates(metrics);
            }
        }
        if first {
            LayerFailed::ModelFailed {
                model: JobId(mid),
                release,
            }
        } else {
            LayerFailed::Swallowed(release)
        }
    }

    /// Abandon whole model runs mid-flight — the owner disconnected
    /// or was shed, so nobody will ever redeem these handles. The
    /// first-failure machinery runs without a failing layer: sibling
    /// trackers are poisoned (released units skip their work, so
    /// every in-flight report still settles), gated units flush, and
    /// resident intermediates free their arena leases *now* rather
    /// than when the last report lands. Non-model ids are ignored.
    /// Returns the flushed units for the caller to push.
    pub(crate) fn abandon(
        &self,
        ids: &[JobId],
        metrics: &Metrics,
    ) -> Vec<WorkUnit> {
        let mut t = self.inner.lock().unwrap();
        let mut release = Vec::new();
        for id in ids {
            let Some(run) = t.models.get_mut(&id.0) else {
                continue;
            };
            if !run.failed {
                run.failed = true;
                for lt in run.trackers.iter().flatten() {
                    lt.mark_failed();
                }
                release.extend(run.gated.drain(..).map(|g| g.unit));
            }
            run.free_intermediates(metrics);
            if run.reports_left == 0 {
                t.models.remove(&id.0);
            }
        }
        release
    }

    /// Assemble the model-level result: the widened output tensor, the
    /// folded layer stats, and the arena telemetry.
    fn finish(
        mut run: ModelRun,
        slow_mhz: f64,
        metrics: &Metrics,
    ) -> (Box<JobResult>, u64) {
        let out_t = run.model.output_tensor();
        let output = run.tensors[out_t]
            .take()
            .expect("model output resident at finish");
        run.tracker.set_output(output.widen());
        if let TensorValue::I8(m) = output {
            run.arena.release_i8(m.data);
        }
        metrics.record_scratch(&ScratchStats::default(), &run.arena.stats());
        let stats = std::mem::take(&mut run.stats);
        match run.tracker.complete_tiles(1, vec![stats], slow_mhz) {
            Completion::Done(result) => (result, run.total_macs),
            Completion::Pending | Completion::Failed => {
                unreachable!(
                    "the model tracker holds exactly one unfailed slot"
                )
            }
        }
    }
}
