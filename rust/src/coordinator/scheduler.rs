//! Weight-delivery scheduling across a tile sequence.
//!
//! The WS array must reload its stationary weights between tiles. The
//! paper's technique 1 exists precisely to make that reload (nearly)
//! free: the B1/BCIN chain streams the *next* tile's weights while the
//! array computes the current one, exposing only the single CEB2 swap
//! cycle. The scheduler quantifies this end-to-end:
//!
//! | policy | exposed cost per tile switch |
//! |---|---|
//! | [`PrefetchPolicy::PingPong`] | 1 cycle (swap pulse) — in-DSP or CLB ping-pong |
//! | [`PrefetchPolicy::Stall`]   | `rows` cycles (full reload) — tinyTPU |
//!
//! The *first* tile's fill cannot overlap anything and costs `rows + 1`
//! either way.

use crate::engines::RunStats;

/// How weight reloads interact with compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefetchPolicy {
    /// Next tile's weights prefetched during compute (the paper's
    /// in-DSP chain, or a CLB ping-pong bank): 1 exposed cycle per swap.
    PingPong,
    /// No prefetch path: the array stalls for the full reload.
    Stall,
}

/// Aggregated schedule over a tile sequence.
#[derive(Debug, Clone)]
pub struct ScheduleReport {
    pub policy: PrefetchPolicy,
    pub tiles: usize,
    /// Total slow-domain cycles including weight handling.
    pub cycles: u64,
    /// Cycles spent purely streaming (compute).
    pub compute_cycles: u64,
    /// Cycles lost to weight loading.
    pub weight_cycles: u64,
    pub macs: u64,
    /// Stationary fills actually performed across the sequence.
    pub fills_issued: u64,
    /// Fills skipped because the weight tile was already resident
    /// (batched weight-tile reuse across jobs).
    pub fills_avoided: u64,
    /// Slow cycles the avoided fills would have cost.
    pub fill_cycles_saved: u64,
    /// Operand density this schedule was planned at (1.0 = dense).
    /// Sparse submissions carry the weight operand's measured density
    /// so the report can predict density-scaled cost — see
    /// [`ScheduleReport::predicted_sparse_cycles`].
    pub density: f64,
}

impl ScheduleReport {
    /// Fraction of time the array computes.
    pub fn compute_fraction(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.compute_cycles as f64 / self.cycles as f64
    }

    /// Achieved MACs/cycle.
    pub fn macs_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.macs as f64 / self.cycles as f64
        }
    }

    /// The MACs/cycle this sequence would achieve if every avoided
    /// fill had been paid — the baseline the amortization is measured
    /// against.
    pub fn macs_per_cycle_unamortized(&self) -> f64 {
        let cycles = self.cycles + self.fill_cycles_saved;
        if cycles == 0 {
            0.0
        } else {
            self.macs as f64 / cycles as f64
        }
    }

    /// Fraction of stationary fills the schedule avoided (0 when no
    /// weights repeat).
    pub fn fill_amortization(&self) -> f64 {
        let total = self.fills_issued + self.fills_avoided;
        if total == 0 {
            0.0
        } else {
            self.fills_avoided as f64 / total as f64
        }
    }

    /// Simulated wall time at `mhz`.
    pub fn simulated_secs(&self, mhz: f64) -> f64 {
        self.cycles as f64 / (mhz * 1e6)
    }

    /// The cycle cost this schedule predicts at its operand density:
    /// compute scales with the fraction of weight tiles that hold any
    /// work (zero tiles are skipped outright, charging nothing), while
    /// weight-delivery cost is already per-*issued*-fill and does not
    /// rescale. Dense reports (`density == 1.0`) predict exactly
    /// [`ScheduleReport::cycles`].
    pub fn predicted_sparse_cycles(&self) -> u64 {
        self.weight_cycles
            + (self.compute_cycles as f64 * self.density).ceil() as u64
    }

    /// Predicted end-to-end speedup from skipping zero work at this
    /// density (≥ 1.0; exactly 1.0 when dense).
    pub fn predicted_speedup(&self) -> f64 {
        let predicted = self.predicted_sparse_cycles();
        if predicted == 0 {
            1.0
        } else {
            self.cycles as f64 / predicted as f64
        }
    }
}

/// Aggregate per-tile run stats under a policy.
///
/// `per_tile` are the engine's stats for each tile run in isolation
/// (each includes its own weight-load accounting); `rows` is the array
/// depth (= uncompressed reload cost).
pub fn schedule(
    policy: PrefetchPolicy,
    per_tile: &[RunStats],
    rows: usize,
) -> ScheduleReport {
    schedule_sparse(policy, per_tile, rows, 1.0)
}

/// [`schedule`] with an operand density attached: the aggregation is
/// identical (the per-tile stats already reflect any skipped tiles —
/// they simply never appear in `per_tile`), but the report carries the
/// density so [`ScheduleReport::predicted_sparse_cycles`] can model
/// density-scaled cost for planning.
pub fn schedule_sparse(
    policy: PrefetchPolicy,
    per_tile: &[RunStats],
    rows: usize,
    density: f64,
) -> ScheduleReport {
    let tiles = per_tile.len();
    // A tile that reused a resident weight tile (`weight_loads == 0`)
    // carries no fill in its cycle count: subtract nothing for it.
    let compute: u64 = per_tile
        .iter()
        .map(|s| {
            let fill_rows = if s.weight_loads > 0 { rows as u64 } else { 0 };
            s.cycles
                .saturating_sub(s.weight_stall_cycles)
                .saturating_sub(fill_rows)
        })
        .sum();
    let macs: u64 = per_tile.iter().map(|s| s.macs).sum();
    let fills_issued =
        per_tile.iter().filter(|s| s.weight_loads > 0).count() as u64;
    let fills_avoided: u64 = per_tile.iter().map(|s| s.fills_avoided).sum();
    let fill_cycles_saved: u64 =
        per_tile.iter().map(|s| s.fill_cycles_saved).sum();
    // First fill is always exposed; only *performed* fills switch.
    let first_fill = (rows + 1) as u64;
    let switches = fills_issued.saturating_sub(1);
    let weight = if fills_issued == 0 {
        0
    } else {
        match policy {
            PrefetchPolicy::PingPong => first_fill + switches,
            PrefetchPolicy::Stall => first_fill + switches * rows as u64,
        }
    };
    ScheduleReport {
        policy,
        tiles,
        cycles: compute + weight,
        compute_cycles: compute,
        weight_cycles: weight,
        macs,
        fills_issued,
        fills_avoided,
        fill_cycles_saved,
        density: density.clamp(0.0, 1.0),
    }
}

/// Aggregate per-tile engine stats into one job-level [`RunStats`]
/// under the engine's *natural* policy: a full-reload stall in any
/// tile marks a tinyTPU-style staller, everything else prefetches
/// (in-DSP or CLB ping-pong). `true_macs` replaces the padded
/// per-tile MAC overcount with the real problem size.
///
/// Both the sequential path (`run_gemm_tiled`) and the sharded
/// assembly (`JobTracker`) call this — keeping the two bit-identical
/// by construction.
pub fn aggregate_tile_stats(
    per_tile: &[RunStats],
    rows: usize,
    true_macs: u64,
) -> RunStats {
    let policy = if per_tile
        .iter()
        .any(|s| s.weight_stall_cycles >= rows as u64)
    {
        PrefetchPolicy::Stall
    } else {
        PrefetchPolicy::PingPong
    };
    let rep = schedule(policy, per_tile, rows);
    RunStats {
        cycles: rep.cycles,
        fast_cycles: rep.cycles,
        macs: true_macs,
        weight_stall_cycles: rep.weight_cycles,
        weight_loads: rep.fills_issued,
        guard_overflows: per_tile.iter().map(|s| s.guard_overflows).sum(),
        fills_avoided: rep.fills_avoided,
        fill_cycles_saved: rep.fill_cycles_saved,
    }
}

/// The end-to-end speedup of ping-pong prefetch over stalling for the
/// same tile sequence.
pub fn prefetch_speedup(per_tile: &[RunStats], rows: usize) -> f64 {
    let pp = schedule(PrefetchPolicy::PingPong, per_tile, rows);
    let st = schedule(PrefetchPolicy::Stall, per_tile, rows);
    st.cycles as f64 / pp.cycles.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(cycles: u64, macs: u64, rows: u64) -> RunStats {
        RunStats {
            cycles: cycles + rows + 1, // engine counts fill+swap per tile
            weight_stall_cycles: 1,
            macs,
            weight_loads: 1,
            ..RunStats::default()
        }
    }

    #[test]
    fn pingpong_exposes_one_cycle_per_switch() {
        let rows = 14;
        let tiles: Vec<RunStats> =
            (0..10).map(|_| stats(100, 1000, rows)).collect();
        let rep = schedule(PrefetchPolicy::PingPong, &tiles, rows as usize);
        assert_eq!(rep.compute_cycles, 1000);
        assert_eq!(rep.weight_cycles, 15 + 9);
        let st = schedule(PrefetchPolicy::Stall, &tiles, rows as usize);
        assert_eq!(st.weight_cycles, 15 + 9 * 14);
        assert!(st.cycles > rep.cycles);
    }

    #[test]
    fn speedup_grows_with_tile_count() {
        let rows = 14;
        let few: Vec<RunStats> = (0..2).map(|_| stats(20, 100, rows)).collect();
        let many: Vec<RunStats> = (0..64).map(|_| stats(20, 100, rows)).collect();
        assert!(
            prefetch_speedup(&many, rows as usize)
                > prefetch_speedup(&few, rows as usize)
        );
    }

    #[test]
    fn single_tile_policies_equal() {
        let rows = 8;
        let one = vec![stats(50, 400, rows)];
        let pp = schedule(PrefetchPolicy::PingPong, &one, rows as usize);
        let st = schedule(PrefetchPolicy::Stall, &one, rows as usize);
        assert_eq!(pp.cycles, st.cycles);
    }

    /// Reused tiles (no fill in their cycles) contribute pure compute:
    /// the schedule only charges weight cycles for fills actually
    /// performed, and surfaces the amortization.
    #[test]
    fn reused_tiles_amortize_weight_cycles() {
        let rows = 14u64;
        let full = stats(100, 1000, rows); // fill + swap included
        let reused = RunStats {
            cycles: 100,
            weight_stall_cycles: 0,
            macs: 1000,
            weight_loads: 0,
            fills_avoided: 1,
            fill_cycles_saved: rows + 1,
            ..RunStats::default()
        };
        let seq = vec![full, reused.clone(), reused];
        let rep = schedule(PrefetchPolicy::PingPong, &seq, rows as usize);
        assert_eq!(rep.compute_cycles, 300);
        // Only one fill issued: no switch cycles at all.
        assert_eq!(rep.weight_cycles, 15);
        assert_eq!(rep.fills_issued, 1);
        assert_eq!(rep.fills_avoided, 2);
        assert_eq!(rep.fill_cycles_saved, 30);
        assert!((rep.fill_amortization() - 2.0 / 3.0).abs() < 1e-12);
        assert!(rep.macs_per_cycle() > rep.macs_per_cycle_unamortized());

        // Same sequence with all fills paid costs strictly more.
        let all_full = vec![stats(100, 1000, rows); 3];
        let base = schedule(PrefetchPolicy::PingPong, &all_full, rows as usize);
        assert!(base.cycles > rep.cycles);
        assert_eq!(base.fills_avoided, 0);
    }

    /// The density model: dense reports predict their own cycles
    /// exactly, density 0 predicts pure weight cost, and predictions
    /// are monotonic in density.
    #[test]
    fn sparse_prediction_scales_with_density() {
        let rows = 14;
        let tiles: Vec<RunStats> =
            (0..10).map(|_| stats(100, 1000, rows)).collect();
        let dense = schedule(PrefetchPolicy::PingPong, &tiles, rows as usize);
        assert_eq!(dense.density, 1.0);
        assert_eq!(dense.predicted_sparse_cycles(), dense.cycles);
        assert!((dense.predicted_speedup() - 1.0).abs() < 1e-12);

        let empty = schedule_sparse(
            PrefetchPolicy::PingPong,
            &tiles,
            rows as usize,
            0.0,
        );
        assert_eq!(empty.predicted_sparse_cycles(), empty.weight_cycles);

        let mut prev = 0;
        for d in [0.1, 0.25, 0.5, 0.9, 1.0] {
            let rep = schedule_sparse(
                PrefetchPolicy::PingPong,
                &tiles,
                rows as usize,
                d,
            );
            // Aggregation itself is density-independent.
            assert_eq!(rep.cycles, dense.cycles);
            let predicted = rep.predicted_sparse_cycles();
            assert!(predicted >= prev, "non-monotonic at d={d}");
            assert!(rep.predicted_speedup() >= 1.0 - 1e-12);
            prev = predicted;
        }
        // Out-of-range densities clamp instead of extrapolating.
        let wild = schedule_sparse(
            PrefetchPolicy::PingPong,
            &tiles,
            rows as usize,
            7.0,
        );
        assert_eq!(wild.density, 1.0);
    }

    #[test]
    fn fractions_sane() {
        let rows = 14;
        let tiles: Vec<RunStats> = (0..5).map(|_| stats(100, 500, rows)).collect();
        let rep = schedule(PrefetchPolicy::PingPong, &tiles, rows as usize);
        assert!(rep.compute_fraction() > 0.9);
        assert!(rep.macs_per_cycle() > 0.0);
        assert!(rep.simulated_secs(666.0) > 0.0);
    }
}
