//! The L3 coordinator: tiling, scheduling and serving matrix workloads
//! on the cycle-accurate engines (cost) and the PJRT runtime (values).
//!
//! The paper's contribution is a *matrix-engine micro-architecture*, so
//! the coordinator here is the surrounding system a deployment needs:
//!
//! * [`job`] — the request types (GEMM / Conv2d / SNN inference);
//! * [`tiler`] — maps arbitrary problem shapes onto an engine's
//!   stationary-tile geometry, K-splitting with guard-band awareness;
//! * [`scheduler`] — aggregates per-tile cycle costs under a
//!   weight-delivery policy: [`scheduler::PrefetchPolicy::PingPong`]
//!   (the paper's in-DSP prefetch: next tile's weights stream during
//!   compute, one exposed swap cycle) vs
//!   [`scheduler::PrefetchPolicy::Stall`] (tinyTPU-style reload stall)
//!   — making the benefit of technique 1 measurable end-to-end;
//! * [`pool`] — the sharded, work-stealing deque pool workers drain;
//! * [`service`] — a multi-worker job service over tile-level work
//!   units: one large GEMM fans out across every worker, partial
//!   results assemble job-level in [`job::JobTracker`] (std threads +
//!   channels; the binary is self-contained and offline).

pub mod job;
pub mod metrics;
pub mod pool;
pub mod scheduler;
pub mod service;
pub mod tiler;

pub use job::{Job, JobId, JobResult, JobTracker};
pub use metrics::Metrics;
pub use pool::WorkPool;
pub use scheduler::{PrefetchPolicy, ScheduleReport};
pub use service::{Service, ServiceConfig};
pub use tiler::{GemmTiler, Tile};
