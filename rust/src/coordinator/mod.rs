//! The L3 coordinator: tiling, scheduling and serving matrix workloads
//! on the cycle-accurate engines (cost) and the PJRT runtime (values).
//!
//! The paper's contribution is a *matrix-engine micro-architecture*, so
//! the coordinator here is the surrounding system a deployment needs:
//!
//! * [`job`] — the request types (GEMM / Conv2d / SNN inference);
//! * [`tiler`] — maps arbitrary problem shapes onto an engine's
//!   stationary-tile geometry, K-splitting with guard-band awareness;
//!   activation operands ([`tiler::ActOperand`]) are extracted per
//!   tile on the worker — conv jobs carry a lazy im2col view
//!   ([`crate::workload::conv::PatchSource`]) so the full patch
//!   matrix is never materialized;
//! * [`scheduler`] — aggregates per-tile cycle costs under a
//!   weight-delivery policy: [`scheduler::PrefetchPolicy::PingPong`]
//!   (the paper's in-DSP prefetch: next tile's weights stream during
//!   compute, one exposed swap cycle) vs
//!   [`scheduler::PrefetchPolicy::Stall`] (tinyTPU-style reload stall)
//!   — making the benefit of technique 1 measurable end-to-end;
//! * [`pool`] — the sharded, work-stealing deque pool workers drain;
//! * [`completion`] — the shared completion table behind the
//!   non-blocking submit/poll front-end ([`completion::JobHandle`]);
//! * [`models`] — whole-network serving: a [`job::Job::Model`]
//!   compiles its layer DAG once and executes as dependency-gated
//!   passes, intermediate activations resident in a per-model arena
//!   (never round-tripping through the client), with weight-fill
//!   groups merged *across layers* at equal wavefront level;
//! * [`service`] — a multi-worker job service over grouped, tile-level
//!   work units: [`service::Service::submit_batch`] groups a batch's
//!   tiles by stationary weight tile (one fill, many streams — the
//!   fill-amortization the paper's prefetch chain makes nearly free
//!   within a job, extended *across* jobs), one large GEMM fans out
//!   across every worker, and partial results assemble job-level in
//!   [`job::JobTracker`] (std threads; the binary is self-contained
//!   and offline).

pub mod completion;
pub mod job;
pub mod metrics;
pub(crate) mod models;
pub mod pool;
pub mod scheduler;
pub mod service;
pub mod tiler;

pub use completion::{CompletionTable, Drained, JobHandle, JobState};
pub use job::{Batch, Job, JobId, JobResult, JobTracker, Reference};
pub use metrics::Metrics;
pub use pool::WorkPool;
pub use scheduler::{PrefetchPolicy, ScheduleReport};
pub use service::{Service, ServiceConfig};
pub use tiler::{ActOperand, GemmTiler, Tile, TileCoord, WeightOperand};
