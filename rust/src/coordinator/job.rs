//! Job types the coordinator accepts.

use crate::engines::RunStats;
use crate::workload::conv::ConvShape;
use crate::workload::{MatI32, MatI8};
use std::time::Duration;

/// Opaque job identifier assigned at submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

/// A unit of work for the matrix engine service.
#[derive(Debug, Clone)]
pub enum Job {
    /// Plain INT8 GEMM: `a (M×K) @ w (K×N)`.
    Gemm { a: MatI8, w: MatI8 },
    /// Conv2d, lowered to GEMM by im2col inside the worker.
    Conv {
        input: Vec<i8>,
        weights: Vec<i8>,
        shape: ConvShape,
    },
    /// Spiking inference: binary spike train (T×P) against weights.
    Snn { spikes: MatI8, weights: MatI8 },
}

impl Job {
    /// MAC count (for throughput accounting).
    pub fn macs(&self) -> u64 {
        match self {
            Job::Gemm { a, w } => (a.rows * a.cols * w.cols) as u64,
            Job::Conv { shape, .. } => shape.macs(),
            Job::Snn { spikes, weights } => {
                (spikes.rows * spikes.cols * weights.cols) as u64
            }
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Job::Gemm { .. } => "gemm",
            Job::Conv { .. } => "conv",
            Job::Snn { .. } => "snn",
        }
    }
}

/// Completed job: output + cycle accounting + wall time.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: JobId,
    pub output: MatI32,
    pub stats: RunStats,
    /// Simulated time at the engine's clock plan.
    pub simulated: Duration,
    /// Host wall-clock the worker spent.
    pub wall: Duration,
    /// Bit-exactness check against the golden reference (when enabled).
    pub verified: Option<bool>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macs_per_kind() {
        let g = Job::Gemm {
            a: MatI8::zeros(4, 8),
            w: MatI8::zeros(8, 2),
        };
        assert_eq!(g.macs(), 64);
        assert_eq!(g.kind(), "gemm");

        let shape = ConvShape {
            in_c: 2,
            in_h: 4,
            in_w: 4,
            out_c: 3,
            k: 3,
            stride: 1,
            pad: 1,
        };
        let c = Job::Conv {
            input: vec![0; 32],
            weights: vec![0; 54],
            shape,
        };
        assert_eq!(c.macs(), shape.macs());
    }
}
