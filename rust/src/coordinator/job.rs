//! Job types the coordinator accepts, and job-level result assembly
//! for tile-sharded execution.

use super::scheduler::aggregate_tile_stats;
use super::tiler::{ActOperand, Tile, WeightOperand};
use crate::engines::RunStats;
use crate::model::{golden_eval, LayerOp, Model};
use crate::workload::conv::{conv2d_direct, ConvShape};
use crate::workload::gemm::golden_gemm;
use crate::workload::{CsrMatI8, MatI32, MatI8, SparseMatI8};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Opaque job identifier assigned at submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

/// A unit of work for the matrix engine service.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Job {
    /// Plain INT8 GEMM: `a (M×K) @ w (K×N)`.
    Gemm { a: MatI8, w: MatI8 },
    /// Conv2d, lowered to GEMM by im2col inside the worker.
    Conv {
        input: Vec<i8>,
        weights: Vec<i8>,
        shape: ConvShape,
    },
    /// Spiking inference: binary spike train (T×P) against weights.
    Snn { spikes: MatI8, weights: MatI8 },
    /// Sparse GEMM: CSR activations against N:M structured weights.
    /// Executes on the dense fabric, but all-zero weight tiles and
    /// empty activation row windows are skipped before enqueue.
    SparseGemm { a: CsrMatI8, w: SparseMatI8 },
    /// A whole network: a validated DAG of layers executed as
    /// dependency-gated passes, intermediate activations resident in
    /// the coordinator's arena. One handle, one result (the final
    /// tensor) — intermediates never round-trip through the client.
    Model { model: Model, input: MatI8 },
}

/// An ordered batch of jobs submitted in one `Service::submit_batch`
/// call. The service groups the batch's tiles by stationary weight
/// tile, so jobs that share weights (the dominant pattern when one
/// model serves many users) pay one fill per tile position and stream
/// the rest — see `RunStats::fills_avoided`.
#[derive(Debug, Clone, Default)]
pub struct Batch {
    pub(crate) jobs: Vec<Job>,
}

impl Batch {
    pub fn new() -> Self {
        Batch::default()
    }

    pub fn push(&mut self, job: Job) -> &mut Self {
        self.jobs.push(job);
        self
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }
}

impl From<Vec<Job>> for Batch {
    fn from(jobs: Vec<Job>) -> Self {
        Batch { jobs }
    }
}

impl FromIterator<Job> for Batch {
    fn from_iter<I: IntoIterator<Item = Job>>(iter: I) -> Self {
        Batch {
            jobs: iter.into_iter().collect(),
        }
    }
}

impl Job {
    /// MAC count (for throughput accounting). Conv shapes must be
    /// valid ([`ConvShape::validate`]) — the count derives the conv
    /// output extent.
    pub fn macs(&self) -> u64 {
        match self {
            Job::Gemm { a, w } => (a.rows * a.cols * w.cols) as u64,
            Job::Conv { shape, .. } => shape.macs(),
            Job::Snn { spikes, weights } => {
                (spikes.rows * spikes.cols * weights.cols) as u64
            }
            // Dense-equivalent MACs, deliberately: skipped zero work
            // still counts as delivered work, so macs/cycle rises with
            // sparsity instead of staying flat.
            Job::SparseGemm { a, w } => {
                (a.rows() * a.cols() * w.cols()) as u64
            }
            // Sum over the matmul layers (0 when the graph is invalid;
            // submission then fails before accounting anyway).
            Job::Model { model, .. } => model.macs(),
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Job::Gemm { .. } => "gemm",
            Job::Conv { .. } => "conv",
            Job::Snn { .. } => "snn",
            Job::SparseGemm { .. } => "sparse",
            Job::Model { .. } => "model",
        }
    }

    /// Operand footprint in bytes — the admission controller's
    /// queued-byte accounting unit. Deliberately the *element* count
    /// (i8 operands are one byte each; sparse index arrays count at
    /// their width), not a malloc-exact figure: the quota bounds how
    /// much client-supplied operand data the coordinator holds per
    /// session, and it must be a deterministic function of the job so
    /// the N-vs-N+1 admission boundary is exact.
    pub fn cost_bytes(&self) -> u64 {
        fn sparse_bytes(w: &SparseMatI8) -> u64 {
            let (idx, val) = w.slots();
            (idx.len() + val.len()) as u64
        }
        match self {
            Job::Gemm { a, w } => (a.data.len() + w.data.len()) as u64,
            Job::Conv { input, weights, .. } => {
                (input.len() + weights.len()) as u64
            }
            Job::Snn { spikes, weights } => {
                (spikes.data.len() + weights.data.len()) as u64
            }
            Job::SparseGemm { a, w } => {
                let (row_ptr, col_idx, val) = a.parts();
                ((row_ptr.len() + col_idx.len())
                    * std::mem::size_of::<usize>()
                    + val.len()) as u64
                    + sparse_bytes(w)
            }
            Job::Model { model, input } => {
                input.data.len() as u64
                    + model
                        .layers
                        .iter()
                        .map(|l| match &l.op {
                            LayerOp::Gemm { w } | LayerOp::Snn { w } => {
                                w.data.len() as u64
                            }
                            LayerOp::SparseGemm { w } => sparse_bytes(w),
                            LayerOp::Conv { weights, .. } => {
                                weights.len() as u64
                            }
                            LayerOp::Requant { .. }
                            | LayerOp::Quant { .. }
                            | LayerOp::Add
                            | LayerOp::Chw { .. } => 0,
                        })
                        .sum::<u64>()
            }
        }
    }
}

/// Completed job: output + cycle accounting + wall time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobResult {
    pub id: JobId,
    pub output: MatI32,
    pub stats: RunStats,
    /// Simulated time at the engine's clock plan.
    pub simulated: Duration,
    /// Host wall-clock from submission to assembly.
    pub wall: Duration,
    /// Bit-exactness check against the golden reference (when enabled).
    pub verified: Option<bool>,
}

/// What [`JobTracker::complete_tiles`] reports back to a worker.
#[derive(Debug)]
pub enum Completion {
    /// Other tiles of this job are still in flight.
    Pending,
    /// This worker finished the last tile: the assembled result.
    Done(Box<JobResult>),
    /// Last tile finished but some tile failed; no result to deliver.
    Failed,
}

/// The golden reference `verified` is checked against (when enabled).
/// Conv jobs verify against the **direct** convolution, so the full
/// im2col matrix is never materialized — not even to verify.
#[derive(Debug)]
pub enum Reference {
    /// `golden_gemm` over the dense operands.
    Gemm,
    /// `conv2d_direct` over the raw NCHW input (held by the job's
    /// [`ActOperand::Patches`]) and these raw (out_c, in_c, k, k)
    /// weights.
    ConvDirect { weights: Vec<i8> },
    /// Sparse jobs verify against `golden_gemm` over **densified**
    /// operands — the densification happens only here, in the checker,
    /// so a skip-path bug cannot hide: the execution path never sees
    /// the dense matrices it must match bit-for-bit.
    SparseDense,
    /// Model jobs verify by replaying the whole DAG through the golden
    /// interpreter layer by layer ([`golden_eval`]) against the dense
    /// model input the tracker holds. `Arc` because the executing side
    /// (the model table) owns the same graph.
    ModelDirect { model: Arc<Model> },
}

/// Shared per-job state for tile-sharded execution.
///
/// The coordinator fans one job out as tile-level work units; every
/// worker that finishes a unit folds its partial output and stats in
/// here, and whichever worker completes the *last* tile assembles the
/// [`JobResult`] — accumulation is commutative (integer adds, and the
/// schedule aggregation only sums), so the result is bit-identical to
/// a sequential run regardless of completion order.
#[derive(Debug)]
pub struct JobTracker {
    id: JobId,
    /// The activation operand: dense, a lazy conv patch view, or CSR
    /// sparse activations that workers materialize per tile. A
    /// `OnceLock` because a model layer's activation is another
    /// layer's output: such trackers are created *deferred* and the
    /// operand bound when the producing layer lands — always before
    /// any work unit of this tracker is released to a worker.
    a: OnceLock<ActOperand>,
    /// The lowered GEMM weight operand (dense or N:M sparse).
    w: WeightOperand,
    /// Lazily densified sparse weights — built at most once, and only
    /// on paths that genuinely need the dense matrix (whole-job units,
    /// row-block streaming, verification). The WS tile path extracts
    /// sparse tiles directly and never populates this.
    w_dense: OnceLock<MatI8>,
    /// True problem MACs (padded tiles overcount).
    macs: u64,
    /// `Some` = cross-check the assembled output against this golden
    /// reference; `None` = verification off (no reference data is
    /// retained at all).
    reference: Option<Reference>,
    /// `Some(rows)` = tile-sharded: assemble stats under the prefetch
    /// scheduler for an array of this depth. `None` = whole-job (or
    /// row-block) units, whose stats simply sum.
    sched_rows: Option<usize>,
    submitted: Instant,
    out: Mutex<MatI32>,
    per_tile: Mutex<Vec<RunStats>>,
    remaining: AtomicUsize,
    failed: AtomicBool,
}

impl JobTracker {
    /// Track a job split into `tiles` work tiles (1 for whole-job
    /// units). `reference: Some(..)` enables output verification.
    pub fn new(
        id: JobId,
        a: ActOperand,
        w: WeightOperand,
        reference: Option<Reference>,
        macs: u64,
        tiles: usize,
        sched_rows: Option<usize>,
    ) -> Self {
        let t = JobTracker::new_deferred(
            id,
            a.rows(),
            w,
            reference,
            macs,
            tiles,
            sched_rows,
        );
        t.bind_activation(a);
        t
    }

    /// Track a job whose activation operand does not exist yet (a
    /// model layer waiting on an upstream tensor). The output rows
    /// must be supplied explicitly; [`JobTracker::bind_activation`]
    /// must run before any worker touches the tracker.
    pub fn new_deferred(
        id: JobId,
        rows: usize,
        w: WeightOperand,
        reference: Option<Reference>,
        macs: u64,
        tiles: usize,
        sched_rows: Option<usize>,
    ) -> Self {
        let out = MatI32::zeros(rows, w.cols());
        JobTracker {
            id,
            a: OnceLock::new(),
            w,
            w_dense: OnceLock::new(),
            macs,
            reference,
            sched_rows,
            submitted: Instant::now(),
            out: Mutex::new(out),
            per_tile: Mutex::new(Vec::with_capacity(tiles)),
            remaining: AtomicUsize::new(tiles),
            failed: AtomicBool::new(false),
        }
    }

    pub fn id(&self) -> JobId {
        self.id
    }

    /// Bind the activation operand of a deferred tracker (at most
    /// once; [`JobTracker::new`] binds immediately).
    pub fn bind_activation(&self, a: ActOperand) {
        assert!(
            self.a.set(a).is_ok(),
            "activation operand bound more than once"
        );
    }

    /// The activation operand workers extract tiles from.
    pub fn a_operand(&self) -> &ActOperand {
        self.a
            .get()
            .expect("activation operand is bound before execution")
    }

    /// The lowered weight operand (dense or N:M sparse).
    pub fn w_operand(&self) -> &WeightOperand {
        &self.w
    }

    /// The dense weight matrix: a borrow for dense operands, a
    /// once-per-job lazy densification for sparse ones.
    pub fn w_dense(&self) -> &MatI8 {
        match &self.w {
            WeightOperand::Dense(m) => m,
            WeightOperand::Sparse(s) => {
                self.w_dense.get_or_init(|| s.to_dense())
            }
        }
    }

    /// True problem MACs (throughput accounting).
    pub fn macs(&self) -> u64 {
        self.macs
    }

    /// Fold one tile's partial product into the job output.
    pub fn accumulate(&self, tile: &Tile, partial: &MatI32) {
        let mut out = self.out.lock().unwrap();
        tile.accumulate_into(&mut out, partial);
    }

    /// Fold a partial product covering output columns
    /// `n0..n0 + partial.cols` (the grouped-unit path, where the
    /// weight tile is shared and only the column span is carried per
    /// pass). Delegates to the one accumulate primitive on [`MatI32`].
    pub fn accumulate_cols(&self, n0: usize, partial: &MatI32) {
        self.out.lock().unwrap().accumulate_cols(n0, partial);
    }

    /// Write a partial product covering output rows
    /// `m0..m0 + partial.rows` (the conv row-block path on
    /// internally-tiling engines; row spans are disjoint).
    pub fn write_rows(&self, m0: usize, partial: &MatI32) {
        self.out.lock().unwrap().write_rows(m0, partial);
    }

    /// Whether some tile of this job already errored (lets a worker
    /// skip the job's remaining passes in a grouped unit).
    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::Relaxed)
    }

    /// Store a whole-job output (non-tiled engines).
    pub fn set_output(&self, output: MatI32) {
        *self.out.lock().unwrap() = output;
    }

    /// Record that a tile of this job errored.
    pub fn mark_failed(&self) {
        self.failed.store(true, Ordering::Relaxed);
    }

    /// Record `stats` for `done` finished tiles; when these were the
    /// last outstanding tiles, assemble the job-level result.
    /// `slow_mhz` converts aggregate cycles to simulated time.
    pub fn complete_tiles(
        &self,
        done: usize,
        stats: Vec<RunStats>,
        slow_mhz: f64,
    ) -> Completion {
        self.per_tile.lock().unwrap().extend(stats);
        let prev = self.remaining.fetch_sub(done, Ordering::AcqRel);
        debug_assert!(prev >= done, "completed more tiles than tracked");
        if prev != done {
            return Completion::Pending;
        }
        if self.failed.load(Ordering::Relaxed) {
            return Completion::Failed;
        }
        Completion::Done(Box::new(self.assemble(slow_mhz)))
    }

    /// Merge per-tile stats and build the [`JobResult`].
    fn assemble(&self, slow_mhz: f64) -> JobResult {
        let per_tile = std::mem::take(&mut *self.per_tile.lock().unwrap());
        let output =
            std::mem::replace(&mut *self.out.lock().unwrap(), MatI32::zeros(0, 0));
        let stats = match self.sched_rows {
            // Same aggregation as the sequential `run_gemm_tiled` path,
            // so sharded stats stay bit-identical (true MACs replace
            // the padded-tile overcount).
            Some(rows) => aggregate_tile_stats(&per_tile, rows, self.macs),
            // Whole-job units carry one entry; conv row blocks carry
            // one per block and simply sum (disjoint row spans, no
            // shared weight fills to re-schedule).
            None => {
                let mut iter = per_tile.into_iter();
                let first = iter.next().unwrap_or_default();
                iter.fold(first, |acc, s| acc.merged_with(&s))
            }
        };
        let verified = self.reference.as_ref().map(|reference| match reference {
            Reference::Gemm => {
                let a = self
                    .a_operand()
                    .dense()
                    .expect("GEMM-verified jobs carry dense operands");
                output == golden_gemm(a, self.w_dense())
            }
            Reference::ConvDirect { weights } => {
                let p = self
                    .a_operand()
                    .patches()
                    .expect("conv-verified jobs carry patch operands");
                output == conv2d_direct(p.input(), weights, p.shape())
            }
            Reference::SparseDense => {
                let a = self
                    .a_operand()
                    .csr()
                    .expect("sparse-verified jobs carry CSR operands")
                    .to_dense();
                output == golden_gemm(&a, self.w_dense())
            }
            Reference::ModelDirect { model } => {
                let input = self
                    .a_operand()
                    .dense()
                    .expect("model-verified jobs carry the dense input");
                // A graph that fails to compile never reaches a
                // tracker, so the replay can only fail verification,
                // not error.
                golden_eval(model, input)
                    .map(|golden| output == golden)
                    .unwrap_or(false)
            }
        });
        let simulated =
            Duration::from_secs_f64(stats.cycles as f64 / (slow_mhz * 1e6));
        JobResult {
            id: self.id,
            output,
            stats,
            simulated,
            wall: self.submitted.elapsed(),
            verified,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macs_per_kind() {
        let g = Job::Gemm {
            a: MatI8::zeros(4, 8),
            w: MatI8::zeros(8, 2),
        };
        assert_eq!(g.macs(), 64);
        assert_eq!(g.kind(), "gemm");

        let shape = ConvShape {
            in_c: 2,
            in_h: 4,
            in_w: 4,
            out_c: 3,
            k: 3,
            stride: 1,
            pad: 1,
            dilation: 1,
            groups: 1,
        };
        let c = Job::Conv {
            input: vec![0; 32],
            weights: vec![0; 54],
            shape,
        };
        assert_eq!(c.macs(), shape.macs());

        // Sparse MACs are dense-equivalent: skipping work must raise
        // macs/cycle, not shrink the numerator.
        use crate::util::rng::XorShift;
        use crate::workload::sparse::NmPattern;
        let mut rng = XorShift::new(2);
        let s = Job::SparseGemm {
            a: CsrMatI8::random_density(&mut rng, 4, 8, 0.25),
            w: SparseMatI8::random_nm(
                &mut rng,
                8,
                2,
                NmPattern::parse("2:4").unwrap(),
            ),
        };
        assert_eq!(s.macs(), 64);
        assert_eq!(s.kind(), "sparse");
    }

    /// `cost_bytes` is deterministic in the operand shapes — the
    /// admission boundary (Nth accepted, N+1th refused) depends on it.
    #[test]
    fn cost_bytes_tracks_operand_footprint() {
        let g = Job::Gemm {
            a: MatI8::zeros(4, 8),
            w: MatI8::zeros(8, 2),
        };
        assert_eq!(g.cost_bytes(), 4 * 8 + 8 * 2);
        let c = Job::Conv {
            input: vec![0; 32],
            weights: vec![0; 54],
            shape: ConvShape {
                in_c: 2,
                in_h: 4,
                in_w: 4,
                out_c: 3,
                k: 3,
                stride: 1,
                pad: 1,
                dilation: 1,
                groups: 1,
            },
        };
        assert_eq!(c.cost_bytes(), 32 + 54);
        let mut m = crate::model::Model::new(2, 8, false);
        m.layer(
            LayerOp::Gemm {
                w: MatI8::zeros(8, 4),
            },
            &[0],
        );
        m.layer(
            LayerOp::Requant {
                num: 1,
                shift: 4,
                zero_point: 0,
            },
            &[1],
        );
        let j = Job::Model {
            model: m,
            input: MatI8::zeros(2, 8),
        };
        assert_eq!(j.cost_bytes(), 2 * 8 + 8 * 4);
    }
}
