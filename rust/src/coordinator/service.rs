//! Multi-worker matrix-engine service with tile-level sharding.
//!
//! Each worker owns one cycle-accurate engine instance (they are cheap:
//! a few hundred KB of register state) and drains a sharded
//! work-stealing pool of *tile-level* work units ([`super::pool`]).
//! A single large GEMM therefore parallelizes across every worker —
//! its tiles fan out, partial results assemble job-level in
//! [`super::job::JobTracker`] — and mixed job sizes no longer convoy
//! behind the largest job. Std threads + channels keep the binary
//! self-contained and offline.

use super::job::{Completion, Job, JobId, JobResult, JobTracker};
use super::metrics::Metrics;
use super::pool::{Provenance, WorkPool};
use super::scheduler::aggregate_tile_stats;
use super::tiler::{GemmTiler, Tile};
use crate::engines::os::{OsConfig, OsEngine, OsVariant};
use crate::engines::snn::{SnnConfig, SnnEngine, SnnVariant};
use crate::engines::ws::{WsConfig, WsEngine, WsVariant};
use crate::engines::{Engine, EngineError, RunStats};
use crate::workload::conv::{im2col, weights_to_gemm};
use crate::workload::{MatI32, MatI8};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Which engine the workers instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    WsTinyTpu,
    WsLibano,
    WsClbFetch,
    WsDspFetch,
    OsOfficial,
    OsEnhanced,
    SnnFireFly,
    SnnEnhanced,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        Some(match s {
            "ws-tinytpu" => EngineKind::WsTinyTpu,
            "ws-libano" => EngineKind::WsLibano,
            "ws-clb-fetch" => EngineKind::WsClbFetch,
            "ws-dsp-fetch" => EngineKind::WsDspFetch,
            "os-official" => EngineKind::OsOfficial,
            "os-enhanced" => EngineKind::OsEnhanced,
            "snn-firefly" => EngineKind::SnnFireFly,
            "snn-enhanced" => EngineKind::SnnEnhanced,
            _ => return None,
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            EngineKind::WsTinyTpu => "ws-tinytpu",
            EngineKind::WsLibano => "ws-libano",
            EngineKind::WsClbFetch => "ws-clb-fetch",
            EngineKind::WsDspFetch => "ws-dsp-fetch",
            EngineKind::OsOfficial => "os-official",
            EngineKind::OsEnhanced => "os-enhanced",
            EngineKind::SnnFireFly => "snn-firefly",
            EngineKind::SnnEnhanced => "snn-enhanced",
        }
    }

    pub fn all() -> [EngineKind; 8] {
        [
            EngineKind::WsTinyTpu,
            EngineKind::WsLibano,
            EngineKind::WsClbFetch,
            EngineKind::WsDspFetch,
            EngineKind::OsOfficial,
            EngineKind::OsEnhanced,
            EngineKind::SnnFireFly,
            EngineKind::SnnEnhanced,
        ]
    }
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub kind: EngineKind,
    pub workers: usize,
    /// WS array geometry (rows, cols); OS/SNN use their paper configs.
    pub ws_rows: usize,
    pub ws_cols: usize,
    /// Cross-check every output against the golden reference.
    pub verify: bool,
    /// Tiles per work unit (shard width): 1 = finest sharding (best
    /// load balance), larger amortizes queue traffic for tiny tiles.
    pub shard_width: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 14,
            ws_cols: 14,
            verify: true,
            shard_width: 1,
        }
    }
}

impl ServiceConfig {
    pub fn build_engine(&self) -> Box<dyn Engine + Send> {
        match self.kind {
            EngineKind::WsTinyTpu
            | EngineKind::WsLibano
            | EngineKind::WsClbFetch
            | EngineKind::WsDspFetch => {
                let variant = match self.kind {
                    EngineKind::WsTinyTpu => WsVariant::TinyTpu,
                    EngineKind::WsLibano => WsVariant::Libano,
                    EngineKind::WsClbFetch => WsVariant::ClbFetch,
                    _ => WsVariant::DspFetch,
                };
                Box::new(WsEngine::new(WsConfig {
                    variant,
                    rows: self.ws_rows,
                    cols: self.ws_cols,
                    target_mhz: if variant == WsVariant::TinyTpu {
                        400.0
                    } else {
                        666.0
                    },
                    strict_guard: false,
                }))
            }
            EngineKind::OsOfficial => {
                Box::new(OsEngine::new(OsConfig::b1024(OsVariant::Official)))
            }
            EngineKind::OsEnhanced => {
                Box::new(OsEngine::new(OsConfig::b1024(OsVariant::Enhanced)))
            }
            EngineKind::SnnFireFly => {
                Box::new(SnnEngine::new(SnnConfig::paper_32x32(SnnVariant::FireFly)))
            }
            EngineKind::SnnEnhanced => {
                Box::new(SnnEngine::new(SnnConfig::paper_32x32(SnnVariant::Enhanced)))
            }
        }
    }

    /// The tiler matching the engine geometry (WS engines only; OS/SNN
    /// tile internally).
    pub fn tiler(&self) -> Option<GemmTiler> {
        match self.kind {
            EngineKind::WsTinyTpu
            | EngineKind::WsLibano
            | EngineKind::WsClbFetch
            | EngineKind::WsDspFetch => {
                Some(GemmTiler::new(self.ws_rows, self.ws_cols))
            }
            _ => None,
        }
    }
}

/// Execute one GEMM on an engine, tiling when needed. This is the same
/// code path workers use; exposed for examples/benches.
pub fn run_gemm_tiled(
    engine: &mut dyn Engine,
    tiler: Option<&GemmTiler>,
    a: &MatI8,
    w: &MatI8,
) -> Result<(MatI32, RunStats), EngineError> {
    match tiler {
        None => {
            let run = engine.run_gemm(a, w)?;
            Ok((run.output, run.stats))
        }
        Some(tiler) => {
            let tiles = tiler.tiles(a, w);
            let mut out = MatI32::zeros(a.rows, w.cols);
            let mut per_tile = Vec::with_capacity(tiles.len());
            for t in &tiles {
                let run = engine.run_gemm(&t.a, &t.w)?;
                tiler.accumulate(&mut out, t, &run.output);
                per_tile.push(run.stats);
            }
            // Padded-tile MACs overcount; report the true problem size.
            let true_macs = (a.rows * a.cols * w.cols) as u64;
            let stats = aggregate_tile_stats(&per_tile, tiler.rows, true_macs);
            Ok((out, stats))
        }
    }
}

/// One unit of work: a batch of tiles of one job, or the whole job for
/// engines that tile internally.
struct WorkUnit {
    job: Arc<JobTracker>,
    tiles: Option<Vec<Tile>>,
}

/// Lower a [`Job`] to its GEMM operands (conv via im2col).
fn lower(job: Job) -> (MatI8, MatI8) {
    match job {
        Job::Gemm { a, w } => (a, w),
        Job::Conv {
            input,
            weights,
            shape,
        } => (im2col(&input, shape), weights_to_gemm(&weights, shape)),
        Job::Snn { spikes, weights } => (spikes, weights),
    }
}

/// The running service.
pub struct Service {
    pool: Arc<WorkPool<WorkUnit>>,
    results_rx: mpsc::Receiver<JobResult>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: u64,
    cfg: ServiceConfig,
    tiler: Option<GemmTiler>,
}

impl Service {
    /// Spawn the worker pool (one deque shard per worker).
    pub fn start(cfg: ServiceConfig) -> Self {
        let workers_n = cfg.workers.max(1);
        let pool = Arc::new(WorkPool::<WorkUnit>::new(workers_n));
        let (results_tx, results_rx) = mpsc::channel::<JobResult>();
        let metrics = Arc::new(Metrics::new());
        let mut workers = Vec::new();
        for wid in 0..workers_n {
            let pool = Arc::clone(&pool);
            let results_tx = results_tx.clone();
            let metrics = Arc::clone(&metrics);
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                let mut engine = cfg.build_engine();
                let slow_mhz = engine.clock_plan().slow_mhz;
                while let Some((unit, prov)) = pool.pop(wid) {
                    if prov == Provenance::Stolen {
                        metrics.steals.fetch_add(1, Ordering::Relaxed);
                    }
                    let (done, stats) =
                        run_unit(engine.as_mut(), &unit, &metrics);
                    match unit.job.complete_tiles(done, stats, slow_mhz) {
                        Completion::Pending => {}
                        Completion::Done(result) => {
                            metrics.record_completion(
                                unit.job.macs(),
                                result.stats.cycles,
                                result.wall,
                            );
                            let _ = results_tx.send(*result);
                        }
                        Completion::Failed => {
                            metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }));
        }
        let tiler = cfg.tiler();
        Service {
            pool,
            results_rx,
            workers,
            metrics,
            next_id: 0,
            cfg,
            tiler,
        }
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Enqueue a job, sharding it into tile-level work units; returns
    /// its id.
    pub fn submit(&mut self, job: Job) -> JobId {
        let id = JobId(self.next_id);
        self.next_id += 1;
        self.metrics
            .jobs_submitted
            .fetch_add(1, Ordering::Relaxed);
        let macs = job.macs();
        let (a, w) = lower(job);
        match &self.tiler {
            Some(tiler) => {
                let tiles = tiler.tiles(&a, &w);
                // Degenerate problems (zero-area GEMM) still owe one
                // (empty) unit so the job assembles and reports.
                let total = tiles.len().max(1);
                let tracker = Arc::new(JobTracker::new(
                    id,
                    a,
                    w,
                    macs,
                    total,
                    Some(tiler.rows),
                    self.cfg.verify,
                ));
                if tiles.is_empty() {
                    self.pool.push(WorkUnit {
                        job: tracker,
                        tiles: Some(Vec::new()),
                    });
                    return id;
                }
                let width = self.cfg.shard_width.max(1);
                let mut batch = Vec::with_capacity(width);
                for tile in tiles {
                    batch.push(tile);
                    if batch.len() == width {
                        self.pool.push(WorkUnit {
                            job: Arc::clone(&tracker),
                            tiles: Some(std::mem::take(&mut batch)),
                        });
                    }
                }
                if !batch.is_empty() {
                    self.pool.push(WorkUnit {
                        job: tracker,
                        tiles: Some(batch),
                    });
                }
            }
            None => {
                let tracker = Arc::new(JobTracker::new(
                    id,
                    a,
                    w,
                    macs,
                    1,
                    None,
                    self.cfg.verify,
                ));
                self.pool.push(WorkUnit {
                    job: tracker,
                    tiles: None,
                });
            }
        }
        id
    }

    /// Receive one completed result (blocking with timeout).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<JobResult> {
        self.results_rx.recv_timeout(timeout).ok()
    }

    /// Stop workers (queued work drains first) and join.
    pub fn shutdown(self) {
        self.pool.stop();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Execute one work unit on a worker's engine. Returns how many tiles
/// the unit accounted for and their stats (short on failure).
fn run_unit(
    engine: &mut dyn Engine,
    unit: &WorkUnit,
    metrics: &Metrics,
) -> (usize, Vec<RunStats>) {
    match &unit.tiles {
        Some(tiles) => {
            let mut stats = Vec::with_capacity(tiles.len());
            for tile in tiles {
                match engine.run_gemm(&tile.a, &tile.w) {
                    Ok(run) => {
                        unit.job.accumulate(tile, &run.output);
                        stats.push(run.stats);
                        metrics.tiles_executed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        unit.job.mark_failed();
                        break;
                    }
                }
            }
            // Empty units (degenerate problems) still account one slot
            // so the tracker assembles.
            (tiles.len().max(1), stats)
        }
        None => match engine.run_gemm(unit.job.a(), unit.job.w()) {
            Ok(run) => {
                unit.job.set_output(run.output);
                metrics.tiles_executed.fetch_add(1, Ordering::Relaxed);
                (1, vec![run.stats])
            }
            Err(_) => {
                unit.job.mark_failed();
                (1, Vec::new())
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::workload::conv::ConvShape;
    use crate::workload::gemm::golden_gemm;

    #[test]
    fn engine_kind_parse_label_round_trips() {
        for kind in EngineKind::all() {
            assert_eq!(EngineKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(EngineKind::parse("warp-drive"), None);
        assert_eq!(EngineKind::parse(""), None);
        assert_eq!(EngineKind::parse("WS-DSP-FETCH"), None); // case-exact
    }

    #[test]
    fn service_runs_gemm_jobs_verified() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(3);
        let n_jobs = 8;
        for _ in 0..n_jobs {
            let a = MatI8::random_bounded(&mut rng, 4, 13, 63);
            let w = MatI8::random(&mut rng, 13, 9);
            svc.submit(Job::Gemm { a, w });
        }
        let mut ok = 0;
        for _ in 0..n_jobs {
            let r = svc
                .recv_timeout(Duration::from_secs(30))
                .expect("job completes");
            assert_eq!(r.verified, Some(true));
            assert!(r.stats.cycles > 0);
            ok += 1;
        }
        assert_eq!(ok, n_jobs);
        assert!(svc.metrics.summary().contains("8/8"));
        svc.shutdown();
    }

    #[test]
    fn service_runs_conv_jobs() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::OsEnhanced,
            workers: 1,
            ws_rows: 0,
            ws_cols: 0,
            verify: true,
            shard_width: 1,
        });
        let shape = ConvShape {
            in_c: 3,
            in_h: 6,
            in_w: 6,
            out_c: 4,
            k: 3,
            stride: 1,
            pad: 1,
        };
        let mut rng = XorShift::new(9);
        svc.submit(Job::Conv {
            input: rng.i8_vec(shape.in_c * shape.in_h * shape.in_w),
            weights: rng.i8_vec(shape.out_c * shape.in_c * shape.k * shape.k),
            shape,
        });
        let r = svc
            .recv_timeout(Duration::from_secs(30))
            .expect("conv completes");
        assert_eq!(r.verified, Some(true));
        svc.shutdown();
    }

    #[test]
    fn snn_service_handles_spike_jobs() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::SnnEnhanced,
            workers: 1,
            ws_rows: 0,
            ws_cols: 0,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(11);
        let spikes = MatI8::from_fn(8, 32, |_, _| rng.chance(1, 3) as i8);
        let weights = MatI8::random_bounded(&mut rng, 32, 32, 50);
        svc.submit(Job::Snn { spikes, weights });
        let r = svc.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r.verified, Some(true));
        svc.shutdown();
    }

    #[test]
    fn big_gemm_tiles_and_verifies() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 1,
            ws_rows: 14,
            ws_cols: 14,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(5);
        let a = MatI8::random_bounded(&mut rng, 6, 100, 63);
        let w = MatI8::random(&mut rng, 100, 40);
        svc.submit(Job::Gemm { a, w });
        let r = svc.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.stats.macs, 6 * 100 * 40);
        svc.shutdown();
    }

    /// A single job sharded across 4 workers is bit-identical — output
    /// *and* aggregate cycle stats — to the same job on 1 worker.
    #[test]
    fn sharded_single_job_matches_sequential() {
        let mut rng = XorShift::new(13);
        let a = MatI8::random_bounded(&mut rng, 8, 60, 63);
        let w = MatI8::random(&mut rng, 60, 30);
        let run = |workers: usize| {
            let mut svc = Service::start(ServiceConfig {
                kind: EngineKind::WsDspFetch,
                workers,
                ws_rows: 6,
                ws_cols: 6,
                verify: false,
                shard_width: 1,
            });
            svc.submit(Job::Gemm {
                a: a.clone(),
                w: w.clone(),
            });
            let r = svc
                .recv_timeout(Duration::from_secs(60))
                .expect("job completes");
            svc.shutdown();
            r
        };
        let seq = run(1);
        let par = run(4);
        assert_eq!(par.output, seq.output);
        assert_eq!(par.output, golden_gemm(&a, &w));
        assert_eq!(par.stats.cycles, seq.stats.cycles);
        assert_eq!(par.stats.weight_loads, seq.stats.weight_loads);
        assert_eq!(par.stats.macs, 8 * 60 * 30);
    }

    /// The sharded path agrees with the sequential `run_gemm_tiled`
    /// helper, stats included.
    #[test]
    fn sharded_stats_match_run_gemm_tiled() {
        let mut rng = XorShift::new(21);
        let a = MatI8::random_bounded(&mut rng, 5, 40, 63);
        let w = MatI8::random(&mut rng, 40, 20);
        let cfg = ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 3,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 2,
        };
        let mut engine = cfg.build_engine();
        let tiler = cfg.tiler().unwrap();
        let (seq_out, seq_stats) =
            run_gemm_tiled(engine.as_mut(), Some(&tiler), &a, &w).unwrap();

        let mut svc = Service::start(cfg);
        svc.submit(Job::Gemm {
            a: a.clone(),
            w: w.clone(),
        });
        let r = svc.recv_timeout(Duration::from_secs(60)).unwrap();
        svc.shutdown();
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.output, seq_out);
        assert_eq!(r.stats.cycles, seq_stats.cycles);
        assert_eq!(r.stats.weight_stall_cycles, seq_stats.weight_stall_cycles);
        assert_eq!(r.stats.macs, seq_stats.macs);
    }

    /// Mixed job sizes on a sharded pool: everything completes and
    /// verifies (no convoying deadlocks, no lost tiles).
    #[test]
    fn mixed_job_sizes_all_complete() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 4,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 3,
        });
        let mut rng = XorShift::new(31);
        let mut jobs = 0;
        for (m, k, n) in [(2, 6, 6), (8, 50, 24), (1, 1, 1), (4, 30, 7), (16, 12, 12)] {
            let a = MatI8::random_bounded(&mut rng, m, k, 63);
            let w = MatI8::random(&mut rng, k, n);
            svc.submit(Job::Gemm { a, w });
            jobs += 1;
        }
        for _ in 0..jobs {
            let r = svc
                .recv_timeout(Duration::from_secs(60))
                .expect("all jobs complete");
            assert_eq!(r.verified, Some(true));
        }
        svc.shutdown();
    }
}
