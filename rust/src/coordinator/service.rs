//! Multi-worker matrix-engine service: batched, non-blocking
//! submission over tile-level sharding with weight-tile reuse.
//!
//! Each worker owns one cycle-accurate engine instance (they are cheap:
//! a few hundred KB of register state) and drains a sharded
//! work-stealing pool of work units ([`super::pool`]). A unit carries
//! one or more [`FillGroup`]s — tiles (possibly of *different* jobs)
//! that share one stationary weight tile, so the worker issues one
//! `fill` and streams every pass against it
//! ([`Engine::run_gemm_reuse`]). A single large GEMM still fans out
//! across every worker; partial results assemble job-level in
//! [`super::job::JobTracker`]; and [`Service::submit`] is
//! non-blocking — it returns a [`JobHandle`] redeemed against the
//! shared [`CompletionTable`] (`poll`/`wait`/`drain`), so a caller can
//! overlap generation, scheduling and retirement. Std threads keep the
//! binary self-contained and offline.

use super::completion::{CompletionTable, Drained, JobHandle, JobState};
use super::job::{Batch, Completion, Job, JobId, JobResult, JobTracker, Reference};
use super::metrics::Metrics;
use super::models::{
    LayerDone, LayerFailed, ModelSubmit, ModelTable,
};
use super::pool::{Provenance, WorkPool};
use super::scheduler::aggregate_tile_stats;
use super::tiler::{ActOperand, GemmTiler, TileCoord, WeightOperand};
use crate::engines::os::{OsConfig, OsEngine, OsVariant};
use crate::engines::snn::{SnnConfig, SnnEngine, SnnVariant};
use crate::engines::ws::{WsConfig, WsEngine, WsVariant};
use crate::engines::{Engine, EngineError, RunStats};
use crate::exec::ScratchStats;
use crate::workload::conv::{weights_to_gemm, ConvShapeError, PatchSource};
use crate::workload::sparse::SparseFormatError;
use crate::workload::{MatI32, MatI8};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Which engine the workers instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    WsTinyTpu,
    WsLibano,
    WsClbFetch,
    WsDspFetch,
    OsOfficial,
    OsEnhanced,
    SnnFireFly,
    SnnEnhanced,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        Some(match s {
            "ws-tinytpu" => EngineKind::WsTinyTpu,
            "ws-libano" => EngineKind::WsLibano,
            "ws-clb-fetch" => EngineKind::WsClbFetch,
            "ws-dsp-fetch" => EngineKind::WsDspFetch,
            "os-official" => EngineKind::OsOfficial,
            "os-enhanced" => EngineKind::OsEnhanced,
            "snn-firefly" => EngineKind::SnnFireFly,
            "snn-enhanced" => EngineKind::SnnEnhanced,
            _ => return None,
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            EngineKind::WsTinyTpu => "ws-tinytpu",
            EngineKind::WsLibano => "ws-libano",
            EngineKind::WsClbFetch => "ws-clb-fetch",
            EngineKind::WsDspFetch => "ws-dsp-fetch",
            EngineKind::OsOfficial => "os-official",
            EngineKind::OsEnhanced => "os-enhanced",
            EngineKind::SnnFireFly => "snn-firefly",
            EngineKind::SnnEnhanced => "snn-enhanced",
        }
    }

    pub fn all() -> [EngineKind; 8] {
        [
            EngineKind::WsTinyTpu,
            EngineKind::WsLibano,
            EngineKind::WsClbFetch,
            EngineKind::WsDspFetch,
            EngineKind::OsOfficial,
            EngineKind::OsEnhanced,
            EngineKind::SnnFireFly,
            EngineKind::SnnEnhanced,
        ]
    }
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub kind: EngineKind,
    pub workers: usize,
    /// WS array geometry (rows, cols); OS/SNN use their paper configs.
    pub ws_rows: usize,
    pub ws_cols: usize,
    /// Cross-check every output against the golden reference.
    pub verify: bool,
    /// Tiles per work unit (shard width): 1 = finest sharding (best
    /// load balance), larger amortizes queue traffic for tiny tiles.
    pub shard_width: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 14,
            ws_cols: 14,
            verify: true,
            shard_width: 1,
        }
    }
}

impl ServiceConfig {
    pub fn build_engine(&self) -> Box<dyn Engine + Send> {
        match self.kind {
            EngineKind::WsTinyTpu
            | EngineKind::WsLibano
            | EngineKind::WsClbFetch
            | EngineKind::WsDspFetch => {
                let variant = match self.kind {
                    EngineKind::WsTinyTpu => WsVariant::TinyTpu,
                    EngineKind::WsLibano => WsVariant::Libano,
                    EngineKind::WsClbFetch => WsVariant::ClbFetch,
                    _ => WsVariant::DspFetch,
                };
                Box::new(WsEngine::new(WsConfig {
                    variant,
                    rows: self.ws_rows,
                    cols: self.ws_cols,
                    target_mhz: if variant == WsVariant::TinyTpu {
                        400.0
                    } else {
                        666.0
                    },
                    strict_guard: false,
                }))
            }
            EngineKind::OsOfficial => {
                Box::new(OsEngine::new(OsConfig::b1024(OsVariant::Official)))
            }
            EngineKind::OsEnhanced => {
                Box::new(OsEngine::new(OsConfig::b1024(OsVariant::Enhanced)))
            }
            EngineKind::SnnFireFly => {
                Box::new(SnnEngine::new(SnnConfig::paper_32x32(SnnVariant::FireFly)))
            }
            EngineKind::SnnEnhanced => {
                Box::new(SnnEngine::new(SnnConfig::paper_32x32(SnnVariant::Enhanced)))
            }
        }
    }

    /// The tiler matching the engine geometry (WS engines only; OS/SNN
    /// tile internally).
    pub fn tiler(&self) -> Option<GemmTiler> {
        match self.kind {
            EngineKind::WsTinyTpu
            | EngineKind::WsLibano
            | EngineKind::WsClbFetch
            | EngineKind::WsDspFetch => {
                Some(GemmTiler::new(self.ws_rows, self.ws_cols))
            }
            _ => None,
        }
    }
}

/// Execute one GEMM on an engine, tiling when needed (tiles stream
/// lazily — nothing is materialized upfront). This is the same code
/// path workers use; exposed for examples/benches.
pub fn run_gemm_tiled(
    engine: &mut dyn Engine,
    tiler: Option<&GemmTiler>,
    a: &MatI8,
    w: &MatI8,
) -> Result<(MatI32, RunStats), EngineError> {
    match tiler {
        None => {
            let run = engine.run_gemm(a, w)?;
            Ok((run.output, run.stats))
        }
        Some(tiler) => {
            let mut out = MatI32::zeros(a.rows, w.cols);
            let mut per_tile =
                Vec::with_capacity(tiler.tile_count(a.cols, w.cols));
            for t in tiler.tile_iter(a, w) {
                let run = engine.run_gemm(&t.a, &t.w)?;
                tiler.accumulate(&mut out, &t, &run.output);
                per_tile.push(run.stats);
            }
            // Padded-tile MACs overcount; report the true problem size.
            let true_macs = (a.rows * a.cols * w.cols) as u64;
            let stats = aggregate_tile_stats(&per_tile, tiler.rows, true_macs);
            Ok((out, stats))
        }
    }
}

/// One streaming pass of a [`FillGroup`]: which job it belongs to and
/// which tile coordinate it covers. The pass carries **no operand
/// data** — the worker extracts the activation tile lazily from the
/// job's [`ActOperand`] when the pass runs, so neither a large GEMM's
/// tiles nor a conv's im2col patches ever sit materialized in the
/// queue. The weight tile lives once on the group, not per pass.
pub(crate) struct Pass {
    pub(crate) job: Arc<JobTracker>,
    pub(crate) coord: TileCoord,
    /// This pass belongs to a *different layer* of the same model
    /// than the pass that filled the group — the cross-layer reuse
    /// the model scheduler engineered ([`Metrics::inter_layer_fill_reuse`]).
    /// Always `false` for batch grouping.
    pub(crate) cross_layer: bool,
}

/// Tiles — possibly of different jobs — that share one stationary
/// weight tile: the worker fills once and streams every pass
/// ([`Engine::run_gemm_reuse`] for passes after the first).
pub(crate) struct FillGroup {
    pub(crate) w: MatI8,
    pub(crate) passes: Vec<Pass>,
}

/// Output-pixel rows per conv row block on internally-tiling engines:
/// bounds the materialized patch slice to `CONV_ROW_BLOCK × K`
/// elements per in-flight unit (and fans large convs out across the
/// pool).
const CONV_ROW_BLOCK: usize = 64;

/// The row-block spans `(m0, m1)` for a conv job of `m` output pixels
/// — the single source both the tracker's unit count and the pushed
/// `RowBlock` units derive from, so the two can never fall out of
/// sync. `m >= 1` for every validated shape, so the list is never
/// empty.
pub(crate) fn conv_row_blocks(m: usize) -> Vec<(usize, usize)> {
    (0..m)
        .step_by(CONV_ROW_BLOCK)
        .map(|m0| (m0, (m0 + CONV_ROW_BLOCK).min(m)))
        .collect()
}

/// One unit of work.
pub(crate) enum WorkUnit {
    /// Fill-groups executed back to back on one engine (tiler path).
    Groups(Vec<FillGroup>),
    /// The whole job, for engines that tile internally.
    Whole(Arc<JobTracker>),
    /// One row block of a conv job on an internally-tiling engine:
    /// the worker materializes patch rows `m0..m1` from the raw input
    /// and writes the disjoint output row span.
    RowBlock {
        job: Arc<JobTracker>,
        m0: usize,
        m1: usize,
    },
    /// Degenerate zero-tile job: accounts one empty slot so the job
    /// assembles and reports.
    Empty(Arc<JobTracker>),
}

/// Why a job failed to lower to service operands — every variant
/// resolves as a `Failed` handle at submit, never a worker panic.
#[derive(Debug)]
enum LowerError {
    Conv(ConvShapeError),
    Sparse(SparseFormatError),
}

impl From<ConvShapeError> for LowerError {
    fn from(e: ConvShapeError) -> Self {
        LowerError::Conv(e)
    }
}

impl From<SparseFormatError> for LowerError {
    fn from(e: SparseFormatError) -> Self {
        LowerError::Sparse(e)
    }
}

/// Lower a [`Job`] to service operands: `(activation, weights,
/// golden reference when verifying, true MACs)`. Conv stays **lazy** —
/// the operand is a [`PatchSource`] view over the raw NCHW input; the
/// full im2col matrix is never built, here or anywhere downstream.
/// Sparse jobs stay sparse the same way: the CSR activations and N:M
/// weights densify per tile (or not at all) on the worker. A
/// degenerate conv shape (zero stride, kernel larger than the padded
/// input, mis-sized buffers) or a structurally broken sparse operand
/// (e.g. decoded off the wire) is a typed error the submit path
/// resolves as a `Failed` handle instead of letting it panic a worker.
/// With `verify` off the reference is `None`, so a conv job does not
/// drag a dead copy of its raw weights through its lifetime.
#[allow(clippy::type_complexity)]
fn lower(
    job: Job,
    verify: bool,
) -> Result<(ActOperand, WeightOperand, Option<Reference>, u64), LowerError> {
    if let Job::Conv { shape, .. } = &job {
        // Validated up front so `Job::macs` (which derives the conv
        // output extent) is safe below.
        shape.validate()?;
    }
    let macs = job.macs();
    Ok(match job {
        Job::Gemm { a, w } => (
            ActOperand::Dense(a),
            WeightOperand::Dense(w),
            verify.then_some(Reference::Gemm),
            macs,
        ),
        Job::Snn { spikes, weights } => (
            ActOperand::Dense(spikes),
            WeightOperand::Dense(weights),
            verify.then_some(Reference::Gemm),
            macs,
        ),
        Job::Conv {
            input,
            weights,
            shape,
        } => {
            if weights.len() != shape.weight_len() {
                return Err(ConvShapeError::WeightLen {
                    expected: shape.weight_len(),
                    got: weights.len(),
                }
                .into());
            }
            let w = weights_to_gemm(&weights, shape);
            let src = PatchSource::new(input, shape)
                .map_err(LowerError::Conv)?;
            let reference = verify.then(|| Reference::ConvDirect { weights });
            (
                ActOperand::Patches(src),
                WeightOperand::Dense(w),
                reference,
                macs,
            )
        }
        Job::SparseGemm { a, w } => {
            a.validate()?;
            w.validate()?;
            (
                ActOperand::Csr(a),
                WeightOperand::Sparse(w),
                verify.then_some(Reference::SparseDense),
                macs,
            )
        }
        // Model jobs are diverted to the model table before lowering —
        // their layers become individually lowered trackers there.
        Job::Model { .. } => {
            unreachable!("model jobs route through the model table")
        }
    })
}

/// The running service.
pub struct Service {
    pool: Arc<WorkPool<WorkUnit>>,
    completion: Arc<CompletionTable>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    models: Arc<ModelTable>,
    next_id: u64,
    cfg: ServiceConfig,
    tiler: Option<GemmTiler>,
}

impl Service {
    /// Spawn the worker pool (one deque shard per worker).
    pub fn start(cfg: ServiceConfig) -> Self {
        let workers_n = cfg.workers.max(1);
        let pool = Arc::new(WorkPool::<WorkUnit>::new(workers_n));
        let completion = Arc::new(CompletionTable::new());
        let metrics = Arc::new(Metrics::new());
        let models = Arc::new(ModelTable::new());
        let mut workers = Vec::new();
        for wid in 0..workers_n {
            let pool = Arc::clone(&pool);
            let completion = Arc::clone(&completion);
            let metrics = Arc::clone(&metrics);
            let models = Arc::clone(&models);
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                let mut engine = cfg.build_engine();
                let tiler = cfg.tiler();
                let slow_mhz = engine.clock_plan().slow_mhz;
                // Last scratch-arena snapshot folded into the shared
                // metrics (the counters are monotonic, so each unit
                // contributes an exact delta).
                let mut scratch_seen = ScratchStats::default();
                while let Some((unit, prov)) = pool.pop(wid) {
                    if prov == Provenance::Stolen {
                        metrics.steals.fetch_add(1, Ordering::Relaxed);
                    }
                    for outcome in
                        run_unit(engine.as_mut(), tiler.as_ref(), &unit, &metrics)
                    {
                        let id = outcome.job.id();
                        match outcome.job.complete_tiles(
                            outcome.done,
                            outcome.stats,
                            slow_mhz,
                        ) {
                            Completion::Pending => {}
                            // Completions consult the model table
                            // first: a model *layer* goes resident as
                            // a tensor (possibly unblocking gated
                            // units) instead of retiring — only the
                            // model-level result reaches the client.
                            Completion::Done(result) => match models
                                .on_layer_done(id, result, &metrics, slow_mhz)
                            {
                                LayerDone::NotModel(result) => {
                                    metrics.record_completion(
                                        outcome.job.macs(),
                                        result.stats.cycles,
                                        result.wall,
                                    );
                                    completion.complete(*result);
                                }
                                LayerDone::Progress(units) => {
                                    for u in units {
                                        pool.push(u);
                                    }
                                }
                                LayerDone::Finished { result, macs } => {
                                    metrics.record_completion(
                                        macs,
                                        result.stats.cycles,
                                        result.wall,
                                    );
                                    completion.complete(*result);
                                }
                                LayerDone::ModelFailed { model } => {
                                    metrics
                                        .jobs_failed
                                        .fetch_add(1, Ordering::Relaxed);
                                    completion.complete_failed(model);
                                }
                            },
                            Completion::Failed => {
                                match models.on_layer_failed(id, &metrics) {
                                    LayerFailed::NotModel => {
                                        metrics
                                            .jobs_failed
                                            .fetch_add(1, Ordering::Relaxed);
                                        completion.complete_failed(id);
                                    }
                                    LayerFailed::Swallowed(units) => {
                                        for u in units {
                                            pool.push(u);
                                        }
                                    }
                                    LayerFailed::ModelFailed {
                                        model,
                                        release,
                                    } => {
                                        metrics
                                            .jobs_failed
                                            .fetch_add(1, Ordering::Relaxed);
                                        completion.complete_failed(model);
                                        // Poisoned units drain (their
                                        // trackers skip the work) so
                                        // every layer report settles.
                                        for u in release {
                                            pool.push(u);
                                        }
                                    }
                                }
                            }
                        }
                    }
                    let snap = engine.scratch_stats();
                    metrics.record_scratch(&scratch_seen, &snap);
                    scratch_seen = snap;
                }
            }));
        }
        let tiler = cfg.tiler();
        Service {
            pool,
            completion,
            workers,
            metrics,
            models,
            next_id: 0,
            cfg,
            tiler,
        }
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Enqueue one job (a batch of 1); non-blocking.
    pub fn submit(&mut self, job: Job) -> JobHandle {
        self.submit_batch(Batch::from(vec![job]))
            .pop()
            .expect("one handle per submitted job")
    }

    /// Enqueue a batch of jobs in one call; non-blocking. Tiles are
    /// grouped by stationary weight tile across the whole batch, so
    /// jobs sharing weights pay one fill per tile position and stream
    /// the rest. Handles come back in job order; redeem them with
    /// [`Service::poll`] / [`Service::wait`], or retire completions in
    /// arrival order with [`Service::wait_any`] / [`Service::drain`].
    pub fn submit_batch(&mut self, batch: Batch) -> Vec<JobHandle> {
        let jobs = batch.jobs;
        let total_jobs = jobs.len();
        let mut handles = Vec::with_capacity(total_jobs);

        // Lower every job and create its tracker. Nothing is
        // registered or enqueued until the whole batch lowers, and a
        // malformed job — degenerate conv shape, mis-sized buffer,
        // inner-dimension mismatch — never panics the submitter or a
        // worker: it is collected here and resolves below as a
        // `Failed` handle.
        let mut trackers: Vec<Arc<JobTracker>> = Vec::with_capacity(total_jobs);
        let mut rejected: Vec<JobId> = Vec::new();
        // Model submissions accepted this batch: their unblocked units
        // (or, for all-glue models, their finished results) are held
        // back until the handles are registered below.
        let mut model_work: Vec<ModelSubmit> = Vec::new();
        let tiler = self.tiler;
        for job in jobs {
            let id = JobId(self.next_id);
            self.next_id += 1;
            handles.push(JobHandle { id });
            if let Job::Model { model, input } = job {
                // Graph compilation happens at submit: a cyclic,
                // dangling, ill-typed or ill-shaped graph resolves as
                // a typed `Failed` handle, exactly like a malformed
                // conv shape — never a worker panic.
                match self.models.submit(
                    id,
                    model,
                    input,
                    self.cfg.verify,
                    tiler.as_ref(),
                    &mut self.next_id,
                    &self.metrics,
                ) {
                    Ok(submit) => model_work.push(submit),
                    Err(_) => rejected.push(id),
                }
                continue;
            }
            let (a, w, reference, macs) = match lower(job, self.cfg.verify) {
                Ok(lowered) => lowered,
                Err(_) => {
                    rejected.push(id);
                    continue;
                }
            };
            if a.cols() != w.rows() {
                // Inner-dimension mismatch: grouping uses the
                // operand's K, so letting this through would truncate
                // or index out of bounds later. Reject it like any
                // other malformed job — uniformly across engine kinds
                // — instead of panicking the submitting thread.
                rejected.push(id);
                continue;
            }
            let (total, sched_rows) = match &tiler {
                Some(t) => {
                    // Sparse weights: all-zero tiles are dropped here,
                    // before anything is enqueued — the tracker only
                    // ever expects the live tiles. Dense weights skip
                    // the scan (`tile_live` is unconditionally true).
                    let live = if w.sparse().is_some() {
                        let m = a.rows() as u64;
                        let mut live = 0usize;
                        let mut skipped = 0u64;
                        let mut macs_skipped = 0u64;
                        for c in t.coords(a.cols(), w.cols()) {
                            if w.tile_live(c) {
                                live += 1;
                            } else {
                                skipped += 1;
                                macs_skipped += m
                                    * (c.k1 - c.k0) as u64
                                    * (c.n1 - c.n0) as u64;
                            }
                        }
                        self.metrics
                            .tiles_skipped
                            .fetch_add(skipped, Ordering::Relaxed);
                        self.metrics
                            .macs_skipped
                            .fetch_add(macs_skipped, Ordering::Relaxed);
                        live
                    } else {
                        t.tile_count(a.cols(), w.cols())
                    };
                    (live.max(1), Some(t.rows))
                }
                None => {
                    // Internally-tiling engines take conv jobs as row
                    // blocks (lazy patch extraction per block), CSR
                    // activations as row blocks with empty windows
                    // dropped, and everything else whole.
                    let units = match &a {
                        ActOperand::Patches(p) => {
                            conv_row_blocks(p.rows()).len()
                        }
                        ActOperand::Dense(_) => 1,
                        ActOperand::Csr(c) => {
                            let (k, n) = (c.cols() as u64, w.cols() as u64);
                            let mut live = 0usize;
                            for (m0, m1) in conv_row_blocks(c.rows()) {
                                if c.rows_nonempty(m0, m1) {
                                    live += 1;
                                } else {
                                    self.metrics
                                        .tiles_skipped
                                        .fetch_add(1, Ordering::Relaxed);
                                    self.metrics.macs_skipped.fetch_add(
                                        (m1 - m0) as u64 * k * n,
                                        Ordering::Relaxed,
                                    );
                                }
                            }
                            live.max(1)
                        }
                    };
                    (units, None)
                }
            };
            trackers.push(Arc::new(JobTracker::new(
                id, a, w, reference, macs, total, sched_rows,
            )));
        }

        // The batch is lowered: account it and register completions
        // before the first unit (or rejection) becomes visible.
        self.metrics
            .batches_submitted
            .fetch_add(1, Ordering::Relaxed);
        self.metrics
            .jobs_submitted
            .fetch_add(total_jobs as u64, Ordering::Relaxed);
        self.completion.register(&handles);
        for id in &rejected {
            self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
            self.completion.complete_failed(*id);
        }
        for submit in model_work {
            match submit {
                ModelSubmit::Scheduled(units) => {
                    for u in units {
                        self.pool.push(u);
                    }
                }
                ModelSubmit::Finished { result, macs } => {
                    // All-glue model: it finished during the submit
                    // cascade, so retire it here (registration above
                    // makes the handle redeemable).
                    self.metrics.record_completion(
                        macs,
                        result.stats.cycles,
                        result.wall,
                    );
                    self.completion.complete(*result);
                }
            }
        }

        let Some(tiler) = tiler else {
            for tracker in trackers {
                match tracker.a_operand() {
                    ActOperand::Patches(p) => {
                        // Validation guarantees at least one output
                        // pixel, so this pushes at least one block —
                        // exactly as many as the tracker was created
                        // expecting.
                        for (m0, m1) in conv_row_blocks(p.rows()) {
                            self.pool.push(WorkUnit::RowBlock {
                                job: Arc::clone(&tracker),
                                m0,
                                m1,
                            });
                        }
                    }
                    ActOperand::Csr(c) => {
                        // Empty row windows were already counted as
                        // skips during planning; push only the live
                        // ones (an all-empty operand degenerates to
                        // one Empty slot, matching the tracker).
                        let mut pushed = 0usize;
                        for (m0, m1) in conv_row_blocks(c.rows()) {
                            if c.rows_nonempty(m0, m1) {
                                pushed += 1;
                                self.pool.push(WorkUnit::RowBlock {
                                    job: Arc::clone(&tracker),
                                    m0,
                                    m1,
                                });
                            }
                        }
                        if pushed == 0 {
                            self.pool.push(WorkUnit::Empty(Arc::clone(
                                &tracker,
                            )));
                        }
                    }
                    ActOperand::Dense(_) => {
                        self.pool.push(WorkUnit::Whole(Arc::clone(&tracker)));
                    }
                }
            }
            return handles;
        };

        // Group tiles by (weight fingerprint, coord); the fingerprint
        // only routes — group membership is confirmed by bit-exact
        // weight-tile equality, so a collision can never mix weights.
        // A batch of one has no cross-job reuse to find, so it skips
        // the fingerprint + map entirely (the hot single-submit path).
        let mut groups: Vec<FillGroup> = Vec::new();
        let mut index: HashMap<(u64, TileCoord), Vec<usize>> = HashMap::new();
        let solo = trackers.len() == 1;
        for tracker in &trackers {
            let (k_dim, w) = (tracker.a_operand().cols(), tracker.w_operand());
            if tiler.tile_count(k_dim, w.cols()) == 0
                || !tiler.coords(k_dim, w.cols()).any(|c| w.tile_live(c))
            {
                // Degenerate zero-area job — or a sparse job whose
                // weight tiles are all zero: one empty slot assembles
                // it (a correct all-zero output, no cycles charged).
                self.pool.push(WorkUnit::Empty(Arc::clone(tracker)));
                continue;
            }
            let wfp = if solo { 0 } else { fingerprint_operand(w) };
            // Dead weight tiles were counted as skips during planning;
            // only the live coords become passes.
            for coord in
                tiler.coords(k_dim, w.cols()).filter(|c| w.tile_live(*c))
            {
                let w_tile = tiler.w_tile_of(w, coord);
                let gi = if solo {
                    // Every coord of a single job is a fresh group.
                    groups.push(FillGroup {
                        w: w_tile,
                        passes: Vec::new(),
                    });
                    groups.len() - 1
                } else {
                    let candidates = index.entry((wfp, coord)).or_default();
                    candidates
                        .iter()
                        .copied()
                        .find(|&g| groups[g].w == w_tile)
                        .unwrap_or_else(|| {
                            groups.push(FillGroup {
                                w: w_tile,
                                passes: Vec::new(),
                            });
                            candidates.push(groups.len() - 1);
                            groups.len() - 1
                        })
                };
                groups[gi].passes.push(Pass {
                    job: Arc::clone(tracker),
                    coord,
                    cross_layer: false,
                });
            }
        }

        // Pack groups into units of up to `shard_width` passes. Groups
        // are never split — splitting would forfeit the reuse — so a
        // group larger than the width gets a unit of its own.
        let width = self.cfg.shard_width.max(1);
        let mut unit: Vec<FillGroup> = Vec::new();
        let mut in_unit = 0usize;
        for group in groups {
            let len = group.passes.len();
            if in_unit > 0 && in_unit + len > width {
                self.pool.push(WorkUnit::Groups(std::mem::take(&mut unit)));
                in_unit = 0;
            }
            unit.push(group);
            in_unit += len;
            if in_unit >= width {
                self.pool.push(WorkUnit::Groups(std::mem::take(&mut unit)));
                in_unit = 0;
            }
        }
        if !unit.is_empty() {
            self.pool.push(WorkUnit::Groups(unit));
        }
        handles
    }

    /// Non-blocking check of one handle.
    pub fn poll(&self, handle: JobHandle) -> JobState {
        self.completion.poll(handle)
    }

    /// Block (up to `timeout`) for one specific job.
    pub fn wait(&self, handle: JobHandle, timeout: Duration) -> JobState {
        self.completion.wait(handle, timeout)
    }

    /// Take the next completion in arrival order (blocking with
    /// timeout).
    pub fn wait_any(&self, timeout: Duration) -> Option<JobResult> {
        self.completion.wait_any(timeout)
    }

    /// Block until everything submitted has retired (or `timeout`) and
    /// take all unclaimed results in completion order, plus the ids of
    /// unobserved failed jobs (cleared from the table — a drain-only
    /// retirement loop leaks nothing).
    pub fn drain(&self, timeout: Duration) -> Drained {
        self.completion.drain(timeout)
    }

    /// Abandon jobs whose owner is gone — disconnected mid-model or
    /// shed by admission control. Model runs poison their layer
    /// trackers and free resident arena intermediates immediately;
    /// their flushed units re-enter the pool so every in-flight
    /// report still settles. Non-model ids are no-ops here (the
    /// completion table owns their retirement).
    pub fn abandon_jobs(&self, ids: &[JobId]) {
        for u in self.models.abandon(ids, &self.metrics) {
            self.pool.push(u);
        }
    }

    /// Jobs submitted but not yet retired.
    pub fn pending(&self) -> usize {
        self.completion.pending()
    }

    /// Jobs that retired as failed (engine errors) and were not yet
    /// observed through a handle. `wait_any` never surfaces these, so
    /// retirement loops must consult this to avoid waiting on them.
    pub fn failed_count(&self) -> usize {
        self.completion.failed_count()
    }

    /// The shared completion table, for front-ends that redeem handles
    /// without holding the service — the wire protocol's
    /// [`crate::proto::Frontend`] waits/polls/drains through this Arc
    /// so a blocked `Wait` from one client never serializes another
    /// client's `Submit`.
    pub fn completion_table(&self) -> Arc<CompletionTable> {
        Arc::clone(&self.completion)
    }

    /// Stop workers (queued work drains first) and join.
    pub fn shutdown(self) {
        self.pool.stop();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// FNV-1a over the weight matrix (dims + bytes): the grouping key's
/// routing half. Collisions are checked against, never trusted.
fn fingerprint(w: &MatI8) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |b: u64| {
        for byte in b.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(w.rows as u64);
    eat(w.cols as u64);
    for &v in &w.data {
        h ^= v as u8 as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// [`fingerprint`] over either weight form. Sparse operands hash their
/// compressed slot buffers directly (no densification); like the dense
/// fingerprint, this only routes — group membership is confirmed by
/// bit-exact weight-*tile* equality downstream.
pub(crate) fn fingerprint_operand(w: &WeightOperand) -> u64 {
    match w {
        WeightOperand::Dense(m) => fingerprint(m),
        WeightOperand::Sparse(s) => {
            let mut h: u64 = 0xcbf2_9ce4_8422_2325;
            let mut eat_byte = |b: u8| {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            };
            let nm = s.nm();
            for dim in [s.rows(), s.cols(), nm.n, nm.m] {
                for byte in (dim as u64).to_le_bytes() {
                    eat_byte(byte);
                }
            }
            let (idx, val) = s.slots();
            for &b in idx {
                eat_byte(b);
            }
            for &v in val {
                eat_byte(v as u8);
            }
            h
        }
    }
}

/// Per-job outcome of one work unit: how many tile slots it accounted
/// for and their stats (short on failure).
struct UnitOutcome {
    job: Arc<JobTracker>,
    done: usize,
    stats: Vec<RunStats>,
}

/// Execute one work unit on a worker's engine. Grouped units fill each
/// stationary tile once and stream every pass against it — each pass's
/// activation tile (a dense slice, or im2col patches for conv) is
/// extracted **here**, on the worker, so peak operand memory is one
/// tile per worker; outcomes come back per job so multi-job units
/// retire each job exactly once.
fn run_unit(
    engine: &mut dyn Engine,
    tiler: Option<&GemmTiler>,
    unit: &WorkUnit,
    metrics: &Metrics,
) -> Vec<UnitOutcome> {
    match unit {
        WorkUnit::Groups(groups) => {
            let tiler =
                tiler.expect("grouped units only exist on tiler engines");
            let mut outcomes: Vec<UnitOutcome> = Vec::new();
            let slot = |outcomes: &mut Vec<UnitOutcome>,
                        job: &Arc<JobTracker>|
             -> usize {
                match outcomes.iter().position(|o| o.job.id() == job.id()) {
                    Some(i) => i,
                    None => {
                        outcomes.push(UnitOutcome {
                            job: Arc::clone(job),
                            done: 0,
                            stats: Vec::new(),
                        });
                        outcomes.len() - 1
                    }
                }
            };
            for group in groups {
                // Reuse only once a pass actually loaded the group's
                // weights: if the first pass was skipped (its job
                // poisoned) or errored, the next one fills instead of
                // streaming against stale array contents.
                let mut filled = false;
                for pass in &group.passes {
                    let si = slot(&mut outcomes, &pass.job);
                    outcomes[si].done += 1;
                    if pass.job.is_failed() {
                        continue; // job already poisoned; skip the work
                    }
                    let a = tiler.a_tile_of(pass.job.a_operand(), pass.coord);
                    let run = if !filled {
                        engine.run_gemm(&a, &group.w)
                    } else {
                        if pass.cross_layer {
                            // A streamed pass from a *different layer*
                            // of the same model — the fill this pass
                            // avoided is inter-layer reuse.
                            metrics
                                .inter_layer_fill_reuse
                                .fetch_add(1, Ordering::Relaxed);
                        }
                        engine.run_gemm_reuse(&a, &group.w)
                    };
                    match run {
                        Ok(run) => {
                            filled = true;
                            pass.job.accumulate_cols(pass.coord.n0, &run.output);
                            metrics
                                .tiles_executed
                                .fetch_add(1, Ordering::Relaxed);
                            metrics.fills_issued.fetch_add(
                                run.stats.weight_loads,
                                Ordering::Relaxed,
                            );
                            metrics.fills_avoided.fetch_add(
                                run.stats.fills_avoided,
                                Ordering::Relaxed,
                            );
                            metrics.fill_cycles_saved.fetch_add(
                                run.stats.fill_cycles_saved,
                                Ordering::Relaxed,
                            );
                            outcomes[si].stats.push(run.stats);
                        }
                        Err(_) => {
                            pass.job.mark_failed();
                        }
                    }
                }
            }
            outcomes
        }
        WorkUnit::Whole(job) => {
            if job.is_failed() {
                // A poisoned model layer: its activation may never
                // have been bound, so skip the work and just account
                // the slot (the job assembles as Failed).
                return vec![UnitOutcome {
                    job: Arc::clone(job),
                    done: 1,
                    stats: Vec::new(),
                }];
            }
            let a = job
                .a_operand()
                .dense()
                .expect("whole-job units carry dense operands");
            match engine.run_gemm(a, job.w_dense()) {
                Ok(run) => {
                    job.set_output(run.output);
                    metrics.tiles_executed.fetch_add(1, Ordering::Relaxed);
                    vec![UnitOutcome {
                        job: Arc::clone(job),
                        done: 1,
                        stats: vec![run.stats],
                    }]
                }
                Err(_) => {
                    job.mark_failed();
                    vec![UnitOutcome {
                        job: Arc::clone(job),
                        done: 1,
                        stats: Vec::new(),
                    }]
                }
            }
        }
        WorkUnit::RowBlock { job, m0, m1 } => {
            let outcome = |stats: Vec<RunStats>| {
                vec![UnitOutcome {
                    job: Arc::clone(job),
                    done: 1,
                    stats,
                }]
            };
            if job.is_failed() {
                // Another block already errored; account the slot so
                // the job still assembles (as Failed).
                return outcome(Vec::new());
            }
            // Lazy extraction: only this block's rows exist (im2col
            // patches, or densified CSR rows), and only while the unit
            // runs.
            let a = match job.a_operand() {
                ActOperand::Patches(src) => src.extract_rows(*m0, *m1),
                ActOperand::Csr(c) => c.extract_rows(*m0, *m1),
                ActOperand::Dense(_) => {
                    unreachable!("row-block units carry lazy operands")
                }
            };
            match engine.run_gemm(&a, job.w_dense()) {
                Ok(run) => {
                    job.write_rows(*m0, &run.output);
                    metrics.tiles_executed.fetch_add(1, Ordering::Relaxed);
                    outcome(vec![run.stats])
                }
                Err(_) => {
                    job.mark_failed();
                    outcome(Vec::new())
                }
            }
        }
        // Degenerate problems still account one slot so the tracker
        // assembles.
        WorkUnit::Empty(job) => vec![UnitOutcome {
            job: Arc::clone(job),
            done: 1,
            stats: Vec::new(),
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::workload::conv::ConvShape;
    use crate::workload::gemm::golden_gemm;

    #[test]
    fn engine_kind_parse_label_round_trips() {
        for kind in EngineKind::all() {
            assert_eq!(EngineKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(EngineKind::parse("warp-drive"), None);
        assert_eq!(EngineKind::parse(""), None);
        assert_eq!(EngineKind::parse("WS-DSP-FETCH"), None); // case-exact
    }

    #[test]
    fn service_runs_gemm_jobs_verified() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(3);
        let n_jobs = 8;
        for _ in 0..n_jobs {
            let a = MatI8::random_bounded(&mut rng, 4, 13, 63);
            let w = MatI8::random(&mut rng, 13, 9);
            svc.submit(Job::Gemm { a, w });
        }
        let mut ok = 0;
        for _ in 0..n_jobs {
            let r = svc
                .wait_any(Duration::from_secs(30))
                .expect("job completes");
            assert_eq!(r.verified, Some(true));
            assert!(r.stats.cycles > 0);
            ok += 1;
        }
        assert_eq!(ok, n_jobs);
        assert!(svc.metrics.summary().contains("8/8"));
        svc.shutdown();
    }

    #[test]
    fn service_runs_conv_jobs() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::OsEnhanced,
            workers: 1,
            ws_rows: 0,
            ws_cols: 0,
            verify: true,
            shard_width: 1,
        });
        let shape = ConvShape {
            in_c: 3,
            in_h: 6,
            in_w: 6,
            out_c: 4,
            k: 3,
            stride: 1,
            pad: 1,
            dilation: 1,
            groups: 1,
        };
        let mut rng = XorShift::new(9);
        svc.submit(Job::Conv {
            input: rng.i8_vec(shape.in_c * shape.in_h * shape.in_w),
            weights: rng.i8_vec(shape.out_c * shape.in_c * shape.k * shape.k),
            shape,
        });
        let r = svc
            .wait_any(Duration::from_secs(30))
            .expect("conv completes");
        assert_eq!(r.verified, Some(true));
        svc.shutdown();
    }

    /// Conv on a WS (tiler) engine: the lazy per-tile patch extraction
    /// matches both the direct convolution (service-side `verified`)
    /// and the eager im2col GEMM, tiles grouped like any GEMM.
    #[test]
    fn conv_on_tiler_engine_matches_eager_im2col() {
        use crate::workload::conv::{conv2d_direct, im2col, weights_to_gemm};
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 6,
            ws_cols: 5,
            verify: true,
            shard_width: 1,
        });
        let shape = ConvShape {
            in_c: 3,
            in_h: 7,
            in_w: 5,
            out_c: 6,
            k: 3,
            stride: 2,
            pad: 1,
            dilation: 1,
            groups: 1,
        };
        let mut rng = XorShift::new(17);
        let input: Vec<i8> =
            (0..shape.input_len()).map(|_| rng.i8_in(-63, 63)).collect();
        let weights: Vec<i8> = (0..shape.weight_len())
            .map(|_| rng.i8_in(-63, 63))
            .collect();
        let h = svc.submit(Job::Conv {
            input: input.clone(),
            weights: weights.clone(),
            shape,
        });
        let r = svc
            .wait(h, Duration::from_secs(60))
            .into_result()
            .expect("conv completes");
        assert_eq!(r.verified, Some(true));
        let eager = golden_gemm(
            &im2col(&input, shape),
            &weights_to_gemm(&weights, shape),
        );
        assert_eq!(r.output, eager);
        assert_eq!(r.output, conv2d_direct(&input, &weights, shape));
        assert_eq!(r.stats.macs, shape.macs());
        svc.shutdown();
    }

    /// A GEMM whose inner dimensions disagree resolves as `Failed`
    /// uniformly — on tiler engines too, where it used to panic the
    /// submitting thread.
    #[test]
    fn mismatched_gemm_resolves_failed_on_tiler_engines() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 1,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        });
        let h = svc.submit(Job::Gemm {
            a: MatI8::zeros(4, 8),
            w: MatI8::zeros(7, 2),
        });
        assert!(matches!(
            svc.wait(h, Duration::from_secs(30)),
            JobState::Failed
        ));
        // The service still serves valid jobs afterwards.
        let mut rng = XorShift::new(51);
        let a = MatI8::random_bounded(&mut rng, 3, 8, 63);
        let w = MatI8::random(&mut rng, 8, 4);
        let h = svc.submit(Job::Gemm { a, w });
        let r = svc
            .wait(h, Duration::from_secs(60))
            .into_result()
            .expect("valid job completes after a rejected one");
        assert_eq!(r.verified, Some(true));
        svc.shutdown();
    }

    /// Degenerate conv shapes resolve as `Failed` at submit — no
    /// worker panic, no leaked completion state — and the service
    /// keeps serving afterwards.
    #[test]
    fn invalid_conv_shapes_resolve_failed_without_poisoning() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 1,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        });
        let good = ConvShape {
            in_c: 2,
            in_h: 5,
            in_w: 5,
            out_c: 3,
            k: 3,
            stride: 1,
            pad: 1,
            dilation: 1,
            groups: 1,
        };
        let mut rng = XorShift::new(29);
        let mk_job = |rng: &mut XorShift, shape: ConvShape| Job::Conv {
            input: (0..shape.input_len()).map(|_| rng.i8_in(-63, 63)).collect(),
            weights: (0..shape.weight_len())
                .map(|_| rng.i8_in(-63, 63))
                .collect(),
            shape,
        };
        let zero_stride = ConvShape { stride: 0, ..good };
        let oversize_k = ConvShape { k: 9, ..good };
        let mut batch = Batch::new();
        batch.push(Job::Conv {
            input: vec![0; good.input_len()],
            weights: vec![0; good.weight_len()],
            shape: zero_stride,
        });
        batch.push(mk_job(&mut rng, good));
        batch.push(Job::Conv {
            input: vec![0; oversize_k.input_len()],
            weights: vec![0; oversize_k.weight_len()],
            shape: oversize_k,
        });
        batch.push(Job::Conv {
            input: vec![0; 3], // wrong input length
            weights: vec![0; good.weight_len()],
            shape: good,
        });
        let handles = svc.submit_batch(batch);
        assert_eq!(handles.len(), 4);
        assert!(matches!(
            svc.wait(handles[0], Duration::from_secs(30)),
            JobState::Failed
        ));
        let ok = svc
            .wait(handles[1], Duration::from_secs(60))
            .into_result()
            .expect("valid job completes");
        assert_eq!(ok.verified, Some(true));
        assert!(matches!(
            svc.wait(handles[2], Duration::from_secs(30)),
            JobState::Failed
        ));
        assert!(matches!(
            svc.wait(handles[3], Duration::from_secs(30)),
            JobState::Failed
        ));
        // Observing the failures consumed them — nothing leaks.
        assert_eq!(svc.failed_count(), 0);
        assert_eq!(svc.pending(), 0);
        // The pool is not poisoned: a follow-up job still runs.
        let h = svc.submit(mk_job(&mut rng, good));
        let r = svc
            .wait(h, Duration::from_secs(60))
            .into_result()
            .expect("service still serves after rejected jobs");
        assert_eq!(r.verified, Some(true));
        // Unobserved failures retire through drain, which clears them.
        svc.submit(Job::Conv {
            input: vec![0; good.input_len()],
            weights: vec![0; good.weight_len()],
            shape: zero_stride,
        });
        let drained = svc.drain(Duration::from_secs(30));
        assert!(drained.completed.is_empty());
        assert_eq!(drained.failed.len(), 1);
        assert_eq!(svc.failed_count(), 0);
        svc.shutdown();
    }

    #[test]
    fn snn_service_handles_spike_jobs() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::SnnEnhanced,
            workers: 1,
            ws_rows: 0,
            ws_cols: 0,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(11);
        let spikes = MatI8::from_fn(8, 32, |_, _| rng.chance(1, 3) as i8);
        let weights = MatI8::random_bounded(&mut rng, 32, 32, 50);
        svc.submit(Job::Snn { spikes, weights });
        let r = svc.wait_any(Duration::from_secs(30)).unwrap();
        assert_eq!(r.verified, Some(true));
        svc.shutdown();
    }

    #[test]
    fn big_gemm_tiles_and_verifies() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 1,
            ws_rows: 14,
            ws_cols: 14,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(5);
        let a = MatI8::random_bounded(&mut rng, 6, 100, 63);
        let w = MatI8::random(&mut rng, 100, 40);
        svc.submit(Job::Gemm { a, w });
        let r = svc.wait_any(Duration::from_secs(60)).unwrap();
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.stats.macs, 6 * 100 * 40);
        svc.shutdown();
    }

    /// A single job sharded across 4 workers is bit-identical — output
    /// *and* aggregate cycle stats — to the same job on 1 worker.
    #[test]
    fn sharded_single_job_matches_sequential() {
        let mut rng = XorShift::new(13);
        let a = MatI8::random_bounded(&mut rng, 8, 60, 63);
        let w = MatI8::random(&mut rng, 60, 30);
        let run = |workers: usize| {
            let mut svc = Service::start(ServiceConfig {
                kind: EngineKind::WsDspFetch,
                workers,
                ws_rows: 6,
                ws_cols: 6,
                verify: false,
                shard_width: 1,
            });
            svc.submit(Job::Gemm {
                a: a.clone(),
                w: w.clone(),
            });
            let r = svc
                .wait_any(Duration::from_secs(60))
                .expect("job completes");
            svc.shutdown();
            r
        };
        let seq = run(1);
        let par = run(4);
        assert_eq!(par.output, seq.output);
        assert_eq!(par.output, golden_gemm(&a, &w));
        assert_eq!(par.stats.cycles, seq.stats.cycles);
        assert_eq!(par.stats.weight_loads, seq.stats.weight_loads);
        assert_eq!(par.stats.macs, 8 * 60 * 30);
    }

    /// The sharded path agrees with the sequential `run_gemm_tiled`
    /// helper, stats included.
    #[test]
    fn sharded_stats_match_run_gemm_tiled() {
        let mut rng = XorShift::new(21);
        let a = MatI8::random_bounded(&mut rng, 5, 40, 63);
        let w = MatI8::random(&mut rng, 40, 20);
        let cfg = ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 3,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 2,
        };
        let mut engine = cfg.build_engine();
        let tiler = cfg.tiler().unwrap();
        let (seq_out, seq_stats) =
            run_gemm_tiled(engine.as_mut(), Some(&tiler), &a, &w).unwrap();

        let mut svc = Service::start(cfg);
        svc.submit(Job::Gemm {
            a: a.clone(),
            w: w.clone(),
        });
        let r = svc.wait_any(Duration::from_secs(60)).unwrap();
        svc.shutdown();
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.output, seq_out);
        assert_eq!(r.stats.cycles, seq_stats.cycles);
        assert_eq!(r.stats.weight_stall_cycles, seq_stats.weight_stall_cycles);
        assert_eq!(r.stats.macs, seq_stats.macs);
    }

    /// A batch of jobs sharing one weight matrix: outputs bit-exact vs
    /// golden, every fill after the first per tile position avoided,
    /// and total cycles strictly below the same jobs submitted singly.
    #[test]
    fn shared_weight_batch_amortizes_fills() {
        let mut rng = XorShift::new(41);
        let (m, k, n) = (8, 12, 10);
        let w = MatI8::random(&mut rng, k, n);
        let acts: Vec<MatI8> = (0..4)
            .map(|_| MatI8::random_bounded(&mut rng, m, k, 63))
            .collect();
        let cfg = ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        };
        let tiles_per_job =
            cfg.tiler().unwrap().tile_count(k, n) as u64;

        // Batched: one submit_batch call.
        let mut svc = Service::start(cfg.clone());
        let batch: Batch = acts
            .iter()
            .map(|a| Job::Gemm {
                a: a.clone(),
                w: w.clone(),
            })
            .collect();
        let handles = svc.submit_batch(batch);
        assert_eq!(handles.len(), acts.len());
        let results = svc.drain(Duration::from_secs(120)).completed;
        assert_eq!(results.len(), acts.len());
        let mut batched_cycles = 0u64;
        for r in &results {
            assert_eq!(r.verified, Some(true));
            let a = &acts[r.id.0 as usize];
            assert_eq!(r.output, golden_gemm(a, &w));
            batched_cycles += r.stats.cycles;
        }
        let issued = svc.metrics.fills_issued.load(Ordering::Relaxed);
        let avoided = svc.metrics.fills_avoided.load(Ordering::Relaxed);
        assert_eq!(issued, tiles_per_job);
        assert_eq!(avoided, tiles_per_job * (acts.len() as u64 - 1));
        assert!(svc.metrics.fill_cycles_saved.load(Ordering::Relaxed) > 0);
        svc.shutdown();

        // The same jobs submitted one at a time: no reuse, more cycles.
        let mut svc = Service::start(cfg);
        for a in &acts {
            svc.submit(Job::Gemm {
                a: a.clone(),
                w: w.clone(),
            });
        }
        let single: Vec<JobResult> =
            svc.drain(Duration::from_secs(120)).completed;
        let single_cycles: u64 =
            single.iter().map(|r| r.stats.cycles).sum();
        assert_eq!(
            svc.metrics.fills_avoided.load(Ordering::Relaxed),
            0
        );
        // Outputs are bit-identical either way.
        for r in &single {
            assert_eq!(r.output, golden_gemm(&acts[r.id.0 as usize], &w));
        }
        assert!(
            batched_cycles < single_cycles,
            "batched {batched_cycles} !< single {single_cycles}"
        );
        svc.shutdown();
    }

    /// Workers fold their engines' scratch-arena telemetry into the
    /// shared metrics: leases accumulate, repeat runs hit the pool,
    /// and the snapshot JSON carries the arena keys.
    #[test]
    fn scratch_telemetry_reaches_metrics() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 1,
            ws_rows: 6,
            ws_cols: 6,
            verify: false,
            shard_width: 1,
        });
        let mut rng = XorShift::new(61);
        for _ in 0..4 {
            let a = MatI8::random_bounded(&mut rng, 4, 6, 63);
            let w = MatI8::random(&mut rng, 6, 4);
            svc.submit(Job::Gemm { a, w });
        }
        let results = svc.drain(Duration::from_secs(60)).completed;
        assert_eq!(results.len(), 4);
        let leases = svc.metrics.scratch_leases.load(Ordering::Relaxed);
        let hits = svc.metrics.scratch_reuse_hits.load(Ordering::Relaxed);
        assert!(leases > 0, "column banks + feed buffers lease per run");
        // Runs after the first reuse the pooled feed buffers.
        assert!(hits > 0, "repeat runs must hit the pool");
        assert!(
            svc.metrics.scratch_high_water_bytes.load(Ordering::Relaxed) > 0
        );
        let ratio = svc.metrics.scratch_reuse_ratio();
        assert!(ratio > 0.0 && ratio <= 1.0);
        let snap = svc.metrics.snapshot_json();
        assert_eq!(
            snap.get("scratch_leases").unwrap().as_i64(),
            Some(leases as i64)
        );
        svc.shutdown();
    }

    /// JobHandle lifecycle: Pending before completion, Done exactly
    /// once, wait() blocks until ready, drain() returns the rest.
    #[test]
    fn handles_poll_wait_drain() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 1,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(43);
        let mut handles = Vec::new();
        for _ in 0..3 {
            let a = MatI8::random_bounded(&mut rng, 4, 6, 63);
            let w = MatI8::random(&mut rng, 6, 4);
            handles.push(svc.submit(Job::Gemm { a, w }));
        }
        // Targeted wait on the last handle.
        let state = svc.wait(handles[2], Duration::from_secs(60));
        let r = state.into_result().expect("job 2 completes");
        assert_eq!(r.id, handles[2].id);
        assert_eq!(r.verified, Some(true));
        // Taken: redeeming again reports Pending-but-gone.
        assert!(matches!(svc.poll(handles[2]), JobState::Pending));
        // Drain retires the remaining two.
        let rest = svc.drain(Duration::from_secs(60)).completed;
        assert_eq!(rest.len(), 2);
        assert_eq!(svc.pending(), 0);
        svc.shutdown();
    }

    /// Batching never changes results for engines that tile
    /// internally (whole-job units, no grouping).
    #[test]
    fn whole_job_engines_accept_batches() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::OsEnhanced,
            workers: 2,
            ws_rows: 0,
            ws_cols: 0,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(47);
        let w = MatI8::random_bounded(&mut rng, 16, 8, 50);
        let batch: Batch = (0..3)
            .map(|_| Job::Gemm {
                a: MatI8::random_bounded(&mut rng, 4, 16, 63),
                w: w.clone(),
            })
            .collect();
        let handles = svc.submit_batch(batch);
        assert_eq!(handles.len(), 3);
        let results = svc.drain(Duration::from_secs(120)).completed;
        assert_eq!(results.len(), 3);
        for r in &results {
            assert_eq!(r.verified, Some(true));
        }
        svc.shutdown();
    }

    /// Mixed job sizes on a sharded pool: everything completes and
    /// verifies (no convoying deadlocks, no lost tiles).
    #[test]
    fn mixed_job_sizes_all_complete() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 4,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 3,
        });
        let mut rng = XorShift::new(31);
        let mut jobs = 0;
        for (m, k, n) in [(2, 6, 6), (8, 50, 24), (1, 1, 1), (4, 30, 7), (16, 12, 12)] {
            let a = MatI8::random_bounded(&mut rng, m, k, 63);
            let w = MatI8::random(&mut rng, k, n);
            svc.submit(Job::Gemm { a, w });
            jobs += 1;
        }
        for _ in 0..jobs {
            let r = svc
                .wait_any(Duration::from_secs(60))
                .expect("all jobs complete");
            assert_eq!(r.verified, Some(true));
        }
        svc.shutdown();
    }
}
