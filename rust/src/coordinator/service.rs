//! Multi-worker matrix-engine service: batched, non-blocking
//! submission over tile-level sharding with weight-tile reuse.
//!
//! Each worker owns one cycle-accurate engine instance (they are cheap:
//! a few hundred KB of register state) and drains a sharded
//! work-stealing pool of work units ([`super::pool`]). A unit carries
//! one or more [`FillGroup`]s — tiles (possibly of *different* jobs)
//! that share one stationary weight tile, so the worker issues one
//! `fill` and streams every pass against it
//! ([`Engine::run_gemm_reuse`]). A single large GEMM still fans out
//! across every worker; partial results assemble job-level in
//! [`super::job::JobTracker`]; and [`Service::submit`] is
//! non-blocking — it returns a [`JobHandle`] redeemed against the
//! shared [`CompletionTable`] (`poll`/`wait`/`drain`), so a caller can
//! overlap generation, scheduling and retirement. Std threads keep the
//! binary self-contained and offline.

use super::completion::{CompletionTable, JobHandle, JobState};
use super::job::{Batch, Completion, Job, JobId, JobResult, JobTracker};
use super::metrics::Metrics;
use super::pool::{Provenance, WorkPool};
use super::scheduler::aggregate_tile_stats;
use super::tiler::{GemmTiler, TileCoord};
use crate::engines::os::{OsConfig, OsEngine, OsVariant};
use crate::engines::snn::{SnnConfig, SnnEngine, SnnVariant};
use crate::engines::ws::{WsConfig, WsEngine, WsVariant};
use crate::engines::{Engine, EngineError, RunStats};
use crate::workload::conv::{im2col, weights_to_gemm};
use crate::workload::{MatI32, MatI8};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Which engine the workers instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    WsTinyTpu,
    WsLibano,
    WsClbFetch,
    WsDspFetch,
    OsOfficial,
    OsEnhanced,
    SnnFireFly,
    SnnEnhanced,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        Some(match s {
            "ws-tinytpu" => EngineKind::WsTinyTpu,
            "ws-libano" => EngineKind::WsLibano,
            "ws-clb-fetch" => EngineKind::WsClbFetch,
            "ws-dsp-fetch" => EngineKind::WsDspFetch,
            "os-official" => EngineKind::OsOfficial,
            "os-enhanced" => EngineKind::OsEnhanced,
            "snn-firefly" => EngineKind::SnnFireFly,
            "snn-enhanced" => EngineKind::SnnEnhanced,
            _ => return None,
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            EngineKind::WsTinyTpu => "ws-tinytpu",
            EngineKind::WsLibano => "ws-libano",
            EngineKind::WsClbFetch => "ws-clb-fetch",
            EngineKind::WsDspFetch => "ws-dsp-fetch",
            EngineKind::OsOfficial => "os-official",
            EngineKind::OsEnhanced => "os-enhanced",
            EngineKind::SnnFireFly => "snn-firefly",
            EngineKind::SnnEnhanced => "snn-enhanced",
        }
    }

    pub fn all() -> [EngineKind; 8] {
        [
            EngineKind::WsTinyTpu,
            EngineKind::WsLibano,
            EngineKind::WsClbFetch,
            EngineKind::WsDspFetch,
            EngineKind::OsOfficial,
            EngineKind::OsEnhanced,
            EngineKind::SnnFireFly,
            EngineKind::SnnEnhanced,
        ]
    }
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub kind: EngineKind,
    pub workers: usize,
    /// WS array geometry (rows, cols); OS/SNN use their paper configs.
    pub ws_rows: usize,
    pub ws_cols: usize,
    /// Cross-check every output against the golden reference.
    pub verify: bool,
    /// Tiles per work unit (shard width): 1 = finest sharding (best
    /// load balance), larger amortizes queue traffic for tiny tiles.
    pub shard_width: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 14,
            ws_cols: 14,
            verify: true,
            shard_width: 1,
        }
    }
}

impl ServiceConfig {
    pub fn build_engine(&self) -> Box<dyn Engine + Send> {
        match self.kind {
            EngineKind::WsTinyTpu
            | EngineKind::WsLibano
            | EngineKind::WsClbFetch
            | EngineKind::WsDspFetch => {
                let variant = match self.kind {
                    EngineKind::WsTinyTpu => WsVariant::TinyTpu,
                    EngineKind::WsLibano => WsVariant::Libano,
                    EngineKind::WsClbFetch => WsVariant::ClbFetch,
                    _ => WsVariant::DspFetch,
                };
                Box::new(WsEngine::new(WsConfig {
                    variant,
                    rows: self.ws_rows,
                    cols: self.ws_cols,
                    target_mhz: if variant == WsVariant::TinyTpu {
                        400.0
                    } else {
                        666.0
                    },
                    strict_guard: false,
                }))
            }
            EngineKind::OsOfficial => {
                Box::new(OsEngine::new(OsConfig::b1024(OsVariant::Official)))
            }
            EngineKind::OsEnhanced => {
                Box::new(OsEngine::new(OsConfig::b1024(OsVariant::Enhanced)))
            }
            EngineKind::SnnFireFly => {
                Box::new(SnnEngine::new(SnnConfig::paper_32x32(SnnVariant::FireFly)))
            }
            EngineKind::SnnEnhanced => {
                Box::new(SnnEngine::new(SnnConfig::paper_32x32(SnnVariant::Enhanced)))
            }
        }
    }

    /// The tiler matching the engine geometry (WS engines only; OS/SNN
    /// tile internally).
    pub fn tiler(&self) -> Option<GemmTiler> {
        match self.kind {
            EngineKind::WsTinyTpu
            | EngineKind::WsLibano
            | EngineKind::WsClbFetch
            | EngineKind::WsDspFetch => {
                Some(GemmTiler::new(self.ws_rows, self.ws_cols))
            }
            _ => None,
        }
    }
}

/// Execute one GEMM on an engine, tiling when needed (tiles stream
/// lazily — nothing is materialized upfront). This is the same code
/// path workers use; exposed for examples/benches.
pub fn run_gemm_tiled(
    engine: &mut dyn Engine,
    tiler: Option<&GemmTiler>,
    a: &MatI8,
    w: &MatI8,
) -> Result<(MatI32, RunStats), EngineError> {
    match tiler {
        None => {
            let run = engine.run_gemm(a, w)?;
            Ok((run.output, run.stats))
        }
        Some(tiler) => {
            let mut out = MatI32::zeros(a.rows, w.cols);
            let mut per_tile =
                Vec::with_capacity(tiler.tile_count(a.cols, w.cols));
            for t in tiler.tile_iter(a, w) {
                let run = engine.run_gemm(&t.a, &t.w)?;
                tiler.accumulate(&mut out, &t, &run.output);
                per_tile.push(run.stats);
            }
            // Padded-tile MACs overcount; report the true problem size.
            let true_macs = (a.rows * a.cols * w.cols) as u64;
            let stats = aggregate_tile_stats(&per_tile, tiler.rows, true_macs);
            Ok((out, stats))
        }
    }
}

/// One streaming pass of a [`FillGroup`]: which job it belongs to,
/// which output columns it covers, and its activation tile. The weight
/// tile lives once on the group, not per pass.
struct Pass {
    job: Arc<JobTracker>,
    n0: usize,
    a: MatI8,
}

/// Tiles — possibly of different jobs — that share one stationary
/// weight tile: the worker fills once and streams every pass
/// ([`Engine::run_gemm_reuse`] for passes after the first).
struct FillGroup {
    w: MatI8,
    passes: Vec<Pass>,
}

/// One unit of work.
enum WorkUnit {
    /// Fill-groups executed back to back on one engine (tiler path).
    Groups(Vec<FillGroup>),
    /// The whole job, for engines that tile internally.
    Whole(Arc<JobTracker>),
    /// Degenerate zero-tile job: accounts one empty slot so the job
    /// assembles and reports.
    Empty(Arc<JobTracker>),
}

/// Lower a [`Job`] to its GEMM operands (conv via im2col).
fn lower(job: Job) -> (MatI8, MatI8) {
    match job {
        Job::Gemm { a, w } => (a, w),
        Job::Conv {
            input,
            weights,
            shape,
        } => (im2col(&input, shape), weights_to_gemm(&weights, shape)),
        Job::Snn { spikes, weights } => (spikes, weights),
    }
}

/// The running service.
pub struct Service {
    pool: Arc<WorkPool<WorkUnit>>,
    completion: Arc<CompletionTable>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: u64,
    cfg: ServiceConfig,
    tiler: Option<GemmTiler>,
}

impl Service {
    /// Spawn the worker pool (one deque shard per worker).
    pub fn start(cfg: ServiceConfig) -> Self {
        let workers_n = cfg.workers.max(1);
        let pool = Arc::new(WorkPool::<WorkUnit>::new(workers_n));
        let completion = Arc::new(CompletionTable::new());
        let metrics = Arc::new(Metrics::new());
        let mut workers = Vec::new();
        for wid in 0..workers_n {
            let pool = Arc::clone(&pool);
            let completion = Arc::clone(&completion);
            let metrics = Arc::clone(&metrics);
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                let mut engine = cfg.build_engine();
                let slow_mhz = engine.clock_plan().slow_mhz;
                while let Some((unit, prov)) = pool.pop(wid) {
                    if prov == Provenance::Stolen {
                        metrics.steals.fetch_add(1, Ordering::Relaxed);
                    }
                    for outcome in run_unit(engine.as_mut(), &unit, &metrics) {
                        let id = outcome.job.id();
                        match outcome.job.complete_tiles(
                            outcome.done,
                            outcome.stats,
                            slow_mhz,
                        ) {
                            Completion::Pending => {}
                            Completion::Done(result) => {
                                metrics.record_completion(
                                    outcome.job.macs(),
                                    result.stats.cycles,
                                    result.wall,
                                );
                                completion.complete(*result);
                            }
                            Completion::Failed => {
                                metrics
                                    .jobs_failed
                                    .fetch_add(1, Ordering::Relaxed);
                                completion.complete_failed(id);
                            }
                        }
                    }
                }
            }));
        }
        let tiler = cfg.tiler();
        Service {
            pool,
            completion,
            workers,
            metrics,
            next_id: 0,
            cfg,
            tiler,
        }
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Enqueue one job (a batch of 1); non-blocking.
    pub fn submit(&mut self, job: Job) -> JobHandle {
        self.submit_batch(Batch::from(vec![job]))
            .pop()
            .expect("one handle per submitted job")
    }

    /// Enqueue a batch of jobs in one call; non-blocking. Tiles are
    /// grouped by stationary weight tile across the whole batch, so
    /// jobs sharing weights pay one fill per tile position and stream
    /// the rest. Handles come back in job order; redeem them with
    /// [`Service::poll`] / [`Service::wait`], or retire completions in
    /// arrival order with [`Service::wait_any`] / [`Service::drain`].
    pub fn submit_batch(&mut self, batch: Batch) -> Vec<JobHandle> {
        let jobs = batch.jobs;
        let mut handles = Vec::with_capacity(jobs.len());

        // Lower every job and create its tracker. Nothing is
        // registered or enqueued until the whole batch validates, so a
        // shape panic here cannot leave the completion table counting
        // jobs that will never run.
        let mut trackers: Vec<Arc<JobTracker>> = Vec::with_capacity(jobs.len());
        let tiler = self.tiler;
        for job in jobs {
            let id = JobId(self.next_id);
            self.next_id += 1;
            handles.push(JobHandle { id });
            let macs = job.macs();
            let (a, w) = lower(job);
            let (total, sched_rows) = match &tiler {
                Some(t) => {
                    // Fail fast like the tiling path always has —
                    // grouping uses a.cols as K, so a mismatch would
                    // otherwise truncate or index out of bounds later.
                    assert_eq!(a.cols, w.rows, "inner dimensions must agree");
                    (t.tile_count(a.cols, w.cols).max(1), Some(t.rows))
                }
                None => (1, None),
            };
            trackers.push(Arc::new(JobTracker::new(
                id,
                a,
                w,
                macs,
                total,
                sched_rows,
                self.cfg.verify,
            )));
        }

        // The batch is valid: account it and register completions
        // before the first unit becomes visible to workers.
        self.metrics
            .batches_submitted
            .fetch_add(1, Ordering::Relaxed);
        self.metrics
            .jobs_submitted
            .fetch_add(trackers.len() as u64, Ordering::Relaxed);
        self.completion.register(trackers.len());

        let Some(tiler) = tiler else {
            // Engines that tile internally take whole jobs.
            for tracker in trackers {
                self.pool.push(WorkUnit::Whole(tracker));
            }
            return handles;
        };

        // Group tiles by (weight fingerprint, coord); the fingerprint
        // only routes — group membership is confirmed by bit-exact
        // weight-tile equality, so a collision can never mix weights.
        // A batch of one has no cross-job reuse to find, so it skips
        // the fingerprint + map entirely (the hot single-submit path).
        let mut groups: Vec<FillGroup> = Vec::new();
        let mut index: HashMap<(u64, TileCoord), Vec<usize>> = HashMap::new();
        let solo = trackers.len() == 1;
        for tracker in &trackers {
            let (a, w) = (tracker.a(), tracker.w());
            if tiler.tile_count(a.cols, w.cols) == 0 {
                // Degenerate zero-area job: one empty slot assembles it.
                self.pool.push(WorkUnit::Empty(Arc::clone(tracker)));
                continue;
            }
            let wfp = if solo { 0 } else { fingerprint(w) };
            for coord in tiler.coords(a.cols, w.cols) {
                let w_tile = tiler.w_tile(w, coord);
                let gi = if solo {
                    // Every coord of a single job is a fresh group.
                    groups.push(FillGroup {
                        w: w_tile,
                        passes: Vec::new(),
                    });
                    groups.len() - 1
                } else {
                    let candidates = index.entry((wfp, coord)).or_default();
                    candidates
                        .iter()
                        .copied()
                        .find(|&g| groups[g].w == w_tile)
                        .unwrap_or_else(|| {
                            groups.push(FillGroup {
                                w: w_tile,
                                passes: Vec::new(),
                            });
                            candidates.push(groups.len() - 1);
                            groups.len() - 1
                        })
                };
                groups[gi].passes.push(Pass {
                    job: Arc::clone(tracker),
                    n0: coord.n0,
                    a: tiler.a_tile(a, coord),
                });
            }
        }

        // Pack groups into units of up to `shard_width` passes. Groups
        // are never split — splitting would forfeit the reuse — so a
        // group larger than the width gets a unit of its own.
        let width = self.cfg.shard_width.max(1);
        let mut unit: Vec<FillGroup> = Vec::new();
        let mut in_unit = 0usize;
        for group in groups {
            let len = group.passes.len();
            if in_unit > 0 && in_unit + len > width {
                self.pool.push(WorkUnit::Groups(std::mem::take(&mut unit)));
                in_unit = 0;
            }
            unit.push(group);
            in_unit += len;
            if in_unit >= width {
                self.pool.push(WorkUnit::Groups(std::mem::take(&mut unit)));
                in_unit = 0;
            }
        }
        if !unit.is_empty() {
            self.pool.push(WorkUnit::Groups(unit));
        }
        handles
    }

    /// Non-blocking check of one handle.
    pub fn poll(&self, handle: JobHandle) -> JobState {
        self.completion.poll(handle)
    }

    /// Block (up to `timeout`) for one specific job.
    pub fn wait(&self, handle: JobHandle, timeout: Duration) -> JobState {
        self.completion.wait(handle, timeout)
    }

    /// Take the next completion in arrival order (blocking with
    /// timeout).
    pub fn wait_any(&self, timeout: Duration) -> Option<JobResult> {
        self.completion.wait_any(timeout)
    }

    /// Block until everything submitted has retired (or `timeout`) and
    /// take all unclaimed results in completion order.
    pub fn drain(&self, timeout: Duration) -> Vec<JobResult> {
        self.completion.drain(timeout)
    }

    /// Jobs submitted but not yet retired.
    pub fn pending(&self) -> usize {
        self.completion.pending()
    }

    /// Jobs that retired as failed (engine errors) and were not yet
    /// observed through a handle. `wait_any` never surfaces these, so
    /// retirement loops must consult this to avoid waiting on them.
    pub fn failed_count(&self) -> usize {
        self.completion.failed_count()
    }

    /// Receive one completed result (blocking with timeout). Alias of
    /// [`Service::wait_any`], kept for the pre-batch call sites.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<JobResult> {
        self.wait_any(timeout)
    }

    /// Stop workers (queued work drains first) and join.
    pub fn shutdown(self) {
        self.pool.stop();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// FNV-1a over the weight matrix (dims + bytes): the grouping key's
/// routing half. Collisions are checked against, never trusted.
fn fingerprint(w: &MatI8) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |b: u64| {
        for byte in b.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(w.rows as u64);
    eat(w.cols as u64);
    for &v in &w.data {
        h ^= v as u8 as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Per-job outcome of one work unit: how many tile slots it accounted
/// for and their stats (short on failure).
struct UnitOutcome {
    job: Arc<JobTracker>,
    done: usize,
    stats: Vec<RunStats>,
}

/// Execute one work unit on a worker's engine. Grouped units fill each
/// stationary tile once and stream every pass against it; outcomes
/// come back per job so multi-job units retire each job exactly once.
fn run_unit(
    engine: &mut dyn Engine,
    unit: &WorkUnit,
    metrics: &Metrics,
) -> Vec<UnitOutcome> {
    match unit {
        WorkUnit::Groups(groups) => {
            let mut outcomes: Vec<UnitOutcome> = Vec::new();
            let slot = |outcomes: &mut Vec<UnitOutcome>,
                        job: &Arc<JobTracker>|
             -> usize {
                match outcomes.iter().position(|o| o.job.id() == job.id()) {
                    Some(i) => i,
                    None => {
                        outcomes.push(UnitOutcome {
                            job: Arc::clone(job),
                            done: 0,
                            stats: Vec::new(),
                        });
                        outcomes.len() - 1
                    }
                }
            };
            for group in groups {
                for (i, pass) in group.passes.iter().enumerate() {
                    let si = slot(&mut outcomes, &pass.job);
                    outcomes[si].done += 1;
                    if pass.job.is_failed() {
                        continue; // job already poisoned; skip the work
                    }
                    let run = if i == 0 {
                        engine.run_gemm(&pass.a, &group.w)
                    } else {
                        engine.run_gemm_reuse(&pass.a, &group.w)
                    };
                    match run {
                        Ok(run) => {
                            pass.job.accumulate_cols(pass.n0, &run.output);
                            metrics
                                .tiles_executed
                                .fetch_add(1, Ordering::Relaxed);
                            metrics.fills_issued.fetch_add(
                                run.stats.weight_loads,
                                Ordering::Relaxed,
                            );
                            metrics.fills_avoided.fetch_add(
                                run.stats.fills_avoided,
                                Ordering::Relaxed,
                            );
                            metrics.fill_cycles_saved.fetch_add(
                                run.stats.fill_cycles_saved,
                                Ordering::Relaxed,
                            );
                            outcomes[si].stats.push(run.stats);
                        }
                        Err(_) => {
                            pass.job.mark_failed();
                        }
                    }
                }
            }
            outcomes
        }
        WorkUnit::Whole(job) => match engine.run_gemm(job.a(), job.w()) {
            Ok(run) => {
                job.set_output(run.output);
                metrics.tiles_executed.fetch_add(1, Ordering::Relaxed);
                vec![UnitOutcome {
                    job: Arc::clone(job),
                    done: 1,
                    stats: vec![run.stats],
                }]
            }
            Err(_) => {
                job.mark_failed();
                vec![UnitOutcome {
                    job: Arc::clone(job),
                    done: 1,
                    stats: Vec::new(),
                }]
            }
        },
        // Degenerate problems still account one slot so the tracker
        // assembles.
        WorkUnit::Empty(job) => vec![UnitOutcome {
            job: Arc::clone(job),
            done: 1,
            stats: Vec::new(),
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::workload::conv::ConvShape;
    use crate::workload::gemm::golden_gemm;

    #[test]
    fn engine_kind_parse_label_round_trips() {
        for kind in EngineKind::all() {
            assert_eq!(EngineKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(EngineKind::parse("warp-drive"), None);
        assert_eq!(EngineKind::parse(""), None);
        assert_eq!(EngineKind::parse("WS-DSP-FETCH"), None); // case-exact
    }

    #[test]
    fn service_runs_gemm_jobs_verified() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(3);
        let n_jobs = 8;
        for _ in 0..n_jobs {
            let a = MatI8::random_bounded(&mut rng, 4, 13, 63);
            let w = MatI8::random(&mut rng, 13, 9);
            svc.submit(Job::Gemm { a, w });
        }
        let mut ok = 0;
        for _ in 0..n_jobs {
            let r = svc
                .recv_timeout(Duration::from_secs(30))
                .expect("job completes");
            assert_eq!(r.verified, Some(true));
            assert!(r.stats.cycles > 0);
            ok += 1;
        }
        assert_eq!(ok, n_jobs);
        assert!(svc.metrics.summary().contains("8/8"));
        svc.shutdown();
    }

    #[test]
    fn service_runs_conv_jobs() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::OsEnhanced,
            workers: 1,
            ws_rows: 0,
            ws_cols: 0,
            verify: true,
            shard_width: 1,
        });
        let shape = ConvShape {
            in_c: 3,
            in_h: 6,
            in_w: 6,
            out_c: 4,
            k: 3,
            stride: 1,
            pad: 1,
        };
        let mut rng = XorShift::new(9);
        svc.submit(Job::Conv {
            input: rng.i8_vec(shape.in_c * shape.in_h * shape.in_w),
            weights: rng.i8_vec(shape.out_c * shape.in_c * shape.k * shape.k),
            shape,
        });
        let r = svc
            .recv_timeout(Duration::from_secs(30))
            .expect("conv completes");
        assert_eq!(r.verified, Some(true));
        svc.shutdown();
    }

    #[test]
    fn snn_service_handles_spike_jobs() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::SnnEnhanced,
            workers: 1,
            ws_rows: 0,
            ws_cols: 0,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(11);
        let spikes = MatI8::from_fn(8, 32, |_, _| rng.chance(1, 3) as i8);
        let weights = MatI8::random_bounded(&mut rng, 32, 32, 50);
        svc.submit(Job::Snn { spikes, weights });
        let r = svc.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r.verified, Some(true));
        svc.shutdown();
    }

    #[test]
    fn big_gemm_tiles_and_verifies() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 1,
            ws_rows: 14,
            ws_cols: 14,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(5);
        let a = MatI8::random_bounded(&mut rng, 6, 100, 63);
        let w = MatI8::random(&mut rng, 100, 40);
        svc.submit(Job::Gemm { a, w });
        let r = svc.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.stats.macs, 6 * 100 * 40);
        svc.shutdown();
    }

    /// A single job sharded across 4 workers is bit-identical — output
    /// *and* aggregate cycle stats — to the same job on 1 worker.
    #[test]
    fn sharded_single_job_matches_sequential() {
        let mut rng = XorShift::new(13);
        let a = MatI8::random_bounded(&mut rng, 8, 60, 63);
        let w = MatI8::random(&mut rng, 60, 30);
        let run = |workers: usize| {
            let mut svc = Service::start(ServiceConfig {
                kind: EngineKind::WsDspFetch,
                workers,
                ws_rows: 6,
                ws_cols: 6,
                verify: false,
                shard_width: 1,
            });
            svc.submit(Job::Gemm {
                a: a.clone(),
                w: w.clone(),
            });
            let r = svc
                .recv_timeout(Duration::from_secs(60))
                .expect("job completes");
            svc.shutdown();
            r
        };
        let seq = run(1);
        let par = run(4);
        assert_eq!(par.output, seq.output);
        assert_eq!(par.output, golden_gemm(&a, &w));
        assert_eq!(par.stats.cycles, seq.stats.cycles);
        assert_eq!(par.stats.weight_loads, seq.stats.weight_loads);
        assert_eq!(par.stats.macs, 8 * 60 * 30);
    }

    /// The sharded path agrees with the sequential `run_gemm_tiled`
    /// helper, stats included.
    #[test]
    fn sharded_stats_match_run_gemm_tiled() {
        let mut rng = XorShift::new(21);
        let a = MatI8::random_bounded(&mut rng, 5, 40, 63);
        let w = MatI8::random(&mut rng, 40, 20);
        let cfg = ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 3,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 2,
        };
        let mut engine = cfg.build_engine();
        let tiler = cfg.tiler().unwrap();
        let (seq_out, seq_stats) =
            run_gemm_tiled(engine.as_mut(), Some(&tiler), &a, &w).unwrap();

        let mut svc = Service::start(cfg);
        svc.submit(Job::Gemm {
            a: a.clone(),
            w: w.clone(),
        });
        let r = svc.recv_timeout(Duration::from_secs(60)).unwrap();
        svc.shutdown();
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.output, seq_out);
        assert_eq!(r.stats.cycles, seq_stats.cycles);
        assert_eq!(r.stats.weight_stall_cycles, seq_stats.weight_stall_cycles);
        assert_eq!(r.stats.macs, seq_stats.macs);
    }

    /// A batch of jobs sharing one weight matrix: outputs bit-exact vs
    /// golden, every fill after the first per tile position avoided,
    /// and total cycles strictly below the same jobs submitted singly.
    #[test]
    fn shared_weight_batch_amortizes_fills() {
        let mut rng = XorShift::new(41);
        let (m, k, n) = (8, 12, 10);
        let w = MatI8::random(&mut rng, k, n);
        let acts: Vec<MatI8> = (0..4)
            .map(|_| MatI8::random_bounded(&mut rng, m, k, 63))
            .collect();
        let cfg = ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        };
        let tiles_per_job =
            cfg.tiler().unwrap().tile_count(k, n) as u64;

        // Batched: one submit_batch call.
        let mut svc = Service::start(cfg.clone());
        let batch: Batch = acts
            .iter()
            .map(|a| Job::Gemm {
                a: a.clone(),
                w: w.clone(),
            })
            .collect();
        let handles = svc.submit_batch(batch);
        assert_eq!(handles.len(), acts.len());
        let results = svc.drain(Duration::from_secs(120));
        assert_eq!(results.len(), acts.len());
        let mut batched_cycles = 0u64;
        for r in &results {
            assert_eq!(r.verified, Some(true));
            let a = &acts[r.id.0 as usize];
            assert_eq!(r.output, golden_gemm(a, &w));
            batched_cycles += r.stats.cycles;
        }
        let issued = svc.metrics.fills_issued.load(Ordering::Relaxed);
        let avoided = svc.metrics.fills_avoided.load(Ordering::Relaxed);
        assert_eq!(issued, tiles_per_job);
        assert_eq!(avoided, tiles_per_job * (acts.len() as u64 - 1));
        assert!(svc.metrics.fill_cycles_saved.load(Ordering::Relaxed) > 0);
        svc.shutdown();

        // The same jobs submitted one at a time: no reuse, more cycles.
        let mut svc = Service::start(cfg);
        for a in &acts {
            svc.submit(Job::Gemm {
                a: a.clone(),
                w: w.clone(),
            });
        }
        let single: Vec<JobResult> = svc.drain(Duration::from_secs(120));
        let single_cycles: u64 =
            single.iter().map(|r| r.stats.cycles).sum();
        assert_eq!(
            svc.metrics.fills_avoided.load(Ordering::Relaxed),
            0
        );
        // Outputs are bit-identical either way.
        for r in &single {
            assert_eq!(r.output, golden_gemm(&acts[r.id.0 as usize], &w));
        }
        assert!(
            batched_cycles < single_cycles,
            "batched {batched_cycles} !< single {single_cycles}"
        );
        svc.shutdown();
    }

    /// JobHandle lifecycle: Pending before completion, Done exactly
    /// once, wait() blocks until ready, drain() returns the rest.
    #[test]
    fn handles_poll_wait_drain() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 1,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(43);
        let mut handles = Vec::new();
        for _ in 0..3 {
            let a = MatI8::random_bounded(&mut rng, 4, 6, 63);
            let w = MatI8::random(&mut rng, 6, 4);
            handles.push(svc.submit(Job::Gemm { a, w }));
        }
        // Targeted wait on the last handle.
        let state = svc.wait(handles[2], Duration::from_secs(60));
        let r = state.into_result().expect("job 2 completes");
        assert_eq!(r.id, handles[2].id);
        assert_eq!(r.verified, Some(true));
        // Taken: redeeming again reports Pending-but-gone.
        assert!(matches!(svc.poll(handles[2]), JobState::Pending));
        // Drain retires the remaining two.
        let rest = svc.drain(Duration::from_secs(60));
        assert_eq!(rest.len(), 2);
        assert_eq!(svc.pending(), 0);
        svc.shutdown();
    }

    /// Batching never changes results for engines that tile
    /// internally (whole-job units, no grouping).
    #[test]
    fn whole_job_engines_accept_batches() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::OsEnhanced,
            workers: 2,
            ws_rows: 0,
            ws_cols: 0,
            verify: true,
            shard_width: 1,
        });
        let mut rng = XorShift::new(47);
        let w = MatI8::random_bounded(&mut rng, 16, 8, 50);
        let batch: Batch = (0..3)
            .map(|_| Job::Gemm {
                a: MatI8::random_bounded(&mut rng, 4, 16, 63),
                w: w.clone(),
            })
            .collect();
        let handles = svc.submit_batch(batch);
        assert_eq!(handles.len(), 3);
        let results = svc.drain(Duration::from_secs(120));
        assert_eq!(results.len(), 3);
        for r in &results {
            assert_eq!(r.verified, Some(true));
        }
        svc.shutdown();
    }

    /// Mixed job sizes on a sharded pool: everything completes and
    /// verifies (no convoying deadlocks, no lost tiles).
    #[test]
    fn mixed_job_sizes_all_complete() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 4,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
            shard_width: 3,
        });
        let mut rng = XorShift::new(31);
        let mut jobs = 0;
        for (m, k, n) in [(2, 6, 6), (8, 50, 24), (1, 1, 1), (4, 30, 7), (16, 12, 12)] {
            let a = MatI8::random_bounded(&mut rng, m, k, 63);
            let w = MatI8::random(&mut rng, k, n);
            svc.submit(Job::Gemm { a, w });
            jobs += 1;
        }
        for _ in 0..jobs {
            let r = svc
                .recv_timeout(Duration::from_secs(60))
                .expect("all jobs complete");
            assert_eq!(r.verified, Some(true));
        }
        svc.shutdown();
    }
}
