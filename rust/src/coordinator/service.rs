//! Multi-worker matrix-engine service.
//!
//! Each worker owns one cycle-accurate engine instance (they are cheap:
//! a few hundred KB of register state) and drains a shared job queue.
//! Channels + std threads keep the binary self-contained and offline.

use super::job::{Job, JobId, JobResult};
use super::metrics::Metrics;
use super::scheduler::{schedule, PrefetchPolicy};
use super::tiler::GemmTiler;
use crate::engines::os::{OsConfig, OsEngine, OsVariant};
use crate::engines::snn::{SnnConfig, SnnEngine, SnnVariant};
use crate::engines::ws::{WsConfig, WsEngine, WsVariant};
use crate::engines::{Engine, EngineError, RunStats};
use crate::workload::conv::{im2col, weights_to_gemm};
use crate::workload::gemm::golden_gemm;
use crate::workload::{MatI32, MatI8};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Which engine the workers instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    WsTinyTpu,
    WsLibano,
    WsClbFetch,
    WsDspFetch,
    OsOfficial,
    OsEnhanced,
    SnnFireFly,
    SnnEnhanced,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        Some(match s {
            "ws-tinytpu" => EngineKind::WsTinyTpu,
            "ws-libano" => EngineKind::WsLibano,
            "ws-clb-fetch" => EngineKind::WsClbFetch,
            "ws-dsp-fetch" => EngineKind::WsDspFetch,
            "os-official" => EngineKind::OsOfficial,
            "os-enhanced" => EngineKind::OsEnhanced,
            "snn-firefly" => EngineKind::SnnFireFly,
            "snn-enhanced" => EngineKind::SnnEnhanced,
            _ => return None,
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            EngineKind::WsTinyTpu => "ws-tinytpu",
            EngineKind::WsLibano => "ws-libano",
            EngineKind::WsClbFetch => "ws-clb-fetch",
            EngineKind::WsDspFetch => "ws-dsp-fetch",
            EngineKind::OsOfficial => "os-official",
            EngineKind::OsEnhanced => "os-enhanced",
            EngineKind::SnnFireFly => "snn-firefly",
            EngineKind::SnnEnhanced => "snn-enhanced",
        }
    }

    pub fn all() -> [EngineKind; 8] {
        [
            EngineKind::WsTinyTpu,
            EngineKind::WsLibano,
            EngineKind::WsClbFetch,
            EngineKind::WsDspFetch,
            EngineKind::OsOfficial,
            EngineKind::OsEnhanced,
            EngineKind::SnnFireFly,
            EngineKind::SnnEnhanced,
        ]
    }
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub kind: EngineKind,
    pub workers: usize,
    /// WS array geometry (rows, cols); OS/SNN use their paper configs.
    pub ws_rows: usize,
    pub ws_cols: usize,
    /// Cross-check every output against the golden reference.
    pub verify: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 14,
            ws_cols: 14,
            verify: true,
        }
    }
}

impl ServiceConfig {
    pub fn build_engine(&self) -> Box<dyn Engine + Send> {
        match self.kind {
            EngineKind::WsTinyTpu
            | EngineKind::WsLibano
            | EngineKind::WsClbFetch
            | EngineKind::WsDspFetch => {
                let variant = match self.kind {
                    EngineKind::WsTinyTpu => WsVariant::TinyTpu,
                    EngineKind::WsLibano => WsVariant::Libano,
                    EngineKind::WsClbFetch => WsVariant::ClbFetch,
                    _ => WsVariant::DspFetch,
                };
                Box::new(WsEngine::new(WsConfig {
                    variant,
                    rows: self.ws_rows,
                    cols: self.ws_cols,
                    target_mhz: if variant == WsVariant::TinyTpu {
                        400.0
                    } else {
                        666.0
                    },
                    strict_guard: false,
                }))
            }
            EngineKind::OsOfficial => {
                Box::new(OsEngine::new(OsConfig::b1024(OsVariant::Official)))
            }
            EngineKind::OsEnhanced => {
                Box::new(OsEngine::new(OsConfig::b1024(OsVariant::Enhanced)))
            }
            EngineKind::SnnFireFly => {
                Box::new(SnnEngine::new(SnnConfig::paper_32x32(SnnVariant::FireFly)))
            }
            EngineKind::SnnEnhanced => {
                Box::new(SnnEngine::new(SnnConfig::paper_32x32(SnnVariant::Enhanced)))
            }
        }
    }

    /// The tiler matching the engine geometry (WS engines only; OS/SNN
    /// tile internally).
    fn tiler(&self) -> Option<GemmTiler> {
        match self.kind {
            EngineKind::WsTinyTpu
            | EngineKind::WsLibano
            | EngineKind::WsClbFetch
            | EngineKind::WsDspFetch => {
                Some(GemmTiler::new(self.ws_rows, self.ws_cols))
            }
            _ => None,
        }
    }
}

/// Execute one GEMM on an engine, tiling when needed. This is the same
/// code path workers use; exposed for examples/benches.
pub fn run_gemm_tiled(
    engine: &mut dyn Engine,
    tiler: Option<&GemmTiler>,
    a: &MatI8,
    w: &MatI8,
) -> Result<(MatI32, RunStats), EngineError> {
    match tiler {
        None => {
            let run = engine.run_gemm(a, w)?;
            Ok((run.output, run.stats))
        }
        Some(tiler) => {
            let tiles = tiler.tiles(a, w);
            let mut out = MatI32::zeros(a.rows, w.cols);
            let mut per_tile = Vec::with_capacity(tiles.len());
            for t in &tiles {
                let run = engine.run_gemm(&t.a, &t.w)?;
                tiler.accumulate(&mut out, t, &run.output);
                per_tile.push(run.stats);
            }
            // Aggregate under the engine's natural policy (in-DSP /
            // CLB ping-pong for everything but tinyTPU, which stalls).
            let policy = if per_tile
                .iter()
                .any(|s| s.weight_stall_cycles >= tiler.rows as u64)
            {
                PrefetchPolicy::Stall
            } else {
                PrefetchPolicy::PingPong
            };
            let rep = schedule(policy, &per_tile, tiler.rows);
            let mut stats = RunStats {
                cycles: rep.cycles,
                fast_cycles: rep.cycles,
                macs: rep.macs,
                weight_stall_cycles: rep.weight_cycles,
                weight_loads: tiles.len() as u64,
                guard_overflows: per_tile.iter().map(|s| s.guard_overflows).sum(),
            };
            // Padded-tile MACs overcount; report the true problem size.
            stats.macs = (a.rows * a.cols * w.cols) as u64;
            Ok((out, stats))
        }
    }
}

enum Message {
    Work(JobId, Job),
    Stop,
}

/// The running service.
pub struct Service {
    tx: mpsc::Sender<Message>,
    results_rx: mpsc::Receiver<JobResult>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: u64,
    cfg: ServiceConfig,
}

impl Service {
    /// Spawn the worker pool.
    pub fn start(cfg: ServiceConfig) -> Self {
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let (results_tx, results_rx) = mpsc::channel::<JobResult>();
        let metrics = Arc::new(Metrics::new());
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let results_tx = results_tx.clone();
            let metrics = Arc::clone(&metrics);
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                let mut engine = cfg.build_engine();
                let tiler = cfg.tiler();
                loop {
                    let msg = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match msg {
                        Ok(Message::Work(id, job)) => {
                            let t0 = Instant::now();
                            match execute(engine.as_mut(), tiler.as_ref(), &job, cfg.verify)
                            {
                                Ok((output, stats, verified)) => {
                                    let wall = t0.elapsed();
                                    let plan = engine.clock_plan();
                                    let simulated = Duration::from_secs_f64(
                                        stats.cycles as f64 / (plan.slow_mhz * 1e6),
                                    );
                                    metrics.record_completion(
                                        job.macs(),
                                        stats.cycles,
                                        wall,
                                    );
                                    let _ = results_tx.send(JobResult {
                                        id,
                                        output,
                                        stats,
                                        simulated,
                                        wall,
                                        verified,
                                    });
                                }
                                Err(_) => {
                                    metrics
                                        .jobs_failed
                                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                                }
                            }
                        }
                        Ok(Message::Stop) | Err(_) => break,
                    }
                }
            }));
        }
        Service {
            tx,
            results_rx,
            workers,
            metrics,
            next_id: 0,
            cfg,
        }
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Enqueue a job; returns its id.
    pub fn submit(&mut self, job: Job) -> JobId {
        let id = JobId(self.next_id);
        self.next_id += 1;
        self.metrics
            .jobs_submitted
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.tx
            .send(Message::Work(id, job))
            .expect("workers alive");
        id
    }

    /// Receive one completed result (blocking with timeout).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<JobResult> {
        self.results_rx.recv_timeout(timeout).ok()
    }

    /// Stop workers and join.
    pub fn shutdown(self) {
        for _ in &self.workers {
            let _ = self.tx.send(Message::Stop);
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn execute(
    engine: &mut dyn Engine,
    tiler: Option<&GemmTiler>,
    job: &Job,
    verify: bool,
) -> Result<(MatI32, RunStats, Option<bool>), EngineError> {
    let (a, w): (MatI8, MatI8) = match job {
        Job::Gemm { a, w } => (a.clone(), w.clone()),
        Job::Conv {
            input,
            weights,
            shape,
        } => (im2col(input, *shape), weights_to_gemm(weights, *shape)),
        Job::Snn { spikes, weights } => (spikes.clone(), weights.clone()),
    };
    let (output, stats) = run_gemm_tiled(engine, tiler, &a, &w)?;
    let verified = verify.then(|| output == golden_gemm(&a, &w));
    Ok((output, stats, verified))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::workload::conv::ConvShape;

    #[test]
    fn service_runs_gemm_jobs_verified() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 2,
            ws_rows: 6,
            ws_cols: 6,
            verify: true,
        });
        let mut rng = XorShift::new(3);
        let n_jobs = 8;
        for _ in 0..n_jobs {
            let a = MatI8::random_bounded(&mut rng, 4, 13, 63);
            let w = MatI8::random(&mut rng, 13, 9);
            svc.submit(Job::Gemm { a, w });
        }
        let mut ok = 0;
        for _ in 0..n_jobs {
            let r = svc
                .recv_timeout(Duration::from_secs(30))
                .expect("job completes");
            assert_eq!(r.verified, Some(true));
            assert!(r.stats.cycles > 0);
            ok += 1;
        }
        assert_eq!(ok, n_jobs);
        assert!(svc.metrics.summary().contains("8/8"));
        svc.shutdown();
    }

    #[test]
    fn service_runs_conv_jobs() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::OsEnhanced,
            workers: 1,
            ws_rows: 0,
            ws_cols: 0,
            verify: true,
        });
        let shape = ConvShape {
            in_c: 3,
            in_h: 6,
            in_w: 6,
            out_c: 4,
            k: 3,
            stride: 1,
            pad: 1,
        };
        let mut rng = XorShift::new(9);
        svc.submit(Job::Conv {
            input: rng.i8_vec(shape.in_c * shape.in_h * shape.in_w),
            weights: rng.i8_vec(shape.out_c * shape.in_c * shape.k * shape.k),
            shape,
        });
        let r = svc
            .recv_timeout(Duration::from_secs(30))
            .expect("conv completes");
        assert_eq!(r.verified, Some(true));
        svc.shutdown();
    }

    #[test]
    fn snn_service_handles_spike_jobs() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::SnnEnhanced,
            workers: 1,
            ws_rows: 0,
            ws_cols: 0,
            verify: true,
        });
        let mut rng = XorShift::new(11);
        let spikes = MatI8::from_fn(8, 32, |_, _| rng.chance(1, 3) as i8);
        let weights = MatI8::random_bounded(&mut rng, 32, 32, 50);
        svc.submit(Job::Snn { spikes, weights });
        let r = svc.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r.verified, Some(true));
        svc.shutdown();
    }

    #[test]
    fn big_gemm_tiles_and_verifies() {
        let mut svc = Service::start(ServiceConfig {
            kind: EngineKind::WsDspFetch,
            workers: 1,
            ws_rows: 14,
            ws_cols: 14,
            verify: true,
        });
        let mut rng = XorShift::new(5);
        let a = MatI8::random_bounded(&mut rng, 6, 100, 63);
        let w = MatI8::random(&mut rng, 100, 40);
        svc.submit(Job::Gemm { a, w });
        let r = svc.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.stats.macs, 6 * 100 * 40);
        svc.shutdown();
    }
}
