//! GEMM tiling onto a stationary-array geometry.
//!
//! A WS engine holds a `(rows × cols)` weight tile; arbitrary `(M, K, N)`
//! problems split into a grid of `(K/rows) × (N/cols)` tiles whose
//! partial results sum over the K axis. The tiler also owns two
//! correctness-critical policies:
//!
//! * **guard awareness** — for packed full-chain engines it can bound
//!   the per-tile cascade depth so worst-case INT8 data stays inside the
//!   18-bit lane guard band (`packing::GUARD_DEPTH` drains);
//! * **padding** — ragged edges pad with zeros (zero products cannot
//!   perturb packed lanes).

use crate::workload::conv::PatchSource;
use crate::workload::{CsrMatI8, MatI32, MatI8, SparseMatI8};

/// The activation operand a job executes against: a dense matrix
/// (GEMM / SNN spike trains), a lazy im2col view over a raw conv input
/// ([`PatchSource`]) that materializes per tile, or CSR sparse
/// activations ([`CsrMatI8`]) that densify per span. Workers extract
/// the activation tile for one coordinate on demand
/// ([`GemmTiler::a_tile_of`]), so no form is ever copied whole into
/// the work queue — and neither the conv patch matrix nor the dense
/// activation image behind a CSR operand is ever built.
#[derive(Debug, Clone)]
pub enum ActOperand {
    Dense(MatI8),
    Patches(PatchSource),
    Csr(CsrMatI8),
}

impl ActOperand {
    /// Problem rows (M).
    pub fn rows(&self) -> usize {
        match self {
            ActOperand::Dense(m) => m.rows,
            ActOperand::Patches(p) => p.rows(),
            ActOperand::Csr(c) => c.rows(),
        }
    }

    /// Problem inner dimension (K).
    pub fn cols(&self) -> usize {
        match self {
            ActOperand::Dense(m) => m.cols,
            ActOperand::Patches(p) => p.cols(),
            ActOperand::Csr(c) => c.cols(),
        }
    }

    /// The dense matrix, when this operand is one.
    pub fn dense(&self) -> Option<&MatI8> {
        match self {
            ActOperand::Dense(m) => Some(m),
            _ => None,
        }
    }

    /// The lazy conv view, when this operand is one.
    pub fn patches(&self) -> Option<&PatchSource> {
        match self {
            ActOperand::Patches(p) => Some(p),
            _ => None,
        }
    }

    /// The CSR sparse activations, when this operand is them.
    pub fn csr(&self) -> Option<&CsrMatI8> {
        match self {
            ActOperand::Csr(c) => Some(c),
            _ => None,
        }
    }
}

/// The weight operand a job executes against: a dense matrix or an
/// N:M structured-sparse one ([`SparseMatI8`]). The sparse form
/// answers the coordinator's liveness query
/// ([`WeightOperand::tile_live`]) without densifying, so all-zero
/// weight tiles are dropped before a fill is ever enqueued — the
/// `FillGroup` reuse machinery generalized to "fill nothing".
#[derive(Debug, Clone)]
pub enum WeightOperand {
    Dense(MatI8),
    Sparse(SparseMatI8),
}

impl WeightOperand {
    /// Problem inner dimension (K).
    pub fn rows(&self) -> usize {
        match self {
            WeightOperand::Dense(m) => m.rows,
            WeightOperand::Sparse(s) => s.rows(),
        }
    }

    /// Problem output columns (N).
    pub fn cols(&self) -> usize {
        match self {
            WeightOperand::Dense(m) => m.cols,
            WeightOperand::Sparse(s) => s.cols(),
        }
    }

    /// The dense matrix, when this operand is one (borrow; sparse
    /// operands densify via [`WeightOperand::to_dense`]).
    pub fn dense(&self) -> Option<&MatI8> {
        match self {
            WeightOperand::Dense(m) => Some(m),
            WeightOperand::Sparse(_) => None,
        }
    }

    /// The N:M sparse matrix, when this operand is one.
    pub fn sparse(&self) -> Option<&SparseMatI8> {
        match self {
            WeightOperand::Dense(_) => None,
            WeightOperand::Sparse(s) => Some(s),
        }
    }

    /// Materialize the full dense weight matrix (the verify path and
    /// internally-tiling engines; the WS tile path never calls this).
    pub fn to_dense(&self) -> MatI8 {
        match self {
            WeightOperand::Dense(m) => m.clone(),
            WeightOperand::Sparse(s) => s.to_dense(),
        }
    }

    /// Stored nonzero fraction (dense operands report 1.0).
    pub fn density(&self) -> f64 {
        match self {
            WeightOperand::Dense(_) => 1.0,
            WeightOperand::Sparse(s) => s.density(),
        }
    }

    /// Does the weight tile at `c` hold any nonzero? `false` means the
    /// tile's partial product is identically zero — its fill and every
    /// activation stream against it can be skipped without touching
    /// the result. Dense operands answer `true` unconditionally (the
    /// scan would cost more than the fill it might save).
    pub fn tile_live(&self, c: TileCoord) -> bool {
        match self {
            WeightOperand::Dense(_) => true,
            WeightOperand::Sparse(s) => {
                s.block_has_nonzero(c.k0, c.k1, c.n0, c.n1)
            }
        }
    }
}

/// The (K, N) span one stationary tile covers — the cheap, data-free
/// half of a [`Tile`]. Coordinates are what batched submission groups
/// by (same weight matrix + same coord ⇒ same stationary tile), and
/// what lazy tiling iterates before any operand copy exists.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileCoord {
    pub k0: usize,
    pub k1: usize,
    pub n0: usize,
    pub n1: usize,
}

/// One weight-stationary tile of a larger GEMM.
#[derive(Debug, Clone)]
pub struct Tile {
    /// K-range of the source problem this tile covers.
    pub k0: usize,
    pub k1: usize,
    /// N-range.
    pub n0: usize,
    pub n1: usize,
    /// The padded activation slice (M × rows).
    pub a: MatI8,
    /// The padded weight tile (rows × tile_cols).
    pub w: MatI8,
}

impl Tile {
    /// Fold this tile's partial product into the job-level output.
    /// K-tiles sum (integer adds commute, so sharded completion order
    /// cannot change the result); N-tiles write disjoint columns.
    pub fn accumulate_into(&self, out: &mut MatI32, partial: &MatI32) {
        assert_eq!(partial.cols, self.n1 - self.n0);
        out.accumulate_cols(self.n0, partial);
    }
}

/// Tiling plan for one engine geometry.
#[derive(Debug, Clone, Copy)]
pub struct GemmTiler {
    /// Stationary K depth per tile (array rows).
    pub rows: usize,
    /// Stationary N width per tile (array cols).
    pub cols: usize,
}

impl GemmTiler {
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(rows > 0 && cols > 0);
        GemmTiler { rows, cols }
    }

    /// Number of (k, n) tiles for a problem.
    pub fn tile_count(&self, k: usize, n: usize) -> usize {
        k.div_ceil(self.rows) * n.div_ceil(self.cols)
    }

    /// The tile-coordinate sequence for a `(K, N)` problem, K-major
    /// (consecutive coords share the same N-columns so the accumulator
    /// stays hot). Coordinates carry no operand data — materialize
    /// them per tile with [`GemmTiler::a_tile`] / [`GemmTiler::w_tile`].
    pub fn coords(
        &self,
        k: usize,
        n: usize,
    ) -> impl Iterator<Item = TileCoord> {
        let (rows, cols) = (self.rows, self.cols);
        (0..n).step_by(cols).flat_map(move |n0| {
            let n1 = (n0 + cols).min(n);
            (0..k).step_by(rows).map(move |k0| TileCoord {
                k0,
                k1: (k0 + rows).min(k),
                n0,
                n1,
            })
        })
    }

    /// Extract the padded activation slice for one coord (M × rows):
    /// straight row-slice copies, no per-element closure and no
    /// per-column `Vec` — this is the tiler's hot path.
    pub fn a_tile(&self, a: &MatI8, c: TileCoord) -> MatI8 {
        let mut t = MatI8::zeros(a.rows, self.rows);
        let span = c.k1 - c.k0;
        for r in 0..a.rows {
            t.row_mut(r)[..span].copy_from_slice(&a.row(r)[c.k0..c.k1]);
        }
        t
    }

    /// Extract the padded activation tile for one coord from either
    /// operand form — the worker-side lazy extraction. Dense operands
    /// slice-copy ([`GemmTiler::a_tile`]); conv operands materialize
    /// their im2col patch columns directly from the raw input
    /// ([`PatchSource::extract_cols`]), zero-padding aware on both the
    /// spatial border and the tile tail.
    pub fn a_tile_of(&self, a: &ActOperand, c: TileCoord) -> MatI8 {
        match a {
            ActOperand::Dense(m) => self.a_tile(m, c),
            ActOperand::Patches(p) => p.extract_cols(c.k0, c.k1, self.rows),
            ActOperand::Csr(m) => m.extract_cols(c.k0, c.k1, self.rows),
        }
    }

    /// Extract the padded weight tile for one coord (rows × (n1-n0)).
    /// K-padding rows stay zero (zero products cannot perturb packed
    /// lanes).
    pub fn w_tile(&self, w: &MatI8, c: TileCoord) -> MatI8 {
        let mut t = MatI8::zeros(self.rows, c.n1 - c.n0);
        for r in 0..(c.k1 - c.k0) {
            t.row_mut(r)
                .copy_from_slice(&w.row(c.k0 + r)[c.n0..c.n1]);
        }
        t
    }

    /// Extract the padded weight tile for one coord from either
    /// operand form. Dense operands slice-copy
    /// ([`GemmTiler::w_tile`]); sparse operands scatter straight from
    /// their group slots ([`SparseMatI8::extract_block`]) — the dense
    /// weight matrix is never materialized on this path.
    pub fn w_tile_of(&self, w: &WeightOperand, c: TileCoord) -> MatI8 {
        match w {
            WeightOperand::Dense(m) => self.w_tile(m, c),
            WeightOperand::Sparse(s) => {
                s.extract_block(c.k0, c.k1, c.n0, c.n1, self.rows)
            }
        }
    }

    /// Lazy tile sequence: each [`Tile`]'s operand copies materialize
    /// only when the iterator reaches it, so a consumer that streams
    /// tiles (the service's submit path, `run_gemm_tiled`) never holds
    /// every tile of a large problem in memory at once.
    pub fn tile_iter<'m>(
        &self,
        a: &'m MatI8,
        w: &'m MatI8,
    ) -> impl Iterator<Item = Tile> + 'm {
        assert_eq!(a.cols, w.rows, "inner dimensions must agree");
        let tiler = *self;
        tiler.coords(a.cols, w.cols).map(move |c| Tile {
            k0: c.k0,
            k1: c.k1,
            n0: c.n0,
            n1: c.n1,
            a: tiler.a_tile(a, c),
            w: tiler.w_tile(w, c),
        })
    }

    /// Materialize every tile upfront (convenience for small problems
    /// and tests; large batches should stream [`GemmTiler::tile_iter`]).
    pub fn tiles(&self, a: &MatI8, w: &MatI8) -> Vec<Tile> {
        self.tile_iter(a, w).collect()
    }

    /// Accumulate a tile's partial result into the full output.
    pub fn accumulate(&self, out: &mut MatI32, tile: &Tile, partial: &MatI32) {
        tile.accumulate_into(out, partial);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::workload::gemm::golden_gemm;

    /// Tiling + golden per-tile GEMM + accumulation == full golden GEMM.
    #[test]
    fn tiles_recompose_exactly() {
        let mut rng = XorShift::new(1);
        for (m, k, n, rows, cols) in
            [(5, 20, 9, 6, 4), (8, 6, 6, 6, 6), (3, 13, 17, 14, 14), (1, 1, 1, 4, 4)]
        {
            let a = MatI8::random(&mut rng, m, k);
            let w = MatI8::random(&mut rng, k, n);
            let tiler = GemmTiler::new(rows, cols);
            let tiles = tiler.tiles(&a, &w);
            assert_eq!(tiles.len(), tiler.tile_count(k, n));
            let mut out = MatI32::zeros(m, n);
            for t in &tiles {
                let partial = golden_gemm(&t.a, &t.w);
                tiler.accumulate(&mut out, t, &partial);
            }
            assert_eq!(out, golden_gemm(&a, &w), "m{m} k{k} n{n} r{rows} c{cols}");
        }
    }

    #[test]
    fn k_major_order_keeps_n_tiles_contiguous() {
        let tiler = GemmTiler::new(4, 4);
        let a = MatI8::zeros(2, 10);
        let w = MatI8::zeros(10, 6);
        let tiles = tiler.tiles(&a, &w);
        // 3 K-tiles × 2 N-tiles; first three share n0 = 0.
        assert_eq!(tiles.len(), 6);
        assert!(tiles[..3].iter().all(|t| t.n0 == 0));
        assert!(tiles[3..].iter().all(|t| t.n0 == 4));
    }

    /// The slice-copy extraction agrees element-for-element with the
    /// straightforward per-element reference, padding included.
    #[test]
    fn slice_extraction_matches_reference() {
        let mut rng = XorShift::new(6);
        for (m, k, n, rows, cols) in [(5, 20, 9, 6, 4), (3, 13, 17, 14, 14)] {
            let a = MatI8::random(&mut rng, m, k);
            let w = MatI8::random(&mut rng, k, n);
            let tiler = GemmTiler::new(rows, cols);
            for c in tiler.coords(k, n) {
                let a_ref = MatI8::from_fn(m, rows, |r, i| {
                    if c.k0 + i < c.k1 {
                        a.at(r, c.k0 + i)
                    } else {
                        0
                    }
                });
                let w_ref = MatI8::from_fn(rows, c.n1 - c.n0, |r, i| {
                    if c.k0 + r < c.k1 {
                        w.at(c.k0 + r, c.n0 + i)
                    } else {
                        0
                    }
                });
                assert_eq!(tiler.a_tile(&a, c), a_ref);
                assert_eq!(tiler.w_tile(&w, c), w_ref);
            }
        }
    }

    /// Lazy iteration covers the same coords as `tile_count` promises,
    /// in the same K-major order as the materialized sequence.
    #[test]
    fn coords_and_tile_iter_agree_with_tiles() {
        let tiler = GemmTiler::new(4, 4);
        let a = MatI8::zeros(2, 10);
        let w = MatI8::zeros(10, 6);
        let coords: Vec<TileCoord> = tiler.coords(10, 6).collect();
        assert_eq!(coords.len(), tiler.tile_count(10, 6));
        let tiles = tiler.tiles(&a, &w);
        assert_eq!(tiles.len(), coords.len());
        for (t, c) in tiles.iter().zip(&coords) {
            assert_eq!((t.k0, t.k1, t.n0, t.n1), (c.k0, c.k1, c.n0, c.n1));
        }
    }

    /// The lazy conv extraction through `a_tile_of` is bit-identical
    /// to slicing the eagerly materialized im2col matrix.
    #[test]
    fn conv_patch_tiles_match_eager_im2col_tiles() {
        use crate::workload::conv::{im2col, ConvShape, PatchSource};
        let shape = ConvShape {
            in_c: 3,
            in_h: 6,
            in_w: 5,
            out_c: 4,
            k: 3,
            stride: 2,
            pad: 1,
            dilation: 1,
            groups: 1,
        };
        let mut rng = XorShift::new(12);
        let input = rng.i8_vec(shape.input_len());
        let eager = im2col(&input, shape);
        let src = PatchSource::new(input, shape).unwrap();
        let lazy = ActOperand::Patches(src);
        assert_eq!(lazy.rows(), eager.rows);
        assert_eq!(lazy.cols(), eager.cols);
        for (rows, cols) in [(4, 3), (14, 14), (7, 2)] {
            let tiler = GemmTiler::new(rows, cols);
            for c in tiler.coords(eager.cols, shape.out_c) {
                assert_eq!(
                    tiler.a_tile_of(&lazy, c),
                    tiler.a_tile(&eager, c),
                    "{c:?} r{rows} c{cols}"
                );
            }
        }
    }

    /// Sparse weight extraction and CSR activation extraction through
    /// the operand-aware entry points are bit-identical to densifying
    /// first and slicing the dense matrix — and `tile_live` answers
    /// exactly "does the densified tile hold a nonzero".
    #[test]
    fn sparse_operand_tiles_match_densified() {
        use crate::workload::sparse::NmPattern;
        let mut rng = XorShift::new(33);
        let nm = NmPattern::parse("2:4").unwrap();
        let (m, k, n) = (5, 30, 25);
        // Blocks aligned to the 6×5 tile grid so whole tiles go dead.
        let sw = SparseMatI8::striped(&mut rng, k, n, nm, 3, (6, 5));
        let dw = sw.to_dense();
        let wop = WeightOperand::Sparse(sw.clone());
        let ca = CsrMatI8::random_density(&mut rng, m, k, 0.3);
        let da = ca.to_dense();
        let aop = ActOperand::Csr(ca);
        assert_eq!((wop.rows(), wop.cols()), (k, n));
        assert_eq!((aop.rows(), aop.cols()), (m, k));
        let tiler = GemmTiler::new(6, 5);
        let mut live = 0;
        for c in tiler.coords(k, n) {
            assert_eq!(tiler.w_tile_of(&wop, c), tiler.w_tile(&dw, c), "{c:?}");
            assert_eq!(tiler.a_tile_of(&aop, c), tiler.a_tile(&da, c), "{c:?}");
            let tile_nonzero =
                tiler.w_tile(&dw, c).data.iter().any(|v| *v != 0);
            assert_eq!(wop.tile_live(c), tile_nonzero, "{c:?}");
            live += wop.tile_live(c) as usize;
        }
        // live_every = 3 over a 5×5 block grid: ids 0,3,6,...,24.
        assert_eq!(live, 9);
        // Dense weights are always live — no scan, no skip.
        let dense_op = WeightOperand::Dense(dw);
        assert!(tiler.coords(k, n).all(|c| dense_op.tile_live(c)));
        assert_eq!(dense_op.density(), 1.0);
    }

    #[test]
    fn padding_is_zero() {
        let tiler = GemmTiler::new(8, 8);
        let a = MatI8::from_fn(2, 3, |_, _| 7);
        let w = MatI8::from_fn(3, 2, |_, _| 9);
        let tiles = tiler.tiles(&a, &w);
        assert_eq!(tiles.len(), 1);
        let t = &tiles[0];
        assert_eq!(t.a.cols, 8);
        assert_eq!(t.a.at(0, 5), 0);
        assert_eq!(t.w.at(6, 1), 0);
    }
}
