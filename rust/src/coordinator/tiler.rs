//! GEMM tiling onto a stationary-array geometry.
//!
//! A WS engine holds a `(rows × cols)` weight tile; arbitrary `(M, K, N)`
//! problems split into a grid of `(K/rows) × (N/cols)` tiles whose
//! partial results sum over the K axis. The tiler also owns two
//! correctness-critical policies:
//!
//! * **guard awareness** — for packed full-chain engines it can bound
//!   the per-tile cascade depth so worst-case INT8 data stays inside the
//!   18-bit lane guard band (`packing::GUARD_DEPTH` drains);
//! * **padding** — ragged edges pad with zeros (zero products cannot
//!   perturb packed lanes).

use crate::workload::{MatI32, MatI8};

/// One weight-stationary tile of a larger GEMM.
#[derive(Debug, Clone)]
pub struct Tile {
    /// K-range of the source problem this tile covers.
    pub k0: usize,
    pub k1: usize,
    /// N-range.
    pub n0: usize,
    pub n1: usize,
    /// The padded activation slice (M × rows).
    pub a: MatI8,
    /// The padded weight tile (rows × tile_cols).
    pub w: MatI8,
}

impl Tile {
    /// Fold this tile's partial product into the job-level output.
    /// K-tiles sum (integer adds commute, so sharded completion order
    /// cannot change the result); N-tiles write disjoint columns.
    pub fn accumulate_into(&self, out: &mut MatI32, partial: &MatI32) {
        assert_eq!(partial.rows, out.rows);
        assert_eq!(partial.cols, self.n1 - self.n0);
        for r in 0..partial.rows {
            for c in 0..partial.cols {
                out.add(r, self.n0 + c, partial.at(r, c));
            }
        }
    }
}

/// Tiling plan for one engine geometry.
#[derive(Debug, Clone, Copy)]
pub struct GemmTiler {
    /// Stationary K depth per tile (array rows).
    pub rows: usize,
    /// Stationary N width per tile (array cols).
    pub cols: usize,
}

impl GemmTiler {
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(rows > 0 && cols > 0);
        GemmTiler { rows, cols }
    }

    /// Number of (k, n) tiles for a problem.
    pub fn tile_count(&self, k: usize, n: usize) -> usize {
        k.div_ceil(self.rows) * n.div_ceil(self.cols)
    }

    /// Produce the tile sequence (K-major, so consecutive tiles share
    /// the same N-columns and the accumulator stays hot).
    pub fn tiles(&self, a: &MatI8, w: &MatI8) -> Vec<Tile> {
        assert_eq!(a.cols, w.rows, "inner dimensions must agree");
        let (m, k) = (a.rows, a.cols);
        let n = w.cols;
        let mut out = Vec::with_capacity(self.tile_count(k, n));
        for n0 in (0..n).step_by(self.cols) {
            let n1 = (n0 + self.cols).min(n);
            for k0 in (0..k).step_by(self.rows) {
                let k1 = (k0 + self.rows).min(k);
                // Pad K to the full array depth; N tiles may be narrow.
                let a_tile = MatI8::from_fn(m, self.rows, |r, c| {
                    if k0 + c < k1 {
                        a.at(r, k0 + c)
                    } else {
                        0
                    }
                });
                let w_tile = MatI8::from_fn(self.rows, n1 - n0, |r, c| {
                    if k0 + r < k1 {
                        w.at(k0 + r, n0 + c)
                    } else {
                        0
                    }
                });
                out.push(Tile {
                    k0,
                    k1,
                    n0,
                    n1,
                    a: a_tile,
                    w: w_tile,
                });
            }
        }
        out
    }

    /// Accumulate a tile's partial result into the full output.
    pub fn accumulate(&self, out: &mut MatI32, tile: &Tile, partial: &MatI32) {
        tile.accumulate_into(out, partial);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::workload::gemm::{golden_gemm, GemmProblem};

    /// Tiling + golden per-tile GEMM + accumulation == full golden GEMM.
    #[test]
    fn tiles_recompose_exactly() {
        let mut rng = XorShift::new(1);
        for (m, k, n, rows, cols) in
            [(5, 20, 9, 6, 4), (8, 6, 6, 6, 6), (3, 13, 17, 14, 14), (1, 1, 1, 4, 4)]
        {
            let a = MatI8::random(&mut rng, m, k);
            let w = MatI8::random(&mut rng, k, n);
            let tiler = GemmTiler::new(rows, cols);
            let tiles = tiler.tiles(&a, &w);
            assert_eq!(tiles.len(), tiler.tile_count(k, n));
            let mut out = MatI32::zeros(m, n);
            for t in &tiles {
                let partial = golden_gemm(&t.a, &t.w);
                tiler.accumulate(&mut out, t, &partial);
            }
            assert_eq!(out, golden_gemm(&a, &w), "m{m} k{k} n{n} r{rows} c{cols}");
        }
    }

    #[test]
    fn k_major_order_keeps_n_tiles_contiguous() {
        let tiler = GemmTiler::new(4, 4);
        let a = MatI8::zeros(2, 10);
        let w = MatI8::zeros(10, 6);
        let tiles = tiler.tiles(&a, &w);
        // 3 K-tiles × 2 N-tiles; first three share n0 = 0.
        assert_eq!(tiles.len(), 6);
        assert!(tiles[..3].iter().all(|t| t.n0 == 0));
        assert!(tiles[3..].iter().all(|t| t.n0 == 4));
    }

    #[test]
    fn padding_is_zero() {
        let tiler = GemmTiler::new(8, 8);
        let a = MatI8::from_fn(2, 3, |_, _| 7);
        let w = MatI8::from_fn(3, 2, |_, _| 9);
        let tiles = tiler.tiles(&a, &w);
        assert_eq!(tiles.len(), 1);
        let t = &tiles[0];
        assert_eq!(t.a.cols, 8);
        assert_eq!(t.a.at(0, 5), 0);
        assert_eq!(t.w.at(6, 1), 0);
    }
}
