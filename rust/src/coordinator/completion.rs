//! The non-blocking front-end's shared completion table.
//!
//! [`super::Service::submit`] returns a [`JobHandle`] immediately;
//! workers retire finished jobs into this table, and the submitter
//! redeems handles through `poll` (non-blocking), `wait` (blocking
//! with timeout) or `drain` (everything outstanding). This replaces
//! the single `mpsc` results channel: completions are addressable by
//! job, arrival order is preserved for `wait_any`, and the table
//! tracks how many jobs are still in flight so `drain` knows when the
//! pipeline is dry.

use super::job::{JobId, JobResult};
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Handle returned by `submit`/`submit_batch`; redeem it through
/// `Service::poll` / `Service::wait`.
///
/// Lifecycle: `Pending` from submission until a worker assembles the
/// job, then exactly one `poll`/`wait` observes `Done` (the result is
/// *taken* — a second redemption reports `Pending` but the result is
/// gone, so keep the `JobResult` you were handed). Jobs whose tiles
/// errored resolve to `Failed` instead — likewise observed exactly
/// once, so the table never accumulates state for retired jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct JobHandle {
    pub id: JobId,
}

/// What a handle redemption observed.
#[derive(Debug)]
pub enum JobState {
    /// Still in flight (or already taken by an earlier redemption).
    Pending,
    /// Completed: the assembled result (taken from the table).
    Done(Box<JobResult>),
    /// A tile of this job errored; no result exists.
    Failed,
    /// The job was evicted by admission control (its session was shed
    /// or force-drained); no result exists. Terminal like `Failed`,
    /// observed exactly once — and unlike `Failed`, a `wait` blocked
    /// on the handle resolves the moment the shed happens instead of
    /// sleeping out its timeout.
    Shed,
}

impl JobState {
    pub fn is_done(&self) -> bool {
        matches!(self, JobState::Done(_))
    }

    pub fn into_result(self) -> Option<Box<JobResult>> {
        match self {
            JobState::Done(r) => Some(r),
            _ => None,
        }
    }
}

/// Everything one [`CompletionTable::drain`] call retired: completed
/// results in arrival order, plus the ids of jobs that failed and had
/// not been observed through a targeted `poll`/`wait`. Draining
/// *takes* both — a long-running retirement loop that only ever calls
/// `drain` cannot leak failed ids (they used to accumulate in the
/// table forever).
#[derive(Debug, Default)]
pub struct Drained {
    pub completed: Vec<JobResult>,
    pub failed: Vec<JobId>,
}

/// `Instant::now() + timeout` without the overflow panic: callers pass
/// `Duration::MAX` to mean "wait forever", which `checked_add`
/// saturates to a far-future deadline (~30 years) instead of
/// panicking the way a bare `+` does.
fn deadline_after(timeout: Duration) -> Instant {
    let now = Instant::now();
    now.checked_add(timeout)
        .unwrap_or_else(|| now + Duration::from_secs(60 * 60 * 24 * 365 * 30))
}

#[derive(Default)]
struct Inner {
    ready: HashMap<JobId, JobResult>,
    /// Completion order, for `wait_any` fairness (ids already taken by
    /// a targeted `poll`/`wait` are skipped lazily).
    order: VecDeque<JobId>,
    failed: HashSet<JobId>,
    /// Ids submitted but not yet retired (completed or failed). Exact
    /// tracking — not a counter — so [`CompletionTable::forget`] can
    /// tell a genuinely in-flight handle from one that already retired
    /// through someone else's drain.
    in_flight: HashSet<JobId>,
    /// In-flight handles abandoned by [`CompletionTable::forget`]:
    /// their results are dropped at retirement instead of parked in
    /// `ready`, so a disconnected client's unredeemed outputs can
    /// never accumulate. Invariant: `orphaned ⊆ in_flight`, so every
    /// entry is removed when its job retires — the set cannot leak.
    orphaned: HashSet<JobId>,
    /// Handles evicted by [`CompletionTable::shed`] and not yet
    /// observed: a `poll`/`wait` consumes the marker and reports
    /// [`JobState::Shed`]. Cleared by `forget` (the owner
    /// disconnected) and taken by `drain`, so the set cannot leak.
    shed: HashSet<JobId>,
}

impl Inner {
    /// Take one parked result by id, pruning its `order` entry — the
    /// queue's length stays bounded by *currently parked* results even
    /// when every redemption is targeted (`poll`/`wait`) and
    /// `wait_any`/`drain` never run to pop it.
    fn take_ready(&mut self, id: JobId) -> Option<JobResult> {
        let r = self.ready.remove(&id)?;
        if let Some(pos) = self.order.iter().position(|x| *x == id) {
            self.order.remove(pos);
        }
        Some(r)
    }
}

/// Shared completion state between workers and the submitter.
#[derive(Default)]
pub struct CompletionTable {
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl CompletionTable {
    pub fn new() -> Self {
        CompletionTable::default()
    }

    /// Account newly submitted jobs by id.
    pub(crate) fn register(&self, handles: &[JobHandle]) {
        let mut g = self.inner.lock().unwrap();
        g.in_flight.extend(handles.iter().map(|h| h.id));
    }

    /// Worker side: retire a completed job. Results for forgotten
    /// (owner-disconnected) handles are dropped here instead of
    /// parked.
    pub(crate) fn complete(&self, result: JobResult) {
        let mut g = self.inner.lock().unwrap();
        g.in_flight.remove(&result.id);
        if !g.orphaned.remove(&result.id) {
            g.order.push_back(result.id);
            g.ready.insert(result.id, result);
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Worker side: retire a failed job.
    pub(crate) fn complete_failed(&self, id: JobId) {
        let mut g = self.inner.lock().unwrap();
        g.in_flight.remove(&id);
        if !g.orphaned.remove(&id) {
            g.failed.insert(id);
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Abandon handles whose owner is gone (a wire client that
    /// disconnected without redeeming them): parked results and failed
    /// markers are dropped now, genuinely in-flight ones are marked
    /// orphaned and dropped at retirement. Ids that already retired —
    /// redeemed by their owner, or taken by someone else's drain — are
    /// ignored, so `forget` can never make the table grow.
    pub fn forget(&self, ids: &[JobId]) {
        let mut g = self.inner.lock().unwrap();
        for id in ids {
            let was_parked =
                g.take_ready(*id).is_some() || g.failed.remove(id);
            if !was_parked && g.in_flight.contains(id) {
                g.orphaned.insert(*id);
            }
            // A disconnected owner can never observe its shed
            // markers; drop them so the set stays leak-free.
            g.shed.remove(id);
        }
    }

    /// Evict handles by admission control: parked results and failed
    /// markers are dropped, genuinely in-flight ones are orphaned
    /// (their results drop at retirement), and every evicted id is
    /// marked [`JobState::Shed`] so the owner's next redemption — or a
    /// `wait` *already blocked* on the handle — resolves to a typed
    /// terminal answer instead of hanging. Already-retired ids are
    /// ignored. Returns how many handles were evicted.
    pub fn shed(&self, ids: &[JobId]) -> usize {
        let mut g = self.inner.lock().unwrap();
        let mut evicted = 0;
        for id in ids {
            let was_parked =
                g.take_ready(*id).is_some() || g.failed.remove(id);
            let in_flight = g.in_flight.contains(id);
            if !was_parked && in_flight {
                g.orphaned.insert(*id);
            }
            if was_parked || in_flight {
                g.shed.insert(*id);
                evicted += 1;
            }
        }
        drop(g);
        if evicted > 0 {
            self.cv.notify_all();
        }
        evicted
    }

    /// Completed results parked in the table and not yet redeemed
    /// (leak telemetry: should trend to zero on a healthy server).
    pub fn unclaimed(&self) -> usize {
        self.inner.lock().unwrap().ready.len()
    }

    /// Non-blocking redemption of one handle.
    pub fn poll(&self, handle: JobHandle) -> JobState {
        let mut g = self.inner.lock().unwrap();
        if g.shed.remove(&handle.id) {
            return JobState::Shed;
        }
        if let Some(r) = g.take_ready(handle.id) {
            return JobState::Done(Box::new(r));
        }
        if g.failed.remove(&handle.id) {
            return JobState::Failed;
        }
        JobState::Pending
    }

    /// Blocking redemption of one handle (up to `timeout`;
    /// `Duration::MAX` waits forever).
    pub fn wait(&self, handle: JobHandle, timeout: Duration) -> JobState {
        let deadline = deadline_after(timeout);
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.shed.remove(&handle.id) {
                return JobState::Shed;
            }
            if let Some(r) = g.take_ready(handle.id) {
                return JobState::Done(Box::new(r));
            }
            if g.failed.remove(&handle.id) {
                return JobState::Failed;
            }
            if g.in_flight.is_empty() {
                // Nothing is in flight, and this id is in neither
                // table: it was already redeemed (or drained), so no
                // state change can ever resolve it. Report Pending —
                // the documented already-taken answer — instead of
                // sleeping out a "wait forever" timeout.
                return JobState::Pending;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return JobState::Pending;
            }
            let (guard, _) = self.cv.wait_timeout(g, left).unwrap();
            g = guard;
        }
    }

    /// Take the next completed job in arrival order (blocking up to
    /// `timeout`; `Duration::MAX` waits forever); `None` on timeout.
    /// Failed jobs never surface here — they resolve through
    /// `poll`/`wait` on their handle, or in bulk through
    /// [`CompletionTable::drain`].
    pub fn wait_any(&self, timeout: Duration) -> Option<JobResult> {
        let deadline = deadline_after(timeout);
        let mut g = self.inner.lock().unwrap();
        loop {
            while let Some(id) = g.order.pop_front() {
                if let Some(r) = g.ready.remove(&id) {
                    return Some(r);
                }
                // Already taken by a targeted poll/wait: skip.
            }
            if g.in_flight.is_empty() {
                // Nothing in flight and nothing queued: no completion
                // can ever arrive (submission requires exclusive
                // access to the service, so none can race in while we
                // hold the lock-and-wait loop). Without this a
                // "wait forever" call would deadlock the moment every
                // outstanding job resolved as Failed.
                return None;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(g, left).unwrap();
            g = guard;
        }
    }

    /// Block until every submitted job has retired (or `timeout`;
    /// `Duration::MAX` waits forever), then take *everything*
    /// unclaimed: completed results in arrival order **and** the ids
    /// of unobserved failed jobs — both cleared from the table, so a
    /// retirement loop built on `drain` alone holds no leaked state.
    pub fn drain(&self, timeout: Duration) -> Drained {
        let deadline = deadline_after(timeout);
        let mut g = self.inner.lock().unwrap();
        while !g.in_flight.is_empty() {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            let (guard, _) = self.cv.wait_timeout(g, left).unwrap();
            g = guard;
        }
        let mut completed = Vec::with_capacity(g.ready.len());
        while let Some(id) = g.order.pop_front() {
            if let Some(r) = g.ready.remove(&id) {
                completed.push(r);
            }
        }
        let mut failed: Vec<JobId> = g.failed.drain().collect();
        // Shed markers for jobs that already retired can never block
        // the drain, but they are unclaimed terminal state — take
        // them too (as failures: no result exists), so a retirement
        // loop built on `drain` alone holds no leaked state.
        failed.extend(g.shed.drain());
        failed.sort_unstable();
        failed.dedup();
        Drained { completed, failed }
    }

    /// Session-scoped drain: block until every id in `ids` has
    /// retired (or `timeout`), then take *their* unclaimed state —
    /// completed results in arrival order, unobserved failed and shed
    /// ids — leaving every other session's handles untouched. Backs
    /// the wire `DrainMine` verb.
    pub fn drain_ids(&self, ids: &[JobId], timeout: Duration) -> Drained {
        let want: HashSet<JobId> = ids.iter().copied().collect();
        let deadline = deadline_after(timeout);
        let mut g = self.inner.lock().unwrap();
        loop {
            let outstanding = g
                .in_flight
                .iter()
                .any(|id| want.contains(id) && !g.shed.contains(id));
            if !outstanding {
                break;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            let (guard, _) = self.cv.wait_timeout(g, left).unwrap();
            g = guard;
        }
        let mut completed = Vec::new();
        let mut i = 0;
        while i < g.order.len() {
            let id = g.order[i];
            if want.contains(&id) {
                g.order.remove(i);
                if let Some(r) = g.ready.remove(&id) {
                    completed.push(r);
                }
            } else {
                i += 1;
            }
        }
        let mut failed: Vec<JobId> = Vec::new();
        for id in &want {
            if g.failed.remove(id) || g.shed.remove(id) {
                failed.push(*id);
            }
        }
        failed.sort_unstable();
        Drained { completed, failed }
    }

    /// Jobs submitted but not yet retired.
    pub fn pending(&self) -> usize {
        self.inner.lock().unwrap().in_flight.len()
    }

    /// Jobs still in flight whose owners are waiting on them —
    /// in-flight minus orphaned. This is the admission gate's measure
    /// of outstanding work: shedding or forgetting a session frees
    /// its slots immediately, before the workers catch up.
    pub fn live_pending(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.in_flight.len() - g.orphaned.len()
    }

    /// Jobs that retired as failed and were not yet observed through
    /// a handle (observing one via `poll`/`wait` consumes it).
    pub fn failed_count(&self) -> usize {
        self.inner.lock().unwrap().failed.len()
    }

    /// Shed markers not yet observed (leak telemetry: trends to zero —
    /// owners observe them, disconnect cleanup clears them).
    pub fn shed_count(&self) -> usize {
        self.inner.lock().unwrap().shed.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::RunStats;
    use crate::workload::MatI32;
    use std::sync::Arc;

    /// Register handles for `ids` (tests submit by bare id).
    fn reg(t: &CompletionTable, ids: &[u64]) {
        let handles: Vec<JobHandle> =
            ids.iter().map(|&i| JobHandle { id: JobId(i) }).collect();
        t.register(&handles);
    }

    fn result(id: u64) -> JobResult {
        JobResult {
            id: JobId(id),
            output: MatI32::zeros(1, 1),
            stats: RunStats::default(),
            simulated: Duration::ZERO,
            wall: Duration::ZERO,
            verified: None,
        }
    }

    #[test]
    fn poll_pending_then_done_takes_once() {
        let t = CompletionTable::new();
        reg(&t, &[0]);
        let h = JobHandle { id: JobId(0) };
        assert!(matches!(t.poll(h), JobState::Pending));
        t.complete(result(0));
        assert_eq!(t.pending(), 0);
        let state = t.poll(h);
        assert!(state.is_done());
        assert_eq!(state.into_result().unwrap().id, JobId(0));
        // Taken: a second redemption does not see it again.
        assert!(matches!(t.poll(h), JobState::Pending));
    }

    #[test]
    fn wait_any_preserves_completion_order_and_skips_taken() {
        let t = CompletionTable::new();
        reg(&t, &[0, 1, 2]);
        t.complete(result(2));
        t.complete(result(0));
        t.complete(result(1));
        // Target-poll the middle one out of band.
        assert!(t.poll(JobHandle { id: JobId(0) }).is_done());
        let a = t.wait_any(Duration::from_millis(10)).unwrap();
        let b = t.wait_any(Duration::from_millis(10)).unwrap();
        assert_eq!((a.id, b.id), (JobId(2), JobId(1)));
        assert!(t.wait_any(Duration::from_millis(5)).is_none());
    }

    #[test]
    fn failed_jobs_resolve_and_retire() {
        let t = CompletionTable::new();
        reg(&t, &[7, 8]);
        t.complete_failed(JobId(7));
        assert_eq!(t.failed_count(), 1);
        assert!(matches!(
            t.wait(JobHandle { id: JobId(7) }, Duration::from_millis(5)),
            JobState::Failed
        ));
        // Observing a failure consumes it — no unbounded growth, and a
        // second redemption reports Pending like a taken Done.
        assert_eq!(t.failed_count(), 0);
        assert!(matches!(
            t.poll(JobHandle { id: JobId(7) }),
            JobState::Pending
        ));
        t.complete(result(8));
        let drained = t.drain(Duration::from_millis(50));
        assert_eq!(drained.completed.len(), 1);
        assert_eq!(drained.completed[0].id, JobId(8));
        assert!(drained.failed.is_empty());
        assert_eq!(t.pending(), 0);
    }

    /// `drain` takes unobserved failed ids with it and clears the set,
    /// so a retirement loop that never targets handles cannot leak.
    #[test]
    fn drain_takes_and_clears_failed_ids() {
        let t = CompletionTable::new();
        reg(&t, &[0, 1, 2, 3]);
        t.complete_failed(JobId(3));
        t.complete(result(1));
        t.complete_failed(JobId(0));
        t.complete(result(2));
        assert_eq!(t.failed_count(), 2);
        let drained = t.drain(Duration::from_millis(50));
        assert_eq!(drained.completed.len(), 2);
        assert_eq!(drained.failed, vec![JobId(0), JobId(3)]);
        // Cleared: the table holds nothing for retired jobs.
        assert_eq!(t.failed_count(), 0);
        assert_eq!(t.pending(), 0);
        let again = t.drain(Duration::from_millis(5));
        assert!(again.completed.is_empty() && again.failed.is_empty());
    }

    /// `wait_any` must not block — let alone "forever" — once every
    /// outstanding job has retired as failed: no completion can ever
    /// arrive, so it reports empty immediately.
    #[test]
    fn wait_any_returns_none_when_all_outstanding_failed() {
        let t = CompletionTable::new();
        reg(&t, &[0, 1]);
        t.complete_failed(JobId(0));
        t.complete_failed(JobId(1));
        let start = Instant::now();
        assert!(t.wait_any(Duration::MAX).is_none());
        assert!(start.elapsed() < Duration::from_secs(5));
        assert_eq!(t.failed_count(), 2);
    }

    /// `Duration::MAX` means "wait forever" and must not panic the
    /// deadline arithmetic in wait / wait_any / drain.
    #[test]
    fn duration_max_timeouts_do_not_panic() {
        let t = CompletionTable::new();
        reg(&t, &[0, 1]);
        t.complete(result(0));
        t.complete(result(1));
        let state = t.wait(JobHandle { id: JobId(0) }, Duration::MAX);
        assert!(state.is_done());
        assert_eq!(t.wait_any(Duration::MAX).unwrap().id, JobId(1));
        let drained = t.drain(Duration::MAX);
        assert!(drained.completed.is_empty() && drained.failed.is_empty());
        // A forever-wait on an already-redeemed handle reports the
        // documented already-taken answer instead of hanging.
        let start = Instant::now();
        assert!(matches!(
            t.wait(JobHandle { id: JobId(0) }, Duration::MAX),
            JobState::Pending
        ));
        assert!(start.elapsed() < Duration::from_secs(5));
    }

    /// `forget` drops parked results immediately and in-flight ones at
    /// retirement; live handles are untouched.
    #[test]
    fn forget_drops_parked_and_inflight_results() {
        let t = CompletionTable::new();
        reg(&t, &[0, 1, 2]);
        t.complete(result(0)); // parked, never redeemed
        t.forget(&[JobId(0), JobId(1)]); // 0 parked, 1 still in flight
        assert_eq!(t.unclaimed(), 0);
        t.complete(result(1)); // orphaned: dropped at retirement
        assert_eq!(t.unclaimed(), 0);
        assert_eq!(t.pending(), 1);
        t.complete(result(2)); // live handle unaffected
        assert_eq!(t.unclaimed(), 1);
        let drained = t.drain(Duration::from_millis(50));
        assert_eq!(drained.completed.len(), 1);
        assert_eq!(drained.completed[0].id, JobId(2));
        assert!(drained.failed.is_empty());
        assert_eq!(t.pending(), 0);
        assert_eq!(t.unclaimed(), 0);
    }

    /// Forgetting failed markers and already-redeemed ids is safe and
    /// leaves no state behind (the orphan set self-clears at empty).
    #[test]
    fn forget_failed_and_redeemed_ids_is_safe() {
        let t = CompletionTable::new();
        reg(&t, &[0, 1]);
        t.complete_failed(JobId(0));
        t.forget(&[JobId(0)]);
        assert_eq!(t.failed_count(), 0);
        t.complete(result(1));
        assert!(t.poll(JobHandle { id: JobId(1) }).is_done());
        // Already redeemed + pipeline empty: ignored entirely.
        t.forget(&[JobId(1)]);
        assert_eq!(t.pending(), 0);
        assert_eq!(t.unclaimed(), 0);
        // The table still works afterwards.
        reg(&t, &[7]);
        t.complete(result(7));
        assert_eq!(t.wait_any(Duration::from_millis(50)).unwrap().id, JobId(7));
    }

    /// Targeted redemption and forget prune `order`: its length tracks
    /// *currently parked* results, not all-time completions — a server
    /// whose clients only ever `wait(id)` must not grow the queue.
    #[test]
    fn order_queue_stays_bounded_under_targeted_redemption() {
        let t = CompletionTable::new();
        reg(&t, &[0, 1, 2]);
        t.complete(result(0));
        t.complete(result(1));
        t.complete(result(2));
        assert!(t.poll(JobHandle { id: JobId(1) }).is_done());
        t.forget(&[JobId(0)]);
        assert_eq!(t.inner.lock().unwrap().order.len(), 1);
        assert_eq!(
            t.wait_any(Duration::from_millis(10)).unwrap().id,
            JobId(2)
        );
        assert_eq!(t.inner.lock().unwrap().order.len(), 0);
    }

    /// Shedding drops parked results, orphans in-flight jobs, and
    /// leaves a consume-once terminal marker.
    #[test]
    fn shed_is_terminal_and_consumed_once() {
        let t = CompletionTable::new();
        reg(&t, &[0, 1, 2]);
        t.complete(result(0)); // parked
        assert_eq!(t.shed(&[JobId(0), JobId(1)]), 2);
        assert_eq!(t.unclaimed(), 0);
        assert_eq!(t.shed_count(), 2);
        assert!(matches!(t.poll(JobHandle { id: JobId(0) }), JobState::Shed));
        assert!(matches!(
            t.wait(JobHandle { id: JobId(1) }, Duration::from_millis(5)),
            JobState::Shed
        ));
        // Consumed: a second redemption reports Pending like a taken
        // Done; the orphaned in-flight job's result drops on arrival.
        assert!(matches!(t.poll(JobHandle { id: JobId(0) }), JobState::Pending));
        t.complete(result(1));
        assert_eq!(t.unclaimed(), 0);
        assert_eq!(t.shed_count(), 0);
        // Untouched third handle still works; already-retired ids
        // shed to nothing.
        t.complete(result(2));
        assert!(t.poll(JobHandle { id: JobId(2) }).is_done());
        assert_eq!(t.shed(&[JobId(2)]), 0);
        assert_eq!(t.pending(), 0);
    }

    /// A `wait` already blocked on a handle resolves to `Shed` the
    /// moment the shed happens — it must not sleep out its timeout.
    #[test]
    fn shed_wakes_a_blocked_wait() {
        let t = Arc::new(CompletionTable::new());
        reg(&t, &[9]);
        let t2 = Arc::clone(&t);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            t2.shed(&[JobId(9)]);
        });
        let start = Instant::now();
        let state = t.wait(JobHandle { id: JobId(9) }, Duration::from_secs(60));
        assert!(matches!(state, JobState::Shed), "got {state:?}");
        assert!(start.elapsed() < Duration::from_secs(30));
        h.join().unwrap();
        // The job is still in flight (orphaned); retirement clears it.
        t.complete_failed(JobId(9));
        assert_eq!(t.pending(), 0);
        assert_eq!(t.failed_count(), 0);
    }

    /// `drain_ids` retires only the requested handles; everyone
    /// else's state stays parked.
    #[test]
    fn drain_ids_scopes_to_the_given_handles() {
        let t = CompletionTable::new();
        reg(&t, &[0, 1, 2, 3]);
        t.complete(result(0));
        t.complete(result(2));
        t.complete_failed(JobId(1));
        t.complete(result(3));
        let mine = t.drain_ids(
            &[JobId(0), JobId(1)],
            Duration::from_millis(50),
        );
        assert_eq!(mine.completed.len(), 1);
        assert_eq!(mine.completed[0].id, JobId(0));
        assert_eq!(mine.failed, vec![JobId(1)]);
        // The other session's results are untouched and still in
        // arrival order.
        assert_eq!(t.unclaimed(), 2);
        assert_eq!(t.wait_any(Duration::from_millis(10)).unwrap().id, JobId(2));
        assert_eq!(t.wait_any(Duration::from_millis(10)).unwrap().id, JobId(3));
    }

    /// `live_pending` discounts orphaned work so shed capacity frees
    /// immediately; global `drain` takes leftover shed markers.
    #[test]
    fn live_pending_discounts_orphans_and_drain_takes_shed() {
        let t = CompletionTable::new();
        reg(&t, &[0, 1]);
        assert_eq!(t.live_pending(), 2);
        t.shed(&[JobId(0)]);
        assert_eq!(t.pending(), 2);
        assert_eq!(t.live_pending(), 1);
        t.complete(result(0));
        t.complete(result(1));
        let drained = t.drain(Duration::from_millis(50));
        assert_eq!(drained.completed.len(), 1);
        assert_eq!(drained.failed, vec![JobId(0)]);
        assert_eq!(t.shed_count(), 0);
    }

    #[test]
    fn wait_blocks_until_cross_thread_completion() {
        let t = Arc::new(CompletionTable::new());
        reg(&t, &[4]);
        let t2 = Arc::clone(&t);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            t2.complete(result(4));
        });
        let state = t.wait(JobHandle { id: JobId(4) }, Duration::from_secs(5));
        assert!(state.is_done());
        h.join().unwrap();
    }
}
