//! Service metrics: shared counters + latency aggregation, global and
//! per-session.

use crate::exec::ScratchStats;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Cap on retained per-session latency samples: a sliding window, so
/// a tenant's job count cannot grow server memory without bound.
/// Percentiles are computed over the most recent window — exactly
/// what a load-shedding decision or a starvation bound wants anyway.
pub const SESSION_LATENCY_WINDOW: usize = 512;

/// Per-session aggregation: the QoS layer records every completion,
/// rejection, shed and deadline miss against the session that caused
/// it, so one tenant's flood is visible *as that tenant's numbers*
/// instead of smearing into the global averages.
#[derive(Debug, Default)]
pub struct SessionStats {
    /// The most recent [`SESSION_LATENCY_WINDOW`] wall latencies (a
    /// ring buffer once full — `lat_next` is the overwrite cursor).
    pub latencies_us: Vec<u64>,
    lat_next: usize,
    /// All-time completions redeemed by this session (not capped by
    /// the latency window).
    pub jobs_completed: u64,
    pub jobs_submitted: u64,
    pub admission_rejected: u64,
    pub shed: u64,
    pub deadline_misses: u64,
}

impl SessionStats {
    fn record_latency(&mut self, us: u64) {
        self.jobs_completed += 1;
        if self.latencies_us.len() < SESSION_LATENCY_WINDOW {
            self.latencies_us.push(us);
        } else {
            self.latencies_us[self.lat_next] = us;
            self.lat_next = (self.lat_next + 1) % SESSION_LATENCY_WINDOW;
        }
    }

    /// (p50, p95, p99) wall latency in microseconds over the retained
    /// window.
    pub fn percentiles(&self) -> (u64, u64, u64) {
        let mut v = self.latencies_us.clone();
        if v.is_empty() {
            return (0, 0, 0);
        }
        v.sort_unstable();
        let at = |pct: usize| v[(v.len() * pct / 100).min(v.len() - 1)];
        (v[v.len() / 2], at(95), at(99))
    }
}

/// Thread-shared metrics for the job service.
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub macs: AtomicU64,
    pub sim_cycles: AtomicU64,
    pub guard_overflows: AtomicU64,
    /// Tile-level work items executed (≥ jobs when sharding).
    pub tiles_executed: AtomicU64,
    /// Work units taken from another worker's shard.
    pub steals: AtomicU64,
    /// `submit_batch` calls (a single `submit` counts as a batch of 1).
    pub batches_submitted: AtomicU64,
    /// Stationary weight fills actually performed by WS workers.
    pub fills_issued: AtomicU64,
    /// Fills skipped because the weight tile was already resident
    /// (batched weight-tile reuse).
    pub fills_avoided: AtomicU64,
    /// Slow cycles the avoided fills would have cost.
    pub fill_cycles_saved: AtomicU64,
    /// Work tiles dropped before enqueue because they held no work:
    /// all-zero sparse weight tiles and empty CSR row windows.
    pub tiles_skipped: AtomicU64,
    /// Dense-equivalent MACs those skipped tiles would have streamed.
    pub macs_skipped: AtomicU64,
    /// Scratch-arena lease calls across all workers' engines.
    pub scratch_leases: AtomicU64,
    /// Scratch leases served by a pooled buffer (no allocation).
    pub scratch_reuse_hits: AtomicU64,
    /// Peak bytes simultaneously out on lease on any one worker's
    /// arena (max across workers, not a sum — it bounds per-engine
    /// footprint).
    pub scratch_high_water_bytes: AtomicU64,
    /// Model-graph layers fully executed (matmul and elementwise glue
    /// alike; a model job of L layers adds L on completion).
    pub layers_completed: AtomicU64,
    /// Peak bytes of intermediate activations simultaneously resident
    /// in model arenas (max across models — the model input and final
    /// output are not counted, only the tensors that would otherwise
    /// round-trip through the client).
    pub intermediate_bytes_resident: AtomicU64,
    /// Stationary fills avoided *across layers of one model* — tiles
    /// from different layers at the same wavefront level sharing one
    /// fill group (a subset of `fills_avoided`).
    pub inter_layer_fill_reuse: AtomicU64,
    /// Bytes of intermediate activations resident in model arenas
    /// *right now* (a live gauge, unlike the
    /// `intermediate_bytes_resident` high-water mark). Returns to
    /// zero whenever no model is mid-execution — the chaos harness's
    /// arena-leak invariant.
    pub intermediate_bytes_now: AtomicU64,
    /// Submits refused by admission control (session quota or the
    /// global high-water gate) — nothing was enqueued.
    pub admission_rejected: AtomicU64,
    /// Handles evicted to relieve overload (largest unprivileged
    /// holder first).
    pub jobs_shed: AtomicU64,
    /// `wait`/`drain` calls whose per-session deadline cap expired
    /// before the handle resolved.
    pub deadline_misses: AtomicU64,
    /// Connections reaped by the idle read deadline (slow-loris /
    /// half-open clients holding a server thread).
    pub idle_reaped: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
    /// Per-session aggregation, keyed by session id. The frontend
    /// reaps an entry when its session closes
    /// ([`Metrics::remove_session`]), so the map is bounded by *live*
    /// connections — connection churn cannot grow server memory for
    /// its lifetime.
    sessions: Mutex<BTreeMap<u64, SessionStats>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_completion(&self, macs: u64, cycles: u64, wall: Duration) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.macs.fetch_add(macs, Ordering::Relaxed);
        self.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
        self.latencies_us
            .lock()
            .unwrap()
            .push(wall.as_micros() as u64);
    }

    /// Record a redeemed result's wall latency against its session
    /// (sliding window: at most [`SESSION_LATENCY_WINDOW`] samples
    /// retained per session).
    pub fn record_session_latency(&self, session: u64, wall: Duration) {
        self.sessions
            .lock()
            .unwrap()
            .entry(session)
            .or_default()
            .record_latency(wall.as_micros() as u64);
    }

    /// Drop a closed session's aggregation entry: called by the
    /// frontend on session close, so per-session state lives exactly
    /// as long as the session does.
    pub fn remove_session(&self, session: u64) {
        self.sessions.lock().unwrap().remove(&session);
    }

    /// Record accepted submissions against a session.
    pub fn record_session_submitted(&self, session: u64, jobs: u64) {
        self.sessions
            .lock()
            .unwrap()
            .entry(session)
            .or_default()
            .jobs_submitted += jobs;
    }

    /// Record an admission refusal against the offending session.
    pub fn record_admission_rejected(&self, session: u64) {
        self.admission_rejected.fetch_add(1, Ordering::Relaxed);
        self.sessions
            .lock()
            .unwrap()
            .entry(session)
            .or_default()
            .admission_rejected += 1;
    }

    /// Record `count` handles shed from a session.
    pub fn record_shed(&self, session: u64, count: u64) {
        self.jobs_shed.fetch_add(count, Ordering::Relaxed);
        self.sessions
            .lock()
            .unwrap()
            .entry(session)
            .or_default()
            .shed += count;
    }

    /// Record a deadline-capped wait that expired unresolved.
    pub fn record_deadline_miss(&self, session: u64) {
        self.deadline_misses.fetch_add(1, Ordering::Relaxed);
        self.sessions
            .lock()
            .unwrap()
            .entry(session)
            .or_default()
            .deadline_misses += 1;
    }

    /// Read one session's p99 latency (tests and the starvation bound).
    pub fn session_p99_us(&self, session: u64) -> u64 {
        self.sessions
            .lock()
            .unwrap()
            .get(&session)
            .map_or(0, |s| s.percentiles().2)
    }

    /// (p50, p95, max) wall latency in microseconds.
    pub fn latency_percentiles(&self) -> (u64, u64, u64) {
        let mut v = self.latencies_us.lock().unwrap().clone();
        if v.is_empty() {
            return (0, 0, 0);
        }
        v.sort_unstable();
        (
            v[v.len() / 2],
            v[(v.len() * 95 / 100).min(v.len() - 1)],
            *v.last().unwrap(),
        )
    }

    /// Fraction of stationary fills avoided through weight-tile reuse
    /// (0 when nothing repeated).
    pub fn fill_amortization(&self) -> f64 {
        let issued = self.fills_issued.load(Ordering::Relaxed);
        let avoided = self.fills_avoided.load(Ordering::Relaxed);
        if issued + avoided == 0 {
            0.0
        } else {
            avoided as f64 / (issued + avoided) as f64
        }
    }

    /// Fold one worker's scratch-arena snapshot into the shared
    /// counters. `prev` is the last snapshot recorded for that worker —
    /// the arena counters are monotonic, so the difference is an exact
    /// delta; the high-water mark takes a max across workers. The
    /// monotonicity contract is enforced here (a non-monotonic source
    /// would otherwise wrap the shared counters): loud in debug,
    /// saturating in release.
    pub fn record_scratch(&self, prev: &ScratchStats, now: &ScratchStats) {
        debug_assert!(
            now.leases() >= prev.leases()
                && now.reuse_hits() >= prev.reuse_hits(),
            "scratch snapshots must be monotonic per worker"
        );
        self.scratch_leases.fetch_add(
            now.leases().saturating_sub(prev.leases()),
            Ordering::Relaxed,
        );
        self.scratch_reuse_hits.fetch_add(
            now.reuse_hits().saturating_sub(prev.reuse_hits()),
            Ordering::Relaxed,
        );
        self.scratch_high_water_bytes
            .fetch_max(now.high_water_bytes, Ordering::Relaxed);
    }

    /// Fraction of scratch leases served from a pool across all
    /// workers (0 when nothing leased yet).
    pub fn scratch_reuse_ratio(&self) -> f64 {
        let leases = self.scratch_leases.load(Ordering::Relaxed);
        if leases == 0 {
            0.0
        } else {
            self.scratch_reuse_hits.load(Ordering::Relaxed) as f64
                / leases as f64
        }
    }

    /// Fraction of submitted MAC work that actually streamed through
    /// an array: `1 - macs_skipped / macs`. 1.0 for all-dense traffic
    /// (nothing skipped) and for an idle service; lower means the
    /// sparse skip paths are eating real work.
    pub fn effective_density(&self) -> f64 {
        let macs = self.macs.load(Ordering::Relaxed);
        if macs == 0 {
            return 1.0;
        }
        let skipped = self.macs_skipped.load(Ordering::Relaxed);
        (1.0 - skipped as f64 / macs as f64).clamp(0.0, 1.0)
    }

    /// Achieved MACs per simulated cycle across every completed job.
    pub fn effective_macs_per_cycle(&self) -> f64 {
        let cycles = self.sim_cycles.load(Ordering::Relaxed);
        if cycles == 0 {
            0.0
        } else {
            self.macs.load(Ordering::Relaxed) as f64 / cycles as f64
        }
    }

    /// The metrics snapshot as one JSON object — the single emitter
    /// behind the wire protocol's `Stats`/`Shutdown` responses,
    /// `serve`'s end-of-run report, and the bench artifact writer.
    /// Counter keys match the field names; derived rates ride along so
    /// consumers never recompute them differently.
    pub fn snapshot_json(&self) -> Json {
        let (p50, p95, max) = self.latency_percentiles();
        let load = |c: &AtomicU64| Json::uint(c.load(Ordering::Relaxed));
        Json::object([
            ("jobs_submitted", load(&self.jobs_submitted)),
            ("jobs_completed", load(&self.jobs_completed)),
            ("jobs_failed", load(&self.jobs_failed)),
            ("batches_submitted", load(&self.batches_submitted)),
            ("macs", load(&self.macs)),
            ("sim_cycles", load(&self.sim_cycles)),
            ("guard_overflows", load(&self.guard_overflows)),
            ("tiles_executed", load(&self.tiles_executed)),
            ("steals", load(&self.steals)),
            ("fills_issued", load(&self.fills_issued)),
            ("fills_avoided", load(&self.fills_avoided)),
            ("fill_cycles_saved", load(&self.fill_cycles_saved)),
            ("fill_amortization", Json::float(self.fill_amortization())),
            ("tiles_skipped", load(&self.tiles_skipped)),
            ("macs_skipped", load(&self.macs_skipped)),
            ("effective_density", Json::float(self.effective_density())),
            ("scratch_leases", load(&self.scratch_leases)),
            ("scratch_reuse_hits", load(&self.scratch_reuse_hits)),
            (
                "scratch_high_water_bytes",
                load(&self.scratch_high_water_bytes),
            ),
            (
                "scratch_reuse_ratio",
                Json::float(self.scratch_reuse_ratio()),
            ),
            ("layers_completed", load(&self.layers_completed)),
            (
                "intermediate_bytes_resident",
                load(&self.intermediate_bytes_resident),
            ),
            (
                "inter_layer_fill_reuse",
                load(&self.inter_layer_fill_reuse),
            ),
            (
                "effective_macs_per_cycle",
                Json::float(self.effective_macs_per_cycle()),
            ),
            ("latency_p50_us", Json::uint(p50)),
            ("latency_p95_us", Json::uint(p95)),
            ("latency_max_us", Json::uint(max)),
            (
                "intermediate_bytes_now",
                load(&self.intermediate_bytes_now),
            ),
            ("admission_rejected", load(&self.admission_rejected)),
            ("jobs_shed", load(&self.jobs_shed)),
            ("deadline_misses", load(&self.deadline_misses)),
            ("idle_reaped", load(&self.idle_reaped)),
            ("sessions", self.sessions_json()),
        ])
    }

    /// The per-session breakdown: an object keyed by decimal session
    /// id, each value carrying that tenant's p50/p95/p99 latency and
    /// its QoS counters.
    fn sessions_json(&self) -> Json {
        let sessions = self.sessions.lock().unwrap();
        Json::object(sessions.iter().map(|(id, s)| {
            let (p50, p95, p99) = s.percentiles();
            (
                id.to_string(),
                Json::object([
                    ("jobs_submitted", Json::uint(s.jobs_submitted)),
                    ("jobs_completed", Json::uint(s.jobs_completed)),
                    ("admission_rejected", Json::uint(s.admission_rejected)),
                    ("shed", Json::uint(s.shed)),
                    ("deadline_misses", Json::uint(s.deadline_misses)),
                    ("latency_p50_us", Json::uint(p50)),
                    ("latency_p95_us", Json::uint(p95)),
                    ("latency_p99_us", Json::uint(p99)),
                ]),
            )
        }))
    }

    pub fn summary(&self) -> String {
        let (p50, p95, max) = self.latency_percentiles();
        format!(
            "jobs {}/{} ok ({} failed), {} MMACs, {} sim-cycles, \
             {} tiles ({} stolen, {} skipped), fills {} issued / {} avoided \
             ({} cycles saved, {} inter-layer), {} layers, \
             latency p50 {}us p95 {}us max {}us",
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.macs.load(Ordering::Relaxed) / 1_000_000,
            self.sim_cycles.load(Ordering::Relaxed),
            self.tiles_executed.load(Ordering::Relaxed),
            self.steals.load(Ordering::Relaxed),
            self.tiles_skipped.load(Ordering::Relaxed),
            self.fills_issued.load(Ordering::Relaxed),
            self.fills_avoided.load(Ordering::Relaxed),
            self.fill_cycles_saved.load(Ordering::Relaxed),
            self.inter_layer_fill_reuse.load(Ordering::Relaxed),
            self.layers_completed.load(Ordering::Relaxed),
            p50,
            p95,
            max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        m.record_completion(1_000_000, 500, Duration::from_micros(100));
        m.record_completion(2_000_000, 700, Duration::from_micros(300));
        let (p50, p95, max) = m.latency_percentiles();
        assert!(p50 >= 100 && p95 <= 300 && max == 300);
        assert!(m.summary().contains("3 MMACs"));
    }

    #[test]
    fn empty_percentiles_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentiles(), (0, 0, 0));
    }

    #[test]
    fn snapshot_json_matches_counters() {
        let m = Metrics::new();
        m.jobs_submitted.fetch_add(2, Ordering::Relaxed);
        m.fills_issued.fetch_add(4, Ordering::Relaxed);
        m.fills_avoided.fetch_add(12, Ordering::Relaxed);
        m.record_completion(1000, 100, Duration::from_micros(5));
        let snap = m.snapshot_json();
        assert_eq!(snap.get("jobs_submitted").unwrap().as_i64(), Some(2));
        assert_eq!(snap.get("jobs_completed").unwrap().as_i64(), Some(1));
        assert_eq!(snap.get("fills_avoided").unwrap().as_i64(), Some(12));
        assert_eq!(snap.get("latency_max_us").unwrap().as_i64(), Some(5));
        match snap.get("effective_macs_per_cycle").unwrap() {
            crate::util::json::Json::Float(f) => {
                assert!((f - 10.0).abs() < 1e-12)
            }
            other => panic!("expected float, got {other:?}"),
        }
        // The snapshot is the wire/report emitter: it must serialize
        // and re-parse unchanged.
        let parsed =
            crate::util::json::Json::parse(&snap.to_string()).unwrap();
        assert_eq!(parsed, snap);
    }

    #[test]
    fn scratch_deltas_accumulate_and_high_water_maxes() {
        use crate::exec::PoolStats;
        let m = Metrics::new();
        let pool = |leases, reuse_hits, high_water_bytes| PoolStats {
            leases,
            reuse_hits,
            leased_bytes: 0,
            high_water_bytes,
        };
        // Worker 1 reports twice; only the delta lands the second time.
        let w1_a = ScratchStats {
            i64_pool: pool(4, 1, 256),
            high_water_bytes: 256,
            ..Default::default()
        };
        m.record_scratch(&ScratchStats::default(), &w1_a);
        let w1_b = ScratchStats {
            i64_pool: pool(10, 6, 256),
            high_water_bytes: 256,
            ..Default::default()
        };
        m.record_scratch(&w1_a, &w1_b);
        // Worker 2's smaller arena peak must not lower the max.
        let w2 = ScratchStats {
            i32_pool: pool(2, 2, 64),
            high_water_bytes: 64,
            ..Default::default()
        };
        m.record_scratch(&ScratchStats::default(), &w2);
        assert_eq!(m.scratch_leases.load(Ordering::Relaxed), 12);
        assert_eq!(m.scratch_reuse_hits.load(Ordering::Relaxed), 8);
        assert_eq!(m.scratch_high_water_bytes.load(Ordering::Relaxed), 256);
        assert!((m.scratch_reuse_ratio() - 8.0 / 12.0).abs() < 1e-12);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("scratch_leases").unwrap().as_i64(), Some(12));
        assert_eq!(
            snap.get("scratch_high_water_bytes").unwrap().as_i64(),
            Some(256)
        );
    }

    /// The sparsity counters: effective density defaults to 1.0 when
    /// idle or all-dense, tracks `1 - macs_skipped / macs` otherwise,
    /// and the snapshot carries all three keys.
    #[test]
    fn sparsity_counters_and_effective_density() {
        let m = Metrics::new();
        assert_eq!(m.effective_density(), 1.0);
        m.record_completion(1000, 100, Duration::from_micros(1));
        assert_eq!(m.effective_density(), 1.0); // dense traffic
        m.tiles_skipped.fetch_add(20, Ordering::Relaxed);
        m.macs_skipped.fetch_add(750, Ordering::Relaxed);
        assert!((m.effective_density() - 0.25).abs() < 1e-12);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("tiles_skipped").unwrap().as_i64(), Some(20));
        assert_eq!(snap.get("macs_skipped").unwrap().as_i64(), Some(750));
        match snap.get("effective_density").unwrap() {
            crate::util::json::Json::Float(f) => {
                assert!((f - 0.25).abs() < 1e-12)
            }
            other => panic!("expected float, got {other:?}"),
        }
        assert!(m.summary().contains("20 skipped"));
    }

    #[test]
    fn model_counters_reach_the_snapshot_and_summary() {
        let m = Metrics::new();
        m.layers_completed.fetch_add(38, Ordering::Relaxed);
        m.inter_layer_fill_reuse.fetch_add(8, Ordering::Relaxed);
        // Residency is a high-water mark: a later, smaller model must
        // not lower it.
        m.intermediate_bytes_resident.fetch_max(4096, Ordering::Relaxed);
        m.intermediate_bytes_resident.fetch_max(512, Ordering::Relaxed);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("layers_completed").unwrap().as_i64(), Some(38));
        assert_eq!(
            snap.get("intermediate_bytes_resident").unwrap().as_i64(),
            Some(4096)
        );
        assert_eq!(
            snap.get("inter_layer_fill_reuse").unwrap().as_i64(),
            Some(8)
        );
        assert!(m.summary().contains("8 inter-layer"));
        assert!(m.summary().contains("38 layers"));
    }

    /// The QoS counters and the per-session breakdown reach the
    /// snapshot — keyed by decimal session id, with per-tenant
    /// percentiles independent of the global ones.
    #[test]
    fn session_stats_reach_the_snapshot() {
        let m = Metrics::new();
        m.record_session_submitted(3, 5);
        for us in [100, 200, 300, 400] {
            m.record_session_latency(3, Duration::from_micros(us));
        }
        m.record_session_latency(9, Duration::from_micros(7000));
        m.record_admission_rejected(9);
        m.record_shed(9, 4);
        m.record_deadline_miss(3);
        m.idle_reaped.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.session_p99_us(3), 400);
        assert_eq!(m.session_p99_us(42), 0);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("admission_rejected").unwrap().as_i64(), Some(1));
        assert_eq!(snap.get("jobs_shed").unwrap().as_i64(), Some(4));
        assert_eq!(snap.get("deadline_misses").unwrap().as_i64(), Some(1));
        assert_eq!(snap.get("idle_reaped").unwrap().as_i64(), Some(1));
        let sessions = snap.get("sessions").unwrap();
        let s3 = sessions.get("3").unwrap();
        assert_eq!(s3.get("jobs_submitted").unwrap().as_i64(), Some(5));
        assert_eq!(s3.get("jobs_completed").unwrap().as_i64(), Some(4));
        assert_eq!(s3.get("latency_p99_us").unwrap().as_i64(), Some(400));
        assert_eq!(s3.get("deadline_misses").unwrap().as_i64(), Some(1));
        let s9 = sessions.get("9").unwrap();
        assert_eq!(s9.get("shed").unwrap().as_i64(), Some(4));
        assert_eq!(s9.get("admission_rejected").unwrap().as_i64(), Some(1));
        // The snapshot still round-trips through the parser.
        let parsed =
            crate::util::json::Json::parse(&snap.to_string()).unwrap();
        assert_eq!(parsed, snap);
    }

    /// Per-session latency retention is a sliding window: sample
    /// storage is capped at [`SESSION_LATENCY_WINDOW`] while the
    /// completion counter keeps the all-time total, and reaping a
    /// session removes its entry entirely.
    #[test]
    fn session_latency_window_is_bounded_and_reapable() {
        let m = Metrics::new();
        let n = SESSION_LATENCY_WINDOW + 100;
        for i in 0..n {
            m.record_session_latency(5, Duration::from_micros(i as u64));
        }
        {
            let sessions = m.sessions.lock().unwrap();
            let s = sessions.get(&5).unwrap();
            assert_eq!(s.latencies_us.len(), SESSION_LATENCY_WINDOW);
            assert_eq!(s.jobs_completed, n as u64);
            // The window holds the most recent samples: the oldest
            // 100 were overwritten.
            assert!(s.latencies_us.iter().all(|&us| us >= 100));
        }
        assert_eq!(
            m.snapshot_json()
                .get("sessions")
                .unwrap()
                .get("5")
                .unwrap()
                .get("jobs_completed")
                .unwrap()
                .as_i64(),
            Some(n as i64)
        );
        m.remove_session(5);
        assert_eq!(m.session_p99_us(5), 0);
        assert!(m
            .snapshot_json()
            .get("sessions")
            .unwrap()
            .get("5")
            .is_none());
    }

    /// `intermediate_bytes_now` is a gauge: it rises with residency
    /// and must return to zero when arenas empty.
    #[test]
    fn intermediate_bytes_now_is_a_gauge() {
        let m = Metrics::new();
        m.intermediate_bytes_now.fetch_add(4096, Ordering::Relaxed);
        assert_eq!(
            m.snapshot_json()
                .get("intermediate_bytes_now")
                .unwrap()
                .as_i64(),
            Some(4096)
        );
        m.intermediate_bytes_now.fetch_sub(4096, Ordering::Relaxed);
        assert_eq!(
            m.snapshot_json()
                .get("intermediate_bytes_now")
                .unwrap()
                .as_i64(),
            Some(0)
        );
    }

    #[test]
    fn fill_amortization_and_effective_rate() {
        let m = Metrics::new();
        assert_eq!(m.fill_amortization(), 0.0);
        assert_eq!(m.effective_macs_per_cycle(), 0.0);
        m.fills_issued.fetch_add(4, Ordering::Relaxed);
        m.fills_avoided.fetch_add(12, Ordering::Relaxed);
        m.record_completion(1000, 100, Duration::from_micros(1));
        assert!((m.fill_amortization() - 0.75).abs() < 1e-12);
        assert!((m.effective_macs_per_cycle() - 10.0).abs() < 1e-12);
        assert!(m.summary().contains("4 issued / 12 avoided"));
    }
}
