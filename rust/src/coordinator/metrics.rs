//! Service metrics: shared counters + latency aggregation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Thread-shared metrics for the job service.
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub macs: AtomicU64,
    pub sim_cycles: AtomicU64,
    pub guard_overflows: AtomicU64,
    /// Tile-level work items executed (≥ jobs when sharding).
    pub tiles_executed: AtomicU64,
    /// Work units taken from another worker's shard.
    pub steals: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_completion(&self, macs: u64, cycles: u64, wall: Duration) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.macs.fetch_add(macs, Ordering::Relaxed);
        self.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
        self.latencies_us
            .lock()
            .unwrap()
            .push(wall.as_micros() as u64);
    }

    /// (p50, p95, max) wall latency in microseconds.
    pub fn latency_percentiles(&self) -> (u64, u64, u64) {
        let mut v = self.latencies_us.lock().unwrap().clone();
        if v.is_empty() {
            return (0, 0, 0);
        }
        v.sort_unstable();
        (
            v[v.len() / 2],
            v[(v.len() * 95 / 100).min(v.len() - 1)],
            *v.last().unwrap(),
        )
    }

    pub fn summary(&self) -> String {
        let (p50, p95, max) = self.latency_percentiles();
        format!(
            "jobs {}/{} ok ({} failed), {} MMACs, {} sim-cycles, \
             {} tiles ({} stolen), latency p50 {}us p95 {}us max {}us",
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.macs.load(Ordering::Relaxed) / 1_000_000,
            self.sim_cycles.load(Ordering::Relaxed),
            self.tiles_executed.load(Ordering::Relaxed),
            self.steals.load(Ordering::Relaxed),
            p50,
            p95,
            max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        m.record_completion(1_000_000, 500, Duration::from_micros(100));
        m.record_completion(2_000_000, 700, Duration::from_micros(300));
        let (p50, p95, max) = m.latency_percentiles();
        assert!(p50 >= 100 && p95 <= 300 && max == 300);
        assert!(m.summary().contains("3 MMACs"));
    }

    #[test]
    fn empty_percentiles_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentiles(), (0, 0, 0));
    }
}
