//! Service metrics: shared counters + latency aggregation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Thread-shared metrics for the job service.
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub macs: AtomicU64,
    pub sim_cycles: AtomicU64,
    pub guard_overflows: AtomicU64,
    /// Tile-level work items executed (≥ jobs when sharding).
    pub tiles_executed: AtomicU64,
    /// Work units taken from another worker's shard.
    pub steals: AtomicU64,
    /// `submit_batch` calls (a single `submit` counts as a batch of 1).
    pub batches_submitted: AtomicU64,
    /// Stationary weight fills actually performed by WS workers.
    pub fills_issued: AtomicU64,
    /// Fills skipped because the weight tile was already resident
    /// (batched weight-tile reuse).
    pub fills_avoided: AtomicU64,
    /// Slow cycles the avoided fills would have cost.
    pub fill_cycles_saved: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_completion(&self, macs: u64, cycles: u64, wall: Duration) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.macs.fetch_add(macs, Ordering::Relaxed);
        self.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
        self.latencies_us
            .lock()
            .unwrap()
            .push(wall.as_micros() as u64);
    }

    /// (p50, p95, max) wall latency in microseconds.
    pub fn latency_percentiles(&self) -> (u64, u64, u64) {
        let mut v = self.latencies_us.lock().unwrap().clone();
        if v.is_empty() {
            return (0, 0, 0);
        }
        v.sort_unstable();
        (
            v[v.len() / 2],
            v[(v.len() * 95 / 100).min(v.len() - 1)],
            *v.last().unwrap(),
        )
    }

    /// Fraction of stationary fills avoided through weight-tile reuse
    /// (0 when nothing repeated).
    pub fn fill_amortization(&self) -> f64 {
        let issued = self.fills_issued.load(Ordering::Relaxed);
        let avoided = self.fills_avoided.load(Ordering::Relaxed);
        if issued + avoided == 0 {
            0.0
        } else {
            avoided as f64 / (issued + avoided) as f64
        }
    }

    /// Achieved MACs per simulated cycle across every completed job.
    pub fn effective_macs_per_cycle(&self) -> f64 {
        let cycles = self.sim_cycles.load(Ordering::Relaxed);
        if cycles == 0 {
            0.0
        } else {
            self.macs.load(Ordering::Relaxed) as f64 / cycles as f64
        }
    }

    pub fn summary(&self) -> String {
        let (p50, p95, max) = self.latency_percentiles();
        format!(
            "jobs {}/{} ok ({} failed), {} MMACs, {} sim-cycles, \
             {} tiles ({} stolen), fills {} issued / {} avoided \
             ({} cycles saved), latency p50 {}us p95 {}us max {}us",
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.macs.load(Ordering::Relaxed) / 1_000_000,
            self.sim_cycles.load(Ordering::Relaxed),
            self.tiles_executed.load(Ordering::Relaxed),
            self.steals.load(Ordering::Relaxed),
            self.fills_issued.load(Ordering::Relaxed),
            self.fills_avoided.load(Ordering::Relaxed),
            self.fill_cycles_saved.load(Ordering::Relaxed),
            p50,
            p95,
            max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        m.record_completion(1_000_000, 500, Duration::from_micros(100));
        m.record_completion(2_000_000, 700, Duration::from_micros(300));
        let (p50, p95, max) = m.latency_percentiles();
        assert!(p50 >= 100 && p95 <= 300 && max == 300);
        assert!(m.summary().contains("3 MMACs"));
    }

    #[test]
    fn empty_percentiles_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentiles(), (0, 0, 0));
    }

    #[test]
    fn fill_amortization_and_effective_rate() {
        let m = Metrics::new();
        assert_eq!(m.fill_amortization(), 0.0);
        assert_eq!(m.effective_macs_per_cycle(), 0.0);
        m.fills_issued.fetch_add(4, Ordering::Relaxed);
        m.fills_avoided.fetch_add(12, Ordering::Relaxed);
        m.record_completion(1000, 100, Duration::from_micros(1));
        assert!((m.fill_amortization() - 0.75).abs() < 1e-12);
        assert!((m.effective_macs_per_cycle() - 10.0).abs() < 1e-12);
        assert!(m.summary().contains("4 issued / 12 avoided"));
    }
}
