//! Spiking workloads: spike trains and the integer LIF neuron.
//!
//! Bit-exact twin of `ref.lif_reference` on the python side:
//! `v' = v - (v >> leak_shift) + I;  spike = v' >= thr;  v'' = v' - spike*thr`.

use crate::util::rng::XorShift;

/// A (T × N) binary spike train, row-major by timestep.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpikeTrain {
    pub steps: usize,
    pub neurons: usize,
    pub spikes: Vec<u8>,
}

impl SpikeTrain {
    /// Bernoulli spike train with firing probability `p_num/p_den`.
    pub fn random(rng: &mut XorShift, steps: usize, neurons: usize, p_num: u64, p_den: u64) -> Self {
        let spikes = (0..steps * neurons)
            .map(|_| u8::from(rng.chance(p_num, p_den)))
            .collect();
        SpikeTrain {
            steps,
            neurons,
            spikes,
        }
    }

    #[inline]
    pub fn at(&self, t: usize, n: usize) -> bool {
        self.spikes[t * self.neurons + n] != 0
    }

    pub fn step_row(&self, t: usize) -> &[u8] {
        &self.spikes[t * self.neurons..(t + 1) * self.neurons]
    }

    /// Mean firing rate (for workload reports).
    pub fn rate(&self) -> f64 {
        if self.spikes.is_empty() {
            return 0.0;
        }
        self.spikes.iter().map(|&s| s as u64).sum::<u64>() as f64
            / self.spikes.len() as f64
    }
}

/// Integer leaky integrate-and-fire layer state.
#[derive(Debug, Clone)]
pub struct LifLayer {
    pub v: Vec<i32>,
    pub threshold: i32,
    pub leak_shift: u32,
}

impl LifLayer {
    pub fn new(neurons: usize, threshold: i32, leak_shift: u32) -> Self {
        LifLayer {
            v: vec![0; neurons],
            threshold,
            leak_shift,
        }
    }

    /// One timestep: integrate `currents`, emit spikes, reset by
    /// subtraction. Returns the output spike row.
    pub fn step(&mut self, currents: &[i32]) -> Vec<u8> {
        assert_eq!(currents.len(), self.v.len());
        let mut out = Vec::with_capacity(self.v.len());
        for (v, &i_t) in self.v.iter_mut().zip(currents) {
            *v = *v - (*v >> self.leak_shift) + i_t;
            if *v >= self.threshold {
                *v -= self.threshold;
                out.push(1);
            } else {
                out.push(0);
            }
        }
        out
    }

    pub fn reset(&mut self) {
        self.v.iter_mut().for_each(|v| *v = 0);
    }
}

/// Reference synaptic currents: `spikes (T×P) @ weights (P×N)`.
pub fn golden_currents(train: &SpikeTrain, weights: &[i8], n_post: usize) -> Vec<i32> {
    assert_eq!(weights.len(), train.neurons * n_post);
    let mut out = vec![0i32; train.steps * n_post];
    for t in 0..train.steps {
        for p in 0..train.neurons {
            if !train.at(t, p) {
                continue;
            }
            for n in 0..n_post {
                out[t * n_post + n] += weights[p * n_post + n] as i32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lif_integrates_and_fires() {
        let mut lif = LifLayer::new(1, 10, 3);
        // Constant current 4: v goes 4, 4-0+4=8 (leak 4>>3=0), 8-1+4=11 -> spike, v=1 ...
        let s1 = lif.step(&[4]);
        assert_eq!(s1, vec![0]);
        assert_eq!(lif.v[0], 4);
        let s2 = lif.step(&[4]);
        assert_eq!(s2, vec![0]);
        assert_eq!(lif.v[0], 8);
        let s3 = lif.step(&[4]);
        assert_eq!(s3, vec![1]);
        assert_eq!(lif.v[0], 8 - 1 + 4 - 10);
    }

    #[test]
    fn lif_leak_decays() {
        let mut lif = LifLayer::new(1, 1_000_000, 2);
        lif.v[0] = 100;
        lif.step(&[0]);
        assert_eq!(lif.v[0], 75);
        lif.step(&[0]);
        assert_eq!(lif.v[0], 57); // 75 - 18
    }

    #[test]
    fn golden_currents_sum_selected_weights() {
        let mut rng = XorShift::new(1);
        let train = SpikeTrain::random(&mut rng, 4, 3, 1, 2);
        let weights: Vec<i8> = (0..6).map(|i| i as i8 + 1).collect(); // 3x2
        let cur = golden_currents(&train, &weights, 2);
        for t in 0..4 {
            for n in 0..2 {
                let expect: i32 = (0..3)
                    .filter(|&p| train.at(t, p))
                    .map(|p| weights[p * 2 + n] as i32)
                    .sum();
                assert_eq!(cur[t * 2 + n], expect);
            }
        }
    }

    #[test]
    fn spike_rate_tracks_probability() {
        let mut rng = XorShift::new(2);
        let train = SpikeTrain::random(&mut rng, 100, 100, 1, 4);
        assert!((train.rate() - 0.25).abs() < 0.02);
    }
}
