//! Symmetric INT8 quantization + the fixed-point requantizer.
//!
//! `requantize` is the bit-exact twin of
//! `python/compile/kernels/ref.py::requantize`; the e2e example relies
//! on the two staying identical (rust cycle-sim output must equal the
//! PJRT-executed HLO byte-for-byte).

/// Fixed-point requantization: `clip(round(acc * num / 2^shift) + zp)`.
///
/// Rounding is round-half-up via a `2^(shift-1)` offset before the
/// arithmetic right shift — the scheme a DSP48E2 implements for free
/// with the RND constant at the W multiplexer.
#[inline]
pub fn requantize(acc: i32, num: i32, shift: u32, zero_point: i32) -> i8 {
    debug_assert!(shift >= 1);
    let wide = acc as i64 * num as i64;
    let rounded = (wide + (1i64 << (shift - 1))) >> shift;
    (rounded + zero_point as i64).clamp(-128, 127) as i8
}

/// Per-tensor symmetric quantization of f32 data to INT8.
///
/// Returns the quantized values and the scale (`x ≈ q * scale`).
pub fn quantize_symmetric(xs: &[f32]) -> (Vec<i8>, f32) {
    let max_abs = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if max_abs == 0.0 {
        return (vec![0; xs.len()], 1.0);
    }
    let scale = max_abs / 127.0;
    let q = xs
        .iter()
        .map(|&x| (x / scale).round().clamp(-128.0, 127.0) as i8)
        .collect();
    (q, scale)
}

/// Derive the fixed-point (num, shift) pair approximating `real_scale`
/// with `shift` fractional bits.
pub fn fixed_point_scale(real_scale: f64, shift: u32) -> i32 {
    (real_scale * (1u64 << shift) as f64).round() as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requantize_matches_float_rounding() {
        // Mirrors python/tests/test_packing_algebra.py::TestRequantize.
        for acc in [-100_000i32, -777, -1, 0, 1, 999, 123_456] {
            for (num, shift) in [(77, 15u32), (1, 1), (32767, 20)] {
                let got = requantize(acc, num, shift, 0);
                let real = acc as f64 * num as f64 / (1u64 << shift) as f64;
                let want = (real + 0.5).floor().clamp(-128.0, 127.0) as i8;
                assert_eq!(got, want, "acc={acc} num={num} shift={shift}");
            }
        }
    }

    #[test]
    fn requantize_clips() {
        assert_eq!(requantize(i32::MAX, 1000, 1, 0), 127);
        assert_eq!(requantize(i32::MIN, 1000, 1, 0), -128);
    }

    #[test]
    fn zero_point_offsets() {
        assert_eq!(requantize(0, 1, 1, 3), 3);
        assert_eq!(requantize(100, 1, 1, 3), 53);
    }

    #[test]
    fn quantize_roundtrips_within_half_lsb() {
        let xs: Vec<f32> = (-50..50).map(|i| i as f32 * 0.37).collect();
        let (q, scale) = quantize_symmetric(&xs);
        for (x, qv) in xs.iter().zip(&q) {
            assert!((x - *qv as f32 * scale).abs() <= scale * 0.5 + 1e-6);
        }
    }

    #[test]
    fn quantize_zeros() {
        let (q, scale) = quantize_symmetric(&[0.0; 8]);
        assert!(q.iter().all(|&v| v == 0));
        assert_eq!(scale, 1.0);
    }

    #[test]
    fn fixed_point_scale_accuracy() {
        let num = fixed_point_scale(0.00235, 15);
        let approx = num as f64 / (1 << 15) as f64;
        assert!((approx - 0.00235).abs() < 1.0 / (1 << 15) as f64);
    }
}
