//! Conv2d lowered to GEMM via im2col — the DPU's native workload.
//!
//! The DPUCZDX8G evaluates convolutions as inner products over
//! (kernel_h × kernel_w × in_channels) patches with pixel/channel
//! parallelism; functionally that is exactly an im2col GEMM, which is
//! how the coordinator maps Conv jobs onto any matrix engine.
//!
//! Two lowering forms live here:
//!
//! * [`im2col`] — the **eager** reference: materializes the whole
//!   `(out_h·out_w) × (k·k·in_c)` patch matrix at once (an O(k²)
//!   memory blow-up over the raw input). Tests and golden comparisons
//!   use it; the service does not.
//! * [`PatchSource`] — the **lazy** view the coordinator executes
//!   against: it holds only the raw NCHW input and materializes the
//!   patch tile for one K-column span (or one row block) on demand,
//!   so peak operand memory stays per-tile no matter how large the
//!   conv is. Property tests pin the two forms bit-identical.

use super::gemm::{MatI32, MatI8};

/// NCHW conv shape descriptor (stride/pad/dilation uniform). Grouped
/// convolution splits the channels into `groups` independent slices:
/// output channel `oc` reads only the `in_c / groups` input channels
/// of its group, and the weight buffer stores
/// `(out_c, in_c / groups, k, k)`. The GEMM lowering stays a single
/// matmul — [`weights_to_gemm`] scatters the grouped storage into a
/// block-diagonal `(k·k·in_c, out_c)` matrix — so every engine path
/// (lazy tiles, row blocks, fill grouping) serves grouped convs
/// unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_c: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// Spacing between kernel taps; 1 = ordinary convolution. The
    /// effective kernel extent is `(k − 1) · dilation + 1`.
    pub dilation: usize,
    /// Channel groups; 1 = full connectivity, `in_c` = depthwise.
    /// Must divide both `in_c` and `out_c`.
    pub groups: usize,
}

/// Why a [`ConvShape`] (or a conv job's operand buffers) cannot be
/// lowered. Returned by [`ConvShape::validate`] / [`PatchSource::new`]
/// so the service resolves a bad submission as `Failed` instead of
/// panicking inside a worker thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvShapeError {
    /// `stride == 0` never advances the kernel window.
    ZeroStride,
    /// `dilation == 0` collapses every kernel tap onto one pixel.
    ZeroDilation,
    /// `groups == 0` leaves no channels anywhere.
    ZeroGroups,
    /// `groups` must divide the named channel dimension evenly.
    GroupsDontDivide {
        dim: &'static str,
        size: usize,
        groups: usize,
    },
    /// A channel/spatial/kernel dimension is zero.
    ZeroDim(&'static str),
    /// The kernel exceeds the padded input extent, so the output
    /// dimensions would underflow.
    KernelExceedsInput {
        k: usize,
        padded_h: usize,
        padded_w: usize,
    },
    /// Input buffer length disagrees with `in_c * in_h * in_w`.
    InputLen { expected: usize, got: usize },
    /// Weight buffer length disagrees with
    /// `out_c * (in_c / groups) * k * k`.
    WeightLen { expected: usize, got: usize },
    /// A derived size (buffer length, patch-matrix extent, MAC count)
    /// overflows `usize`.
    TooLarge,
}

impl std::fmt::Display for ConvShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConvShapeError::ZeroStride => write!(f, "stride must be > 0"),
            ConvShapeError::ZeroDilation => {
                write!(f, "dilation must be > 0")
            }
            ConvShapeError::ZeroGroups => write!(f, "groups must be > 0"),
            ConvShapeError::GroupsDontDivide { dim, size, groups } => {
                write!(f, "groups {groups} does not divide {dim} {size}")
            }
            ConvShapeError::ZeroDim(name) => {
                write!(f, "dimension `{name}` must be > 0")
            }
            ConvShapeError::KernelExceedsInput {
                k,
                padded_h,
                padded_w,
            } => write!(
                f,
                "kernel {k} exceeds padded input {padded_h}x{padded_w}"
            ),
            ConvShapeError::InputLen { expected, got } => {
                write!(f, "input has {got} elements, shape needs {expected}")
            }
            ConvShapeError::WeightLen { expected, got } => {
                write!(f, "weights have {got} elements, shape needs {expected}")
            }
            ConvShapeError::TooLarge => {
                write!(f, "shape dimensions overflow the address space")
            }
        }
    }
}

impl std::error::Error for ConvShapeError {}

impl ConvShape {
    /// Effective kernel extent under dilation: `(k − 1)·dilation + 1`
    /// (`None` on overflow or `dilation == 0`).
    fn checked_extent(&self) -> Option<usize> {
        if self.dilation == 0 {
            return None;
        }
        self.k
            .checked_sub(1)?
            .checked_mul(self.dilation)?
            .checked_add(1)
    }

    /// Output height if the shape is well-formed (`None` when the
    /// dilated kernel underflows the padded extent, `stride == 0`, or
    /// `dilation == 0`).
    pub fn checked_out_h(&self) -> Option<usize> {
        if self.stride == 0 {
            return None;
        }
        let padded = self.in_h.checked_add(self.pad.checked_mul(2)?)?;
        padded
            .checked_sub(self.checked_extent()?)
            .map(|d| d / self.stride + 1)
    }

    /// Output width, checked like [`ConvShape::checked_out_h`].
    pub fn checked_out_w(&self) -> Option<usize> {
        if self.stride == 0 {
            return None;
        }
        let padded = self.in_w.checked_add(self.pad.checked_mul(2)?)?;
        padded
            .checked_sub(self.checked_extent()?)
            .map(|d| d / self.stride + 1)
    }

    pub fn out_h(&self) -> usize {
        self.checked_out_h()
            .expect("invalid ConvShape (ConvShape::validate rejects it)")
    }

    pub fn out_w(&self) -> usize {
        self.checked_out_w()
            .expect("invalid ConvShape (ConvShape::validate rejects it)")
    }

    /// Reject shapes the arithmetic above cannot serve: zero stride
    /// (the window never advances), zero dimensions, and kernels larger
    /// than the padded input (output dims would underflow). The service
    /// calls this at submit so a bad shape resolves the job handle as
    /// `Failed` instead of panicking in a worker.
    pub fn validate(&self) -> Result<(), ConvShapeError> {
        if self.stride == 0 {
            return Err(ConvShapeError::ZeroStride);
        }
        if self.dilation == 0 {
            return Err(ConvShapeError::ZeroDilation);
        }
        if self.groups == 0 {
            return Err(ConvShapeError::ZeroGroups);
        }
        for (name, v) in [
            ("in_c", self.in_c),
            ("in_h", self.in_h),
            ("in_w", self.in_w),
            ("out_c", self.out_c),
            ("k", self.k),
        ] {
            if v == 0 {
                return Err(ConvShapeError::ZeroDim(name));
            }
        }
        for (dim, size) in [("in_c", self.in_c), ("out_c", self.out_c)] {
            if size % self.groups != 0 {
                return Err(ConvShapeError::GroupsDontDivide {
                    dim,
                    size,
                    groups: self.groups,
                });
            }
        }
        if self.checked_out_h().is_none() || self.checked_out_w().is_none() {
            let pad2 = self.pad.saturating_mul(2);
            // Report the *effective* (dilated) extent: that is what
            // exceeded the padded input.
            return Err(ConvShapeError::KernelExceedsInput {
                k: self
                    .checked_extent()
                    .unwrap_or(usize::MAX),
                padded_h: self.in_h.saturating_add(pad2),
                padded_w: self.in_w.saturating_add(pad2),
            });
        }
        // Every derived size downstream (buffer lengths, the patch
        // matrix extent, the MAC count) must fit in usize, or the
        // plain multiplications in input_len/weight_len/macs would
        // re-open the overflow-panic path this validation closes.
        let sizes_fit = (|| {
            let plane = self.in_h.checked_mul(self.in_w)?;
            plane.checked_mul(self.in_c)?;
            let kdim = self
                .k
                .checked_mul(self.k)?
                .checked_mul(self.in_c)?;
            kdim.checked_mul(self.out_c)?;
            let m = self.checked_out_h()?.checked_mul(self.checked_out_w()?)?;
            m.checked_mul(kdim)?.checked_mul(self.out_c)
        })();
        if sizes_fit.is_none() {
            return Err(ConvShapeError::TooLarge);
        }
        Ok(())
    }

    /// Elements a conforming NCHW input buffer must hold.
    pub fn input_len(&self) -> usize {
        self.in_c * self.in_h * self.in_w
    }

    /// Input channels per group (`in_c` when `groups == 1`).
    pub fn group_in_c(&self) -> usize {
        // groups == 0 is rejected by validate; max(1) keeps the
        // accessor total so error paths can still format lengths.
        self.in_c / self.groups.max(1)
    }

    /// Elements a conforming `(out_c, in_c / groups, k, k)` weight
    /// buffer must hold.
    pub fn weight_len(&self) -> usize {
        self.out_c * self.group_in_c() * self.k * self.k
    }

    /// GEMM dimensions after im2col: (M, K, N). K spans **all** input
    /// channels even when `groups > 1` — the grouped weight matrix is
    /// block-diagonal over the same K, so the lowering stays a single
    /// GEMM on every engine path.
    pub fn gemm_dims(&self) -> (usize, usize, usize) {
        (
            self.out_h() * self.out_w(),
            self.k * self.k * self.in_c,
            self.out_c,
        )
    }

    /// Dense-equivalent MACs of the lowered GEMM. Like the sparse
    /// workload's accounting, the zero blocks a grouped conv streams
    /// count as delivered work (the array executes them).
    pub fn macs(&self) -> u64 {
        let (m, k, n) = self.gemm_dims();
        (m * k * n) as u64
    }
}

/// im2col: input (C, H, W) flattened row-major -> patch matrix
/// (out_h*out_w, k*k*in_c). Zero padding. This is the eager reference
/// the lazy [`PatchSource`] is property-tested against.
pub fn im2col(input: &[i8], shape: ConvShape) -> MatI8 {
    assert_eq!(input.len(), shape.input_len());
    let (m, kdim, _) = shape.gemm_dims();
    let mut out = MatI8::zeros(m, kdim);
    let (oh, ow) = (shape.out_h(), shape.out_w());
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            let mut col = 0;
            for c in 0..shape.in_c {
                for ky in 0..shape.k {
                    for kx in 0..shape.k {
                        let iy = (oy * shape.stride + ky * shape.dilation) as isize
                            - shape.pad as isize;
                        let ix = (ox * shape.stride + kx * shape.dilation) as isize
                            - shape.pad as isize;
                        let v = if iy >= 0
                            && ix >= 0
                            && (iy as usize) < shape.in_h
                            && (ix as usize) < shape.in_w
                        {
                            input[c * shape.in_h * shape.in_w
                                + iy as usize * shape.in_w
                                + ix as usize]
                        } else {
                            0
                        };
                        out.set(row, col, v);
                        col += 1;
                    }
                }
            }
        }
    }
    out
}

/// A lazily-tiled im2col view over a raw NCHW input.
///
/// Holds only the input buffer (O(C·H·W)); the patch matrix —
/// `(out_h·out_w) × (k·k·in_c)`, an O(k²) blow-up — is never built.
/// Instead the coordinator asks for exactly the slice one work unit
/// needs: [`PatchSource::extract_cols`] for a weight-stationary tile's
/// K-span (the WS tiler path) or [`PatchSource::extract_rows`] for a
/// row block (engines that tile internally). Column order matches
/// [`im2col`] exactly: `col = c·k·k + ky·k + kx`.
#[derive(Debug, Clone)]
pub struct PatchSource {
    input: Vec<i8>,
    shape: ConvShape,
    out_h: usize,
    out_w: usize,
}

impl PatchSource {
    /// Validate the shape and take ownership of the input buffer.
    pub fn new(input: Vec<i8>, shape: ConvShape) -> Result<Self, ConvShapeError> {
        shape.validate()?;
        if input.len() != shape.input_len() {
            return Err(ConvShapeError::InputLen {
                expected: shape.input_len(),
                got: input.len(),
            });
        }
        Ok(PatchSource {
            out_h: shape.out_h(),
            out_w: shape.out_w(),
            input,
            shape,
        })
    }

    pub fn shape(&self) -> ConvShape {
        self.shape
    }

    /// The raw NCHW input buffer (for direct-conv verification).
    pub fn input(&self) -> &[i8] {
        &self.input
    }

    /// Patch-matrix rows: M = out_h · out_w.
    pub fn rows(&self) -> usize {
        self.out_h * self.out_w
    }

    /// Patch-matrix columns: K = k · k · in_c.
    pub fn cols(&self) -> usize {
        self.shape.k * self.shape.k * self.shape.in_c
    }

    /// Decompose a patch-matrix column into `(channel, ky, kx)` — the
    /// inverse of the column-order invariant `col = c·k·k + ky·k + kx`
    /// shared with [`im2col`] and [`weights_to_gemm`]. Every lazy
    /// extraction goes through this one helper so the ordering cannot
    /// silently diverge between paths.
    fn col_decompose(&self, col: usize) -> (usize, usize, usize) {
        let k = self.shape.k;
        let rem = col % (k * k);
        (col / (k * k), rem / k, rem % k)
    }

    /// One patch-matrix element, zero-padding aware (the per-element
    /// reference [`PatchSource::extract_cols`] is tested against).
    pub fn at(&self, row: usize, col: usize) -> i8 {
        let s = &self.shape;
        let (oy, ox) = (row / self.out_w, row % self.out_w);
        let (c, ky, kx) = self.col_decompose(col);
        let iy = (oy * s.stride + ky * s.dilation) as isize - s.pad as isize;
        let ix = (ox * s.stride + kx * s.dilation) as isize - s.pad as isize;
        if iy < 0 || ix < 0 || iy as usize >= s.in_h || ix as usize >= s.in_w {
            0
        } else {
            self.input[c * s.in_h * s.in_w + iy as usize * s.in_w + ix as usize]
        }
    }

    /// Materialize patch columns `k0..k1` for every output pixel into
    /// an `(M × width)` tile, the tail columns zero — exactly the
    /// padded activation tile a weight-stationary array consumes for
    /// one [`TileCoord`](crate::coordinator::tiler::TileCoord). The
    /// per-column kernel offset is decomposed once, then the inner
    /// loops walk the input plane.
    pub fn extract_cols(&self, k0: usize, k1: usize, width: usize) -> MatI8 {
        assert!(k0 <= k1 && k1 <= self.cols(), "K span out of range");
        assert!(k1 - k0 <= width, "tile width smaller than K span");
        let s = &self.shape;
        let mut t = MatI8::zeros(self.rows(), width);
        for (i, col) in (k0..k1).enumerate() {
            let (c, ky, kx) = self.col_decompose(col);
            let plane = &self.input[c * s.in_h * s.in_w..(c + 1) * s.in_h * s.in_w];
            let mut row = 0;
            for oy in 0..self.out_h {
                let iy =
                    (oy * s.stride + ky * s.dilation) as isize - s.pad as isize;
                let in_y = iy >= 0 && (iy as usize) < s.in_h;
                for ox in 0..self.out_w {
                    let ix = (ox * s.stride + kx * s.dilation) as isize
                        - s.pad as isize;
                    if in_y && ix >= 0 && (ix as usize) < s.in_w {
                        t.set(row, i, plane[iy as usize * s.in_w + ix as usize]);
                    }
                    row += 1;
                }
            }
        }
        t
    }

    /// Materialize patch rows `m0..m1` with all K columns — the row
    /// block an internally-tiling engine streams. Like
    /// [`PatchSource::extract_cols`], the kernel offset is decomposed
    /// once per column and the output pixel walks incrementally, so
    /// the inner loop is division-free (this is the conv hot path on
    /// OS/SNN engines).
    pub fn extract_rows(&self, m0: usize, m1: usize) -> MatI8 {
        assert!(m0 <= m1 && m1 <= self.rows(), "row span out of range");
        let s = &self.shape;
        let kdim = self.cols();
        let mut t = MatI8::zeros(m1 - m0, kdim);
        for col in 0..kdim {
            let (c, ky, kx) = self.col_decompose(col);
            let plane = &self.input[c * s.in_h * s.in_w..(c + 1) * s.in_h * s.in_w];
            let (mut oy, mut ox) = (m0 / self.out_w, m0 % self.out_w);
            for r in m0..m1 {
                let iy =
                    (oy * s.stride + ky * s.dilation) as isize - s.pad as isize;
                let ix =
                    (ox * s.stride + kx * s.dilation) as isize - s.pad as isize;
                if iy >= 0
                    && ix >= 0
                    && (iy as usize) < s.in_h
                    && (ix as usize) < s.in_w
                {
                    t.set(
                        r - m0,
                        col,
                        plane[iy as usize * s.in_w + ix as usize],
                    );
                }
                ox += 1;
                if ox == self.out_w {
                    ox = 0;
                    oy += 1;
                }
            }
        }
        t
    }

    /// The whole patch matrix (tests / eager comparisons only — the
    /// service never calls this).
    pub fn materialize(&self) -> MatI8 {
        self.extract_rows(0, self.rows())
    }
}

/// Weights (out_c, in_c / groups, k, k) flattened -> GEMM weight
/// matrix (k*k*in_c, out_c), matching [`im2col`]'s column order. With
/// `groups > 1` the result is block-diagonal: output column `oc` holds
/// zeros for every input channel outside its group, so a single GEMM
/// over the full-K patch matrix computes the grouped conv exactly.
pub fn weights_to_gemm(weights: &[i8], shape: ConvShape) -> MatI8 {
    assert_eq!(weights.len(), shape.weight_len());
    let kdim = shape.k * shape.k * shape.in_c;
    let cpg = shape.group_in_c();
    let opg = shape.out_c / shape.groups;
    MatI8::from_fn(kdim, shape.out_c, |row, oc| {
        // row = c * k * k + ky * k + kx
        let c = row / (shape.k * shape.k);
        let rem = row % (shape.k * shape.k);
        let gi = oc / opg;
        if (gi * cpg..(gi + 1) * cpg).contains(&c) {
            weights[oc * cpg * shape.k * shape.k
                + (c - gi * cpg) * shape.k * shape.k
                + rem]
        } else {
            0
        }
    })
}

/// Direct (naive) convolution for cross-checking the im2col path.
/// Walks only the `in_c / groups` channels of `oc`'s group, with the
/// dilated tap positions — the semantic reference the block-diagonal
/// GEMM lowering must match.
pub fn conv2d_direct(input: &[i8], weights: &[i8], shape: ConvShape) -> MatI32 {
    let (oh, ow) = (shape.out_h(), shape.out_w());
    let cpg = shape.group_in_c();
    let opg = shape.out_c / shape.groups;
    let mut out = MatI32::zeros(oh * ow, shape.out_c);
    for oc in 0..shape.out_c {
        let gi = oc / opg;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0i32;
                for c_local in 0..cpg {
                    let c = gi * cpg + c_local;
                    for ky in 0..shape.k {
                        for kx in 0..shape.k {
                            let iy = (oy * shape.stride
                                + ky * shape.dilation)
                                as isize
                                - shape.pad as isize;
                            let ix = (ox * shape.stride
                                + kx * shape.dilation)
                                as isize
                                - shape.pad as isize;
                            if iy < 0
                                || ix < 0
                                || iy as usize >= shape.in_h
                                || ix as usize >= shape.in_w
                            {
                                continue;
                            }
                            let iv = input[c * shape.in_h * shape.in_w
                                + iy as usize * shape.in_w
                                + ix as usize] as i32;
                            let wv = weights[oc * cpg * shape.k * shape.k
                                + c_local * shape.k * shape.k
                                + ky * shape.k
                                + kx] as i32;
                            acc += iv * wv;
                        }
                    }
                }
                out.set(oy * ow + ox, oc, acc);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::workload::gemm::golden_gemm;

    fn check_shape(shape: ConvShape, seed: u64) {
        let mut rng = XorShift::new(seed);
        let input = rng.i8_vec(shape.input_len());
        let weights = rng.i8_vec(shape.weight_len());
        let patches = im2col(&input, shape);
        let wmat = weights_to_gemm(&weights, shape);
        let via_gemm = golden_gemm(&patches, &wmat);
        let direct = conv2d_direct(&input, &weights, shape);
        assert_eq!(via_gemm, direct, "{shape:?}");
        // The lazy view agrees with the eager matrix element-for-element.
        let src = PatchSource::new(input, shape).unwrap();
        assert_eq!(src.rows(), patches.rows);
        assert_eq!(src.cols(), patches.cols);
        assert_eq!(src.materialize(), patches, "{shape:?}");
    }

    #[test]
    fn im2col_equals_direct_3x3() {
        check_shape(
            ConvShape {
                in_c: 3,
                in_h: 8,
                in_w: 8,
                out_c: 4,
                k: 3,
                stride: 1,
                pad: 1,
                dilation: 1,
                groups: 1,
            },
            1,
        );
    }

    #[test]
    fn im2col_equals_direct_strided_no_pad() {
        check_shape(
            ConvShape {
                in_c: 2,
                in_h: 9,
                in_w: 7,
                out_c: 5,
                k: 3,
                stride: 2,
                pad: 0,
                dilation: 1,
                groups: 1,
            },
            2,
        );
    }

    #[test]
    fn im2col_equals_direct_1x1() {
        check_shape(
            ConvShape {
                in_c: 8,
                in_h: 4,
                in_w: 4,
                out_c: 8,
                k: 1,
                stride: 1,
                pad: 0,
                dilation: 1,
                groups: 1,
            },
            3,
        );
    }

    #[test]
    fn im2col_equals_direct_strided_padded_nonsquare() {
        // stride > 1 combined with pad > 0 on a non-square input.
        check_shape(
            ConvShape {
                in_c: 2,
                in_h: 7,
                in_w: 5,
                out_c: 3,
                k: 3,
                stride: 2,
                pad: 1,
                dilation: 1,
                groups: 1,
            },
            4,
        );
    }

    #[test]
    fn im2col_equals_direct_kernel_taller_than_input() {
        // k > in_h is valid as long as padding covers the deficit.
        check_shape(
            ConvShape {
                in_c: 3,
                in_h: 2,
                in_w: 9,
                out_c: 4,
                k: 3,
                stride: 1,
                pad: 1,
                dilation: 1,
                groups: 1,
            },
            5,
        );
    }

    #[test]
    fn im2col_equals_direct_dilated() {
        // dilation 2 on a padded input: taps reach 2 pixels apart, so
        // the effective extent is 5 over a 9x9 plane.
        check_shape(
            ConvShape {
                in_c: 3,
                in_h: 9,
                in_w: 9,
                out_c: 4,
                k: 3,
                stride: 1,
                pad: 2,
                dilation: 2,
                groups: 1,
            },
            6,
        );
    }

    #[test]
    fn im2col_equals_direct_grouped() {
        // 2 groups over 6->4 channels: each output channel reads only
        // its 3-channel slice; the GEMM lowering goes block-diagonal.
        check_shape(
            ConvShape {
                in_c: 6,
                in_h: 6,
                in_w: 6,
                out_c: 4,
                k: 3,
                stride: 1,
                pad: 1,
                dilation: 1,
                groups: 2,
            },
            7,
        );
    }

    #[test]
    fn im2col_equals_direct_depthwise_dilated_strided() {
        // Depthwise (groups == in_c == out_c) combined with dilation
        // and stride — every new shape field at once.
        check_shape(
            ConvShape {
                in_c: 4,
                in_h: 11,
                in_w: 9,
                out_c: 4,
                k: 3,
                stride: 2,
                pad: 2,
                dilation: 2,
                groups: 4,
            },
            8,
        );
    }

    #[test]
    fn grouped_weight_len_and_dims() {
        let s = ConvShape {
            in_c: 8,
            in_h: 5,
            in_w: 5,
            out_c: 6,
            k: 3,
            stride: 1,
            pad: 1,
            dilation: 1,
            groups: 2,
        };
        assert_eq!(s.validate(), Ok(()));
        assert_eq!(s.group_in_c(), 4);
        // Weights shrink per-group; K of the lowered GEMM does not.
        assert_eq!(s.weight_len(), 6 * 4 * 3 * 3);
        assert_eq!(s.gemm_dims(), (25, 72, 6));
    }

    #[test]
    fn dilation_shrinks_output_like_a_larger_kernel() {
        let base = ConvShape {
            in_c: 1,
            in_h: 10,
            in_w: 10,
            out_c: 1,
            k: 3,
            stride: 1,
            pad: 0,
            dilation: 3,
            groups: 1,
        };
        // Effective extent (3-1)*3+1 = 7 -> out 4x4.
        assert_eq!(base.validate(), Ok(()));
        assert_eq!((base.out_h(), base.out_w()), (4, 4));
    }

    #[test]
    fn validate_rejects_bad_dilation_and_groups() {
        let good = ConvShape {
            in_c: 4,
            in_h: 6,
            in_w: 6,
            out_c: 4,
            k: 3,
            stride: 1,
            pad: 1,
            dilation: 1,
            groups: 1,
        };
        assert_eq!(good.validate(), Ok(()));

        let zd = ConvShape { dilation: 0, ..good };
        assert_eq!(zd.validate(), Err(ConvShapeError::ZeroDilation));
        assert!(zd.checked_out_h().is_none());

        let zg = ConvShape { groups: 0, ..good };
        assert_eq!(zg.validate(), Err(ConvShapeError::ZeroGroups));

        let uneven = ConvShape { groups: 3, ..good };
        assert_eq!(
            uneven.validate(),
            Err(ConvShapeError::GroupsDontDivide {
                dim: "in_c",
                size: 4,
                groups: 3,
            })
        );
        let uneven_out = ConvShape { out_c: 6, groups: 4, ..good };
        assert_eq!(
            uneven_out.validate(),
            Err(ConvShapeError::GroupsDontDivide {
                dim: "out_c",
                size: 6,
                groups: 4,
            })
        );

        // A dilated kernel whose *effective* extent exceeds the padded
        // input is rejected with that extent (k stays small).
        let over = ConvShape { dilation: 4, pad: 0, ..good };
        assert!(matches!(
            over.validate(),
            Err(ConvShapeError::KernelExceedsInput { k: 9, .. })
        ));
    }

    #[test]
    fn gemm_dims_consistent() {
        let s = ConvShape {
            in_c: 16,
            in_h: 14,
            in_w: 14,
            out_c: 32,
            k: 3,
            stride: 1,
            pad: 1,
            dilation: 1,
            groups: 1,
        };
        assert_eq!(s.gemm_dims(), (196, 144, 32));
        assert_eq!(s.macs(), 196 * 144 * 32);
        assert_eq!(s.input_len(), 16 * 14 * 14);
        assert_eq!(s.weight_len(), 32 * 16 * 3 * 3);
    }

    #[test]
    fn validate_rejects_degenerate_shapes() {
        let good = ConvShape {
            in_c: 2,
            in_h: 4,
            in_w: 4,
            out_c: 2,
            k: 3,
            stride: 1,
            pad: 0,
            dilation: 1,
            groups: 1,
        };
        assert_eq!(good.validate(), Ok(()));

        let zero_stride = ConvShape { stride: 0, ..good };
        assert_eq!(zero_stride.validate(), Err(ConvShapeError::ZeroStride));
        assert!(zero_stride.checked_out_h().is_none());

        let zero_dim = ConvShape { in_c: 0, ..good };
        assert_eq!(zero_dim.validate(), Err(ConvShapeError::ZeroDim("in_c")));

        // k > in_h + 2*pad used to underflow-panic in out_h().
        let oversize = ConvShape { k: 6, ..good };
        assert!(matches!(
            oversize.validate(),
            Err(ConvShapeError::KernelExceedsInput { k: 6, .. })
        ));
        assert!(oversize.checked_out_h().is_none());

        // ...but the same kernel with enough padding is fine.
        let padded = ConvShape { k: 6, pad: 1, ..good };
        assert_eq!(padded.validate(), Ok(()));
        assert_eq!(padded.out_h(), 1);

        // Dimensions whose derived sizes overflow usize are rejected
        // instead of wrapping (release) or panicking (debug) later.
        let huge = ConvShape {
            in_c: 4,
            in_h: usize::MAX / 2,
            in_w: usize::MAX / 2,
            ..good
        };
        assert_eq!(huge.validate(), Err(ConvShapeError::TooLarge));
    }

    #[test]
    #[should_panic(expected = "invalid ConvShape")]
    fn out_h_panics_deterministically_on_invalid_shape() {
        let bad = ConvShape {
            in_c: 1,
            in_h: 2,
            in_w: 2,
            out_c: 1,
            k: 5,
            stride: 1,
            pad: 0,
            dilation: 1,
            groups: 1,
        };
        let _ = bad.out_h();
    }

    #[test]
    fn patch_source_rejects_bad_buffers() {
        let shape = ConvShape {
            in_c: 2,
            in_h: 3,
            in_w: 3,
            out_c: 1,
            k: 1,
            stride: 1,
            pad: 0,
            dilation: 1,
            groups: 1,
        };
        assert_eq!(
            PatchSource::new(vec![0; 5], shape).unwrap_err(),
            ConvShapeError::InputLen {
                expected: 18,
                got: 5
            }
        );
        assert!(PatchSource::new(vec![0; 18], shape).is_ok());
    }

    #[test]
    fn extract_cols_pads_the_tail_with_zeros() {
        let shape = ConvShape {
            in_c: 1,
            in_h: 3,
            in_w: 3,
            out_c: 1,
            k: 2,
            stride: 1,
            pad: 0,
            dilation: 1,
            groups: 1,
        };
        let src =
            PatchSource::new(vec![1, 2, 3, 4, 5, 6, 7, 8, 9], shape).unwrap();
        // K = 4; take columns 1..3 into a width-6 tile.
        let t = src.extract_cols(1, 3, 6);
        assert_eq!((t.rows, t.cols), (4, 6));
        let eager = im2col(src.input(), shape);
        for r in 0..4 {
            assert_eq!(t.at(r, 0), eager.at(r, 1));
            assert_eq!(t.at(r, 1), eager.at(r, 2));
            for pad_col in 2..6 {
                assert_eq!(t.at(r, pad_col), 0);
            }
        }
    }
}
