//! Conv2d lowered to GEMM via im2col — the DPU's native workload.
//!
//! The DPUCZDX8G evaluates convolutions as inner products over
//! (kernel_h × kernel_w × in_channels) patches with pixel/channel
//! parallelism; functionally that is exactly an im2col GEMM, which is
//! how the coordinator maps Conv jobs onto any matrix engine.

use super::gemm::{MatI32, MatI8};

/// NCHW conv shape descriptor (stride/pad uniform, no dilation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_c: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvShape {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k) / self.stride + 1
    }
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k) / self.stride + 1
    }
    /// GEMM dimensions after im2col: (M, K, N).
    pub fn gemm_dims(&self) -> (usize, usize, usize) {
        (
            self.out_h() * self.out_w(),
            self.k * self.k * self.in_c,
            self.out_c,
        )
    }
    pub fn macs(&self) -> u64 {
        let (m, k, n) = self.gemm_dims();
        (m * k * n) as u64
    }
}

/// im2col: input (C, H, W) flattened row-major -> patch matrix
/// (out_h*out_w, k*k*in_c). Zero padding.
pub fn im2col(input: &[i8], shape: ConvShape) -> MatI8 {
    assert_eq!(input.len(), shape.in_c * shape.in_h * shape.in_w);
    let (m, kdim, _) = shape.gemm_dims();
    let mut out = MatI8::zeros(m, kdim);
    let (oh, ow) = (shape.out_h(), shape.out_w());
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            let mut col = 0;
            for c in 0..shape.in_c {
                for ky in 0..shape.k {
                    for kx in 0..shape.k {
                        let iy = (oy * shape.stride + ky) as isize - shape.pad as isize;
                        let ix = (ox * shape.stride + kx) as isize - shape.pad as isize;
                        let v = if iy >= 0
                            && ix >= 0
                            && (iy as usize) < shape.in_h
                            && (ix as usize) < shape.in_w
                        {
                            input[c * shape.in_h * shape.in_w
                                + iy as usize * shape.in_w
                                + ix as usize]
                        } else {
                            0
                        };
                        out.set(row, col, v);
                        col += 1;
                    }
                }
            }
        }
    }
    out
}

/// Weights (out_c, in_c, k, k) flattened -> GEMM weight matrix
/// (k*k*in_c, out_c), matching [`im2col`]'s column order.
pub fn weights_to_gemm(weights: &[i8], shape: ConvShape) -> MatI8 {
    assert_eq!(weights.len(), shape.out_c * shape.in_c * shape.k * shape.k);
    let kdim = shape.k * shape.k * shape.in_c;
    MatI8::from_fn(kdim, shape.out_c, |row, oc| {
        // row = c * k * k + ky * k + kx
        let c = row / (shape.k * shape.k);
        let rem = row % (shape.k * shape.k);
        weights[oc * shape.in_c * shape.k * shape.k + c * shape.k * shape.k + rem]
    })
}

/// Direct (naive) convolution for cross-checking the im2col path.
pub fn conv2d_direct(input: &[i8], weights: &[i8], shape: ConvShape) -> MatI32 {
    let (oh, ow) = (shape.out_h(), shape.out_w());
    let mut out = MatI32::zeros(oh * ow, shape.out_c);
    for oc in 0..shape.out_c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0i32;
                for c in 0..shape.in_c {
                    for ky in 0..shape.k {
                        for kx in 0..shape.k {
                            let iy =
                                (oy * shape.stride + ky) as isize - shape.pad as isize;
                            let ix =
                                (ox * shape.stride + kx) as isize - shape.pad as isize;
                            if iy < 0
                                || ix < 0
                                || iy as usize >= shape.in_h
                                || ix as usize >= shape.in_w
                            {
                                continue;
                            }
                            let iv = input[c * shape.in_h * shape.in_w
                                + iy as usize * shape.in_w
                                + ix as usize] as i32;
                            let wv = weights[oc * shape.in_c * shape.k * shape.k
                                + c * shape.k * shape.k
                                + ky * shape.k
                                + kx] as i32;
                            acc += iv * wv;
                        }
                    }
                }
                out.set(oy * ow + ox, oc, acc);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::workload::gemm::golden_gemm;

    fn check_shape(shape: ConvShape, seed: u64) {
        let mut rng = XorShift::new(seed);
        let input = rng.i8_vec(shape.in_c * shape.in_h * shape.in_w);
        let weights = rng.i8_vec(shape.out_c * shape.in_c * shape.k * shape.k);
        let patches = im2col(&input, shape);
        let wmat = weights_to_gemm(&weights, shape);
        let via_gemm = golden_gemm(&patches, &wmat);
        let direct = conv2d_direct(&input, &weights, shape);
        assert_eq!(via_gemm, direct, "{shape:?}");
    }

    #[test]
    fn im2col_equals_direct_3x3() {
        check_shape(
            ConvShape {
                in_c: 3,
                in_h: 8,
                in_w: 8,
                out_c: 4,
                k: 3,
                stride: 1,
                pad: 1,
            },
            1,
        );
    }

    #[test]
    fn im2col_equals_direct_strided_no_pad() {
        check_shape(
            ConvShape {
                in_c: 2,
                in_h: 9,
                in_w: 7,
                out_c: 5,
                k: 3,
                stride: 2,
                pad: 0,
            },
            2,
        );
    }

    #[test]
    fn im2col_equals_direct_1x1() {
        check_shape(
            ConvShape {
                in_c: 8,
                in_h: 4,
                in_w: 4,
                out_c: 8,
                k: 1,
                stride: 1,
                pad: 0,
            },
            3,
        );
    }

    #[test]
    fn gemm_dims_consistent() {
        let s = ConvShape {
            in_c: 16,
            in_h: 14,
            in_w: 14,
            out_c: 32,
            k: 3,
            stride: 1,
            pad: 1,
        };
        assert_eq!(s.gemm_dims(), (196, 144, 32));
        assert_eq!(s.macs(), 196 * 144 * 32);
    }
}
