//! Workload substrate: problems the engines and coordinator execute.
//!
//! * [`gemm`] — INT8 matrices, golden INT32 matmul, random problems;
//! * [`conv`] — Conv2d described as im2col-lowered GEMM (the DPU's
//!   native workload shape);
//! * [`quant`] — symmetric INT8 quantization + the fixed-point
//!   requantizer shared bit-for-bit with `python/compile/kernels/ref.py`;
//! * [`snn`] — spike-train generation and the integer LIF neuron used by
//!   the FireFly engines;
//! * [`sparse`] — N:M structured weight tiles and CSR activations with
//!   dense-roundtrip oracles (zero work the coordinator can skip).

pub mod conv;
pub mod gemm;
pub mod quant;
pub mod snn;
pub mod sparse;

pub use gemm::{GemmProblem, MatI32, MatI8};
pub use sparse::{CsrMatI8, NmPattern, SparseFormatError, SparseMatI8};
