//! INT8 GEMM problems and the golden INT32 reference.

use crate::util::rng::XorShift;

/// Row-major INT8 matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MatI8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
}

impl MatI8 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatI8 {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> i8) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        MatI8 { rows, cols, data }
    }

    pub fn random(rng: &mut XorShift, rows: usize, cols: usize) -> Self {
        MatI8 {
            rows,
            cols,
            data: rng.i8_vec(rows * cols),
        }
    }

    /// Random with bounded magnitude (realistic quantized layers).
    pub fn random_bounded(rng: &mut XorShift, rows: usize, cols: usize, bound: i8) -> Self {
        MatI8 {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.i8_in(-bound, bound)).collect(),
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i8 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i8) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [i8] {
        let cols = self.cols;
        &mut self.data[r * cols..(r + 1) * cols]
    }

    /// Borrowing column walk — the allocation-free access for hot paths
    /// that consume a matrix column-wise (WS weight fills, tiling).
    pub fn col_iter(
        &self,
        c: usize,
    ) -> impl DoubleEndedIterator<Item = i8> + ExactSizeIterator + '_ {
        debug_assert!(c < self.cols);
        (0..self.rows).map(move |r| self.data[r * self.cols + c])
    }

    /// Copy column `c` into caller-owned storage — the slice-copy
    /// variant for hot paths that need a materialized column without
    /// allocating per call. `out` must hold exactly `rows` elements.
    pub fn col_into(&self, c: usize, out: &mut [i8]) {
        debug_assert!(c < self.cols);
        assert_eq!(out.len(), self.rows, "destination length must equal rows");
        for (r, slot) in out.iter_mut().enumerate() {
            *slot = self.data[r * self.cols + c];
        }
    }

    /// Column copy (convenience; hot paths use [`MatI8::col_iter`] or
    /// [`MatI8::col_into`] into a reused buffer).
    pub fn col(&self, c: usize) -> Vec<i8> {
        let mut out = vec![0; self.rows];
        self.col_into(c, &mut out);
        out
    }

    pub fn transpose(&self) -> MatI8 {
        MatI8::from_fn(self.cols, self.rows, |r, c| self.at(c, r))
    }
}

/// Row-major INT32 matrix (accumulator outputs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MatI32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl MatI32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatI32 {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn add(&mut self, r: usize, c: usize, v: i32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] += v;
    }

    /// Write `partial` over the row span starting at `m0` (column
    /// counts must match, span must fit). Row spans are disjoint by
    /// construction — the conv row-block path on internally-tiling
    /// engines — so this is a plain overwrite, not an accumulate.
    pub fn write_rows(&mut self, m0: usize, partial: &MatI32) {
        assert_eq!(partial.cols, self.cols);
        assert!(m0 + partial.rows <= self.rows);
        let start = m0 * self.cols;
        self.data[start..start + partial.data.len()]
            .copy_from_slice(&partial.data);
    }

    /// Fold `partial` into the column span starting at `n0` (row counts
    /// must match, span must fit). Integer adds commute, so callers may
    /// fold partial products in any completion order — this is the one
    /// accumulate primitive behind both the sequential tiling path and
    /// the batched fill-group path.
    pub fn accumulate_cols(&mut self, n0: usize, partial: &MatI32) {
        assert_eq!(partial.rows, self.rows);
        assert!(n0 + partial.cols <= self.cols);
        for r in 0..partial.rows {
            for c in 0..partial.cols {
                self.add(r, n0 + c, partial.at(r, c));
            }
        }
    }
}

/// Golden reference: `a (M×K) @ w (K×N) -> (M×N)` in INT32.
pub fn golden_gemm(a: &MatI8, w: &MatI8) -> MatI32 {
    assert_eq!(a.cols, w.rows, "inner dimensions must agree");
    let mut out = MatI32::zeros(a.rows, w.cols);
    for m in 0..a.rows {
        for k in 0..a.cols {
            let av = a.at(m, k) as i32;
            if av == 0 {
                continue;
            }
            for n in 0..w.cols {
                out.data[m * w.cols + n] += av * w.at(k, n) as i32;
            }
        }
    }
    out
}

/// A self-contained GEMM problem instance.
#[derive(Debug, Clone)]
pub struct GemmProblem {
    pub a: MatI8,
    pub w: MatI8,
}

impl GemmProblem {
    /// Random problem: `a` is M×K, `w` is K×N.
    pub fn random(m: usize, n: usize, k: usize, seed: u64) -> Self {
        let mut rng = XorShift::new(seed);
        GemmProblem {
            a: MatI8::random(&mut rng, m, k),
            w: MatI8::random(&mut rng, k, n),
        }
    }

    pub fn golden(&self) -> MatI32 {
        golden_gemm(&self.a, &self.w)
    }

    pub fn m(&self) -> usize {
        self.a.rows
    }
    pub fn n(&self) -> usize {
        self.w.cols
    }
    pub fn k(&self) -> usize {
        self.a.cols
    }

    /// Multiply-accumulate operations in this problem.
    pub fn macs(&self) -> u64 {
        (self.m() * self.n() * self.k()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_identity() {
        let a = MatI8::from_fn(3, 3, |r, c| if r == c { 1 } else { 0 });
        let w = MatI8::from_fn(3, 2, |r, c| (r * 2 + c) as i8);
        let out = golden_gemm(&a, &w);
        for r in 0..3 {
            for c in 0..2 {
                assert_eq!(out.at(r, c), w.at(r, c) as i32);
            }
        }
    }

    #[test]
    fn golden_known_values() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = MatI8 {
            rows: 2,
            cols: 2,
            data: vec![1, 2, 3, 4],
        };
        let w = MatI8 {
            rows: 2,
            cols: 2,
            data: vec![5, 6, 7, 8],
        };
        let out = golden_gemm(&a, &w);
        assert_eq!(out.data, vec![19, 22, 43, 50]);
    }

    #[test]
    fn col_iter_matches_col_and_reverses() {
        let mut rng = XorShift::new(8);
        let m = MatI8::random(&mut rng, 6, 4);
        let mut scratch = vec![0i8; m.rows];
        for c in 0..m.cols {
            assert_eq!(m.col_iter(c).collect::<Vec<_>>(), m.col(c));
            let mut rev: Vec<i8> = m.col_iter(c).rev().collect();
            rev.reverse();
            assert_eq!(rev, m.col(c));
            assert_eq!(m.col_iter(c).len(), m.rows);
            m.col_into(c, &mut scratch);
            assert_eq!(scratch, m.col(c));
        }
    }

    #[test]
    #[should_panic(expected = "destination length")]
    fn col_into_rejects_wrong_length() {
        let m = MatI8::zeros(3, 2);
        let mut out = vec![0i8; 2];
        m.col_into(0, &mut out);
    }

    #[test]
    fn row_mut_writes_in_place() {
        let mut m = MatI8::zeros(3, 4);
        m.row_mut(1).copy_from_slice(&[1, 2, 3, 4]);
        assert_eq!(m.at(1, 2), 3);
        assert_eq!(m.at(0, 2), 0);
        assert_eq!(m.at(2, 2), 0);
    }

    #[test]
    fn write_rows_overwrites_disjoint_spans() {
        let mut out = MatI32::zeros(5, 3);
        let top = MatI32 {
            rows: 2,
            cols: 3,
            data: vec![1, 2, 3, 4, 5, 6],
        };
        let bottom = MatI32 {
            rows: 2,
            cols: 3,
            data: vec![7, 8, 9, 10, 11, 12],
        };
        out.write_rows(0, &top);
        out.write_rows(3, &bottom);
        assert_eq!(out.at(1, 2), 6);
        assert_eq!(out.at(2, 0), 0); // untouched middle row
        assert_eq!(out.at(3, 0), 7);
        assert_eq!(out.at(4, 2), 12);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = XorShift::new(4);
        let m = MatI8::random(&mut rng, 5, 7);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn problem_macs() {
        let p = GemmProblem::random(4, 6, 8, 0);
        assert_eq!(p.macs(), 4 * 6 * 8);
    }

    #[test]
    #[should_panic(expected = "inner dimensions")]
    fn dimension_mismatch_panics() {
        let a = MatI8::zeros(2, 3);
        let w = MatI8::zeros(4, 2);
        golden_gemm(&a, &w);
    }
}
