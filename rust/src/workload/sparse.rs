//! Sparse operands: N:M structured weight tiles and CSR activations.
//!
//! Two representations, one contract — **densify and you must get the
//! bit-identical dense operand back**:
//!
//! * [`SparseMatI8`] — N:M structured sparsity for weights (per
//!   "Systolic Sparse Tensor Slices", arXiv 2502.03763): every group
//!   of `m` consecutive columns in a row holds at most `n` nonzeros,
//!   stored as per-group `(index, value)` slots. The fixed slot count
//!   keeps the storage rectangular (hardware-friendly) and makes
//!   [`SparseMatI8::from_dense`] / [`SparseMatI8::to_dense`] an exact
//!   roundtrip oracle.
//! * [`CsrMatI8`] — compressed-sparse-row activations (the spada-sim
//!   idiom): `row_ptr` / `col_idx` / `val`, with lazy per-span
//!   densification ([`CsrMatI8::extract_rows`] for row-block engines,
//!   [`CsrMatI8::extract_cols`] for the WS tiler's K-span) so the
//!   coordinator never materializes the whole operand to tile it.
//!
//! Neither form executes sparsely on the array — the DSP fabric
//! computes dense tiles. The win is **what never reaches the array**:
//! the coordinator queries [`SparseMatI8::block_has_nonzero`] to drop
//! all-zero weight tiles before they are enqueued, and
//! [`CsrMatI8::rows_nonempty`] to skip empty activation row windows.

use super::gemm::MatI8;
use crate::util::rng::XorShift;

/// Slot marker for an unused `(index, value)` pair in a group.
const SLOT_EMPTY: u8 = u8::MAX;

/// Why a sparse operand is malformed. Returned by the constructors and
/// by [`SparseMatI8::validate`] / [`CsrMatI8::validate`] so the service
/// resolves a bad submission (e.g. decoded off the wire) as `Failed`
/// instead of panicking in a worker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SparseFormatError {
    /// An `n:m` pattern that cannot describe a group: `n == 0`,
    /// `n > m`, or `m` too large for the u8 slot indices.
    BadPattern(String),
    /// A dense row group carries more nonzeros than the pattern allows.
    GroupOverflow {
        row: usize,
        group: usize,
        count: usize,
        cap: usize,
    },
    /// A structural invariant does not hold (slot index out of range,
    /// unsorted slots, buffer length mismatch, zero stored as a live
    /// value, non-monotonic `row_ptr`, ...).
    Layout(&'static str),
}

impl std::fmt::Display for SparseFormatError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SparseFormatError::BadPattern(s) => {
                write!(f, "bad N:M pattern `{s}`")
            }
            SparseFormatError::GroupOverflow {
                row,
                group,
                count,
                cap,
            } => write!(
                f,
                "row {row} group {group} has {count} nonzeros (cap {cap})"
            ),
            SparseFormatError::Layout(why) => {
                write!(f, "malformed sparse operand: {why}")
            }
        }
    }
}

impl std::error::Error for SparseFormatError {}

/// An `n:m` structured-sparsity pattern: at most `n` nonzeros in every
/// group of `m` consecutive columns. `4:4` is dense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NmPattern {
    pub n: usize,
    pub m: usize,
}

impl NmPattern {
    /// The degenerate dense pattern (every slot may be live).
    pub const DENSE: NmPattern = NmPattern { n: 4, m: 4 };

    pub fn new(n: usize, m: usize) -> Result<NmPattern, SparseFormatError> {
        if n == 0 || m == 0 || n > m || m >= SLOT_EMPTY as usize {
            return Err(SparseFormatError::BadPattern(format!("{n}:{m}")));
        }
        Ok(NmPattern { n, m })
    }

    /// Parse `"2:4"`-style pattern strings (the CLI `--nm` format).
    pub fn parse(s: &str) -> Result<NmPattern, SparseFormatError> {
        let bad = || SparseFormatError::BadPattern(s.to_string());
        let (n, m) = s.split_once(':').ok_or_else(bad)?;
        let n: usize = n.trim().parse().map_err(|_| bad())?;
        let m: usize = m.trim().parse().map_err(|_| bad())?;
        NmPattern::new(n, m)
    }

    /// The highest density the pattern admits (`n / m`).
    pub fn density_cap(&self) -> f64 {
        self.n as f64 / self.m as f64
    }
}

impl std::fmt::Display for NmPattern {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.n, self.m)
    }
}

/// An N:M structured-sparse INT8 matrix (row-major groups along the
/// column axis). Every group owns exactly `nm.n` `(index, value)`
/// slots; unused slots hold `(SLOT_EMPTY, 0)`. Canonical form — live
/// slots first, strictly increasing indices, values nonzero — makes
/// `==` meaningful and the dense roundtrip exact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SparseMatI8 {
    rows: usize,
    cols: usize,
    nm: NmPattern,
    /// Per-slot column offset within the group (`SLOT_EMPTY` = unused).
    idx: Vec<u8>,
    /// Per-slot value (0 for unused slots).
    val: Vec<i8>,
}

impl SparseMatI8 {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nm(&self) -> NmPattern {
        self.nm
    }

    fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.nm.m)
    }

    /// Raw slot buffers (index bytes, value bytes) — the wire encoding.
    pub fn slots(&self) -> (&[u8], &[i8]) {
        (&self.idx, &self.val)
    }

    /// Rebuild from wire-decoded slot buffers; [`SparseMatI8::validate`]
    /// runs so a malformed frame cannot smuggle in a broken invariant.
    pub fn from_slots(
        rows: usize,
        cols: usize,
        nm: NmPattern,
        idx: Vec<u8>,
        val: Vec<i8>,
    ) -> Result<SparseMatI8, SparseFormatError> {
        let s = SparseMatI8 {
            rows,
            cols,
            nm,
            idx,
            val,
        };
        s.validate()?;
        Ok(s)
    }

    /// Pack a dense matrix, rejecting any group denser than `n:m`.
    pub fn from_dense(
        dense: &MatI8,
        nm: NmPattern,
    ) -> Result<SparseMatI8, SparseFormatError> {
        let gpr = dense.cols.div_ceil(nm.m);
        let mut idx = vec![SLOT_EMPTY; dense.rows * gpr * nm.n];
        let mut val = vec![0i8; dense.rows * gpr * nm.n];
        for r in 0..dense.rows {
            let row = dense.row(r);
            for g in 0..gpr {
                let c0 = g * nm.m;
                let c1 = (c0 + nm.m).min(dense.cols);
                let base = (r * gpr + g) * nm.n;
                let mut slot = 0;
                for c in c0..c1 {
                    if row[c] == 0 {
                        continue;
                    }
                    if slot == nm.n {
                        return Err(SparseFormatError::GroupOverflow {
                            row: r,
                            group: g,
                            count: row[c0..c1]
                                .iter()
                                .filter(|v| **v != 0)
                                .count(),
                            cap: nm.n,
                        });
                    }
                    idx[base + slot] = (c - c0) as u8;
                    val[base + slot] = row[c];
                    slot += 1;
                }
            }
        }
        Ok(SparseMatI8 {
            rows: dense.rows,
            cols: dense.cols,
            nm,
            idx,
            val,
        })
    }

    /// The exact dense matrix this packs — the roundtrip oracle and
    /// the densify-to-verify path.
    pub fn to_dense(&self) -> MatI8 {
        let mut out = MatI8::zeros(self.rows, self.cols);
        let (gpr, n) = (self.groups_per_row(), self.nm.n);
        for r in 0..self.rows {
            let row = out.row_mut(r);
            for g in 0..gpr {
                let base = (r * gpr + g) * n;
                for s in 0..n {
                    if self.idx[base + s] == SLOT_EMPTY {
                        break;
                    }
                    row[g * self.nm.m + self.idx[base + s] as usize] =
                        self.val[base + s];
                }
            }
        }
        out
    }

    /// Stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.idx.iter().filter(|i| **i != SLOT_EMPTY).count()
    }

    /// Fraction of elements that are nonzero (0.0 for empty shapes).
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Does `[r0, r1) × [c0, c1)` hold any nonzero? The coordinator's
    /// tile-liveness query: `false` means the whole weight tile is
    /// zero and its fill (and every stream against it) can be skipped.
    pub fn block_has_nonzero(
        &self,
        r0: usize,
        r1: usize,
        c0: usize,
        c1: usize,
    ) -> bool {
        if c0 >= c1 {
            return false;
        }
        let (gpr, n, m) = (self.groups_per_row(), self.nm.n, self.nm.m);
        let (g0, g1) = (c0 / m, (c1 - 1) / m);
        for r in r0..r1.min(self.rows) {
            for g in g0..=g1.min(gpr.saturating_sub(1)) {
                let base = (r * gpr + g) * n;
                for s in 0..n {
                    if self.idx[base + s] == SLOT_EMPTY {
                        break;
                    }
                    let c = g * m + self.idx[base + s] as usize;
                    if c >= c0 && c < c1 {
                        return true;
                    }
                }
            }
        }
        false
    }

    /// Densify the block `[k0, k1) × [c0, c1)` into an
    /// `out_rows × (c1-c0)` tile (tail rows zero-padded) — exactly the
    /// stationary weight tile `GemmTiler::w_tile` would slice out of
    /// the densified matrix, scattered straight from the group slots.
    pub fn extract_block(
        &self,
        k0: usize,
        k1: usize,
        c0: usize,
        c1: usize,
        out_rows: usize,
    ) -> MatI8 {
        assert!(k0 <= k1 && k1 <= self.rows, "row span out of range");
        assert!(c0 <= c1 && c1 <= self.cols, "col span out of range");
        assert!(k1 - k0 <= out_rows, "tile rows smaller than row span");
        let mut out = MatI8::zeros(out_rows, c1 - c0);
        if c0 == c1 {
            return out;
        }
        let (gpr, n, m) = (self.groups_per_row(), self.nm.n, self.nm.m);
        let (g0, g1) = (c0 / m, (c1 - 1) / m);
        for r in k0..k1 {
            let row = out.row_mut(r - k0);
            for g in g0..=g1 {
                let base = (r * gpr + g) * n;
                for s in 0..n {
                    if self.idx[base + s] == SLOT_EMPTY {
                        break;
                    }
                    let c = g * m + self.idx[base + s] as usize;
                    if c >= c0 && c < c1 {
                        row[c - c0] = self.val[base + s];
                    }
                }
            }
        }
        out
    }

    /// Check every structural invariant (wire-decoded operands pass
    /// through here before a worker touches them).
    pub fn validate(&self) -> Result<(), SparseFormatError> {
        NmPattern::new(self.nm.n, self.nm.m)?;
        let (gpr, n, m) = (self.groups_per_row(), self.nm.n, self.nm.m);
        let slots = self
            .rows
            .checked_mul(gpr)
            .and_then(|g| g.checked_mul(n))
            .ok_or(SparseFormatError::Layout("slot count overflows"))?;
        if self.idx.len() != slots || self.val.len() != slots {
            return Err(SparseFormatError::Layout(
                "slot buffers disagree with rows * groups * n",
            ));
        }
        for r in 0..self.rows {
            for g in 0..gpr {
                let base = (r * gpr + g) * n;
                let extent = self.cols - g * m; // columns this group spans
                let mut done = false;
                let mut prev: Option<u8> = None;
                for s in 0..n {
                    let i = self.idx[base + s];
                    if i == SLOT_EMPTY {
                        done = true;
                        if self.val[base + s] != 0 {
                            return Err(SparseFormatError::Layout(
                                "empty slot carries a value",
                            ));
                        }
                        continue;
                    }
                    if done {
                        return Err(SparseFormatError::Layout(
                            "live slot after an empty slot",
                        ));
                    }
                    if (i as usize) >= m.min(extent) {
                        return Err(SparseFormatError::Layout(
                            "slot index outside its group",
                        ));
                    }
                    if prev.is_some_and(|p| i <= p) {
                        return Err(SparseFormatError::Layout(
                            "slot indices not strictly increasing",
                        ));
                    }
                    if self.val[base + s] == 0 {
                        return Err(SparseFormatError::Layout(
                            "live slot carries a zero value",
                        ));
                    }
                    prev = Some(i);
                }
            }
        }
        Ok(())
    }

    /// Random N:M matrix: every group carries exactly
    /// `min(n, group extent)` nonzeros at random positions — the
    /// densest matrix the pattern admits (`2:4` ⇒ density 0.5).
    pub fn random_nm(
        rng: &mut XorShift,
        rows: usize,
        cols: usize,
        nm: NmPattern,
    ) -> SparseMatI8 {
        Self::generate(rng, rows, cols, nm, |_, _| true)
    }

    /// Random N:M matrix thinned to an overall `density` by killing
    /// whole `(bh × bw)` element blocks: a block survives with
    /// probability `density / (n/m)`, surviving blocks carry full N:M
    /// groups. Coarse-grained zeroing is what makes *entire weight
    /// tiles* go dead at low density — the skip path's food; elementwise
    /// thinning would almost never zero a whole tile.
    pub fn random_density(
        rng: &mut XorShift,
        rows: usize,
        cols: usize,
        nm: NmPattern,
        density: f64,
        (bh, bw): (usize, usize),
    ) -> SparseMatI8 {
        assert!(bh > 0 && bw > 0, "block dims must be positive");
        let live_fraction = (density / nm.density_cap()).clamp(0.0, 1.0);
        let per_mille = (live_fraction * 1000.0).round() as u64;
        let nb_c = cols.div_ceil(bw).max(1);
        let nb = rows.div_ceil(bh).max(1) * nb_c;
        let live: Vec<bool> =
            (0..nb).map(|_| rng.chance(per_mille, 1000)).collect();
        Self::generate(rng, rows, cols, nm, |r, c| {
            live[(r / bh) * nb_c + c / bw]
        })
    }

    /// Deterministic block-strided N:M matrix: element blocks of
    /// `(bh × bw)` are live iff `block_id % live_every == 0` (row-major
    /// block ids). Values are random but the zero *structure* — and so
    /// the exact number of skippable tiles — is a pure function of the
    /// shape, which is what lets the bench gate `sparse_tiles_skipped`
    /// as an exact counter.
    pub fn striped(
        rng: &mut XorShift,
        rows: usize,
        cols: usize,
        nm: NmPattern,
        live_every: usize,
        (bh, bw): (usize, usize),
    ) -> SparseMatI8 {
        assert!(live_every > 0 && bh > 0 && bw > 0);
        let nb_c = cols.div_ceil(bw).max(1);
        Self::generate(rng, rows, cols, nm, move |r, c| {
            ((r / bh) * nb_c + c / bw) % live_every == 0
        })
    }

    /// Shared generator core: per group, pick up to `n` distinct
    /// positions among those `live` admits, with random nonzero values.
    fn generate(
        rng: &mut XorShift,
        rows: usize,
        cols: usize,
        nm: NmPattern,
        live: impl Fn(usize, usize) -> bool,
    ) -> SparseMatI8 {
        let gpr = cols.div_ceil(nm.m);
        let mut idx = vec![SLOT_EMPTY; rows * gpr * nm.n];
        let mut val = vec![0i8; rows * gpr * nm.n];
        let mut candidates: Vec<usize> = Vec::with_capacity(nm.m);
        for r in 0..rows {
            for g in 0..gpr {
                let c0 = g * nm.m;
                let c1 = (c0 + nm.m).min(cols);
                candidates.clear();
                candidates.extend((c0..c1).filter(|c| live(r, *c)));
                // Partial Fisher-Yates: the first `take` entries become
                // a uniform random subset.
                let take = nm.n.min(candidates.len());
                for i in 0..take {
                    let j = i + rng.below((candidates.len() - i) as u64)
                        as usize;
                    candidates.swap(i, j);
                }
                candidates[..take].sort_unstable();
                let base = (r * gpr + g) * nm.n;
                for (s, c) in candidates[..take].iter().enumerate() {
                    let mut v = rng.i8_in(-63, 63);
                    if v == 0 {
                        v = 1;
                    }
                    idx[base + s] = (c - c0) as u8;
                    val[base + s] = v;
                }
            }
        }
        SparseMatI8 {
            rows,
            cols,
            nm,
            idx,
            val,
        }
    }
}

/// Compressed-sparse-row INT8 activations: `row_ptr[r]..row_ptr[r+1]`
/// indexes this row's `(col_idx, val)` pairs, columns strictly
/// increasing within a row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CsrMatI8 {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<usize>,
    val: Vec<i8>,
}

impl CsrMatI8 {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Raw CSR arrays — the wire encoding.
    pub fn parts(&self) -> (&[usize], &[usize], &[i8]) {
        (&self.row_ptr, &self.col_idx, &self.val)
    }

    /// Rebuild from wire-decoded arrays; validated like
    /// [`SparseMatI8::from_slots`].
    pub fn from_parts(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<usize>,
        val: Vec<i8>,
    ) -> Result<CsrMatI8, SparseFormatError> {
        let c = CsrMatI8 {
            rows,
            cols,
            row_ptr,
            col_idx,
            val,
        };
        c.validate()?;
        Ok(c)
    }

    /// Compress a dense matrix (zeros dropped).
    pub fn from_dense(dense: &MatI8) -> CsrMatI8 {
        let mut row_ptr = Vec::with_capacity(dense.rows + 1);
        let mut col_idx = Vec::new();
        let mut val = Vec::new();
        row_ptr.push(0);
        for r in 0..dense.rows {
            for (c, v) in dense.row(r).iter().enumerate() {
                if *v != 0 {
                    col_idx.push(c);
                    val.push(*v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        CsrMatI8 {
            rows: dense.rows,
            cols: dense.cols,
            row_ptr,
            col_idx,
            val,
        }
    }

    /// The exact dense matrix this compresses.
    pub fn to_dense(&self) -> MatI8 {
        self.extract_rows(0, self.rows)
    }

    pub fn nnz(&self) -> usize {
        self.val.len()
    }

    /// Fraction of elements that are nonzero (0.0 for empty shapes).
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Does any row in `[m0, m1)` hold a nonzero? `false` means the
    /// whole output row window is zero and an internally-tiling engine
    /// can skip streaming it entirely.
    pub fn rows_nonempty(&self, m0: usize, m1: usize) -> bool {
        assert!(m0 <= m1 && m1 <= self.rows, "row span out of range");
        self.row_ptr[m0] != self.row_ptr[m1]
    }

    /// Densify rows `[m0, m1)` with all columns — the row block an
    /// internally-tiling engine streams (mirrors
    /// `PatchSource::extract_rows`).
    pub fn extract_rows(&self, m0: usize, m1: usize) -> MatI8 {
        assert!(m0 <= m1 && m1 <= self.rows, "row span out of range");
        let mut out = MatI8::zeros(m1 - m0, self.cols);
        for r in m0..m1 {
            let row = out.row_mut(r - m0);
            for e in self.row_ptr[r]..self.row_ptr[r + 1] {
                row[self.col_idx[e]] = self.val[e];
            }
        }
        out
    }

    /// Densify columns `[k0, k1)` for every row into an `(M × width)`
    /// tile, tail columns zero — the padded activation tile a WS array
    /// consumes for one tile coordinate (mirrors
    /// `PatchSource::extract_cols`). Columns are sorted per row, so
    /// each row scans one contiguous entry span.
    pub fn extract_cols(&self, k0: usize, k1: usize, width: usize) -> MatI8 {
        assert!(k0 <= k1 && k1 <= self.cols, "K span out of range");
        assert!(k1 - k0 <= width, "tile width smaller than K span");
        let mut out = MatI8::zeros(self.rows, width);
        for r in 0..self.rows {
            let row = out.row_mut(r);
            let (lo, hi) = (self.row_ptr[r], self.row_ptr[r + 1]);
            let start = lo + self.col_idx[lo..hi].partition_point(|c| *c < k0);
            for e in start..hi {
                let c = self.col_idx[e];
                if c >= k1 {
                    break;
                }
                row[c - k0] = self.val[e];
            }
        }
        out
    }

    /// Check every structural invariant.
    pub fn validate(&self) -> Result<(), SparseFormatError> {
        if self.row_ptr.len() != self.rows + 1 {
            return Err(SparseFormatError::Layout(
                "row_ptr length must be rows + 1",
            ));
        }
        if self.row_ptr[0] != 0
            || *self.row_ptr.last().unwrap() != self.col_idx.len()
        {
            return Err(SparseFormatError::Layout(
                "row_ptr must start at 0 and end at nnz",
            ));
        }
        if self.col_idx.len() != self.val.len() {
            return Err(SparseFormatError::Layout(
                "col_idx and val lengths disagree",
            ));
        }
        for r in 0..self.rows {
            let (lo, hi) = (self.row_ptr[r], self.row_ptr[r + 1]);
            if lo > hi {
                return Err(SparseFormatError::Layout(
                    "row_ptr not monotonic",
                ));
            }
            let mut prev: Option<usize> = None;
            for e in lo..hi {
                let c = self.col_idx[e];
                if c >= self.cols {
                    return Err(SparseFormatError::Layout(
                        "column index out of range",
                    ));
                }
                if prev.is_some_and(|p| c <= p) {
                    return Err(SparseFormatError::Layout(
                        "columns not strictly increasing in a row",
                    ));
                }
                if self.val[e] == 0 {
                    return Err(SparseFormatError::Layout(
                        "stored zero value",
                    ));
                }
                prev = Some(c);
            }
        }
        Ok(())
    }

    /// Random activations: each element nonzero with probability
    /// `density`, magnitudes bounded like quantized layers.
    pub fn random_density(
        rng: &mut XorShift,
        rows: usize,
        cols: usize,
        density: f64,
    ) -> CsrMatI8 {
        let per_mille = (density.clamp(0.0, 1.0) * 1000.0).round() as u64;
        let dense = MatI8::from_fn(rows, cols, |_, _| {
            if rng.chance(per_mille, 1000) {
                let v = rng.i8_in(-63, 63);
                if v == 0 {
                    1
                } else {
                    v
                }
            } else {
                0
            }
        });
        CsrMatI8::from_dense(&dense)
    }

    /// Random binary spike trains at `density` (SNN crossbars consume
    /// 0/1 activations).
    pub fn random_spikes(
        rng: &mut XorShift,
        rows: usize,
        cols: usize,
        density: f64,
    ) -> CsrMatI8 {
        let per_mille = (density.clamp(0.0, 1.0) * 1000.0).round() as u64;
        let dense = MatI8::from_fn(rows, cols, |_, _| {
            rng.chance(per_mille, 1000) as i8
        });
        CsrMatI8::from_dense(&dense)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nm_parse_and_display() {
        let nm = NmPattern::parse("2:4").unwrap();
        assert_eq!((nm.n, nm.m), (2, 4));
        assert_eq!(nm.to_string(), "2:4");
        assert_eq!(nm.density_cap(), 0.5);
        for bad in ["", "4", "0:4", "5:4", "a:b", "2:0", "2:300"] {
            assert!(NmPattern::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn nm_pack_unpack_roundtrip() {
        let mut rng = XorShift::new(7);
        let nm = NmPattern::new(2, 4).unwrap();
        for (rows, cols) in [(6, 8), (5, 10), (1, 3), (14, 14), (3, 1)] {
            let s = SparseMatI8::random_nm(&mut rng, rows, cols, nm);
            s.validate().unwrap();
            let dense = s.to_dense();
            let back = SparseMatI8::from_dense(&dense, nm).unwrap();
            assert_eq!(back, s, "{rows}x{cols}");
            assert_eq!(back.to_dense(), dense);
            assert_eq!(s.nnz(), dense.data.iter().filter(|v| **v != 0).count());
        }
    }

    #[test]
    fn from_dense_rejects_overdense_groups() {
        let nm = NmPattern::new(1, 4).unwrap();
        let mut dense = MatI8::zeros(2, 8);
        dense.set(1, 4, 3);
        dense.set(1, 6, -2); // two nonzeros in group 1 of row 1
        let err = SparseMatI8::from_dense(&dense, nm).unwrap_err();
        assert_eq!(
            err,
            SparseFormatError::GroupOverflow {
                row: 1,
                group: 1,
                count: 2,
                cap: 1
            }
        );
    }

    #[test]
    fn block_queries_match_dense_slices() {
        let mut rng = XorShift::new(21);
        let nm = NmPattern::new(2, 4).unwrap();
        let s = SparseMatI8::striped(&mut rng, 12, 10, nm, 2, (6, 5));
        let dense = s.to_dense();
        for (r0, r1, c0, c1) in
            [(0, 6, 0, 5), (6, 12, 0, 5), (0, 6, 5, 10), (3, 9, 2, 8), (0, 12, 0, 10)]
        {
            let any = (r0..r1)
                .any(|r| (c0..c1).any(|c| dense.at(r, c) != 0));
            assert_eq!(
                s.block_has_nonzero(r0, r1, c0, c1),
                any,
                "[{r0},{r1})x[{c0},{c1})"
            );
            let tile = s.extract_block(r0, r1, c0, c1, (r1 - r0) + 2);
            for r in r0..r1 {
                for c in c0..c1 {
                    assert_eq!(tile.at(r - r0, c - c0), dense.at(r, c));
                }
            }
            // Tail padding rows stay zero.
            assert!(tile.row((r1 - r0) + 1).iter().all(|v| *v == 0));
        }
        // The stripe mask is deterministic: block (0,0) live, (0,1) dead.
        assert!(s.block_has_nonzero(0, 6, 0, 5));
        assert!(!s.block_has_nonzero(0, 6, 5, 10));
    }

    #[test]
    fn density_edges() {
        let mut rng = XorShift::new(3);
        let nm = NmPattern::DENSE;
        let zero =
            SparseMatI8::random_density(&mut rng, 8, 8, nm, 0.0, (4, 4));
        assert_eq!(zero.nnz(), 0);
        assert_eq!(zero.to_dense(), MatI8::zeros(8, 8));
        assert!(!zero.block_has_nonzero(0, 8, 0, 8));
        let full =
            SparseMatI8::random_density(&mut rng, 8, 8, nm, 1.0, (4, 4));
        assert_eq!(full.nnz(), 64);
        assert!((full.density() - 1.0).abs() < 1e-12);
        let empty_csr = CsrMatI8::random_density(&mut rng, 6, 9, 0.0);
        assert_eq!(empty_csr.nnz(), 0);
        assert!(!empty_csr.rows_nonempty(0, 6));
        let full_csr = CsrMatI8::random_density(&mut rng, 6, 9, 1.0);
        assert_eq!(full_csr.nnz(), 54);
    }

    #[test]
    fn csr_roundtrip_and_extraction() {
        let mut rng = XorShift::new(9);
        for density in [0.0, 0.15, 0.6, 1.0] {
            let c = CsrMatI8::random_density(&mut rng, 7, 11, density);
            c.validate().unwrap();
            let dense = c.to_dense();
            assert_eq!(CsrMatI8::from_dense(&dense), c);
            // Row-span extraction == dense row slices.
            for (m0, m1) in [(0, 7), (2, 5), (3, 3)] {
                let rows = c.extract_rows(m0, m1);
                for r in m0..m1 {
                    assert_eq!(rows.row(r - m0), dense.row(r));
                }
                assert_eq!(
                    c.rows_nonempty(m0, m1),
                    (m0..m1).any(|r| dense.row(r).iter().any(|v| *v != 0))
                );
            }
            // K-span extraction == padded dense column slices.
            for (k0, k1, width) in [(0, 11, 11), (3, 9, 8), (10, 11, 4)] {
                let t = c.extract_cols(k0, k1, width);
                assert_eq!((t.rows, t.cols), (7, width));
                for r in 0..7 {
                    for i in 0..width {
                        let want = if k0 + i < k1 {
                            dense.at(r, k0 + i)
                        } else {
                            0
                        };
                        assert_eq!(t.at(r, i), want, "d{density} r{r} i{i}");
                    }
                }
            }
        }
    }

    #[test]
    fn validate_rejects_malformed_operands() {
        let mut rng = XorShift::new(5);
        let nm = NmPattern::new(2, 4).unwrap();
        let good = SparseMatI8::random_nm(&mut rng, 3, 8, nm);
        let (idx, val) = good.slots();
        // Truncated slot buffer.
        assert!(SparseMatI8::from_slots(
            3,
            8,
            nm,
            idx[..idx.len() - 1].to_vec(),
            val.to_vec()
        )
        .is_err());
        // Slot index outside the group.
        let mut bad_idx = idx.to_vec();
        bad_idx[0] = 9;
        let mut bad_val = val.to_vec();
        bad_val[0] = 1;
        assert!(
            SparseMatI8::from_slots(3, 8, nm, bad_idx, bad_val).is_err()
        );

        let csr = CsrMatI8::random_density(&mut rng, 4, 6, 0.5);
        let (rp, ci, v) = csr.parts();
        // row_ptr ending short of nnz.
        let mut bad_rp = rp.to_vec();
        *bad_rp.last_mut().unwrap() = 0;
        assert!(CsrMatI8::from_parts(
            4,
            6,
            bad_rp,
            ci.to_vec(),
            v.to_vec()
        )
        .is_err());
        // Column index out of range.
        if !ci.is_empty() {
            let mut bad_ci = ci.to_vec();
            bad_ci[0] = 6;
            assert!(CsrMatI8::from_parts(
                4,
                6,
                rp.to_vec(),
                bad_ci,
                v.to_vec()
            )
            .is_err());
        }
    }

    #[test]
    fn striped_density_lands_near_target() {
        let mut rng = XorShift::new(13);
        let nm = NmPattern::new(2, 4).unwrap();
        // 1-in-5 live blocks of full 2:4 groups ⇒ density 0.1 exactly
        // when block width is a multiple of m (groups never straddle a
        // live/dead boundary).
        let s = SparseMatI8::striped(&mut rng, 140, 140, nm, 5, (14, 20));
        assert!((s.density() - 0.1).abs() < 1e-9, "{}", s.density());
        let d = SparseMatI8::random_density(
            &mut rng,
            140,
            140,
            nm,
            0.1,
            (14, 14),
        );
        assert!(d.density() <= 0.5 + 1e-9);
    }
}
