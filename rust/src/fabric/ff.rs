//! CLB-side sequential primitives: flip-flop banks, staging chains and
//! LUT multiplexers — with toggle accounting for the power model.

use super::clock::ClockDomain;

/// A bank of CLB flip-flops holding `width`-bit values.
///
/// One `FfBank` entry = `width` physical FDRE cells; `toggles` counts
/// *bit* toggles so power integrates real switching activity.
#[derive(Debug, Clone)]
pub struct FfBank {
    values: Vec<i64>,
    width: u32,
    domain: ClockDomain,
    toggles: u64,
    ticks: u64,
}

impl FfBank {
    pub fn new(len: usize, width: u32, domain: ClockDomain) -> Self {
        assert!(width <= 64);
        FfBank {
            values: vec![0; len],
            width,
            domain,
            toggles: 0,
            ticks: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Physical flip-flop count (len × width).
    pub fn ff_count(&self) -> usize {
        self.values.len() * self.width as usize
    }

    pub fn domain(&self) -> ClockDomain {
        self.domain
    }

    pub fn get(&self, i: usize) -> i64 {
        self.values[i]
    }

    /// Clock entry `i` with `v` (when `ce`); counts bit toggles.
    pub fn clock(&mut self, i: usize, v: i64, ce: bool) {
        self.ticks += 1;
        if !ce {
            return;
        }
        let mask = if self.width == 64 {
            !0u64
        } else {
            (1u64 << self.width) - 1
        };
        let old = self.values[i] as u64 & mask;
        let new = v as u64 & mask;
        self.toggles += (old ^ new).count_ones() as u64;
        self.values[i] = v;
    }

    /// Total bit toggles so far (power-model input).
    pub fn toggles(&self) -> u64 {
        self.toggles
    }

    /// Mean toggle rate per FF per tick (0..=1), for reporting.
    pub fn toggle_rate(&self) -> f64 {
        if self.ticks == 0 || self.ff_count() == 0 {
            return 0.0;
        }
        // ticks counts clock() calls; each call touches one entry.
        self.toggles as f64 / (self.ticks as f64 * self.width as f64)
    }

    pub fn reset(&mut self) {
        for v in &mut self.values {
            *v = 0;
        }
    }
}

/// A horizontal staging (shift) chain of registers — the CLB pipeline
/// that carries activations across a systolic row. `depth` stages of
/// `width` bits; shifting in advances every stage.
#[derive(Debug, Clone)]
pub struct StagingChain {
    stages: Vec<i64>,
    width: u32,
    domain: ClockDomain,
    toggles: u64,
}

impl StagingChain {
    pub fn new(depth: usize, width: u32, domain: ClockDomain) -> Self {
        StagingChain {
            stages: vec![0; depth],
            width,
            domain,
            toggles: 0,
        }
    }

    pub fn depth(&self) -> usize {
        self.stages.len()
    }

    pub fn ff_count(&self) -> usize {
        self.stages.len() * self.width as usize
    }

    pub fn domain(&self) -> ClockDomain {
        self.domain
    }

    /// Value currently at stage `i` (0 = first stage after input).
    pub fn stage(&self, i: usize) -> i64 {
        self.stages[i]
    }

    /// Last stage (the chain's output).
    pub fn out(&self) -> i64 {
        *self.stages.last().expect("empty chain has no output")
    }

    /// Shift `v` in; every stage advances one position.
    pub fn shift(&mut self, v: i64) {
        let mask = if self.width == 64 {
            !0u64
        } else {
            (1u64 << self.width) - 1
        };
        let mut incoming = v;
        for s in &mut self.stages {
            let old = *s as u64 & mask;
            let new = incoming as u64 & mask;
            self.toggles += (old ^ new).count_ones() as u64;
            let next_in = *s;
            *s = incoming;
            incoming = next_in;
        }
    }

    pub fn toggles(&self) -> u64 {
        self.toggles
    }

    pub fn reset(&mut self) {
        for s in &mut self.stages {
            *s = 0;
        }
    }
}

/// A LUT-based 2:1 multiplexer bank (the CLB DDR mux the paper's in-DSP
/// multiplexing eliminates). `width` LUTs wide; counts select toggles.
#[derive(Debug, Clone)]
pub struct LutMux {
    width: u32,
    domain: ClockDomain,
    selects: u64,
}

impl LutMux {
    pub fn new(width: u32, domain: ClockDomain) -> Self {
        LutMux {
            width,
            domain,
            selects: 0,
        }
    }

    /// LUT count (one per bit).
    pub fn lut_count(&self) -> usize {
        self.width as usize
    }

    pub fn domain(&self) -> ClockDomain {
        self.domain
    }

    /// Select between two operands (counts activity).
    pub fn select(&mut self, sel: bool, a: i64, b: i64) -> i64 {
        self.selects += 1;
        if sel {
            b
        } else {
            a
        }
    }

    pub fn activity(&self) -> u64 {
        self.selects
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ffbank_counts_bit_toggles() {
        let mut bank = FfBank::new(2, 8, ClockDomain::Slow);
        bank.clock(0, 0b1111_0000, true);
        assert_eq!(bank.toggles(), 4);
        bank.clock(0, 0b1111_0001, true);
        assert_eq!(bank.toggles(), 5);
        bank.clock(1, -1, true); // 8 bits flip
        assert_eq!(bank.toggles(), 13);
    }

    #[test]
    fn ffbank_ce_gates_capture() {
        let mut bank = FfBank::new(1, 8, ClockDomain::Slow);
        bank.clock(0, 0xFF, false);
        assert_eq!(bank.get(0), 0);
        assert_eq!(bank.toggles(), 0);
    }

    #[test]
    fn staging_chain_shifts_in_order() {
        let mut chain = StagingChain::new(3, 8, ClockDomain::Slow);
        chain.shift(1);
        chain.shift(2);
        chain.shift(3);
        assert_eq!(chain.stage(0), 3);
        assert_eq!(chain.stage(1), 2);
        assert_eq!(chain.out(), 1);
        chain.shift(4);
        assert_eq!(chain.out(), 2);
    }

    #[test]
    fn staging_ff_count() {
        let chain = StagingChain::new(14, 16, ClockDomain::Slow);
        assert_eq!(chain.ff_count(), 224);
    }

    #[test]
    fn lutmux_selects_and_counts() {
        let mut mux = LutMux::new(8, ClockDomain::Fast);
        assert_eq!(mux.select(false, 3, 9), 3);
        assert_eq!(mux.select(true, 3, 9), 9);
        assert_eq!(mux.activity(), 2);
        assert_eq!(mux.lut_count(), 8);
    }
}
