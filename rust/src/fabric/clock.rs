//! Two-domain clocking: the DPU-style Clk×1 / Clk×2 scheme.
//!
//! The DPUCZDX8G (and the paper's enhanced engine) run DSP48E2s at twice
//! the fabric clock. In a synchronous 2:1 ratio every slow edge
//! coincides with a fast edge; the *other* fast edge falls mid-slow-
//! cycle. The scheduler hands engines a deterministic edge sequence:
//!
//! ```text
//! slow:  |S0        |S1        |S2        ...
//! fast:  |F0   |F1  |F0   |F1  |F0   |F1  ...  (F0 aligned with slow)
//! ```
//!
//! Engines tick fast-domain logic on every fast edge and slow-domain
//! logic only on `Phase::Aligned` edges.

/// Which clock an element belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ClockDomain {
    /// Clk×1 — the fabric clock (e.g. 333 MHz on the paper's ZU3EG runs).
    Slow,
    /// Clk×2 — the DSP clock (e.g. 666 MHz).
    Fast,
}

/// Position of a fast edge relative to the slow clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Fast edge coinciding with a slow edge (slow logic also ticks).
    Aligned,
    /// The mid-cycle fast edge (fast logic only).
    Mid,
}

/// Frequency plan for the two domains, used by timing/power models.
#[derive(Debug, Clone, Copy)]
pub struct ClockPlan {
    pub slow_mhz: f64,
    pub fast_mhz: f64,
}

impl ClockPlan {
    /// The paper's DPU experiment plan: 333/666 MHz on XCZU3EG.
    pub fn dpu_paper() -> Self {
        ClockPlan {
            slow_mhz: 333.0,
            fast_mhz: 666.0,
        }
    }

    /// Single-domain plan (WS engines): everything at `mhz`.
    pub fn single(mhz: f64) -> Self {
        ClockPlan {
            slow_mhz: mhz,
            fast_mhz: mhz,
        }
    }
}

/// Deterministic generator of the fast-edge sequence.
#[derive(Debug, Clone, Default)]
pub struct TwoDomainClock {
    fast_edges: u64,
}

impl TwoDomainClock {
    pub fn new() -> Self {
        TwoDomainClock::default()
    }

    /// Advance one fast edge; returns its phase.
    pub fn next_edge(&mut self) -> Phase {
        let phase = if self.fast_edges % 2 == 0 {
            Phase::Aligned
        } else {
            Phase::Mid
        };
        self.fast_edges += 1;
        phase
    }

    /// Fast edges elapsed.
    pub fn fast_cycles(&self) -> u64 {
        self.fast_edges
    }

    /// Completed slow cycles.
    pub fn slow_cycles(&self) -> u64 {
        self.fast_edges / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alternates_phases_starting_aligned() {
        let mut clk = TwoDomainClock::new();
        assert_eq!(clk.next_edge(), Phase::Aligned);
        assert_eq!(clk.next_edge(), Phase::Mid);
        assert_eq!(clk.next_edge(), Phase::Aligned);
        assert_eq!(clk.next_edge(), Phase::Mid);
        assert_eq!(clk.fast_cycles(), 4);
        assert_eq!(clk.slow_cycles(), 2);
    }

    #[test]
    fn plan_ratios() {
        let p = ClockPlan::dpu_paper();
        assert!((p.fast_mhz / p.slow_mhz - 2.0).abs() < 1e-9);
    }
}
