//! FPGA fabric substrate: CLB-side primitives and the two-domain clock.
//!
//! The engines combine [`crate::dsp::Dsp48e2`] slices (hard blocks) with
//! the CLB-side state modeled here: flip-flop banks, shift/staging
//! chains and LUT multiplexers. Every primitive counts its toggles so
//! the [`crate::cost::power`] model can integrate activity instead of
//! guessing, and each knows its clock domain so the DDR engines account
//! fast-domain activity at the right rate.

mod clock;
mod ff;

pub use clock::{ClockDomain, ClockPlan, Phase, TwoDomainClock};
pub use ff::{FfBank, LutMux, StagingChain};
