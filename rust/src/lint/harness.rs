//! The lint harness: construct every `EngineKind` with its shipped
//! `Attributes` profile, drive one representative tile per workload,
//! and lint the recorded control schedule.
//!
//! The harness runs engines on the *calling* thread (the trace sink is
//! thread-local), with deterministic operands — linting is about the
//! control schedule, which for these engines depends on shapes and
//! sparsity structure, not operand values.

use crate::coordinator::service::EngineKind;
use crate::coordinator::ServiceConfig;
use crate::engines::Engine;
use crate::lint::diag::{Diagnostic, LintReport, RunSummary};
use crate::lint::rules::ScheduleChecker;
use crate::lint::trace;
use crate::workload::quant::requantize;
use crate::workload::MatI8;

/// The representative workloads every engine is linted under. "model"
/// is the graph-scheduler shape: two chained matmul passes over the
/// same stationary weights with elementwise glue between them — the
/// back-to-back schedule (including the stationary-reuse fill skip)
/// that a multi-layer model drives through the fill-group machinery.
pub const WORKLOADS: &[&str] = &["gemm", "conv", "snn", "sparse", "model"];

/// Deterministic small dense value in roughly [-3, 3].
fn dense(r: usize, c: usize) -> i8 {
    ((r * 7 + c * 5 + 3) % 7) as i8 - 3
}

/// Deterministic spike bit.
fn spike(r: usize, c: usize) -> i8 {
    i8::from((r * 13 + c * 11) % 3 == 0)
}

/// Representative operands for one `(kind, workload)` run.
///
/// Shapes are family-specific: WS tiles are fixed at the service
/// geometry (k = rows = 14), the SNN crossbar consumes 32 binary
/// inputs, the OS engine self-tiles any shape. "conv" differs from
/// "gemm" by an im2col-shaped activation count, "sparse" zeroes
/// weights in a 2:4 structure, "snn" drives binary activations — the
/// schedule variations (tile counts, fill patterns, spike masks) are
/// what gets linted.
fn operands(kind: EngineKind, workload: &str) -> (MatI8, MatI8) {
    let snn = matches!(kind, EngineKind::SnnFireFly | EngineKind::SnnEnhanced);
    let ws = matches!(
        kind,
        EngineKind::WsTinyTpu
            | EngineKind::WsLibano
            | EngineKind::WsClbFetch
            | EngineKind::WsDspFetch
    );
    // "model" chains the output back through the same weights, so its
    // weight matrix must be square (layer 1's n is layer 2's k).
    let (k, n) = if snn {
        (32, if workload == "model" { 32 } else { 16 })
    } else if ws {
        (14, 14)
    } else {
        (8, if workload == "model" { 8 } else { 7 })
    };
    let m = match workload {
        // 3x3 window over a 3x3 output patch, im2col'd.
        "conv" => 9,
        _ => 6,
    };
    let a = if snn || workload == "snn" {
        MatI8::from_fn(m, k, spike)
    } else {
        MatI8::from_fn(m, k, dense)
    };
    let w = if workload == "sparse" {
        // 2:4 structured sparsity along k.
        MatI8::from_fn(k, n, |r, c| if r % 4 < 2 { dense(r, c) } else { 0 })
    } else {
        MatI8::from_fn(k, n, dense)
    };
    (a, w)
}

/// Lint one engine kind under every representative workload,
/// appending to the report. Returns an error string when a run itself
/// fails (a harness bug, not a lint finding).
pub fn lint_kind(kind: EngineKind, report: &mut LintReport) -> Result<(), String> {
    let label = kind.label();
    for (tile, workload) in WORKLOADS.iter().copied().enumerate() {
        let mut engine: Box<dyn Engine + Send> = ServiceConfig {
            kind,
            ..ServiceConfig::default()
        }
        .build_engine();
        let (a, w) = operands(kind, workload);
        trace::begin();
        let mut run = engine.run_gemm(&a, &w);
        if workload == "model" {
            if let Ok(first) = &run {
                // The glue pass between layers: requantize (binarize
                // on the spiking crossbars) the accumulators into the
                // next layer's activations, then stream them against
                // the still-resident weights — one model trace, two
                // array passes, one fill.
                let snn = matches!(
                    kind,
                    EngineKind::SnnFireFly | EngineKind::SnnEnhanced
                );
                let out = &first.output;
                let a2 = MatI8::from_fn(out.rows, out.cols, |r, c| {
                    if snn {
                        i8::from(requantize(out.at(r, c), 1, 1, 0) > 0)
                    } else {
                        requantize(out.at(r, c), 1, 4, 0)
                    }
                });
                run = engine.run_gemm_reuse(&a2, &w);
            }
        }
        let recorded = trace::end();
        run.map_err(|e| format!("{label}/{workload}: engine run failed: {e:?}"))?;
        let findings = ScheduleChecker::check_trace(&recorded);
        report.runs.push(RunSummary {
            engine: label.to_string(),
            workload,
            edges: recorded.steps.len(),
            findings: findings.len(),
        });
        report
            .diagnostics
            .extend(findings.into_iter().map(|f| Diagnostic::locate(f, label, workload, tile)));
    }
    Ok(())
}

/// Lint every shipped engine kind. The `lint` CLI subcommand and the
/// all-kinds-clean test both sit on this.
pub fn lint_kinds(kinds: &[EngineKind]) -> Result<LintReport, String> {
    let mut report = LintReport::default();
    for &kind in kinds {
        lint_kind(kind, &mut report)?;
    }
    Ok(report)
}

/// Lint all 8 engine kinds.
pub fn lint_all() -> Result<LintReport, String> {
    lint_kinds(&EngineKind::all())
}
