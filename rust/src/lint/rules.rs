//! The UG579-style control-legality rule catalog and schedule checker.
//!
//! Rules operate on `(Attributes, ColumnCtrl)` pairs — the *static*
//! slice configuration against the *per-edge* control word — plus a
//! little protocol state for the paper's scheduling disciplines. The
//! point is the class of bug bit-identity testing cannot see: a
//! schedule that simulates fine (the behavioral model happily
//! multiplies under `FOUR12`) but is illegal on real silicon and would
//! sink an RTL port.
//!
//! Every rule has a stable ID; `tests/lint_props.rs` pins the IDs with
//! deliberately illegal schedules and `rust/README.md` carries the
//! catalog prose. Severity `Warning` still counts as a violation for
//! the CI gate — a warning rule is one where UG579 leaves the
//! configuration functional but pointless (e.g. a driven cascade no
//! mux ever reads), which in this codebase always means a schedule bug.

use crate::dsp::contract;
use crate::dsp::{
    Attributes, CascadeTap, ColumnCtrl, InMode, InputSource, MultSel, OpMode, SimdMode, WMux,
    XMux, YMux, ZMux,
};
use crate::lint::trace::{CtrlTrace, StepKind, TraceStep};

/// How bad a finding is. Both levels fail the `lint` subcommand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Functional on silicon but certainly not what the schedule meant.
    Warning,
    /// Illegal or undefined per UG579 / the paper's protocol.
    Error,
}

impl Severity {
    /// Lower-case label used in text and JSON output.
    pub fn label(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One catalog entry.
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    /// Stable identifier (`SIMD-001`, ...). Never renumber.
    pub id: &'static str,
    /// Finding severity.
    pub severity: Severity,
    /// One-line statement of the constraint.
    pub summary: &'static str,
}

/// The full rule catalog, in ID order.
pub const RULES: &[Rule] = &[
    Rule {
        id: "CTRL-001",
        severity: Severity::Error,
        summary: "OPMODE X and Y must select the multiplier together (UG579 Table 2-7)",
    },
    Rule {
        id: "SIMD-001",
        severity: Severity::Error,
        summary: "SIMD modes (TWO24/FOUR12) forbid the multiplier path: no X=M / Y=M",
    },
    Rule {
        id: "SIMD-002",
        severity: Severity::Error,
        summary: "SIMD modes require MREG unused: CEM must stay low when an M register exists",
    },
    Rule {
        id: "PIPE-001",
        severity: Severity::Error,
        summary: "INMODE[0] (use A1) requires a two-deep A pipeline (AREG = 2)",
    },
    Rule {
        id: "PIPE-002",
        severity: Severity::Error,
        summary: "INMODE[4] (use B1) requires a two-deep B pipeline (BREG = 2)",
    },
    Rule {
        id: "PIPE-003",
        severity: Severity::Error,
        summary: "INMODE[2] (enable D) requires the D register (DREG = 1)",
    },
    Rule {
        id: "PRE-001",
        severity: Severity::Error,
        summary: "pre-adder operand registers must clock with the multiplier: CEAD/CED \
                  may not gate while CEM captures an AMULTSEL=AD product",
    },
    Rule {
        id: "PRE-002",
        severity: Severity::Warning,
        summary: "INMODE drives the pre-adder (D enable / subtract) but AMULTSEL=A ignores it",
    },
    Rule {
        id: "CASC-001",
        severity: Severity::Error,
        summary: "BCIN driven but B input source is DIRECT — the cascade feed is never read",
    },
    Rule {
        id: "CASC-002",
        severity: Severity::Error,
        summary: "ACIN driven but A input source is DIRECT — the cascade feed is never read",
    },
    Rule {
        id: "CASC-003",
        severity: Severity::Warning,
        summary: "PCIN driven but OPMODE Z never selects the P cascade",
    },
    Rule {
        id: "WS-001",
        severity: Severity::Error,
        summary: "CEB2 may only pulse once B1 holds a complete prefetched weight set \
                  (paper Fig. 3 discipline)",
    },
    Rule {
        id: "FEED-001",
        severity: Severity::Error,
        summary: "operand/mask feeds must cover the array geometry (shared shape contract)",
    },
];

/// Catalog lookup by ID. Panics on an unknown ID — rule IDs are
/// compile-time constants inside this module.
pub fn rule(id: &str) -> &'static Rule {
    RULES
        .iter()
        .find(|r| r.id == id)
        .unwrap_or_else(|| panic!("unknown rule id {id}"))
}

/// One rule violation at a trace location.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule ID (`SIMD-001`, ...).
    pub rule: &'static str,
    /// Severity copied from the catalog.
    pub severity: Severity,
    /// Human-readable detail with the offending values.
    pub message: String,
    /// Pre-edge cycle counter of the ticked structure.
    pub cycle: u64,
    /// Column, when the violation is slice-specific.
    pub col: Option<usize>,
    /// Row, when the violation is slice-specific.
    pub row: Option<usize>,
}

/// Replays a [`CtrlTrace`] against the catalog.
///
/// The checker is stateful only for the protocol rules: `WS-001`
/// tracks how many B1 shift edges have landed since the last CEB2
/// swap. Use one checker per recorded trace.
#[derive(Debug, Default)]
pub struct ScheduleChecker {
    /// B1 shift edges accumulated since the last swap (WS-001).
    shifts: u64,
}

impl ScheduleChecker {
    /// Fresh checker (no protocol state).
    pub fn new() -> Self {
        Self::default()
    }

    /// Check a whole trace in order.
    pub fn check_trace(trace: &CtrlTrace) -> Vec<Finding> {
        let mut checker = Self::new();
        let mut out = Vec::new();
        for step in &trace.steps {
            checker.check_step(step, &mut out);
        }
        out
    }

    /// Check one step, appending findings.
    pub fn check_step(&mut self, step: &TraceStep, out: &mut Vec<Finding>) {
        match &step.kind {
            StepKind::Tick {
                ctrl,
                acin0,
                bcin0,
                pcin0,
            } => {
                check_ctrl(step, ctrl, *acin0, *bcin0, *pcin0, None, None, out);
                self.ws_discipline(step, ctrl, out);
            }
            StepKind::TickRow {
                col,
                row,
                ctrl,
                acin,
                bcin,
                pcin,
            } => {
                // Per-slice commits are direct loads outside the
                // column-wide shift protocol: WS-001 does not apply.
                check_ctrl(step, ctrl, *acin, *bcin, *pcin, Some(*col), Some(*row), out);
            }
            StepKind::WsStream { a_len, d_len } => {
                // Implied control word of the streaming fast path: the
                // B pipeline frozen, activations through A (and D when
                // the pre-adder packs two lanes), MULT_CASCADE compute.
                let inmode = if step.attrs.amultsel == MultSel::Ad {
                    InMode::A2_B2.with_d()
                } else {
                    InMode::A2_B2
                };
                let ctrl = ColumnCtrl {
                    inmode,
                    opmode: OpMode::MULT_CASCADE,
                    ceb1: false,
                    ceb2: false,
                    ..ColumnCtrl::default()
                };
                check_ctrl(step, &ctrl, false, false, false, None, None, out);
                if let Err(e) =
                    contract::ws_stream_feeds(step.rows * step.cols, *a_len, *d_len)
                {
                    push(out, "FEED-001", step, None, None, format!("tick_ws_stream: {e}"));
                }
            }
            StepKind::OsChain {
                a_len,
                d_len,
                b_len,
                use_b1,
                ceb1,
                ceb2,
            } => {
                // Uniform part of the chain schedule; the three skewed
                // controls arrive as per-column row masks below.
                let ctrl = ColumnCtrl {
                    inmode: InMode::A2_B2.with_d(),
                    opmode: OpMode::MULT_CASCADE,
                    ..ColumnCtrl::default()
                };
                check_ctrl(step, &ctrl, false, false, false, None, None, out);
                if step.attrs.breg < 2 {
                    // BREG=1 has no B1 stage at all: any INMODE[4]
                    // select reads a register that does not exist.
                    for (col, mask) in use_b1.iter().enumerate() {
                        if *mask != 0 {
                            let row = mask.trailing_zeros() as usize;
                            push(
                                out,
                                "PIPE-002",
                                step,
                                Some(col),
                                Some(row),
                                format!(
                                    "INMODE[4] selects B1 on a BREG={} chain \
                                     (use_b1 mask {:#x})",
                                    step.attrs.breg, mask
                                ),
                            );
                        }
                    }
                }
                if let Err(e) = contract::os_chain_feeds(
                    step.rows,
                    step.rows * step.cols,
                    *a_len,
                    *d_len,
                    *b_len,
                    step.cols,
                    use_b1.len(),
                    ceb1.len(),
                    ceb2.len(),
                ) {
                    push(out, "FEED-001", step, None, None, format!("tick_os_chain: {e}"));
                }
            }
            StepKind::SnnCrossbar { mask_cols } => {
                // Implied control word of the crossbar: spike muxes on
                // the wide buses, every input register held, only CEP.
                let ctrl = ColumnCtrl {
                    opmode: OpMode {
                        x: XMux::Ab,
                        y: YMux::C,
                        z: ZMux::Pcin,
                        w: WMux::Zero,
                    },
                    cea1: false,
                    cea2: false,
                    ceb1: false,
                    ceb2: false,
                    ced: false,
                    cead: false,
                    cec: false,
                    cem: false,
                    ..ColumnCtrl::default()
                };
                check_ctrl(step, &ctrl, false, false, false, None, None, out);
                if let Err(e) = contract::snn_crossbar_masks(
                    step.rows, step.cols, *mask_cols, *mask_cols,
                ) {
                    push(
                        out,
                        "FEED-001",
                        step,
                        None,
                        None,
                        format!("tick_snn_crossbar: {e}"),
                    );
                }
            }
        }
    }

    /// WS-001: the Fig. 3 prefetch discipline. On a prefetch-configured
    /// column (B cascade input tapped at Reg1 into a two-deep pipeline),
    /// a CEB2 swap pulse is only legal after at least `rows` CEB1 shift
    /// edges — otherwise B2 captures a half-loaded weight set.
    fn ws_discipline(&mut self, step: &TraceStep, ctrl: &ColumnCtrl, out: &mut Vec<Finding>) {
        let at = &step.attrs;
        let prefetch = at.b_input == InputSource::Cascade
            && at.b_cascade_tap == CascadeTap::Reg1
            && at.breg >= 2
            && !at.b2_direct;
        if !prefetch {
            return;
        }
        if ctrl.ceb2 {
            if self.shifts < step.rows as u64 {
                push(
                    out,
                    "WS-001",
                    step,
                    None,
                    None,
                    format!(
                        "CEB2 swap after only {} B1 shift edges; a complete \
                         prefetched set needs {}",
                        self.shifts, step.rows
                    ),
                );
            }
            self.shifts = u64::from(ctrl.ceb1);
        } else if ctrl.ceb1 {
            self.shifts += 1;
        }
    }
}

/// The stateless per-edge rules over one `(Attributes, ColumnCtrl)`
/// pair plus the cascade-head drive flags.
#[allow(clippy::too_many_arguments)]
fn check_ctrl(
    step: &TraceStep,
    ctrl: &ColumnCtrl,
    acin: bool,
    bcin: bool,
    pcin: bool,
    col: Option<usize>,
    row: Option<usize>,
    out: &mut Vec<Finding>,
) {
    let at = &step.attrs;
    let x_m = ctrl.opmode.x == XMux::M;
    let y_m = ctrl.opmode.y == YMux::M;

    if x_m != y_m {
        push(
            out,
            "CTRL-001",
            step,
            col,
            row,
            format!(
                "OPMODE selects M on {} only (x={:?}, y={:?})",
                if x_m { "X" } else { "Y" },
                ctrl.opmode.x,
                ctrl.opmode.y
            ),
        );
    }
    if at.simd != SimdMode::One48 {
        if x_m || y_m {
            push(
                out,
                "SIMD-001",
                step,
                col,
                row,
                format!(
                    "OPMODE routes the multiplier (x={:?}, y={:?}) under {:?}",
                    ctrl.opmode.x, ctrl.opmode.y, at.simd
                ),
            );
        }
        if ctrl.cem && at.mreg {
            push(
                out,
                "SIMD-002",
                step,
                col,
                row,
                format!("CEM clocks the M register under {:?}", at.simd),
            );
        }
    }
    if ctrl.inmode.use_a1() && at.areg < 2 {
        push(
            out,
            "PIPE-001",
            step,
            col,
            row,
            format!("INMODE[0] selects A1 but AREG={}", at.areg),
        );
    }
    if ctrl.inmode.use_b1() && at.breg < 2 {
        push(
            out,
            "PIPE-002",
            step,
            col,
            row,
            format!("INMODE[4] selects B1 but BREG={}", at.breg),
        );
    }
    if ctrl.inmode.d_enable() && !at.dreg {
        push(
            out,
            "PIPE-003",
            step,
            col,
            row,
            "INMODE[2] enables the D port but DREG=0".to_string(),
        );
    }
    if at.amultsel == MultSel::Ad
        && at.mreg
        && ctrl.cem
        && ((at.adreg && !ctrl.cead) || (at.dreg && !ctrl.ced))
    {
        push(
            out,
            "PRE-001",
            step,
            col,
            row,
            format!(
                "CEM captures an AD product while the pre-adder pipeline gates \
                 (cead={}, ced={})",
                ctrl.cead, ctrl.ced
            ),
        );
    }
    if (ctrl.inmode.d_enable() || ctrl.inmode.preadd_sub()) && at.amultsel == MultSel::A {
        push(
            out,
            "PRE-002",
            step,
            col,
            row,
            "INMODE drives the pre-adder but AMULTSEL=A bypasses it".to_string(),
        );
    }
    if bcin && at.b_input == InputSource::Direct {
        push(
            out,
            "CASC-001",
            step,
            col,
            row,
            "BCIN driven on a DIRECT-B slice".to_string(),
        );
    }
    if acin && at.a_input == InputSource::Direct {
        push(
            out,
            "CASC-002",
            step,
            col,
            row,
            "ACIN driven on a DIRECT-A slice".to_string(),
        );
    }
    if pcin && !matches!(ctrl.opmode.z, ZMux::Pcin | ZMux::PcinShift17) {
        push(
            out,
            "CASC-003",
            step,
            col,
            row,
            format!("PCIN driven but OPMODE z={:?}", ctrl.opmode.z),
        );
    }
}

fn push(
    out: &mut Vec<Finding>,
    id: &'static str,
    step: &TraceStep,
    col: Option<usize>,
    row: Option<usize>,
    message: String,
) {
    out.push(Finding {
        rule: rule(id).id,
        severity: rule(id).severity,
        message,
        cycle: step.cycle,
        col,
        row,
    });
}

/// Lint a column/array configuration against one explicit control word
/// — the entry point for checking a schedule *before* it ever ticks,
/// without recording a trace.
pub fn check_pair(attrs: &Attributes, rows: usize, ctrl: &ColumnCtrl) -> Vec<Finding> {
    let step = TraceStep {
        attrs: *attrs,
        rows,
        cols: 1,
        cycle: 0,
        kind: StepKind::Tick {
            ctrl: *ctrl,
            acin0: false,
            bcin0: false,
            pcin0: false,
        },
    };
    let mut out = Vec::new();
    check_ctrl(&step, ctrl, false, false, false, None, None, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_ids_are_unique() {
        let mut ids: Vec<_> = RULES.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), RULES.len());
    }

    #[test]
    fn default_pair_is_clean() {
        let f = check_pair(&Attributes::default(), 4, &ColumnCtrl::default());
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn simd_with_mult_mux_trips_simd_001() {
        let at = Attributes::firefly_crossbar();
        let f = check_pair(&at, 4, &ColumnCtrl::default());
        assert!(f.iter().any(|f| f.rule == "SIMD-001"), "{f:?}");
    }
}
