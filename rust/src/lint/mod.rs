//! Static control-legality model of the DSP48E2 control space.
//!
//! Bit-identity testing (the column/array oracle tower) proves the
//! simulator computes the right numbers; it cannot prove a schedule is
//! *legal on silicon*. The paper's techniques are all control-schedule
//! tricks — INMODE[4] prefetch swaps, CEB1/CEB2 gating, TWO24/FOUR12
//! SIMD modes, PCIN cascades — and an engine can drive the behavioral
//! model with a control word UG579 forbids (multiplier under a SIMD
//! mode, a B1 tap on a one-deep pipeline) while every output bit still
//! checks out. This module is the second correctness axis:
//!
//! * [`trace`] — a zero-cost-when-off recorder that captures each tick
//!   edge's symbolic control word from `DspColumn`/`DspArray`;
//! * [`rules`] — the UG579-style rule catalog with stable IDs and a
//!   [`rules::ScheduleChecker`] that replays a trace against it;
//! * [`diag`] — findings located in `(engine, tile, cycle, col, row)`
//!   space, rendered as text or canonical JSON;
//! * [`harness`] — builds all 8 `EngineKind`s, drives one
//!   representative tile per workload, and lints the recorded
//!   schedules (the `lint` CLI subcommand and CI gate).

pub mod diag;
pub mod harness;
pub mod rules;
pub mod trace;

pub use diag::{Diagnostic, LintReport, RunSummary};
pub use harness::{lint_all, lint_kind, lint_kinds};
pub use rules::{check_pair, Finding, Rule, ScheduleChecker, Severity, RULES};
pub use trace::{CtrlTrace, StepKind, TraceStep};
