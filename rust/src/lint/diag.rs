//! Diagnostics: findings located in `(engine, tile, cycle, col, row)`
//! space, rendered as human text and canonical JSON (`util/json`).

use crate::lint::rules::{Finding, Severity, RULES};
use crate::util::json::Json;

/// One located violation.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Rule ID.
    pub rule: &'static str,
    /// Severity copied from the catalog.
    pub severity: Severity,
    /// Human-readable detail.
    pub message: String,
    /// Engine label (`ws-dspfetch`, ...).
    pub engine: String,
    /// Which representative workload drove the schedule.
    pub workload: &'static str,
    /// Tile index within the run.
    pub tile: usize,
    /// Pre-edge cycle counter of the ticked structure.
    pub cycle: u64,
    /// Column, when slice-specific.
    pub col: Option<usize>,
    /// Row, when slice-specific.
    pub row: Option<usize>,
}

/// Per-run bookkeeping for the report.
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// Engine label.
    pub engine: String,
    /// Workload name.
    pub workload: &'static str,
    /// Recorded tick edges linted.
    pub edges: usize,
    /// Findings in this run.
    pub findings: usize,
}

/// The whole lint report: every run plus every diagnostic.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// One entry per `(engine, workload)` run.
    pub runs: Vec<RunSummary>,
    /// All located violations, in run order.
    pub diagnostics: Vec<Diagnostic>,
}

impl Diagnostic {
    /// Attach run coordinates to a raw rule finding.
    pub fn locate(f: Finding, engine: &str, workload: &'static str, tile: usize) -> Self {
        Diagnostic {
            rule: f.rule,
            severity: f.severity,
            message: f.message,
            engine: engine.to_string(),
            workload,
            tile,
            cycle: f.cycle,
            col: f.col,
            row: f.row,
        }
    }

    fn to_json(&self) -> Json {
        fn opt(v: Option<usize>) -> Json {
            v.map_or(Json::Null, Json::from)
        }
        Json::object(vec![
            ("rule", Json::from(self.rule)),
            ("severity", Json::from(self.severity.label())),
            ("message", Json::from(self.message.as_str())),
            ("engine", Json::from(self.engine.as_str())),
            ("workload", Json::from(self.workload)),
            ("tile", Json::from(self.tile)),
            ("cycle", Json::uint(self.cycle)),
            ("col", opt(self.col)),
            ("row", opt(self.row)),
        ])
    }
}

impl LintReport {
    /// Total violations (warnings included — both levels gate CI).
    pub fn violations(&self) -> usize {
        self.diagnostics.len()
    }

    /// Canonical JSON for the CI artifact.
    pub fn to_json(&self) -> Json {
        Json::object(vec![
            ("version", Json::from(1i64)),
            ("violations", Json::from(self.violations())),
            (
                "rules",
                Json::array(
                    RULES
                        .iter()
                        .map(|r| {
                            Json::object(vec![
                                ("id", Json::from(r.id)),
                                ("severity", Json::from(r.severity.label())),
                                ("summary", Json::from(r.summary)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "runs",
                Json::array(
                    self.runs
                        .iter()
                        .map(|r| {
                            Json::object(vec![
                                ("engine", Json::from(r.engine.as_str())),
                                ("workload", Json::from(r.workload)),
                                ("edges", Json::from(r.edges)),
                                ("findings", Json::from(r.findings)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "diagnostics",
                Json::array(self.diagnostics.iter().map(|d| d.to_json()).collect()),
            ),
        ])
    }

    /// Human-readable report.
    pub fn render_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "control-legality lint: {} run(s)", self.runs.len());
        for r in &self.runs {
            let _ = writeln!(
                out,
                "  {:<14} {:<6} {:>7} edge(s)  {}",
                r.engine,
                r.workload,
                r.edges,
                if r.findings == 0 {
                    "clean".to_string()
                } else {
                    format!("{} finding(s)", r.findings)
                }
            );
        }
        for d in &self.diagnostics {
            let loc = match (d.col, d.row) {
                (Some(c), Some(r)) => format!(" col {c} row {r}"),
                (Some(c), None) => format!(" col {c}"),
                _ => String::new(),
            };
            let _ = writeln!(
                out,
                "{}: {} [{}/{} tile {} cycle {}{}] {}",
                d.severity.label(),
                d.rule,
                d.engine,
                d.workload,
                d.tile,
                d.cycle,
                loc,
                d.message
            );
        }
        let _ = writeln!(out, "violations: {}", self.violations());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_report_serializes_clean() {
        let rep = LintReport::default();
        assert_eq!(rep.violations(), 0);
        let j = rep.to_json().to_string();
        assert!(j.contains("\"violations\": 0"), "{j}");
        assert!(j.contains("SIMD-001"), "{j}");
        assert!(rep.render_text().contains("violations: 0"));
    }
}
