//! Control-schedule trace recorder.
//!
//! When enabled, every tick edge of a [`DspColumn`](crate::dsp::DspColumn)
//! or [`DspArray`](crate::dsp::DspArray) records one [`TraceStep`] — the
//! *symbolic* control word that drove the edge, never operand data. The
//! lint rule engine then replays the step stream against the UG579-style
//! rule catalog (`lint::rules`).
//!
//! The recorder is a thread-local sink behind a `Cell<bool>` gate, so
//! the cost in the simulation hot loops when tracing is off is one
//! thread-local boolean load per tick call (not per slice), and the
//! frozen bench metrics cannot move: recording observes control words,
//! it never alters them.

use std::cell::{Cell, RefCell};

use crate::dsp::{Attributes, ColumnCtrl};

/// What kind of tick edge a step describes.
#[derive(Debug, Clone)]
pub enum StepKind {
    /// A generic full-column/full-array `tick`: one shared control word
    /// for every slice, plus whether each cascade head port was driven.
    Tick {
        /// The shared control word.
        ctrl: ColumnCtrl,
        /// ACIN was driven non-zero at some column head.
        acin0: bool,
        /// BCIN was driven non-zero at some column head.
        bcin0: bool,
        /// PCIN was driven non-zero at some column head.
        pcin0: bool,
    },
    /// A single-slice `tick_row` edge.
    TickRow {
        /// Column of the slice.
        col: usize,
        /// Row of the slice.
        row: usize,
        /// The control word for this slice.
        ctrl: ColumnCtrl,
        /// ACIN driven non-zero.
        acin: bool,
        /// BCIN driven non-zero.
        bcin: bool,
        /// PCIN driven non-zero.
        pcin: bool,
    },
    /// The weight-stationary streaming fast path (implied control word:
    /// `MULT_CASCADE`, B pipeline frozen).
    WsStream {
        /// Words supplied on the A stream.
        a_len: usize,
        /// Words supplied on the D stream.
        d_len: usize,
    },
    /// The output-stationary chain fast path with its per-column
    /// `use_b1` / `ceb1` / `ceb2` row bitmasks.
    OsChain {
        /// Words supplied on A.
        a_len: usize,
        /// Words supplied on D.
        d_len: usize,
        /// Words supplied on B.
        b_len: usize,
        /// Per-column INMODE[4] row masks.
        use_b1: Vec<u64>,
        /// Per-column CEB1 row masks.
        ceb1: Vec<u64>,
        /// Per-column CEB2 row masks.
        ceb2: Vec<u64>,
    },
    /// The SNN crossbar fast path (accumulate-only OPMODE, spike masks).
    SnnCrossbar {
        /// Mask words supplied (per column).
        mask_cols: usize,
    },
}

/// One recorded tick edge.
#[derive(Debug, Clone)]
pub struct TraceStep {
    /// The static attribute profile of the ticked column/array.
    pub attrs: Attributes,
    /// Rows per column.
    pub rows: usize,
    /// Columns (1 for a `DspColumn`).
    pub cols: usize,
    /// Pre-edge cycle counter of the ticked structure.
    pub cycle: u64,
    /// The edge's control payload.
    pub kind: StepKind,
}

/// An ordered stream of recorded tick edges.
#[derive(Debug, Clone, Default)]
pub struct CtrlTrace {
    /// The steps, in tick order.
    pub steps: Vec<TraceStep>,
}

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static SINK: RefCell<CtrlTrace> = const { RefCell::new(CtrlTrace { steps: Vec::new() }) };
}

/// Is the recorder currently capturing on this thread?
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.with(|e| e.get())
}

/// Start capturing: clears any previous trace and arms the sink.
pub fn begin() {
    SINK.with(|s| s.borrow_mut().steps.clear());
    ENABLED.with(|e| e.set(true));
}

/// Stop capturing and take the recorded trace.
pub fn end() -> CtrlTrace {
    ENABLED.with(|e| e.set(false));
    SINK.with(|s| std::mem::take(&mut *s.borrow_mut()))
}

/// Append a step (callers must gate on [`enabled`] first — tick paths
/// do, so the off-path cost stays one boolean load).
pub(crate) fn record(step: TraceStep) {
    SINK.with(|s| s.borrow_mut().steps.push(step));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::Attributes;

    #[test]
    fn begin_end_round_trip_is_isolated() {
        assert!(!enabled());
        begin();
        assert!(enabled());
        record(TraceStep {
            attrs: Attributes::default(),
            rows: 1,
            cols: 1,
            cycle: 0,
            kind: StepKind::WsStream { a_len: 1, d_len: 1 },
        });
        let t = end();
        assert!(!enabled());
        assert_eq!(t.steps.len(), 1);
        // A second end() after taking yields an empty trace.
        assert!(end().steps.is_empty());
    }
}
